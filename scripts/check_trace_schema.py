#!/usr/bin/env python3
"""Schema check for trace/telemetry JSONL (the CI trace-smoke gate).

Two line formats share this checker:

* span/instant traces written by `graphedge serve --trace out.jsonl`
  (or `GRAPHEDGE_TRACE=out.jsonl`) — one JSON object per event with
  `ts_us`, `dur_us`, `kind`, `name`, `span`, `parent`, `fields`;
* per-episode training telemetry written by
  `graphedge train --telemetry out.jsonl`.

Beyond per-line shape, `--serve` reconstructs the batch lifecycle
(step -> churn -> repair/drift, enqueue -> batch_close -> batch span
wrapping infer + batch_complete) and fails when any stage stopped
emitting — the failure mode of silently dropped instrumentation.
`--train` checks the episode series is complete and ordered.

Usage: check_trace_schema.py FILE.jsonl [--serve | --train]
"""

import json
import math
import sys

SERVE_REQUIRED = {
    "serve.step": "span",
    "serve.churn": "span",
    "partition.repair": "span",
    "partition.drift": "instant",
    "router.enqueue": "instant",
    "router.batch_close": "instant",
    "serve.batch": "span",
    "serve.infer": "span",
    "serve.batch_complete": "instant",
}

TRAIN_KEYS = [
    "episode",
    "reward",
    "system_cost",
    "critic_loss",
    "actor_loss",
    "steps",
    "drift",
]


def fail(msg: str) -> None:
    print(f"TRACE schema check FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def require_number(where: str, key: str, value: object) -> float:
    if not isinstance(value, (int, float)) or isinstance(value, bool):
        fail(f"{where}: {key} is {value!r}, expected a number")
    if not math.isfinite(value):
        fail(f"{where}: {key} is non-finite ({value!r})")
    return float(value)


def load_lines(path: str) -> list:
    try:
        with open(path, encoding="utf-8") as fh:
            raw = fh.read()
    except FileNotFoundError:
        fail(f"{path} not found — did the traced run happen?")
    lines = []
    for i, line in enumerate(raw.splitlines(), 1):
        if not line.strip():
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError as e:
            fail(f"{path}:{i} is not valid JSON: {e}")
        if not isinstance(obj, dict):
            fail(f"{path}:{i} is {type(obj).__name__}, expected an object")
        lines.append((i, obj))
    if not lines:
        fail(f"{path} is empty — the run emitted no events")
    return lines


def check_event_lines(lines: list) -> list:
    """Validate the span/instant event shape; return the parsed events."""
    events = []
    for i, obj in lines:
        where = f"line {i}"
        kind = obj.get("kind")
        if kind not in ("span", "instant"):
            fail(f"{where}: kind is {kind!r}, expected 'span' or 'instant'")
        name = obj.get("name")
        if not isinstance(name, str) or not name:
            fail(f"{where}: name is {name!r}, expected a non-empty string")
        ts = require_number(where, "ts_us", obj.get("ts_us"))
        dur = require_number(where, "dur_us", obj.get("dur_us"))
        span = require_number(where, "span", obj.get("span"))
        require_number(where, "parent", obj.get("parent"))
        if ts < 0 or dur < 0:
            fail(f"{where}: negative timestamp or duration")
        if kind == "span" and span <= 0:
            fail(f"{where}: span event with non-positive id {span}")
        if kind == "instant" and span != 0:
            fail(f"{where}: instant carries span id {span}, expected 0")
        fields = obj.get("fields", {})
        if not isinstance(fields, dict):
            fail(f"{where}: fields is {type(fields).__name__}, expected object")
        for key, value in fields.items():
            # null encodes a non-finite measurement; anything else is a bug.
            if value is None:
                continue
            require_number(where, f"fields.{key}", value)
        events.append({**obj, "line": i})
    return events


def check_serve(events: list) -> None:
    by_name = {}
    for e in events:
        by_name.setdefault(e["name"], []).append(e)
    for name, kind in SERVE_REQUIRED.items():
        got = by_name.get(name, [])
        if not got:
            fail(f"no {name!r} events — that pipeline stage emitted nothing")
        for e in got:
            if e["kind"] != kind:
                fail(f"line {e['line']}: {name} has kind {e['kind']!r}, expected {kind!r}")

    # Every dispatched batch wraps exactly one inference and one completion.
    infer_parents = [e["parent"] for e in by_name["serve.infer"]]
    complete_parents = [e["parent"] for e in by_name["serve.batch_complete"]]
    for batch in by_name["serve.batch"]:
        sid = batch["span"]
        if infer_parents.count(sid) != 1:
            fail(f"serve.batch span {sid} has {infer_parents.count(sid)} serve.infer children, expected 1")
        if complete_parents.count(sid) != 1:
            fail(f"serve.batch span {sid} has {complete_parents.count(sid)} serve.batch_complete children, expected 1")

    # Conservation: every enqueued request leaves in exactly one close.
    enqueued = len(by_name["router.enqueue"])
    closed = 0.0
    for e in by_name["router.batch_close"]:
        closed += require_number(f"line {e['line']}", "fields.size", e.get("fields", {}).get("size"))
    if int(closed) != enqueued:
        fail(f"{enqueued} router.enqueue events but batch_close sizes sum to {int(closed)}")

    # Repair spans nest under churn spans; drift instants under repairs.
    churn_ids = {e["span"] for e in by_name["serve.churn"]}
    repair_ids = {e["span"] for e in by_name["partition.repair"]}
    for e in by_name["partition.repair"]:
        if e["parent"] not in churn_ids:
            fail(f"line {e['line']}: partition.repair outside any serve.churn span")
    for e in by_name["partition.drift"]:
        if e["parent"] not in repair_ids:
            fail(f"line {e['line']}: partition.drift outside any partition.repair span")

    n_steps = len(by_name["serve.step"])
    n_batches = len(by_name["serve.batch"])
    print(
        f"TRACE schema check OK (serve): {len(events)} events, "
        f"{n_steps} steps, {enqueued} requests, {n_batches} batches, "
        f"{len(repair_ids)} repairs"
    )


def check_train(lines: list) -> None:
    last = -1
    for i, obj in lines:
        where = f"line {i}"
        for key in TRAIN_KEYS:
            if key not in obj:
                fail(f"{where}: {key} missing")
            # Losses may be null early in training (no gradient step yet).
            if obj[key] is None and key in ("critic_loss", "actor_loss"):
                continue
            require_number(where, key, obj[key])
        episode = int(obj["episode"])
        if episode < last:
            fail(f"{where}: episode {episode} after {last} — series out of order")
        last = episode
    print(f"TRACE schema check OK (train): {len(lines)} episodes, last index {last}")


def main() -> None:
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    flags = [a for a in sys.argv[1:] if a.startswith("--")]
    if not args or len(args) > 1 or any(f not in ("--serve", "--train") for f in flags):
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    lines = load_lines(args[0])
    if "--train" in flags:
        check_train(lines)
        return
    events = check_event_lines(lines)
    if "--serve" in flags:
        check_serve(events)
    else:
        print(f"TRACE schema check OK: {len(events)} well-formed events")


if __name__ == "__main__":
    main()
