#!/usr/bin/env python3
"""Generate golden vectors for the Rust native-kernel parity tests.

Mirrors ``python/compile/kernels/ref.py`` in plain numpy float64 (no
JAX dependency, so the fixtures regenerate anywhere python3+numpy
exists) and writes ``rust/tests/fixtures/kernel_golden.json``, which
``rust/tests/kernel_parity.rs`` replays against the f32 kernels in
``rust/src/runtime/native/kernels.rs`` at 1e-4 absolute tolerance.

Inputs are drawn from an explicit 64-bit LCG — not numpy's RNG — so
the vectors are bit-stable across numpy versions.  The committed JSON
is the contract; rerun this script only when ref.py's math changes.
"""

import json
import math
import pathlib

import numpy as np

NEG_SLOPE = 0.2

N = 12       # vertices (last PAD rows are padding: zero features, no edges)
PAD = 3
F = 10       # input features
H = 8        # hidden width
C = 4        # classes


class Lcg:
    """splitmix-free 64-bit LCG; top 53 bits -> [0, 1)."""

    MUL = 6364136223846793005
    INC = 1442695040888963407
    MASK = (1 << 64) - 1

    def __init__(self, seed):
        self.s = seed & self.MASK

    def f64(self):
        self.s = (self.s * self.MUL + self.INC) & self.MASK
        return (self.s >> 11) / float(1 << 53)

    def uniform(self, lo, hi):
        return lo + (hi - lo) * self.f64()

    def matrix(self, rows, cols, lo=-1.0, hi=1.0):
        return np.array(
            [[self.uniform(lo, hi) for _ in range(cols)] for _ in range(rows)],
            dtype=np.float64,
        )


# --- ref.py oracles, numpy float64 -----------------------------------------

def matmul_bias_act(x, y, b, act="none"):
    v = x @ y + b
    if act == "relu":
        v = np.maximum(v, 0.0)
    elif act == "sigmoid":
        v = 1.0 / (1.0 + np.exp(-v))
    elif act != "none":
        raise ValueError(act)
    return v


def mean_agg(adj, x, inv_deg):
    return (adj @ x) * inv_deg


def attn_scores(sl, sr):
    e = sl + sr.reshape(1, -1)
    return np.where(e >= 0.0, e, NEG_SLOPE * e)


def masked_softmax(scores, adj):
    mask = adj > 0.0
    s = np.where(mask, scores, -1e30)
    s = s - np.max(s, axis=-1, keepdims=True)
    e = np.exp(s) * mask.astype(np.float64)
    return e / (np.sum(e, axis=-1, keepdims=True) + 1e-9)


def gcn_forward(a_norm, x, w0, b0, w1, b1):
    h = matmul_bias_act(a_norm, x @ w0, b0, "relu")
    return matmul_bias_act(a_norm, h @ w1, b1, "none")


def sgc_forward(a_norm, x, w, b):
    return (a_norm @ (a_norm @ x)) @ w + b


def sage_layer(adj, inv_deg, x, w_self, w_neigh, b, act):
    v = x @ w_self + mean_agg(adj, x, inv_deg) @ w_neigh + b
    return np.maximum(v, 0.0) if act == "relu" else v


def sage_forward(adj, inv_deg, x, ws0, wn0, b0, ws1, wn1, b1):
    h = sage_layer(adj, inv_deg, x, ws0, wn0, b0, "relu")
    return sage_layer(adj, inv_deg, h, ws1, wn1, b1, "none")


def gat_layer(adj, x, w, a_l, a_r, b, act):
    h = x @ w
    sl = (h @ a_l).reshape(-1, 1)
    sr = (h @ a_r).reshape(-1, 1)
    att = masked_softmax(attn_scores(sl, sr), adj)
    v = att @ h + b
    return np.maximum(v, 0.0) if act == "relu" else v


def gat_forward(adj, x, w0, al0, ar0, b0, w1, al1, ar1, b1):
    h = gat_layer(adj, x, w0, al0, ar0, b0, "relu")
    return gat_layer(adj, h, w1, al1, ar1, b1, "none")


def sym_norm_adj(adj):
    deg = adj.sum(axis=1)
    inv_sqrt = np.where(deg > 0, 1.0 / np.sqrt(np.maximum(deg, 1e-12)), 0.0)
    return adj * inv_sqrt[:, None] * inv_sqrt[None, :]


def inv_degree(adj):
    deg = adj.sum(axis=1, keepdims=True)
    return np.where(deg > 0, 1.0 / np.maximum(deg, 1e-12), 0.0)


# --- fixture assembly -------------------------------------------------------

def tensor(a):
    a = np.asarray(a, dtype=np.float64)
    if a.ndim == 1:
        a = a.reshape(1, -1)
    return {"shape": list(a.shape), "data": [float(np.float32(v)) for v in a.ravel()]}


def main():
    rng = Lcg(0x5EED_60_1D)
    real = N - PAD

    # Symmetric 0/1 adjacency with self-loops on the real block; the
    # padding rows/cols stay all-zero (the serving-path layout).
    adj = np.zeros((N, N), dtype=np.float64)
    for i in range(real):
        adj[i, i] = 1.0
        for j in range(i + 1, real):
            if rng.f64() < 0.35:
                adj[i, j] = adj[j, i] = 1.0
    a_norm = sym_norm_adj(adj)
    inv_deg = inv_degree(adj)

    x = rng.matrix(N, F)
    x[real:, :] = 0.0

    cases = {}

    a = rng.matrix(5, 7)
    bm = rng.matrix(7, 6)
    cases["matmul"] = {"a": tensor(a), "b": tensor(bm), "out": tensor(a @ bm)}

    bias = rng.matrix(1, 6)
    for act in ("none", "relu", "sigmoid"):
        cases[f"matmul_bias_{act}"] = {
            "a": tensor(a),
            "b": tensor(bm),
            "bias": tensor(bias),
            "out": tensor(matmul_bias_act(a, bm, bias, act)),
        }

    cases["mean_agg"] = {
        "adj": tensor(adj),
        "x": tensor(x),
        "inv_deg": tensor(inv_deg),
        "out": tensor(mean_agg(adj, x, inv_deg)),
    }

    sl = rng.matrix(N, 1, -2.0, 2.0)
    sr = rng.matrix(N, 1, -2.0, 2.0)
    scores = attn_scores(sl, sr)
    cases["attn_scores"] = {"sl": tensor(sl), "sr": tensor(sr), "out": tensor(scores)}
    cases["masked_softmax"] = {
        "scores": tensor(scores),
        "adj": tensor(adj),
        "out": tensor(masked_softmax(scores, adj)),
    }

    w0, b0 = rng.matrix(F, H), rng.matrix(1, H)
    w1, b1 = rng.matrix(H, C), rng.matrix(1, C)
    cases["gcn"] = {
        "x": tensor(x), "a_norm": tensor(a_norm),
        "w0": tensor(w0), "b0": tensor(b0), "w1": tensor(w1), "b1": tensor(b1),
        "out": tensor(gcn_forward(a_norm, x, w0, b0, w1, b1)),
    }

    w, b = rng.matrix(F, C), rng.matrix(1, C)
    cases["sgc"] = {
        "x": tensor(x), "a_norm": tensor(a_norm), "w": tensor(w), "b": tensor(b),
        "out": tensor(sgc_forward(a_norm, x, w, b)),
    }

    ws0, wn0, sb0 = rng.matrix(F, H), rng.matrix(F, H), rng.matrix(1, H)
    ws1, wn1, sb1 = rng.matrix(H, C), rng.matrix(H, C), rng.matrix(1, C)
    cases["sage"] = {
        "x": tensor(x), "adj": tensor(adj), "inv_deg": tensor(inv_deg),
        "ws0": tensor(ws0), "wn0": tensor(wn0), "b0": tensor(sb0),
        "ws1": tensor(ws1), "wn1": tensor(wn1), "b1": tensor(sb1),
        "out": tensor(sage_forward(adj, inv_deg, x, ws0, wn0, sb0, ws1, wn1, sb1)),
    }

    gw0, gal0, gar0, gb0 = rng.matrix(F, H), rng.matrix(H, 1), rng.matrix(H, 1), rng.matrix(1, H)
    gw1, gal1, gar1, gb1 = rng.matrix(H, C), rng.matrix(C, 1), rng.matrix(C, 1), rng.matrix(1, C)
    cases["gat"] = {
        "x": tensor(x), "adj": tensor(adj),
        "w0": tensor(gw0), "al0": tensor(gal0), "ar0": tensor(gar0), "b0": tensor(gb0),
        "w1": tensor(gw1), "al1": tensor(gal1), "ar1": tensor(gar1), "b1": tensor(gb1),
        "out": tensor(gat_forward(adj, x, gw0, gal0, gar0, gb0, gw1, gal1, gar1, gb1)),
    }

    out = {"tolerance": 1e-4, "pad_rows": PAD, "cases": cases}
    path = pathlib.Path(__file__).resolve().parent.parent / "rust/tests/fixtures/kernel_golden.json"
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=None, separators=(",", ":")) + "\n")
    size = path.stat().st_size
    print(f"wrote {path} ({size} bytes, {len(cases)} cases)")
    for k, v in cases.items():
        flat = v["out"]["data"]
        print(f"  {k:<20} out {v['out']['shape']}  max|v|={max(abs(f) for f in flat):.4f}"
              f"  finite={all(math.isfinite(f) for f in flat)}")


if __name__ == "__main__":
    main()
