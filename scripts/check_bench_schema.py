#!/usr/bin/env python3
"""Schema check for BENCH_partition.json (the CI bench-smoke gate).

The perf benches (`env_step`, `partition_incremental`,
`partition_parallel`, `vec_env`, `scenario_vec`, `memo`, `inference`)
each merge one top-level section into the shared results file.  This script fails CI
when a bench stopped writing its section, dropped a key, or produced
non-finite numbers — the failure modes of silent bench bit-rot.

Usage: check_bench_schema.py [--require-runs] [BENCH_partition.json]

By default an empty `runs` array passes (a bench may legitimately be
configured down to zero sweep points locally); `--require-runs` makes
empties fail, which is what CI uses — a bench whose sweep loop silently
stopped emitting runs still "writes its section" and would otherwise
pass the gate forever.
"""

import argparse
import json
import math
import sys

# Per-section scalar keys every bench run must produce.
SECTION_KEYS = {
    "env": [
        "n_users",
        "agents",
        "obs_dim",
        "reps",
        "state_cached_s",
        "state_recompute_s",
        "state_speedup",
        "episode_cached_s",
        "episode_recompute_s",
        "episode_speedup",
        "mutate_reset_s",
    ],
    "incremental": ["n_users", "mean_degree", "steps"],
    "parallel": ["n_users", "communities", "mean_degree", "reps"],
    "vec_env": ["n_users", "agents", "obs_dim", "reps"],
    "scenario": ["n_users", "n_assocs", "obs_dim", "reps"],
    "memo": [
        "n_users",
        "agents",
        "obs_dim",
        "reps",
        "rates_hit_s",
        "rates_build_s",
        "rates_speedup",
        "evaluate_tabled_s",
        "evaluate_fresh_s",
        "evaluate_speedup",
    ],
    "inference": ["n_max", "c_pad", "reps"],
}

# Sections carrying a "runs" array, with required per-run keys.
RUN_KEYS = {
    "incremental": [
        "churn",
        "repair_step_s",
        "full_step_s",
        "speedup",
        "cut_ratio_mean",
        "full_fallbacks",
        "local_recuts",
    ],
    "parallel": ["workers", "sequential_s", "sharded_s", "speedup"],
    "vec_env": [
        "envs",
        "workers",
        "state_assembly_s",
        "rollout_steps_per_s",
        "episodes",
    ],
    "scenario": [
        "envs",
        "workers",
        "set_gen_s",
        "state_assembly_s",
        "rollout_steps_per_s",
        "episodes",
    ],
    "memo": [
        "mutate_every",
        "episodes",
        "obs_hit_rate",
        "rates_hit_rate",
        "cold_read_s",
        "warm_read_s",
        "rebuild_penalty",
    ],
    "inference": ["real_size", "infer_s_mean", "infer_s_p99", "rows_per_s"],
}


def fail(msg: str) -> None:
    print(f"BENCH schema check FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def require_number(section: str, key: str, value: object) -> None:
    if not isinstance(value, (int, float)) or isinstance(value, bool):
        fail(f"{section}.{key} is {value!r}, expected a number")
    if not math.isfinite(value):
        fail(f"{section}.{key} is non-finite ({value!r})")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("path", nargs="?", default="BENCH_partition.json")
    parser.add_argument(
        "--require-runs",
        action="store_true",
        help="fail when a runs array is empty (the CI bench-smoke mode)",
    )
    args = parser.parse_args()
    path = args.path
    try:
        with open(path, encoding="utf-8") as fh:
            root = json.load(fh)
    except FileNotFoundError:
        fail(f"{path} not found — did the benches run?")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")
    if not isinstance(root, dict):
        fail(f"{path} top level is {type(root).__name__}, expected an object")

    for section, keys in SECTION_KEYS.items():
        if section not in root:
            fail(f"missing section {section!r} — its bench did not write")
        body = root[section]
        if not isinstance(body, dict):
            fail(f"section {section!r} is {type(body).__name__}, expected object")
        for key in keys:
            if key not in body:
                fail(f"{section}.{key} missing")
            require_number(section, key, body[key])

    for section, keys in RUN_KEYS.items():
        runs = root[section].get("runs")
        if not isinstance(runs, list):
            fail(f"{section}.runs missing or not an array")
        if args.require_runs and not runs:
            fail(f"{section}.runs is empty — the bench sweep emitted no runs")
        for i, run in enumerate(runs):
            if not isinstance(run, dict):
                fail(f"{section}.runs[{i}] is not an object")
            for key in keys:
                if key not in run:
                    fail(f"{section}.runs[{i}].{key} missing")
                require_number(f"{section}.runs[{i}]", key, run[key])

    names = ", ".join(sorted(SECTION_KEYS))
    print(f"BENCH schema check OK: {path} has valid sections [{names}]")


if __name__ == "__main__":
    main()
