//! Random-graph generators for the Fig. 6 scale experiments and tests.
//!
//! Fig. 6 evaluates graph-cut time on random graphs with a given vertex
//! and edge budget ("sparse": 500–20 000 vertices / 5 010–800 040
//! edges, "non-sparse": 500 100–8 000 400 edges), with edge weights
//! uniform in 1–100.  [`uniform_random`] produces exactly that;
//! [`preferential_attachment`] mirrors the Python dataset generator for
//! degree-distribution experiments on the Rust side.

use super::Graph;
use crate::util::rng::Rng;

/// Uniform random graph with exactly `edges` distinct edges.
///
/// Below half density this uses rejection sampling with a hash set —
/// fine up to the Fig. 6 maximum of 8M edges over 20k vertices (4% of
/// all pairs), and kept so existing seeds reproduce their graphs.  At
/// or above half density the rejection loop degenerates (the expected
/// tries per fresh edge diverge as `edges → max_edges`, and the
/// complete graph never terminates), so dense requests switch to
/// Floyd's algorithm over pair ranks: exactly `edges` distinct pairs
/// in O(edges) expected draws, terminating even at `edges ==
/// max_edges`.
pub fn uniform_random(n: usize, edges: usize, rng: &mut Rng) -> Graph {
    let max_edges = if n < 2 { 0 } else { n * (n - 1) / 2 };
    assert!(edges <= max_edges, "cannot fit {edges} edges into {n} vertices");
    if edges == 0 {
        return Graph::new(n);
    }
    if edges <= max_edges / 2 {
        // Sparse: rejection sampling (≤ 2 expected tries per edge).
        let mut seen = std::collections::HashSet::with_capacity(edges * 2);
        let mut list = Vec::with_capacity(edges);
        while list.len() < edges {
            let u = rng.below(n);
            let v = rng.below(n);
            if u == v {
                continue;
            }
            let key = if u < v {
                (u as u64) << 32 | v as u64
            } else {
                (v as u64) << 32 | u as u64
            };
            if seen.insert(key) {
                list.push((u.min(v) as u32, u.max(v) as u32));
            }
        }
        return Graph::from_edges(n, &list);
    }
    // Dense: Floyd's subset sampling over the pair ranks
    // [0, max_edges).  Each round inserts exactly one fresh rank (j
    // itself cannot have been chosen earlier: previous rounds only
    // insert values ≤ their own smaller j), so the loop runs exactly
    // `edges` times regardless of density.
    let mut seen = std::collections::HashSet::with_capacity(edges * 2);
    let mut list = Vec::with_capacity(edges);
    for j in (max_edges - edges)..max_edges {
        let t = rng.below(j + 1);
        let rank = if seen.insert(t as u64) { t } else { j };
        if rank == j {
            seen.insert(j as u64);
        }
        list.push(unrank_pair(n, rank));
    }
    Graph::from_edges(n, &list)
}

/// Inverse of the row-major pair ranking: rank `r` in
/// `[0, n·(n-1)/2)` → the r-th pair `(u, v)` with `u < v`, ordered by
/// `u` then `v`.  Rows are located by binary search on the cumulative
/// pair count `C(u) = u·(n-1) − u·(u-1)/2`.
fn unrank_pair(n: usize, r: usize) -> (u32, u32) {
    let cum = |u: usize| u * (n - 1) - u * (u.saturating_sub(1)) / 2;
    let (mut lo, mut hi) = (0usize, n - 1);
    // Largest u with C(u) <= r.
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        if cum(mid) <= r {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    let u = lo;
    let v = u + 1 + (r - cum(u));
    (u as u32, v as u32)
}

/// Random integer edge weights in `[lo, hi]` keyed by canonical edge —
/// the Fig. 6 comparison's 1–100 weights for the min-cut baseline.
pub fn random_weights(
    g: &Graph,
    lo: u32,
    hi: u32,
    rng: &mut Rng,
) -> std::collections::HashMap<(u32, u32), u32> {
    let mut w = std::collections::HashMap::with_capacity(g.num_edges());
    for (u, v) in g.edge_list() {
        w.insert((u, v), lo + rng.below((hi - lo + 1) as usize) as u32);
    }
    w
}

/// Preferential-attachment graph (degree-proportional endpoint choice),
/// ~`mean_degree/2` attachments per incoming vertex.
///
/// Degenerate sizes are safe by construction: `m >= 1` always, so the
/// seed clique `(m + 1).min(n)` has at least two vertices (and hence a
/// non-empty attachment pool) whenever any vertex remains to attach
/// (`n > seed` implies `n >= 2` implies `seed >= 2`).  `n <= 1` builds
/// an edgeless graph and `n <= mean_degree / 2` collapses to the
/// complete graph — both panic-free and connected (see the tiny-n
/// tests below).
pub fn preferential_attachment(n: usize, mean_degree: usize, rng: &mut Rng) -> Graph {
    let m = (mean_degree / 2).max(1);
    let mut g = Graph::new(n);
    let seed = (m + 1).min(n);
    let mut pool: Vec<u32> = Vec::new();
    for i in 0..seed {
        for j in (i + 1)..seed {
            if g.add_edge(i, j) {
                pool.push(i as u32);
                pool.push(j as u32);
            }
        }
    }
    for v in seed..n {
        let mut added = 0;
        let mut tries = 0;
        while added < m && tries < 20 * m {
            tries += 1;
            let u = *rng.choose(&pool) as usize;
            if g.add_edge(u, v) {
                pool.push(u as u32);
                pool.push(v as u32);
                added += 1;
            }
        }
        if added == 0 {
            let u = rng.below(v);
            g.add_edge(u, v);
            pool.push(u as u32);
            pool.push(v as u32);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_seeds;

    #[test]
    fn uniform_random_exact_edge_count() {
        check_seeds(10, |rng| {
            let n = rng.range(10, 200);
            let e = rng.below(n * (n - 1) / 4);
            let g = uniform_random(n, e, rng);
            g.num_edges() == e && g.len() == n
        });
    }

    #[test]
    #[should_panic(expected = "cannot fit")]
    fn uniform_random_rejects_overfull() {
        let mut rng = Rng::seed_from(0);
        uniform_random(4, 100, &mut rng);
    }

    #[test]
    fn weights_in_range_and_complete() {
        let mut rng = Rng::seed_from(1);
        let g = uniform_random(50, 200, &mut rng);
        let w = random_weights(&g, 1, 100, &mut rng);
        assert_eq!(w.len(), 200);
        assert!(w.values().all(|&x| (1..=100).contains(&x)));
    }

    #[test]
    fn preferential_attachment_heavy_tail() {
        let mut rng = Rng::seed_from(2);
        let g = preferential_attachment(2000, 6, &mut rng);
        let mean = 2.0 * g.num_edges() as f64 / g.len() as f64;
        let max = (0..g.len()).map(|v| g.degree(v)).max().unwrap() as f64;
        assert!(max > 4.0 * mean, "max {max} mean {mean}");
    }

    #[test]
    fn uniform_random_dense_terminates_with_exact_counts() {
        // Regression: the rejection sampler degenerated toward
        // non-termination as `edges -> max_edges` (the complete graph
        // never finished).  The Floyd path must terminate and still
        // deliver exact counts at and near full density.
        for n in [2usize, 5, 12, 40] {
            let max_edges = n * (n - 1) / 2;
            for edges in [max_edges, max_edges.saturating_sub(1), max_edges * 4 / 5] {
                let mut rng = Rng::seed_from(7 + n as u64);
                let g = uniform_random(n, edges, &mut rng);
                assert_eq!(g.len(), n);
                assert_eq!(g.num_edges(), edges, "n={n} edges={edges}");
            }
        }
        // The complete graph really is complete.
        let mut rng = Rng::seed_from(8);
        let g = uniform_random(9, 36, &mut rng);
        for u in 0..9 {
            for v in (u + 1)..9 {
                assert!(g.has_edge(u, v), "missing edge ({u},{v})");
            }
        }
    }

    #[test]
    fn uniform_random_tiny_vertex_counts() {
        let mut rng = Rng::seed_from(9);
        assert_eq!(uniform_random(0, 0, &mut rng).len(), 0);
        assert_eq!(uniform_random(1, 0, &mut rng).num_edges(), 0);
        let g = uniform_random(2, 1, &mut rng);
        assert!(g.has_edge(0, 1));
    }

    #[test]
    fn unrank_pair_bijects_onto_ordered_pairs() {
        for n in [2usize, 3, 7, 23] {
            let mut seen = std::collections::HashSet::new();
            for r in 0..n * (n - 1) / 2 {
                let (u, v) = unrank_pair(n, r);
                assert!(u < v && (v as usize) < n, "n={n} r={r} -> ({u},{v})");
                assert!(seen.insert((u, v)), "rank {r} duplicated pair ({u},{v})");
            }
        }
    }

    #[test]
    fn preferential_attachment_tiny_n_is_connected_and_panic_free() {
        // The seed-clique clamp audit: n in {1, 2, 3} across degenerate
        // mean degrees (incl. n <= mean_degree/2) must neither panic
        // nor fragment the graph.
        for n in [1usize, 2, 3] {
            for mean_degree in [0usize, 1, 2, 6, 100] {
                let mut rng = Rng::seed_from((n * 100 + mean_degree) as u64);
                let g = preferential_attachment(n, mean_degree, &mut rng);
                assert_eq!(g.len(), n);
                if n == 1 {
                    assert_eq!(g.num_edges(), 0);
                } else {
                    let comps = g.components(|_| true);
                    assert_eq!(
                        comps.len(),
                        1,
                        "n={n} mean_degree={mean_degree} fragmented: {comps:?}"
                    );
                }
            }
        }
        // n = 0 is a valid (empty) request too.
        let mut rng = Rng::seed_from(3);
        assert_eq!(preferential_attachment(0, 4, &mut rng).len(), 0);
    }

    #[test]
    fn preferential_attachment_connected_enough() {
        let mut rng = Rng::seed_from(3);
        let g = preferential_attachment(500, 4, &mut rng);
        let comps = g.components(|_| true);
        assert_eq!(comps.len(), 1, "PA graph should be connected");
    }
}
