//! Random-graph generators for the Fig. 6 scale experiments and tests.
//!
//! Fig. 6 evaluates graph-cut time on random graphs with a given vertex
//! and edge budget ("sparse": 500–20 000 vertices / 5 010–800 040
//! edges, "non-sparse": 500 100–8 000 400 edges), with edge weights
//! uniform in 1–100.  [`uniform_random`] produces exactly that;
//! [`preferential_attachment`] mirrors the Python dataset generator for
//! degree-distribution experiments on the Rust side.

use super::Graph;
use crate::util::rng::Rng;

/// Uniform random graph with exactly `edges` distinct edges.
///
/// Uses rejection sampling with a hash set — fine up to the Fig. 6
/// maximum of 8M edges over 20k vertices (4% of all pairs).
pub fn uniform_random(n: usize, edges: usize, rng: &mut Rng) -> Graph {
    let max_edges = n * (n - 1) / 2;
    assert!(edges <= max_edges, "cannot fit {edges} edges into {n} vertices");
    let mut seen = std::collections::HashSet::with_capacity(edges * 2);
    let mut list = Vec::with_capacity(edges);
    while list.len() < edges {
        let u = rng.below(n);
        let v = rng.below(n);
        if u == v {
            continue;
        }
        let key = if u < v {
            (u as u64) << 32 | v as u64
        } else {
            (v as u64) << 32 | u as u64
        };
        if seen.insert(key) {
            list.push((u.min(v) as u32, u.max(v) as u32));
        }
    }
    Graph::from_edges(n, &list)
}

/// Random integer edge weights in `[lo, hi]` keyed by canonical edge —
/// the Fig. 6 comparison's 1–100 weights for the min-cut baseline.
pub fn random_weights(
    g: &Graph,
    lo: u32,
    hi: u32,
    rng: &mut Rng,
) -> std::collections::HashMap<(u32, u32), u32> {
    let mut w = std::collections::HashMap::with_capacity(g.num_edges());
    for (u, v) in g.edge_list() {
        w.insert((u, v), lo + rng.below((hi - lo + 1) as usize) as u32);
    }
    w
}

/// Preferential-attachment graph (degree-proportional endpoint choice),
/// ~`mean_degree/2` attachments per incoming vertex.
pub fn preferential_attachment(n: usize, mean_degree: usize, rng: &mut Rng) -> Graph {
    let m = (mean_degree / 2).max(1);
    let mut g = Graph::new(n);
    let seed = (m + 1).min(n);
    let mut pool: Vec<u32> = Vec::new();
    for i in 0..seed {
        for j in (i + 1)..seed {
            if g.add_edge(i, j) {
                pool.push(i as u32);
                pool.push(j as u32);
            }
        }
    }
    for v in seed..n {
        let mut added = 0;
        let mut tries = 0;
        while added < m && tries < 20 * m {
            tries += 1;
            let u = *rng.choose(&pool) as usize;
            if g.add_edge(u, v) {
                pool.push(u as u32);
                pool.push(v as u32);
                added += 1;
            }
        }
        if added == 0 {
            let u = rng.below(v);
            g.add_edge(u, v);
            pool.push(u as u32);
            pool.push(v as u32);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_seeds;

    #[test]
    fn uniform_random_exact_edge_count() {
        check_seeds(10, |rng| {
            let n = rng.range(10, 200);
            let e = rng.below(n * (n - 1) / 4);
            let g = uniform_random(n, e, rng);
            g.num_edges() == e && g.len() == n
        });
    }

    #[test]
    #[should_panic(expected = "cannot fit")]
    fn uniform_random_rejects_overfull() {
        let mut rng = Rng::seed_from(0);
        uniform_random(4, 100, &mut rng);
    }

    #[test]
    fn weights_in_range_and_complete() {
        let mut rng = Rng::seed_from(1);
        let g = uniform_random(50, 200, &mut rng);
        let w = random_weights(&g, 1, 100, &mut rng);
        assert_eq!(w.len(), 200);
        assert!(w.values().all(|&x| (1..=100).contains(&x)));
    }

    #[test]
    fn preferential_attachment_heavy_tail() {
        let mut rng = Rng::seed_from(2);
        let g = preferential_attachment(2000, 6, &mut rng);
        let mean = 2.0 * g.num_edges() as f64 / g.len() as f64;
        let max = (0..g.len()).map(|v| g.degree(v)).max().unwrap() as f64;
        assert!(max > 4.0 * mean, "max {max} mean {mean}");
    }

    #[test]
    fn preferential_attachment_connected_enough() {
        let mut rng = Rng::seed_from(3);
        let g = preferential_attachment(500, 4, &mut rng);
        let comps = g.components(|_| true);
        assert_eq!(comps.len(), 1, "PA graph should be connected");
    }
}
