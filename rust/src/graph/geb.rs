//! Loader for the `.geb` synthetic citation datasets written by
//! `python/compile/data.py` (see that module for the byte layout).
//!
//! A [`Dataset`] owns the topology, labels and *sparse* bag-of-words
//! features; dense padded feature blocks for the GNN executables are
//! materialized on demand by the serving layer.

use std::path::Path;

use super::Graph;

#[derive(Debug, thiserror::Error)]
pub enum GebError {
    #[error("bad GEB magic")]
    BadMagic,
    #[error("truncated GEB file")]
    Truncated,
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

/// A loaded citation dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    /// Vertex count.
    pub n: usize,
    /// Undirected edge count.
    pub e: usize,
    /// Real (un-padded) feature dimensionality.
    pub feat_dim: usize,
    /// Class count.
    pub classes: usize,
    pub labels: Vec<u8>,
    /// CSR over sparse feature indices.
    pub feat_ptr: Vec<u32>,
    pub feat_idx: Vec<u16>,
    pub graph: Graph,
}

impl Dataset {
    /// Synthetic in-memory dataset (no `.geb` file / artifacts): a
    /// preferential-attachment topology with placeholder sparse
    /// features (one index per vertex) and cyclic 3-class labels.
    /// The shared scaffold for environment tests and toolchain-only
    /// benches (`tests/properties.rs`, `benches/env_step.rs`,
    /// `drl::env::testutil`).
    pub fn synthetic(n: usize, rng: &mut crate::util::rng::Rng) -> Self {
        let graph = super::generate::preferential_attachment(n, 6, rng);
        Dataset {
            name: "synthetic".into(),
            n,
            e: graph.num_edges(),
            feat_dim: 64,
            classes: 3,
            labels: (0..n).map(|i| (i % 3) as u8).collect(),
            feat_ptr: (0..=n as u32).collect(),
            feat_idx: (0..n).map(|i| (i % 64) as u16).collect(),
            graph,
        }
    }

    pub fn load(path: impl AsRef<Path>, name: &str) -> Result<Self, GebError> {
        let buf = std::fs::read(path)?;
        Self::parse(&buf, name)
    }

    pub fn parse(buf: &[u8], name: &str) -> Result<Self, GebError> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8], GebError> {
            if *pos + n > buf.len() {
                return Err(GebError::Truncated);
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 4)? != b"GEB1" {
            return Err(GebError::BadMagic);
        }
        let u32at = |b: &[u8]| u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        let hdr = take(&mut pos, 16)?;
        let (n, e, feat_dim, classes) = (
            u32at(&hdr[0..4]) as usize,
            u32at(&hdr[4..8]) as usize,
            u32at(&hdr[8..12]) as usize,
            u32at(&hdr[12..16]) as usize,
        );
        let labels = take(&mut pos, n)?.to_vec();
        let feat_ptr: Vec<u32> = take(&mut pos, 4 * (n + 1))?
            .chunks_exact(4)
            .map(u32at)
            .collect();
        let nnz = *feat_ptr.last().unwrap() as usize;
        let feat_idx: Vec<u16> = take(&mut pos, 2 * nnz)?
            .chunks_exact(2)
            .map(|b| u16::from_le_bytes([b[0], b[1]]))
            .collect();
        let raw_edges = take(&mut pos, 8 * e)?;
        let mut graph = Graph::new(n);
        for ch in raw_edges.chunks_exact(8) {
            let u = u32at(&ch[0..4]) as usize;
            let v = u32at(&ch[4..8]) as usize;
            graph.add_edge(u, v);
        }
        Ok(Dataset {
            name: name.to_string(),
            n,
            e,
            feat_dim,
            classes,
            labels,
            feat_ptr,
            feat_idx,
            graph,
        })
    }

    /// Sparse feature indices of one document.
    pub fn features_of(&self, v: usize) -> &[u16] {
        let lo = self.feat_ptr[v] as usize;
        let hi = self.feat_ptr[v + 1] as usize;
        &self.feat_idx[lo..hi]
    }

    /// Write vertex `v`'s features, L2-normalized, into a dense row
    /// (matching `data.dense_features` on the Python side).
    pub fn write_dense_row(&self, v: usize, row: &mut [f32]) {
        row.fill(0.0);
        let idx = self.features_of(v);
        if idx.is_empty() {
            return;
        }
        let val = 1.0 / (idx.len() as f32).sqrt();
        for &i in idx {
            if (i as usize) < row.len() {
                row[i as usize] = val;
            }
        }
    }

    /// Task data size in Mbit for user/vertex `v` — the paper maps each
    /// feature dimension to 1 kb and caps dimensions at 1500 (§6.1).
    pub fn task_mbit(&self, _v: usize) -> f64 {
        (self.feat_dim.min(1500) as f64) * 1.0e3 / 1.0e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-build a tiny GEB byte image.
    fn tiny_geb() -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(b"GEB1");
        for v in [3u32, 2, 8, 2] {
            b.extend_from_slice(&v.to_le_bytes()); // n=3 e=2 f=8 c=2
        }
        b.extend_from_slice(&[0, 1, 0]); // labels
        for v in [0u32, 2, 3, 5] {
            b.extend_from_slice(&v.to_le_bytes()); // feat_ptr
        }
        for v in [1u16, 4, 2, 0, 7] {
            b.extend_from_slice(&v.to_le_bytes()); // feat_idx
        }
        for v in [0u32, 1, 1, 2] {
            b.extend_from_slice(&v.to_le_bytes()); // edges (0,1),(1,2)
        }
        b
    }

    #[test]
    fn parses_tiny() {
        let d = Dataset::parse(&tiny_geb(), "tiny").unwrap();
        assert_eq!((d.n, d.e, d.feat_dim, d.classes), (3, 2, 8, 2));
        assert_eq!(d.labels, vec![0, 1, 0]);
        assert_eq!(d.features_of(0), &[1, 4]);
        assert_eq!(d.features_of(1), &[2]);
        assert_eq!(d.features_of(2), &[0, 7]);
        assert!(d.graph.has_edge(0, 1) && d.graph.has_edge(1, 2));
        assert!(!d.graph.has_edge(0, 2));
    }

    #[test]
    fn dense_row_is_l2_normalized() {
        let d = Dataset::parse(&tiny_geb(), "tiny").unwrap();
        let mut row = vec![0.0f32; 8];
        d.write_dense_row(0, &mut row);
        let norm: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-6);
        assert!(row[1] > 0.0 && row[4] > 0.0);
        assert_eq!(row.iter().filter(|&&x| x > 0.0).count(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(matches!(Dataset::parse(b"XXXX", "x"), Err(GebError::BadMagic)));
        assert!(matches!(
            Dataset::parse(b"GEB1\x01\x00", "x"),
            Err(GebError::Truncated)
        ));
    }

    #[test]
    fn task_size_tracks_feat_dim() {
        let d = Dataset::parse(&tiny_geb(), "tiny").unwrap();
        assert!((d.task_mbit(0) - 8.0e3 / 1.0e6).abs() < 1e-12);
    }
}
