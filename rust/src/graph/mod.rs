//! Graph substrate: topology, the paper's dynamic graph model (§3.2),
//! dataset loading and synthetic generation.
//!
//! * [`Graph`] — adjacency-list undirected graph, the common currency
//!   of HiCut, the cost model and the serving layer.
//! * [`dynamic`] — mask module + position attributes (§3.2): user
//!   join/leave, mobility, association churn.
//! * [`geb`] — loader for the `.geb` synthetic citation datasets
//!   produced at artifact-build time.
//! * [`generate`] — random-graph generators for the Fig. 6 scale
//!   experiments (uniform-random and preferential-attachment).
//! * [`sample`] — scenario sampling: draw N users / E associations
//!   from a dataset graph, as §6.3 does.

pub mod dynamic;
pub mod geb;
pub mod generate;
pub mod sample;
pub mod stats;

pub use dynamic::DynamicGraph;
pub use geb::Dataset;

/// Undirected graph over vertices `0..n` as sorted adjacency lists.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    adj: Vec<Vec<u32>>,
    edges: usize,
}

impl Graph {
    pub fn new(n: usize) -> Self {
        Graph { adj: vec![Vec::new(); n], edges: 0 }
    }

    /// Build from an edge list (duplicates and self-loops ignored).
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Self {
        let mut g = Graph::new(n);
        for &(u, v) in edges {
            g.add_edge(u as usize, v as usize);
        }
        g
    }

    pub fn len(&self) -> usize {
        self.adj.len()
    }

    pub fn is_empty(&self) -> bool {
        self.adj.is_empty()
    }

    pub fn num_edges(&self) -> usize {
        self.edges
    }

    pub fn degree(&self, v: usize) -> usize {
        self.adj[v].len()
    }

    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.adj[v]
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.adj[u].binary_search(&(v as u32)).is_ok()
    }

    /// Insert an undirected edge; returns false if it already existed
    /// or is a self-loop.
    pub fn add_edge(&mut self, u: usize, v: usize) -> bool {
        if u == v || u >= self.len() || v >= self.len() {
            return false;
        }
        match self.adj[u].binary_search(&(v as u32)) {
            Ok(_) => false,
            Err(iu) => {
                self.adj[u].insert(iu, v as u32);
                let iv = self.adj[v].binary_search(&(u as u32)).unwrap_err();
                self.adj[v].insert(iv, u as u32);
                self.edges += 1;
                true
            }
        }
    }

    /// Remove an undirected edge; returns false if absent.
    pub fn remove_edge(&mut self, u: usize, v: usize) -> bool {
        if u >= self.len() || v >= self.len() {
            return false;
        }
        match self.adj[u].binary_search(&(v as u32)) {
            Ok(iu) => {
                self.adj[u].remove(iu);
                let iv = self.adj[v].binary_search(&(u as u32)).unwrap();
                self.adj[v].remove(iv);
                self.edges -= 1;
                true
            }
            Err(_) => false,
        }
    }

    /// Drop every edge incident to `v` (used when a user leaves, §3.2).
    pub fn isolate(&mut self, v: usize) {
        let neigh = std::mem::take(&mut self.adj[v]);
        for &u in &neigh {
            let iu = self.adj[u as usize].binary_search(&(v as u32)).unwrap();
            self.adj[u as usize].remove(iu);
        }
        self.edges -= neigh.len();
    }

    pub fn edge_list(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.edges);
        for (u, neigh) in self.adj.iter().enumerate() {
            for &v in neigh {
                if (u as u32) < v {
                    out.push((u as u32, v));
                }
            }
        }
        out
    }

    /// Connected components as vertex lists (restricted to `alive`).
    pub fn components(&self, alive: impl Fn(usize) -> bool) -> Vec<Vec<usize>> {
        let n = self.len();
        let mut seen = vec![false; n];
        let mut out = Vec::new();
        for s in 0..n {
            if seen[s] || !alive(s) {
                continue;
            }
            let mut comp = Vec::new();
            let mut queue = std::collections::VecDeque::from([s]);
            seen[s] = true;
            while let Some(u) = queue.pop_front() {
                comp.push(u);
                for &v in self.neighbors(u) {
                    let v = v as usize;
                    if !seen[v] && alive(v) {
                        seen[v] = true;
                        queue.push_back(v);
                    }
                }
            }
            out.push(comp);
        }
        out
    }

    /// Vertices within `hops` BFS hops of the seed set (seed included) —
    /// the halo construction for distributed GNN inference.
    pub fn k_hop(&self, seeds: &[usize], hops: usize) -> Vec<usize> {
        let mut dist = vec![usize::MAX; self.len()];
        let mut queue = std::collections::VecDeque::new();
        for &s in seeds {
            dist[s] = 0;
            queue.push_back(s);
        }
        let mut out: Vec<usize> = seeds.to_vec();
        while let Some(u) = queue.pop_front() {
            if dist[u] == hops {
                continue;
            }
            for &v in self.neighbors(u) {
                let v = v as usize;
                if dist[v] == usize::MAX {
                    dist[v] = dist[u] + 1;
                    out.push(v);
                    queue.push_back(v);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_seeds;

    fn path_graph(n: usize) -> Graph {
        let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
        Graph::from_edges(n, &edges)
    }

    #[test]
    fn add_remove_edges() {
        let mut g = Graph::new(4);
        assert!(g.add_edge(0, 1));
        assert!(!g.add_edge(1, 0)); // duplicate
        assert!(!g.add_edge(2, 2)); // self loop
        assert_eq!(g.num_edges(), 1);
        assert!(g.has_edge(0, 1) && g.has_edge(1, 0));
        assert!(g.remove_edge(0, 1));
        assert!(!g.remove_edge(0, 1));
        assert_eq!(g.num_edges(), 0);
    }

    #[test]
    fn isolate_removes_all_incident() {
        let mut g = Graph::from_edges(4, &[(0, 1), (0, 2), (0, 3), (1, 2)]);
        g.isolate(0);
        assert_eq!(g.degree(0), 0);
        assert_eq!(g.num_edges(), 1);
        assert!(g.has_edge(1, 2));
    }

    #[test]
    fn components_split() {
        let g = Graph::from_edges(6, &[(0, 1), (1, 2), (3, 4)]);
        let comps = g.components(|_| true);
        assert_eq!(comps.len(), 3); // {0,1,2}, {3,4}, {5}
        let sizes: Vec<usize> = comps.iter().map(|c| c.len()).collect();
        assert_eq!(sizes, vec![3, 2, 1]);
    }

    #[test]
    fn components_respect_alive_mask() {
        let g = path_graph(5);
        // Killing the middle vertex splits the path.
        let comps = g.components(|v| v != 2);
        assert_eq!(comps.len(), 2);
    }

    #[test]
    fn k_hop_halo() {
        let g = path_graph(7);
        let mut h = g.k_hop(&[3], 2);
        h.sort_unstable();
        assert_eq!(h, vec![1, 2, 3, 4, 5]);
        let mut h0 = g.k_hop(&[0], 1);
        h0.sort_unstable();
        assert_eq!(h0, vec![0, 1]);
    }

    #[test]
    fn edge_list_round_trip() {
        check_seeds(30, |rng| {
            let n = rng.range(2, 40);
            let mut g = Graph::new(n);
            for _ in 0..rng.below(3 * n) {
                g.add_edge(rng.below(n), rng.below(n));
            }
            let rebuilt = Graph::from_edges(n, &g.edge_list());
            (0..n).all(|v| rebuilt.neighbors(v) == g.neighbors(v))
                && rebuilt.num_edges() == g.num_edges()
        });
    }

    #[test]
    fn degree_sums_to_twice_edges() {
        check_seeds(30, |rng| {
            let n = rng.range(2, 60);
            let mut g = Graph::new(n);
            for _ in 0..rng.below(4 * n) {
                g.add_edge(rng.below(n), rng.below(n));
            }
            let degsum: usize = (0..n).map(|v| g.degree(v)).sum();
            degsum == 2 * g.num_edges()
        });
    }
}
