//! The paper's dynamic graph model (§3.2).
//!
//! `G(t) = (V(t), E(t))` as perceived by the EC controller, extended
//! with a **mask module** (an array of length N whose entries flip to 0
//! when users drop out and back to 1 when new users take their slots)
//! and per-vertex **position attributes** synchronized to user
//! locations.  Three kinds of dynamics are supported, exactly the ones
//! §3.2 enumerates:
//!
//! 1. location changes (`move_users`),
//! 2. user count changes (`remove_users` / `add_users`),
//! 3. association changes (`rewire`).
//!
//! [`DynamicGraph::step`] applies a randomized mixture of all three —
//! the per-episode scenario churn of Algorithm 2 line 8.
//!
//! When delta recording is enabled ([`DynamicGraph::record_deltas`])
//! every mutation additionally appends a typed [`GraphDelta`] to an
//! internal journal, in application order.  Draining that journal
//! ([`DynamicGraph::drain_deltas`]) gives downstream consumers —
//! chiefly [`crate::partition::incremental::IncrementalPartitioner`] —
//! an exact replayable description of one churn step, so derived state
//! can be *repaired* instead of recomputed from scratch.

use super::Graph;
use crate::util::rng::Rng;
use crate::util::version::Version;

/// 2-D position on the EC plane, meters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pos {
    pub x: f64,
    pub y: f64,
}

impl Pos {
    pub fn dist(&self, other: &Pos) -> f64 {
        ((self.x - other.x).powi(2) + (self.y - other.y).powi(2)).sqrt()
    }
}

/// One §3.2 scenario change, as seen by the partition layer.
///
/// The four variants cover exactly the paper's dynamics: mobility
/// (`Moved`), user-count changes (`Joined` / `Left`) and association
/// rewiring (`Rewired`).  Replaying a journal in order onto a copy of
/// the pre-step graph reproduces the post-step graph bit for bit (see
/// the `deltas_replay_to_identical_topology` test).
#[derive(Clone, Debug, PartialEq)]
pub enum GraphDelta {
    /// A user moved on the EC plane (position only, no topology).
    Moved { user: usize, to: Pos },
    /// A fresh user took a mask-0 slot.  Its associations arrive as
    /// subsequent [`GraphDelta::Rewired`] events.
    Joined { user: usize, pos: Pos },
    /// A user dropped out; `neighbors` is its adjacency at departure
    /// (those edges are removed atomically with the mask flip).
    Left { user: usize, neighbors: Vec<u32> },
    /// One association appeared (`added = true`) or disappeared.
    Rewired { a: usize, b: usize, added: bool },
}

/// Churn configuration for [`DynamicGraph::step`].
#[derive(Clone, Copy, Debug)]
pub struct ChurnConfig {
    /// Fraction of users that may join/leave per step (paper: 20%).
    pub user_change_rate: f64,
    /// Fraction of associations rewired per step (paper: 20%).
    pub assoc_change_rate: f64,
    /// Max per-step movement in meters (random walk).
    pub move_radius_m: f64,
    /// Plane side length in meters (Table 2: 2000).
    pub plane_m: f64,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        ChurnConfig {
            user_change_rate: 0.2,
            assoc_change_rate: 0.2,
            move_radius_m: 100.0,
            plane_m: 2000.0,
        }
    }
}

/// Dynamic user graph with mask + positions (§3.2).
#[derive(Clone, Debug)]
pub struct DynamicGraph {
    graph: Graph,
    mask: Vec<bool>,
    pos: Vec<Pos>,
    /// Task data size per user in Mbit (X_i of Table 1).
    task_mb: Vec<f64>,
    /// Mean active degree at construction — the association density the
    /// churn process preserves (without an anchor, departures bleed
    /// edges faster than arrivals restore them and |E| decays).
    target_mean_deg: f64,
    /// Recorded [`GraphDelta`]s since the last drain (empty unless
    /// `recording`).
    journal: Vec<GraphDelta>,
    recording: bool,
    /// Bumped on every mutation (edges, mask, positions, task sizes) —
    /// whether or not delta recording is on.  Consumers key
    /// `util::version::Memoized` caches on this stamp; see
    /// [`DynamicGraph::topology_version`].
    topology: Version,
}

impl DynamicGraph {
    /// Build with all users alive, positions uniform on the plane and
    /// task sizes supplied by the caller (from dataset feature dims).
    pub fn new(graph: Graph, task_mb: Vec<f64>, plane_m: f64, rng: &mut Rng) -> Self {
        let n = graph.len();
        let pos = (0..n)
            .map(|_| Pos { x: rng.range_f64(0.0, plane_m), y: rng.range_f64(0.0, plane_m) })
            .collect();
        Self::with_positions(graph, task_mb, pos)
    }

    /// Build with all users alive at caller-supplied positions — the
    /// constructor the scenario generators use, where positions are
    /// part of the generated scenario (clustered/hotspot layouts)
    /// rather than fresh uniform draws.
    pub fn with_positions(graph: Graph, task_mb: Vec<f64>, pos: Vec<Pos>) -> Self {
        let n = graph.len();
        assert_eq!(task_mb.len(), n);
        assert_eq!(pos.len(), n);
        let target_mean_deg = 2.0 * graph.num_edges() as f64 / n.max(1) as f64;
        DynamicGraph {
            graph,
            mask: vec![true; n],
            pos,
            task_mb,
            target_mean_deg,
            journal: Vec::new(),
            recording: false,
            topology: Version::ZERO,
        }
    }

    /// The graph's change stamp: strictly increases on every mutation
    /// (§3.2 dynamics, explicit association edits, task-size updates),
    /// in or out of delta-recording mode.  Derived-data caches compare
    /// this against the stamp they were built at (`util::version`).
    pub fn topology_version(&self) -> Version {
        self.topology
    }

    // -- delta journal ------------------------------------------------------

    /// Start/stop recording [`GraphDelta`]s.  The journal is cleared on
    /// every call, so a consumer sees only changes after its own
    /// snapshot.  Off by default: an undrained journal would grow
    /// without bound across training episodes.
    pub fn record_deltas(&mut self, on: bool) {
        self.recording = on;
        self.journal.clear();
    }

    pub fn recording(&self) -> bool {
        self.recording
    }

    /// Take the recorded delta batch, clearing the journal.
    pub fn drain_deltas(&mut self) -> Vec<GraphDelta> {
        std::mem::take(&mut self.journal)
    }

    /// Insert an association through the journal (all internal edge
    /// mutations funnel through here so the delta stream stays exact).
    fn add_assoc(&mut self, u: usize, v: usize) -> bool {
        let added = self.graph.add_edge(u, v);
        if added {
            self.topology.bump();
            if self.recording {
                self.journal.push(GraphDelta::Rewired { a: u, b: v, added: true });
            }
        }
        added
    }

    /// Remove an association through the journal.
    fn remove_assoc(&mut self, u: usize, v: usize) -> bool {
        let removed = self.graph.remove_edge(u, v);
        if removed {
            self.topology.bump();
            if self.recording {
                self.journal.push(GraphDelta::Rewired { a: u, b: v, added: false });
            }
        }
        removed
    }

    /// Externally driven association arrival (§3.2 dynamic #3).
    /// Returns false if either endpoint is inactive or the edge exists.
    pub fn add_association(&mut self, u: usize, v: usize) -> bool {
        if !self.mask[u] || !self.mask[v] {
            return false;
        }
        self.add_assoc(u, v)
    }

    /// Externally driven association departure; false if absent.
    pub fn remove_association(&mut self, u: usize, v: usize) -> bool {
        self.remove_assoc(u, v)
    }

    pub fn capacity(&self) -> usize {
        self.graph.len()
    }

    /// Number of *active* users (mask = 1).
    pub fn active_count(&self) -> usize {
        self.mask.iter().filter(|&&m| m).count()
    }

    pub fn is_active(&self, v: usize) -> bool {
        self.mask[v]
    }

    pub fn active_users(&self) -> Vec<usize> {
        (0..self.capacity()).filter(|&v| self.mask[v]).collect()
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn pos(&self, v: usize) -> Pos {
        self.pos[v]
    }

    pub fn task_mb(&self, v: usize) -> f64 {
        self.task_mb[v]
    }

    pub fn set_task_mb(&mut self, v: usize, mb: f64) {
        self.task_mb[v] = mb;
        self.topology.bump();
    }

    /// Active-neighbor count — |N_i(t)| of the cost model.
    pub fn active_degree(&self, v: usize) -> usize {
        self.graph
            .neighbors(v)
            .iter()
            .filter(|&&u| self.mask[u as usize])
            .count()
    }

    /// Total active associations (edges with both ends alive).
    pub fn active_edges(&self) -> usize {
        self.graph
            .edge_list()
            .iter()
            .filter(|&&(u, v)| self.mask[u as usize] && self.mask[v as usize])
            .count()
    }

    // -- §3.2 dynamics ------------------------------------------------------

    /// Users drop out: mask to 0 and remove their associations.
    pub fn remove_users(&mut self, users: &[usize]) {
        for &v in users {
            if self.mask[v] {
                self.mask[v] = false;
                self.topology.bump();
                if self.recording {
                    let neighbors = self.graph.neighbors(v).to_vec();
                    self.journal.push(GraphDelta::Left { user: v, neighbors });
                }
                self.graph.isolate(v);
            }
        }
    }

    /// New users take mask-0 slots: mask back to 1, fresh positions and
    /// associations supplied by the caller.  Returns the slot ids used.
    pub fn add_users(
        &mut self,
        count: usize,
        positions: &mut dyn FnMut(usize, &mut Rng) -> Pos,
        rng: &mut Rng,
    ) -> Vec<usize> {
        let free: Vec<usize> = (0..self.capacity()).filter(|&v| !self.mask[v]).collect();
        let take = count.min(free.len());
        let chosen = &free[..take];
        for (i, &slot) in chosen.iter().enumerate() {
            self.mask[slot] = true;
            self.pos[slot] = positions(i, rng);
            self.topology.bump();
            if self.recording {
                self.journal
                    .push(GraphDelta::Joined { user: slot, pos: self.pos[slot] });
            }
        }
        chosen.to_vec()
    }

    /// Random-walk position update for all active users.
    pub fn move_users(&mut self, radius_m: f64, plane_m: f64, rng: &mut Rng) {
        for v in 0..self.capacity() {
            if !self.mask[v] {
                continue;
            }
            let dx = rng.range_f64(-radius_m, radius_m);
            let dy = rng.range_f64(-radius_m, radius_m);
            self.pos[v] = Pos {
                x: (self.pos[v].x + dx).clamp(0.0, plane_m),
                y: (self.pos[v].y + dy).clamp(0.0, plane_m),
            };
            self.topology.bump();
            if self.recording {
                self.journal.push(GraphDelta::Moved { user: v, to: self.pos[v] });
            }
        }
    }

    /// Teleport all active users to fresh uniform positions (the
    /// "randomly change the position of all users" experiment of §6.3).
    pub fn scatter_users(&mut self, plane_m: f64, rng: &mut Rng) {
        for v in 0..self.capacity() {
            if self.mask[v] {
                self.pos[v] = Pos {
                    x: rng.range_f64(0.0, plane_m),
                    y: rng.range_f64(0.0, plane_m),
                };
                self.topology.bump();
                if self.recording {
                    self.journal.push(GraphDelta::Moved { user: v, to: self.pos[v] });
                }
            }
        }
    }

    /// Rewire `count` associations: drop a random active edge, add a
    /// random active non-edge (keeping |E| roughly stable).
    pub fn rewire(&mut self, count: usize, rng: &mut Rng) {
        let active = self.active_users();
        if active.len() < 2 {
            return;
        }
        for _ in 0..count {
            let edges: Vec<(u32, u32)> = self
                .graph
                .edge_list()
                .into_iter()
                .filter(|&(u, v)| self.mask[u as usize] && self.mask[v as usize])
                .collect();
            let pick = rng.below(edges.len().max(1)).min(edges.len().saturating_sub(1));
            if let Some(&(u, v)) = edges.get(pick) {
                if !edges.is_empty() {
                    self.remove_assoc(u as usize, v as usize);
                }
            }
            // Add a fresh association between random active users.
            for _ in 0..10 {
                let a = *rng.choose(&active);
                let b = *rng.choose(&active);
                if a != b && self.add_assoc(a, b) {
                    break;
                }
            }
        }
    }

    /// One scenario step: randomized mixture of §3.2's three dynamics
    /// (Algorithm 2 line 8 / Fig. 11's 20% churn protocol).
    pub fn step(&mut self, cfg: &ChurnConfig, rng: &mut Rng) {
        // 1. churn user count: remove up to rate/2, re-add up to rate/2.
        let active = self.active_users();
        // Churn is sized against *capacity* (the nominal population),
        // not the current active count: a multiplicative random walk
        // on the active count drifts downward over long training runs
        // and silently empties the scenario.  Removals draw from the
        // active set; admissions refill free slots, so the population
        // mean-reverts to ~capacity.  Rounded with a floor of one so a
        // nonzero rate still churns small scenarios — plain truncation
        // froze every population under ~1/(rate·0.5) users (e.g. <10
        // users at the paper's 20% rate).
        let churn = if cfg.user_change_rate > 0.0 && self.capacity() > 0 {
            ((self.capacity() as f64) * cfg.user_change_rate * 0.5)
                .round()
                .max(1.0) as usize
        } else {
            0
        };
        if churn > 0 {
            let victims: Vec<usize> = rng
                .sample_indices(active.len(), churn.min(active.len()))
                .into_iter()
                .map(|i| active[i])
                .collect();
            self.remove_users(&victims);
            let plane = cfg.plane_m;
            let free = self.capacity() - self.active_count();
            let added = self.add_users(
                rng.range(free.saturating_sub(churn / 2), free + 1),
                &mut |_, r| Pos {
                    x: r.range_f64(0.0, plane),
                    y: r.range_f64(0.0, plane),
                },
                rng,
            );
            // Fresh users attach with the scenario's mean degree,
            // degree-proportionally (otherwise every churn round bleeds
            // ~mean_deg associations per replaced user and |E| collapses
            // over long training runs).
            let now_active = self.active_users();
            let active_n = now_active.len().max(1);
            let mean_deg = ((2 * self.active_edges()) as f64 / active_n as f64).round() as usize;
            // Degree-proportional endpoint pool.
            let mut pool: Vec<usize> = Vec::with_capacity(2 * self.active_edges() + active_n);
            for &u in &now_active {
                pool.push(u); // +1 smoothing so isolated users are reachable
                for _ in 0..self.active_degree(u) {
                    pool.push(u);
                }
            }
            for v in added {
                let want = mean_deg.max(1);
                let mut tries = 0;
                let mut got = 0;
                while got < want && tries < 20 * want {
                    tries += 1;
                    let u = *rng.choose(&pool);
                    if u != v && self.add_assoc(u, v) {
                        got += 1;
                    }
                }
            }
        }
        // 2. mobility.
        self.move_users(cfg.move_radius_m, cfg.plane_m, rng);
        // 3. association churn.
        let assoc = ((self.active_edges() as f64) * cfg.assoc_change_rate) as usize;
        self.rewire(assoc, rng);
        // 4. density anchor: top associations back up toward the
        // construction-time mean degree (scaled to the live
        // population), degree-proportionally.
        let active = self.active_users();
        if active.len() >= 2 {
            let desired = (self.target_mean_deg * active.len() as f64 / 2.0).round() as usize;
            // Compute the deficit once (active_edges() is O(E)); count
            // successful insertions instead of re-scanning.
            let deficit = desired.saturating_sub(self.active_edges());
            let mut got = 0;
            let mut tries = 0;
            while got < deficit && tries < 50 * deficit.max(1) {
                tries += 1;
                let u = *rng.choose(&active);
                let v = *rng.choose(&active);
                if u != v && self.add_assoc(u, v) {
                    got += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_seeds;

    fn make(n: usize, rng: &mut Rng) -> DynamicGraph {
        let mut g = Graph::new(n);
        for _ in 0..2 * n {
            g.add_edge(rng.below(n), rng.below(n));
        }
        DynamicGraph::new(g, vec![1.0; n], 2000.0, rng)
    }

    #[test]
    fn remove_users_clears_mask_and_edges() {
        let mut rng = Rng::seed_from(1);
        let mut d = make(20, &mut rng);
        let before = d.active_count();
        d.remove_users(&[3, 7]);
        assert_eq!(d.active_count(), before - 2);
        assert!(!d.is_active(3));
        assert_eq!(d.graph().degree(3), 0);
        assert_eq!(d.active_degree(3), 0);
    }

    #[test]
    fn add_users_fills_freed_slots() {
        let mut rng = Rng::seed_from(2);
        let mut d = make(10, &mut rng);
        d.remove_users(&[1, 2, 3]);
        let added = d.add_users(
            2,
            &mut |_, r| Pos { x: r.range_f64(0.0, 10.0), y: 0.0 },
            &mut rng,
        );
        assert_eq!(added.len(), 2);
        assert!(added.iter().all(|&v| [1usize, 2, 3].contains(&v)));
        assert_eq!(d.active_count(), 9);
    }

    #[test]
    fn add_users_never_exceeds_capacity() {
        let mut rng = Rng::seed_from(3);
        let mut d = make(8, &mut rng);
        let added = d.add_users(5, &mut |_, _| Pos { x: 0.0, y: 0.0 }, &mut rng);
        assert!(added.is_empty()); // no free slots
        assert_eq!(d.active_count(), 8);
    }

    #[test]
    fn move_users_stays_on_plane() {
        check_seeds(20, |rng| {
            let mut d = make(30, rng);
            for _ in 0..5 {
                d.move_users(500.0, 2000.0, rng);
            }
            (0..30).all(|v| {
                let p = d.pos(v);
                (0.0..=2000.0).contains(&p.x) && (0.0..=2000.0).contains(&p.y)
            })
        });
    }

    #[test]
    fn step_keeps_invariants() {
        check_seeds(15, |rng| {
            let mut d = make(40, rng);
            let cfg = ChurnConfig::default();
            for _ in 0..8 {
                d.step(&cfg, rng);
                // Mask-0 vertices must never carry edges.
                for v in 0..d.capacity() {
                    if !d.is_active(v) && d.graph().degree(v) > 0 {
                        return false;
                    }
                }
                if d.active_count() > d.capacity() {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn population_stays_stable_under_long_churn() {
        // Regression: additions must balance removals on average, or
        // training scenarios silently decay to a handful of users.
        let mut rng = Rng::seed_from(77);
        let mut d = make(100, &mut rng);
        let e0 = d.active_edges();
        let cfg = ChurnConfig::default();
        for _ in 0..60 {
            d.step(&cfg, &mut rng);
        }
        assert!(
            d.active_count() >= 60,
            "population collapsed to {}",
            d.active_count()
        );
        let e1 = d.active_edges();
        assert!(
            e1 * 2 >= e0,
            "association count collapsed: {e0} -> {e1}"
        );
    }

    #[test]
    fn journal_is_empty_unless_recording() {
        let mut rng = Rng::seed_from(21);
        let mut d = make(30, &mut rng);
        d.step(&ChurnConfig::default(), &mut rng);
        assert!(d.drain_deltas().is_empty());
        d.record_deltas(true);
        d.step(&ChurnConfig::default(), &mut rng);
        assert!(!d.drain_deltas().is_empty());
        // Drain clears; a quiet period records nothing.
        assert!(d.drain_deltas().is_empty());
    }

    #[test]
    fn explicit_association_changes_are_journaled() {
        let mut rng = Rng::seed_from(22);
        let mut d = make(10, &mut rng);
        d.record_deltas(true);
        d.remove_users(&[3]);
        assert!(!d.add_association(3, 4)); // inactive endpoint refused
        let (u, v) = (0usize, 4usize);
        let had = d.graph().has_edge(u, v);
        if had {
            assert!(d.remove_association(u, v));
        } else {
            assert!(d.add_association(u, v));
        }
        let deltas = d.drain_deltas();
        assert!(matches!(deltas[0], GraphDelta::Left { user: 3, .. }));
        assert!(deltas
            .iter()
            .any(|x| matches!(x, GraphDelta::Rewired { a: 0, b: 4, .. })));
    }

    #[test]
    fn deltas_replay_to_identical_topology() {
        // The journal is exact: replaying it onto a copy of the
        // pre-churn graph reproduces adjacency and mask bit for bit.
        check_seeds(10, |rng| {
            let n = 50;
            let mut d = make(n, rng);
            let mut shadow = d.graph().clone();
            let mut mask = vec![true; n];
            d.record_deltas(true);
            let cfg = ChurnConfig::default();
            for _ in 0..6 {
                d.step(&cfg, rng);
                for delta in d.drain_deltas() {
                    match delta {
                        GraphDelta::Moved { .. } => {}
                        GraphDelta::Joined { user, .. } => mask[user] = true,
                        GraphDelta::Left { user, .. } => {
                            mask[user] = false;
                            shadow.isolate(user);
                        }
                        GraphDelta::Rewired { a, b, added } => {
                            if added {
                                shadow.add_edge(a, b);
                            } else {
                                shadow.remove_edge(a, b);
                            }
                        }
                    }
                }
                if shadow.num_edges() != d.graph().num_edges() {
                    return false;
                }
                if (0..n).any(|v| mask[v] != d.is_active(v)) {
                    return false;
                }
                if (0..n).any(|v| shadow.neighbors(v) != d.graph().neighbors(v)) {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn small_scenarios_still_churn_users() {
        // Regression: `(capacity * rate * 0.5) as usize` floored to 0
        // for populations under ~1/(rate·0.5), so a 10-user scenario at
        // the paper's 20% rate never saw a join or leave.
        check_seeds(10, |rng| {
            let mut d = make(10, rng);
            d.record_deltas(true);
            let cfg = ChurnConfig::default(); // 20% user churn
            d.step(&cfg, rng);
            let deltas = d.drain_deltas();
            // churn = round(10·0.2·0.5).max(1) = 1: at least one user
            // must leave (and the freed slot is refilled).
            deltas.iter().any(|x| matches!(x, GraphDelta::Left { .. }))
                && deltas.iter().any(|x| matches!(x, GraphDelta::Joined { .. }))
        });
    }

    #[test]
    fn zero_churn_rate_means_no_user_churn() {
        let mut rng = Rng::seed_from(31);
        let mut d = make(10, &mut rng);
        d.record_deltas(true);
        let cfg = ChurnConfig { user_change_rate: 0.0, ..ChurnConfig::default() };
        d.step(&cfg, &mut rng);
        let deltas = d.drain_deltas();
        assert!(!deltas
            .iter()
            .any(|x| matches!(x, GraphDelta::Left { .. } | GraphDelta::Joined { .. })));
    }

    #[test]
    fn topology_version_tracks_every_mutation_kind() {
        let mut rng = Rng::seed_from(41);
        let mut d = make(20, &mut rng);
        let v0 = d.topology_version();
        // Reads leave the stamp alone.
        let _ = (d.active_users(), d.active_edges(), d.pos(0), d.task_mb(0));
        assert_eq!(d.topology_version(), v0);
        d.remove_users(&[2]);
        let v1 = d.topology_version();
        assert!(v1 > v0, "user removal must bump");
        d.remove_users(&[2]); // already inactive: no mutation
        assert_eq!(d.topology_version(), v1);
        d.move_users(50.0, 2000.0, &mut rng);
        let v2 = d.topology_version();
        assert!(v2 > v1, "mobility must bump");
        let had = d.graph().has_edge(0, 1);
        if had {
            assert!(d.remove_association(0, 1));
        } else {
            assert!(d.add_association(0, 1));
        }
        let v3 = d.topology_version();
        assert!(v3 > v2, "association change must bump");
        d.set_task_mb(0, 2.5);
        assert!(d.topology_version() > v3, "task-size change must bump");
        // Churn steps bump regardless of delta recording.
        assert!(!d.recording());
        let before = d.topology_version();
        d.step(&ChurnConfig::default(), &mut rng);
        assert!(d.topology_version() > before);
    }

    #[test]
    fn pos_distance() {
        let a = Pos { x: 0.0, y: 0.0 };
        let b = Pos { x: 3.0, y: 4.0 };
        assert!((a.dist(&b) - 5.0).abs() < 1e-12);
    }
}
