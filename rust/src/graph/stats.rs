//! Graph statistics for the Fig. 5 experiment (vertex degree
//! distributions of the three datasets) and general diagnostics.

use super::Graph;

/// (degree, count) pairs sorted by degree — what Fig. 5 plots.
pub fn degree_distribution(g: &Graph) -> Vec<(usize, usize)> {
    crate::util::stats::int_distribution((0..g.len()).map(|v| g.degree(v)))
}

/// Summary of a distribution for table output.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DegreeSummary {
    pub min: usize,
    pub max: usize,
    pub mean: f64,
    pub median: usize,
}

pub fn degree_summary(g: &Graph) -> DegreeSummary {
    let mut degs: Vec<usize> = (0..g.len()).map(|v| g.degree(v)).collect();
    degs.sort_unstable();
    let n = degs.len().max(1);
    DegreeSummary {
        min: degs.first().copied().unwrap_or(0),
        max: degs.last().copied().unwrap_or(0),
        mean: degs.iter().sum::<usize>() as f64 / n as f64,
        median: degs[n / 2],
    }
}

/// Pearson-style tail heaviness probe: fraction of vertices with degree
/// above `k * mean` — citation graphs have a visible heavy tail.
pub fn tail_fraction(g: &Graph, k: f64) -> f64 {
    let mean = 2.0 * g.num_edges() as f64 / g.len().max(1) as f64;
    let cut = k * mean;
    (0..g.len()).filter(|&v| g.degree(v) as f64 > cut).count() as f64
        / g.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{preferential_attachment, uniform_random};
    use crate::util::rng::Rng;

    #[test]
    fn distribution_sums_to_vertex_count() {
        let mut rng = Rng::seed_from(1);
        let g = uniform_random(200, 600, &mut rng);
        let dist = degree_distribution(&g);
        let total: usize = dist.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 200);
        let edge_mass: usize = dist.iter().map(|&(d, c)| d * c).sum();
        assert_eq!(edge_mass, 2 * g.num_edges());
    }

    #[test]
    fn summary_consistent() {
        let g = Graph::from_edges(4, &[(0, 1), (0, 2), (0, 3)]);
        let s = degree_summary(&g);
        assert_eq!(s.max, 3);
        assert_eq!(s.min, 1);
        assert!((s.mean - 1.5).abs() < 1e-12);
    }

    #[test]
    fn pa_has_heavier_tail_than_uniform() {
        let mut rng = Rng::seed_from(7);
        let pa = preferential_attachment(3000, 8, &mut rng);
        let er = uniform_random(3000, pa.num_edges(), &mut rng);
        assert!(tail_fraction(&pa, 4.0) > tail_fraction(&er, 4.0));
    }
}
