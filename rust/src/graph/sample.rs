//! Scenario sampling (§6.3): draw `users` vertices and `assocs`
//! associations from a dataset graph to form one EC scenario.
//!
//! The paper "randomly samples 300 documents and 4800 citation links
//! from PubMed" for training and resamples per evaluation; the sampler
//! here does the same for any dataset: a BFS ball gives a locally
//! connected user set (documents that actually cite each other), then
//! associations are the induced edges, randomly topped up or trimmed to
//! the requested count.

use super::geb::Dataset;
use super::Graph;
use crate::util::rng::Rng;

/// One sampled EC scenario: `users[i]` is the dataset vertex backing
/// scenario user `i`; `graph` is over scenario indices `0..users.len()`.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub users: Vec<u32>,
    pub graph: Graph,
}

/// Sample `n_users` vertices and exactly `n_assocs` associations
/// (when achievable: capped by the complete graph, floored at the
/// induced edges found).
pub fn sample_scenario(ds: &Dataset, n_users: usize, n_assocs: usize, rng: &mut Rng) -> Scenario {
    assert!(n_users <= ds.n, "dataset {} has {} < {} vertices", ds.name, ds.n, n_users);
    // BFS ball from a random seed (restart on exhaustion) for locality.
    let mut chosen: Vec<u32> = Vec::with_capacity(n_users);
    let mut in_set = vec![false; ds.n];
    let mut queue = std::collections::VecDeque::new();
    while chosen.len() < n_users {
        if queue.is_empty() {
            loop {
                let s = rng.below(ds.n);
                if !in_set[s] {
                    queue.push_back(s);
                    break;
                }
            }
        }
        let u = queue.pop_front().unwrap();
        if in_set[u] {
            continue;
        }
        in_set[u] = true;
        chosen.push(u as u32);
        for &v in ds.graph.neighbors(u) {
            if !in_set[v as usize] {
                queue.push_back(v as usize);
            }
        }
    }
    let index: std::collections::HashMap<u32, u32> = chosen
        .iter()
        .enumerate()
        .map(|(i, &v)| (v, i as u32))
        .collect();

    // Induced edges.
    let mut g = Graph::new(n_users);
    for (si, &dv) in chosen.iter().enumerate() {
        for &nb in ds.graph.neighbors(dv as usize) {
            if let Some(&sj) = index.get(&nb) {
                g.add_edge(si, sj as usize);
            }
        }
    }
    // Trim or top-up to n_assocs.
    let max_edges = n_users * (n_users - 1) / 2;
    let target = n_assocs.min(max_edges);
    while g.num_edges() > target {
        let edges = g.edge_list();
        let &(u, v) = rng.choose(&edges);
        g.remove_edge(u as usize, v as usize);
    }
    // Top-up prefers triadic closure (neighbors-of-neighbors), which
    // keeps the citation graph's homophily — uniform random edges both
    // misrepresent citation structure and drag GNN accuracy below the
    // paper's band.  Fall back to uniform pairs when closure stalls.
    let mut stall = 0;
    while g.num_edges() < target && stall < 100_000 {
        let u = rng.below(n_users);
        let added = if g.degree(u) > 0 && rng.chance(0.8) {
            let via = g.neighbors(u)[rng.below(g.degree(u))] as usize;
            if g.degree(via) > 0 {
                let w = g.neighbors(via)[rng.below(g.degree(via))] as usize;
                g.add_edge(u, w)
            } else {
                false
            }
        } else {
            g.add_edge(u, rng.below(n_users))
        };
        if !added {
            stall += 1;
        }
    }
    Scenario { users: chosen, graph: g }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::preferential_attachment;

    fn fake_dataset(n: usize, mean_deg: usize) -> Dataset {
        let mut rng = Rng::seed_from(99);
        let graph = preferential_attachment(n, mean_deg, &mut rng);
        Dataset {
            name: "fake".into(),
            n,
            e: graph.num_edges(),
            feat_dim: 32,
            classes: 3,
            labels: vec![0; n],
            feat_ptr: vec![0; n + 1],
            feat_idx: vec![],
            graph,
        }
    }

    #[test]
    fn sample_hits_exact_counts() {
        let ds = fake_dataset(1000, 8);
        let mut rng = Rng::seed_from(1);
        let s = sample_scenario(&ds, 300, 1800, &mut rng);
        assert_eq!(s.users.len(), 300);
        assert_eq!(s.graph.len(), 300);
        assert_eq!(s.graph.num_edges(), 1800);
        // All users distinct and valid dataset vertices.
        let set: std::collections::HashSet<_> = s.users.iter().collect();
        assert_eq!(set.len(), 300);
        assert!(s.users.iter().all(|&u| (u as usize) < 1000));
    }

    #[test]
    fn sample_trims_to_target() {
        let ds = fake_dataset(500, 16);
        let mut rng = Rng::seed_from(2);
        let s = sample_scenario(&ds, 200, 100, &mut rng);
        assert_eq!(s.graph.num_edges(), 100);
    }

    #[test]
    fn sample_caps_at_complete_graph() {
        let ds = fake_dataset(100, 4);
        let mut rng = Rng::seed_from(3);
        let s = sample_scenario(&ds, 10, 1_000_000, &mut rng);
        assert_eq!(s.graph.num_edges(), 45);
    }

    #[test]
    fn sampled_users_locally_connected() {
        // BFS-ball sampling should keep most induced structure: the
        // scenario graph should not be mostly isolated vertices.
        let ds = fake_dataset(2000, 10);
        let mut rng = Rng::seed_from(4);
        let s = sample_scenario(&ds, 300, 1500, &mut rng);
        let isolated = (0..300).filter(|&v| s.graph.degree(v) == 0).count();
        assert!(isolated < 60, "too many isolated vertices: {isolated}");
    }
}
