//! # GraphEdge
//!
//! A production-shaped reproduction of *GraphEdge: Dynamic Graph
//! Partition and Task Scheduling for GNNs Computing in Edge Network*
//! (Xiao et al., 2025), built as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **Layer 3 (this crate)** — the EC controller and everything around
//!   it: the dynamic graph model (§3.2), the HiCut hierarchical
//!   traversal graph-cut (§4, Algorithm 1), the DRLGO multi-agent
//!   offloading algorithm (§5, Algorithm 2) plus the paper's baselines
//!   (PTOM/GM/RM and the max-flow min-cut comparator), the radio/energy
//!   cost model (Eqs. 3–13), and a simulated heterogeneous edge-server
//!   fleet that *actually executes* GNN inference.
//!
//! Dynamic scenarios no longer recut the world every step: §3.2 churn
//! is recorded as a typed [`graph::dynamic::GraphDelta`] stream and the
//! [`partition::incremental`] subsystem repairs the live HiCut layout —
//! exact O(1) cut bookkeeping per delta, majority-attach for arrivals,
//! a bounded greedy refinement sweep, and local region re-cuts of
//! subgraphs whose boundary degraded — in O(Δ·deg + dirty region) per
//! step versus the full cut's O(N² + N·E) (§4.4).  A
//! [`partition::incremental::DriftMonitor`] compares the live
//! inter-subgraph association count against the last full cut and
//! falls back to full HiCut past a configurable bound, so repair never
//! silently erodes layout quality.  `coordinator::Controller::run_dynamic`
//! and `serving::serve_dynamic_run` ride this path online.
//!
//! Staleness across the stack is governed by one substrate,
//! [`util::version`]: producers ([`graph::dynamic::DynamicGraph`]
//! topology, the installed partition layout, the system parameters)
//! stamp monotonic [`util::version::Version`]s, and every derived-state
//! cache — the DRLGO observation templates, the cost model's rate
//! tables, the incremental partitioner's repaired-to mark, the serving
//! router's deadline window, [`util::stats::Sample`]'s percentile sort —
//! is a [`util::version::Memoized`] cell that re-validates its version
//! key on every read and rebuilds lazily on mismatch.  There is no
//! "invalidate on mutation" choke point to forget: a stale read is
//! impossible by construction, staleness *debt* is observable as
//! `version.lag.*` gauges in the metrics pipeline, and the
//! `tests/properties.rs` suite pins every memoized read bit-identical
//! to a from-scratch recompute under interleaved churn.
//! * **Layer 2 (JAX, build time)** — GCN/GAT/GraphSAGE/SGC forwards and
//!   the MADDPG/PPO train steps, AOT-lowered to HLO text.
//! * **Layer 1 (Pallas, build time)** — the dense aggregation kernels
//!   behind every GNN layer.
//!
//! Python never runs on the request path.  Inference and the DRL
//! train steps execute through a pluggable [`runtime::Backend`]: the
//! **default is the pure-Rust native backend**
//! ([`runtime::native`] — CSR SpMM + dense kernels ported from the
//! `ref.py` oracles, row-parallel over [`util::threadpool`]), which
//! needs no artifacts directory at all; with `--features xla` an
//! on-disk `make artifacts` tree is compiled and executed through the
//! PJRT C API instead.  Both backends are pinned to the same Python
//! oracles — see `rust/ARCHITECTURE.md` for the end-to-end dataflow
//! (scenario → HiCut/incremental repair → router → backend inference)
//! and which layer bumps which [`util::version`] stamp.
//!
//! Start with [`coordinator::Controller`] for the end-to-end loop, or
//! the `examples/` directory.

pub mod bench;
pub mod coordinator;
pub mod drl;
pub mod graph;
pub mod net;
pub mod partition;
pub mod runtime;
pub mod scenario;
pub mod serving;
pub mod tensor;
pub mod util;

/// Crate-wide result alias (anyhow-based; library-level typed errors
/// live next to their modules as `thiserror` enums).
pub type Result<T> = anyhow::Result<T>;
