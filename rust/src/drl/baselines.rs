//! Non-learning baselines of §6.1: GM (greedy nearest server) and RM
//! (uniform random server) — single-env, batched variants that
//! evaluate every slot of a [`VecEnv`] concurrently, and the
//! scenario-set evaluator that runs GM over a
//! [`ScenarioSet`]'s held-out split.

use crate::net::cost::CostBreakdown;
use crate::scenario::ScenarioSet;
use crate::util::rng::Rng;

use super::env::{Env, EnvConfig};
use super::vec_env::VecEnv;

/// GM: offload every user to the nearest edge server that still has
/// capacity (falling back to nearest overall).
pub fn run_greedy(env: &mut Env) {
    env.reset();
    while let Some(u) = env.current_user() {
        let pos = env.users.pos(u);
        let eligible = env.eligible();
        let server = if eligible.is_empty() {
            env.net.nearest(pos)
        } else {
            // total_cmp: a NaN distance (degenerate positions) sorts
            // last instead of panicking the whole serving loop.
            *eligible
                .iter()
                .min_by(|&&a, &&b| {
                    let da = env.net.servers[a].pos.dist(&pos);
                    let db = env.net.servers[b].pos.dist(&pos);
                    da.total_cmp(&db)
                })
                .unwrap()
        };
        env.step(server);
    }
}

/// RM: uniform random placement, ignoring all scenario information.
pub fn run_random(env: &mut Env, rng: &mut Rng) {
    env.reset();
    while env.current_user().is_some() {
        let server = rng.below(env.agents());
        env.step(server);
    }
}

/// Batched GM: run the greedy policy to completion in every slot of
/// the vector (fanned out across its worker threads) and return the
/// per-slot evaluated cost.  Slots are neither churned nor counted as
/// training episodes — this is the evaluation rollout.
pub fn run_greedy_vec(venv: &mut VecEnv) -> Vec<CostBreakdown> {
    venv.evaluate_with(|_, env| run_greedy(env))
}

/// Batched RM: like [`run_greedy_vec`] but with uniform random
/// placement; slot `i` draws from `Rng::seed_from(seed + i)` so the
/// result is deterministic and worker-count independent.
pub fn run_random_vec(venv: &mut VecEnv, seed: u64) -> Vec<CostBreakdown> {
    venv.evaluate_with(|i, env| {
        let mut rng = Rng::seed_from(seed.wrapping_add(i as u64));
        run_random(env, &mut rng);
    })
}

/// Evaluate GM on every scenario of a set's *eval* split (one slot per
/// held-out scenario) — the reference cost a trained policy is
/// compared against on unseen topologies.  Both the environment
/// construction (each slot's initial HiCut, the dominant cost) and the
/// greedy rollouts fan out over `workers` threads; the result is
/// worker-count invariant.  Empty when the set has no eval split.
pub fn run_greedy_eval_set(
    set: &ScenarioSet,
    cfg: &EnvConfig,
    workers: usize,
) -> Vec<CostBreakdown> {
    let picks: Vec<&crate::scenario::Scenario> = set.eval_scenarios().collect();
    if picks.is_empty() {
        return Vec::new();
    }
    let mut venv = VecEnv::from_scenarios(&picks, cfg, 0, workers.max(1));
    venv.set_workers(workers.max(1));
    run_greedy_vec(&mut venv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::drl::env::testutil::small_env;

    #[test]
    fn greedy_completes_and_prefers_near_servers() {
        let mut env = small_env(11);
        run_greedy(&mut env);
        assert!(env.finished());
        let active = env.users.active_users();
        assert!(env.offload.all_assigned(&active));
        // Spot-check: with all servers eligible at start, user 0's
        // server should be its nearest.
        let mut env2 = small_env(11);
        let u = env2.current_user().unwrap();
        let pos = env2.users.pos(u);
        run_greedy(&mut env2);
        let nearest = env2.net.nearest(pos);
        assert_eq!(env2.offload.server[u], nearest);
    }

    #[test]
    fn random_completes() {
        let mut env = small_env(12);
        let mut rng = Rng::seed_from(5);
        run_random(&mut env, &mut rng);
        assert!(env.finished());
        assert!(env.offload.all_assigned(&env.users.active_users()));
    }

    #[test]
    fn vec_baselines_match_their_single_env_runs() {
        // Batched evaluation is the same policy per slot: a 3-slot
        // vector (no churn yet, so all slots share the scenario) must
        // produce exactly the single-env greedy cost in every slot,
        // and stay identical across worker counts.
        use crate::drl::vec_env::VecEnv;
        let mut single = small_env(14);
        run_greedy(&mut single);
        let expected = single.evaluate().total();
        for workers in [1usize, 3] {
            let proto = small_env(14);
            let mut venv = VecEnv::replicate(&proto, 3, 77);
            venv.set_workers(workers);
            let costs = run_greedy_vec(&mut venv);
            assert_eq!(costs.len(), 3);
            for c in &costs {
                assert!((c.total() - expected).abs() < 1e-12, "greedy cost diverged");
            }
        }
        // Random: deterministic per slot seed, independent of workers.
        let proto = small_env(14);
        let mut a = VecEnv::replicate(&proto, 3, 77);
        let mut b = VecEnv::replicate(&proto, 3, 77);
        b.set_workers(3);
        let ca = run_random_vec(&mut a, 9);
        let cb = run_random_vec(&mut b, 9);
        for (x, y) in ca.iter().zip(&cb) {
            assert_eq!(x.total().to_bits(), y.total().to_bits());
        }
    }

    #[test]
    fn greedy_eval_set_covers_the_holdout_split() {
        use crate::net::SystemParams;
        let params = SystemParams::default();
        let spec = "uniform@30x60,hotspot@40x90";
        let set = ScenarioSet::from_spec(spec, 0, 0, &params, 4, 5).unwrap();
        let cfg = EnvConfig::default();
        let costs = run_greedy_eval_set(&set, &cfg, 2);
        assert_eq!(costs.len(), set.eval.len());
        assert!(!costs.is_empty());
        for c in &costs {
            assert!(c.total() > 0.0);
        }
        // Deterministic and worker-count invariant.
        let again = run_greedy_eval_set(&set, &cfg, 1);
        for (a, b) in costs.iter().zip(&again) {
            assert_eq!(a.total().to_bits(), b.total().to_bits());
        }
    }

    #[test]
    fn greedy_generally_cheaper_than_random() {
        // Averaged over seeds (GM considers distance; RM nothing).
        let mut g_total = 0.0;
        let mut r_total = 0.0;
        for seed in 0..8 {
            let mut eg = small_env(100 + seed);
            run_greedy(&mut eg);
            g_total += eg.evaluate().total();
            let mut er = small_env(100 + seed);
            let mut rng = Rng::seed_from(seed);
            run_random(&mut er, &mut rng);
            r_total += er.evaluate().total();
        }
        assert!(
            g_total < r_total * 1.1,
            "greedy {g_total} should not be much worse than random {r_total}"
        );
    }
}
