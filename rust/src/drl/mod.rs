//! DRL-based graph offloading (§5) and the §6 baselines.
//!
//! * [`env`] — the MAMDP environment of §5.2: per-agent observations,
//!   global state, two-dimensional agent actions, the cost-based
//!   reward with the subgraph-colocation term R_sp (Eq. 25), and the
//!   user-by-user episode protocol of Algorithm 2.
//! * [`vec_env`] — E independent episodes stepped as a batch, with
//!   per-slot churn streams, thread fan-out and auto-reset (the layer
//!   the training loops roll out on).  Slots either replicate one
//!   shared scenario or each own a distinct generated
//!   [`crate::scenario::Scenario`] (`--scenarios`).
//! * [`replay`] — experience replay buffer D.
//! * [`maddpg`] — DRLGO: the MADDPG trainer driving the AOT-compiled
//!   `actor_fwd` / `maddpg_train` executables over vectorized
//!   rollouts, plus greedy policy execution for evaluation.
//! * [`ppo`] — PTOM: the single-agent PPO baseline (global state, no
//!   HiCut, no R_sp), also trained on vectorized rollouts.
//! * [`baselines`] — GM (nearest server) and RM (random server),
//!   single-env and batched.
//! * [`telemetry`] — per-episode training curves exported as JSONL
//!   (`graphedge train --telemetry <path>`).
//!
//! Everything numeric runs through PJRT; this module owns only control
//! flow, the environment and the buffers.

pub mod baselines;
pub mod env;
pub mod maddpg;
pub mod ppo;
pub mod replay;
pub mod telemetry;
pub mod vec_env;

pub use env::{Env, EnvConfig, StepOutcome};
pub use maddpg::{MaddpgConfig, MaddpgTrainer};
pub use ppo::{PpoConfig, PpoTrainer};
pub use vec_env::{VecEnv, VecStep};

/// Offloading method identifiers used across benches and the CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// DRLGO: HiCut + MADDPG (the paper's proposal).
    Drlgo,
    /// PTOM: PPO over the global state, no HiCut/R_sp.
    Ptom,
    /// Greedy: nearest server with remaining capacity.
    Greedy,
    /// Random server.
    Random,
    /// Ablation: MADDPG without HiCut and without R_sp (§6.5).
    DrlOnly,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Drlgo => "DRLGO",
            Method::Ptom => "PTOM",
            Method::Greedy => "GM",
            Method::Random => "RM",
            Method::DrlOnly => "DRL-only",
        }
    }
}
