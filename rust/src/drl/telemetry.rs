//! Training telemetry export: the per-episode curve as JSONL.
//!
//! Both trainers ([`crate::drl::maddpg`], [`crate::drl::ppo`]) return
//! their reward curve as `Vec<EpisodeStats>`; `graphedge train
//! --telemetry <path>` writes it through [`write_episode_jsonl`] — one
//! object per episode with `episode`, `reward`, `system_cost`,
//! `critic_loss`, `actor_loss`, `steps` and `drift` keys — so runs can
//! be diffed and plotted without scraping the printed table.  The
//! schema is validated offline by `scripts/check_trace_schema.py
//! --train`.
//!
//! This is the *summary* series; the step-grained view of the same
//! runs (spans, `train.episode` instants) comes from
//! [`crate::util::trace`] via `GRAPHEDGE_TRACE`.

use std::io::Write as _;
use std::path::Path;

use super::maddpg::EpisodeStats;

fn push_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        out.push_str(&format!("{v}"));
    } else {
        out.push_str("null");
    }
}

/// One episode record as a single JSONL line (no trailing newline).
pub fn episode_to_json(s: &EpisodeStats) -> String {
    let mut line = String::with_capacity(128);
    line.push_str(&format!("{{\"episode\":{},\"reward\":", s.episode));
    push_f64(&mut line, s.reward);
    line.push_str(",\"system_cost\":");
    push_f64(&mut line, s.system_cost);
    line.push_str(",\"critic_loss\":");
    push_f64(&mut line, s.critic_loss);
    line.push_str(",\"actor_loss\":");
    push_f64(&mut line, s.actor_loss);
    line.push_str(&format!(",\"steps\":{},\"drift\":", s.steps));
    push_f64(&mut line, s.drift);
    line.push('}');
    line
}

/// Write a training curve as JSONL, one episode per line.
pub fn write_episode_jsonl(path: &Path, curve: &[EpisodeStats]) -> crate::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for s in curve {
        writeln!(f, "{}", episode_to_json(s))?;
    }
    f.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(episode: usize) -> EpisodeStats {
        EpisodeStats {
            episode,
            reward: -3.25,
            system_cost: 12.5,
            critic_loss: 0.125,
            actor_loss: f64::NAN,
            steps: 40,
            drift: 0.0625,
        }
    }

    #[test]
    fn episode_lines_are_valid_json() {
        let line = episode_to_json(&stats(7));
        let v = crate::util::json::Value::parse(&line).expect("valid JSON");
        assert_eq!(v.path(&["episode"]).unwrap().as_usize(), Some(7));
        assert_eq!(v.path(&["reward"]).unwrap().as_f64(), Some(-3.25));
        assert_eq!(v.path(&["steps"]).unwrap().as_usize(), Some(40));
        assert_eq!(v.path(&["drift"]).unwrap().as_f64(), Some(0.0625));
        // Non-finite values must not break the line.
        assert!(matches!(
            v.path(&["actor_loss"]),
            Some(crate::util::json::Value::Null)
        ));
    }

    #[test]
    fn write_episode_jsonl_emits_one_line_per_episode() {
        let curve: Vec<EpisodeStats> = (0..5).map(stats).collect();
        let dir = std::env::temp_dir().join(format!("ge_telemetry_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("curve.jsonl");
        write_episode_jsonl(&path, &curve).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 5);
        for (i, line) in text.lines().enumerate() {
            let v = crate::util::json::Value::parse(line).unwrap();
            assert_eq!(v.path(&["episode"]).unwrap().as_usize(), Some(i));
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
