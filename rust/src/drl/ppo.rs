//! PTOM — the PPO-based task-offloading baseline (§6.1).
//!
//! A single agent observes the *global* environment state, samples one
//! of the M servers per user, and learns with the clipped surrogate
//! objective.  No HiCut layout optimization, no R_sp shaping — exactly
//! the paper's comparison configuration (same network sizes as DRLGO).
//!
//! The math lives in two AOT executables: `ppo_fwd` (logits + value)
//! and `ppo_train` (one clipped-surrogate epoch on a fixed horizon of
//! 256 steps).  GAE(γ = 0.99, λ = 0.95) is computed host-side.

use std::sync::Arc;

use crate::runtime::{lit, Executable, Runtime};
use crate::util::rng::Rng;

use super::env::Env;
use super::maddpg::EpisodeStats;

#[derive(Clone, Debug)]
pub struct PpoConfig {
    pub episodes: usize,
    /// Train epochs per collected horizon.
    pub epochs: usize,
    pub gamma: f64,
    pub lam: f64,
    pub churn: bool,
    pub seed: u64,
}

impl Default for PpoConfig {
    fn default() -> Self {
        PpoConfig {
            episodes: 150,
            epochs: 4,
            gamma: 0.99,
            lam: 0.95,
            churn: true,
            seed: 0x990,
        }
    }
}

/// Rollout storage for one horizon.
#[derive(Default)]
struct Rollout {
    states: Vec<f32>,  // [T * STATE]
    actions: Vec<usize>,
    logps: Vec<f32>,
    values: Vec<f32>,
    rewards: Vec<f32>,
    dones: Vec<f32>,
}

impl Rollout {
    fn len(&self) -> usize {
        self.actions.len()
    }

    fn clear(&mut self) {
        self.states.clear();
        self.actions.clear();
        self.logps.clear();
        self.values.clear();
        self.rewards.clear();
        self.dones.clear();
    }
}

pub struct PpoTrainer<'rt> {
    fwd: Arc<Executable>,
    train_exe: Arc<Executable>,
    pub state_dim: usize,
    pub actions: usize,
    pub horizon: usize,
    params: Vec<f32>,
    m_p: Vec<f32>,
    v_p: Vec<f32>,
    step: f32,
    roll: Rollout,
    _rt: std::marker::PhantomData<&'rt Runtime>,
}

impl<'rt> PpoTrainer<'rt> {
    pub fn new(rt: &'rt Runtime) -> crate::Result<Self> {
        let fwd = rt.load("ppo_fwd")?;
        let train_exe = rt.load("ppo_train")?;
        let state_dim = rt.manifest.constant("state_dim")?;
        let actions = rt.manifest.constant("m_agents")?;
        let horizon = rt.manifest.constant("batch")?;
        let p_ppo = rt.manifest.constant("p_ppo")?;
        let init = rt.load_archive("drl/drl_init.gta")?;
        let params = init.get_shaped("ppo", &[p_ppo])?.f32_data.clone();
        Ok(PpoTrainer {
            fwd,
            train_exe,
            state_dim,
            actions,
            horizon,
            m_p: vec![0.0; params.len()],
            v_p: vec![0.0; params.len()],
            params,
            step: init.get("ppo_step")?.f32_data[0],
            roll: Rollout::default(),
            _rt: std::marker::PhantomData,
        })
    }

    /// Sample an action from the categorical policy; returns
    /// (action, log-prob, value).
    pub fn select(&self, state: &[f32], rng: &mut Rng, greedy: bool)
        -> crate::Result<(usize, f32, f32)> {
        let p = lit(&[self.params.len()], &self.params)?;
        let s = lit(&[1, self.state_dim], state)?;
        let out = self.fwd.run_borrowed(&[&p, &s])?;
        let logits = out[0].to_vec::<f32>()?;
        let value = out[1].to_vec::<f32>()?[0];
        // Softmax (stable).
        let mx = logits.iter().cloned().fold(f32::MIN, f32::max);
        let exps: Vec<f32> = logits.iter().map(|&l| (l - mx).exp()).collect();
        let z: f32 = exps.iter().sum();
        let probs: Vec<f32> = exps.iter().map(|&e| e / z).collect();
        let action = if greedy {
            // total_cmp: NaN logits (diverged policy) must not panic
            // the evaluation rollout.
            probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0
        } else {
            let mut u = rng.f32();
            let mut a = self.actions - 1;
            for (i, &pr) in probs.iter().enumerate() {
                if u < pr {
                    a = i;
                    break;
                }
                u -= pr;
            }
            a
        };
        Ok((action, probs[action].max(1e-12).ln(), value))
    }

    /// Run one PPO update over the stored horizon (must be full).
    fn update(&mut self, epochs: usize, gamma: f64, lam: f64, last_value: f32)
        -> crate::Result<(f64, f64)> {
        let t = self.roll.len();
        debug_assert_eq!(t, self.horizon);
        // GAE advantages + returns.
        let mut adv = vec![0.0f32; t];
        let mut ret = vec![0.0f32; t];
        let mut gae = 0.0f64;
        for i in (0..t).rev() {
            let next_v = if i + 1 < t {
                // value bootstrap is zeroed across episode boundaries
                if self.roll.dones[i] > 0.5 { 0.0 } else { self.roll.values[i + 1] as f64 }
            } else if self.roll.dones[i] > 0.5 {
                0.0
            } else {
                last_value as f64
            };
            let nonterminal = if self.roll.dones[i] > 0.5 { 0.0 } else { 1.0 };
            let delta =
                self.roll.rewards[i] as f64 + gamma * next_v - self.roll.values[i] as f64;
            gae = delta + gamma * lam * nonterminal * gae;
            adv[i] = gae as f32;
            ret[i] = adv[i] + self.roll.values[i];
        }
        // Normalize advantages.
        let mean = adv.iter().sum::<f32>() / t as f32;
        let var = adv.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / t as f32;
        let std = var.sqrt().max(1e-6);
        for a in &mut adv {
            *a = (*a - mean) / std;
        }
        let mut onehot = vec![0.0f32; t * self.actions];
        for (i, &a) in self.roll.actions.iter().enumerate() {
            onehot[i * self.actions + a] = 1.0;
        }
        let (mut pl, mut vl) = (0.0, 0.0);
        for _ in 0..epochs {
            let inputs = vec![
                lit(&[self.params.len()], &self.params)?,
                lit(&[self.params.len()], &self.m_p)?,
                lit(&[self.params.len()], &self.v_p)?,
                lit(&[], &[self.step])?,
                lit(&[t, self.state_dim], &self.roll.states)?,
                lit(&[t, self.actions], &onehot)?,
                lit(&[t], &self.roll.logps)?,
                lit(&[t], &adv)?,
                lit(&[t], &ret)?,
            ];
            let out = self.train_exe.run(&inputs)?;
            self.params = out[0].to_vec::<f32>()?;
            self.m_p = out[1].to_vec::<f32>()?;
            self.v_p = out[2].to_vec::<f32>()?;
            self.step = out[3].get_first_element::<f32>()?;
            pl = out[4].get_first_element::<f32>()? as f64;
            vl = out[5].get_first_element::<f32>()? as f64;
        }
        self.roll.clear();
        Ok((pl, vl))
    }

    /// Full training: episodes over a (churning) environment.
    pub fn train(&mut self, env: &mut Env, cfg: &PpoConfig)
        -> crate::Result<Vec<EpisodeStats>> {
        let mut rng = Rng::seed_from(cfg.seed);
        let mut curve = Vec::new();
        for ep in 0..cfg.episodes {
            if cfg.churn && ep > 0 {
                env.mutate(&mut rng);
            }
            env.reset();
            let mut reward = 0.0;
            let mut steps = 0;
            // The post-step state serves both the horizon-boundary
            // value bootstrap and the next iteration's policy input —
            // one state build per env step.
            let mut s = env.state();
            while !env.finished() {
                let (a, logp, v) = self.select(&s, &mut rng, false)?;
                let out = env.step(a);
                let r: f64 = out.rewards.iter().sum();
                reward += r;
                steps += 1;
                self.roll.states.extend_from_slice(&s);
                self.roll.actions.push(a);
                self.roll.logps.push(logp);
                self.roll.values.push(v);
                self.roll.rewards.push(r as f32);
                self.roll.dones.push(out.finished as u8 as f32);
                let s_next = env.state();
                if self.roll.len() == self.horizon {
                    let last_v = if env.finished() {
                        0.0
                    } else {
                        self.select(&s_next, &mut rng, false)?.2
                    };
                    self.update(cfg.epochs, cfg.gamma, cfg.lam, last_v)?;
                }
                s = s_next;
            }
            curve.push(EpisodeStats {
                episode: ep,
                reward,
                system_cost: env.evaluate().total(),
                critic_loss: 0.0,
                actor_loss: 0.0,
                steps,
            });
            log::debug!("ppo ep {ep}: reward {reward:.3}");
        }
        Ok(curve)
    }

    /// Greedy policy rollout for evaluation.
    pub fn policy_offload(&mut self, env: &mut Env) -> crate::Result<()> {
        let mut rng = Rng::seed_from(0);
        env.reset();
        while !env.finished() {
            let (a, _, _) = self.select(&env.state(), &mut rng, true)?;
            env.step(a);
        }
        Ok(())
    }
}
