//! PTOM — the PPO-based task-offloading baseline (§6.1).
//!
//! A single agent observes the *global* environment state, samples one
//! of the M servers per user, and learns with the clipped surrogate
//! objective.  No HiCut layout optimization, no R_sp shaping — exactly
//! the paper's comparison configuration (same network sizes as DRLGO).
//!
//! The math lives in two runtime artifacts (native kernels by
//! default, PJRT under `--features xla`): `ppo_fwd` (logits + value)
//! and `ppo_train` (one clipped-surrogate epoch on a fixed horizon of
//! 256 steps).  GAE(γ = 0.99, λ = 0.95) is computed host-side.  On a
//! dynamic-batch backend one `ppo_fwd` call covers all E slots of a
//! [`VecEnv`] selection round.
//!
//! Training consumes **vectorized rollouts** ([`PpoTrainer::train`] /
//! [`PpoTrainer::train_vec`]): E episode slots of a [`VecEnv`] step
//! together, one policy-selection round per vector step, while each
//! slot fills its *own* horizon buffer — GAE's recurrence runs over a
//! single trajectory, so interleaving slots into one buffer would
//! corrupt the advantages.  E = 1 reproduces the classic loop.

use std::sync::Arc;

use anyhow::Context;

use crate::runtime::{mat, mat_scalar, Executable, Runtime};
use crate::tensor::Matrix;
use crate::util::rng::Rng;

use super::env::Env;
use super::maddpg::EpisodeStats;
use super::vec_env::VecEnv;

#[derive(Clone, Debug)]
pub struct PpoConfig {
    pub episodes: usize,
    /// Train epochs per collected horizon.
    pub epochs: usize,
    pub gamma: f64,
    pub lam: f64,
    pub churn: bool,
    /// Parallel episode slots per vector step (`--envs`; 1 = the
    /// classic single-episode loop).
    pub envs: usize,
    /// Scenario-diversity spec (`--scenarios`; see
    /// [`crate::scenario::set`]): `None`/`"replicate"` clones one
    /// sampled scenario into every slot, any other spec generates a
    /// [`crate::scenario::ScenarioSet`] and gives each slot its own
    /// topology.
    pub scenarios: Option<String>,
    pub seed: u64,
}

impl Default for PpoConfig {
    fn default() -> Self {
        PpoConfig {
            episodes: 150,
            epochs: 4,
            gamma: 0.99,
            lam: 0.95,
            churn: true,
            envs: 1,
            scenarios: None,
            seed: 0x990,
        }
    }
}

/// Rollout storage for one horizon.
#[derive(Default)]
struct Rollout {
    states: Vec<f32>,  // [T * STATE]
    actions: Vec<usize>,
    logps: Vec<f32>,
    values: Vec<f32>,
    rewards: Vec<f32>,
    dones: Vec<f32>,
}

impl Rollout {
    fn len(&self) -> usize {
        self.actions.len()
    }

    fn clear(&mut self) {
        self.states.clear();
        self.actions.clear();
        self.logps.clear();
        self.values.clear();
        self.rewards.clear();
        self.dones.clear();
    }
}

pub struct PpoTrainer<'rt> {
    fwd: Arc<Executable>,
    train_exe: Arc<Executable>,
    pub state_dim: usize,
    pub actions: usize,
    pub horizon: usize,
    params: Vec<f32>,
    m_p: Vec<f32>,
    v_p: Vec<f32>,
    step: f32,
    _rt: std::marker::PhantomData<&'rt Runtime>,
}

impl<'rt> PpoTrainer<'rt> {
    pub fn new(rt: &'rt Runtime) -> crate::Result<Self> {
        let fwd = rt.load("ppo_fwd")?;
        let train_exe = rt.load("ppo_train")?;
        let state_dim = rt.manifest.constant("state_dim")?;
        let actions = rt.manifest.constant("m_agents")?;
        let horizon = rt.manifest.constant("batch")?;
        let p_ppo = rt.manifest.constant("p_ppo")?;
        let init = rt.load_archive("drl/drl_init.gta")?;
        let params = init.get_shaped("ppo", &[p_ppo])?.f32_data.clone();
        Ok(PpoTrainer {
            fwd,
            train_exe,
            state_dim,
            actions,
            horizon,
            m_p: vec![0.0; params.len()],
            v_p: vec![0.0; params.len()],
            params,
            step: init.get("ppo_step")?.f32_data[0],
            _rt: std::marker::PhantomData,
        })
    }

    /// Softmax-sample (or argmax) one action from a logits row.
    fn pick(&self, logits: &[f32], value: f32, rng: &mut Rng, greedy: bool) -> (usize, f32, f32) {
        // Softmax (stable).
        let mx = logits.iter().cloned().fold(f32::MIN, f32::max);
        let exps: Vec<f32> = logits.iter().map(|&l| (l - mx).exp()).collect();
        let z: f32 = exps.iter().sum();
        let probs: Vec<f32> = exps.iter().map(|&e| e / z).collect();
        let action = if greedy {
            // total_cmp: NaN logits (diverged policy) must not panic
            // the evaluation rollout.
            probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0
        } else {
            let mut u = rng.f32();
            let mut a = self.actions - 1;
            for (i, &pr) in probs.iter().enumerate() {
                if u < pr {
                    a = i;
                    break;
                }
                u -= pr;
            }
            a
        };
        (action, probs[action].max(1e-12).ln(), value)
    }

    /// Sample an action from the categorical policy; returns
    /// (action, log-prob, value).
    pub fn select(
        &self,
        state: &[f32],
        rng: &mut Rng,
        greedy: bool,
    ) -> crate::Result<(usize, f32, f32)> {
        let p = mat(&[self.params.len()], self.params.clone())?;
        let s = mat(&[1, self.state_dim], state.to_vec())?;
        let out = self.fwd.run(&[&p, &s])?;
        let logits = &out[0].data;
        let value = out[1].data[0];
        Ok(self.pick(logits, value, rng, greedy))
    }

    /// Sample actions for all E slots of a batch state matrix in one
    /// round; returns per-slot `(action, log-prob, value)`.  On a
    /// dynamic-batch backend (native) this is a single `ppo_fwd` call
    /// over the `[E, STATE]` matrix; fixed-shape backends fall back
    /// to one forward per slot.
    pub fn select_batch(
        &self,
        states: &[f32],
        envs: usize,
        rng: &mut Rng,
        greedy: bool,
    ) -> crate::Result<Vec<(usize, f32, f32)>> {
        anyhow::ensure!(
            states.len() == envs * self.state_dim,
            "batch states {} != {envs} slots x {}",
            states.len(),
            self.state_dim
        );
        if !self.fwd.dynamic_batch() {
            let mut out = Vec::with_capacity(envs);
            for i in 0..envs {
                let s = &states[i * self.state_dim..(i + 1) * self.state_dim];
                out.push(self.select(s, rng, greedy)?);
            }
            return Ok(out);
        }
        let p = mat(&[self.params.len()], self.params.clone())?;
        let s = mat(&[envs, self.state_dim], states.to_vec())?;
        let out = self.fwd.run(&[&p, &s])?;
        let (logits, values) = (&out[0], &out[1]);
        anyhow::ensure!(
            logits.rows == envs && values.data.len() == envs,
            "ppo_fwd batch output {}x{} / {}",
            logits.rows,
            logits.cols,
            values.data.len()
        );
        Ok((0..envs)
            .map(|i| {
                let row = &logits.data[i * logits.cols..(i + 1) * logits.cols];
                self.pick(row, values.data[i], rng, greedy)
            })
            .collect())
    }

    /// Run one PPO update over a filled horizon buffer (consumed).
    fn update(
        &mut self,
        roll: &mut Rollout,
        epochs: usize,
        gamma: f64,
        lam: f64,
        last_value: f32,
    ) -> crate::Result<(f64, f64)> {
        let t = roll.len();
        debug_assert_eq!(t, self.horizon);
        // GAE advantages + returns.
        let mut adv = vec![0.0f32; t];
        let mut ret = vec![0.0f32; t];
        let mut gae = 0.0f64;
        for i in (0..t).rev() {
            let next_v = if i + 1 < t {
                // value bootstrap is zeroed across episode boundaries
                if roll.dones[i] > 0.5 {
                    0.0
                } else {
                    roll.values[i + 1] as f64
                }
            } else if roll.dones[i] > 0.5 {
                0.0
            } else {
                last_value as f64
            };
            let nonterminal = if roll.dones[i] > 0.5 { 0.0 } else { 1.0 };
            let delta = roll.rewards[i] as f64 + gamma * next_v - roll.values[i] as f64;
            gae = delta + gamma * lam * nonterminal * gae;
            adv[i] = gae as f32;
            ret[i] = adv[i] + roll.values[i];
        }
        // Normalize advantages.
        let mean = adv.iter().sum::<f32>() / t as f32;
        let var = adv.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / t as f32;
        let std = var.sqrt().max(1e-6);
        for a in &mut adv {
            *a = (*a - mean) / std;
        }
        let mut onehot = vec![0.0f32; t * self.actions];
        for (i, &a) in roll.actions.iter().enumerate() {
            onehot[i * self.actions + a] = 1.0;
        }
        let (mut pl, mut vl) = (0.0, 0.0);
        for _ in 0..epochs {
            let inputs = vec![
                mat(&[self.params.len()], self.params.clone())?,
                mat(&[self.params.len()], self.m_p.clone())?,
                mat(&[self.params.len()], self.v_p.clone())?,
                mat_scalar(self.step),
                mat(&[t, self.state_dim], roll.states.clone())?,
                mat(&[t, self.actions], onehot.clone())?,
                mat(&[t], roll.logps.clone())?,
                mat(&[t], adv.clone())?,
                mat(&[t], ret.clone())?,
            ];
            let refs: Vec<&Matrix> = inputs.iter().collect();
            let out = self.train_exe.run(&refs)?;
            anyhow::ensure!(out.len() == 7, "ppo_train returned {} outputs", out.len());
            let mut out = out.into_iter().map(|o| o.data);
            let mut next = || out.next().context("ppo_train output missing");
            self.params = next()?;
            self.m_p = next()?;
            self.v_p = next()?;
            self.step = next()?[0];
            pl = next()?[0] as f64;
            vl = next()?[0] as f64;
        }
        roll.clear();
        Ok((pl, vl))
    }

    /// Full training: episodes over a (churning) environment.  Builds
    /// the `cfg.envs`-slot vector via [`VecEnv::for_training`]
    /// (replicate mode, or one generated scenario per slot when
    /// `cfg.scenarios` holds a spec), trains via
    /// [`PpoTrainer::train_vec`], and leaves `env` holding slot 0's
    /// final scenario.
    pub fn train(&mut self, env: &mut Env, cfg: &PpoConfig) -> crate::Result<Vec<EpisodeStats>> {
        let mut venv =
            VecEnv::for_training(env, cfg.envs.max(1), cfg.scenarios.as_deref(), cfg.seed)?;
        let curve = self.train_vec(&mut venv, cfg)?;
        *env = venv.into_first();
        Ok(curve)
    }

    /// The vectorized training loop: one policy-selection round per
    /// vector step; each slot fills its own horizon buffer and updates
    /// independently when full (GAE runs over one trajectory).  Runs
    /// until `cfg.episodes` episodes completed across the batch.
    pub fn train_vec(
        &mut self,
        venv: &mut VecEnv,
        cfg: &PpoConfig,
    ) -> crate::Result<Vec<EpisodeStats>> {
        anyhow::ensure!(
            venv.state_dim() == self.state_dim,
            "vec env state width {} != manifest state_dim {}",
            venv.state_dim(),
            self.state_dim
        );
        let mut rng = Rng::seed_from(cfg.seed);
        venv.set_churn(cfg.churn);
        venv.reset_all();
        let e = venv.len();
        let sd = self.state_dim;
        let mut rolls: Vec<Rollout> = (0..e).map(|_| Rollout::default()).collect();
        let mut ep_reward = vec![0.0f64; e];
        let mut ep_steps = vec![0usize; e];
        let mut curve: Vec<EpisodeStats> = Vec::with_capacity(cfg.episodes);
        let mut states = venv.states();
        while curve.len() < cfg.episodes {
            let picked = self.select_batch(&states, e, &mut rng, false)?;
            let servers: Vec<usize> = picked.iter().map(|p| p.0).collect();
            let results = venv.step_servers(&servers);
            for i in 0..e {
                let res = &results[i];
                let (a, logp, v) = picked[i];
                let r: f64 = res.outcome.rewards.iter().sum();
                ep_reward[i] += r;
                ep_steps[i] += 1;
                let roll = &mut rolls[i];
                roll.states.extend_from_slice(&states[i * sd..(i + 1) * sd]);
                roll.actions.push(a);
                roll.logps.push(logp);
                roll.values.push(v);
                roll.rewards.push(r as f32);
                roll.dones.push(res.outcome.finished as u8 as f32);
                if res.reset {
                    let stats = EpisodeStats {
                        episode: curve.len(),
                        reward: ep_reward[i],
                        system_cost: res.terminal_cost,
                        critic_loss: 0.0,
                        actor_loss: 0.0,
                        steps: ep_steps[i],
                        drift: venv.env(i).layout_maintenance_stats(0).2,
                    };
                    stats.record(i);
                    log::debug!(
                        "ppo ep {} (slot {i}): reward {:.3}",
                        stats.episode,
                        stats.reward
                    );
                    curve.push(stats);
                    ep_reward[i] = 0.0;
                    ep_steps[i] = 0;
                }
            }
            // Horizon-boundary updates, bootstrapping from the
            // post-step (pre-reset) state of the same vector step.
            for i in 0..e {
                if rolls[i].len() == self.horizon {
                    let res = &results[i];
                    let last_v = if res.outcome.finished {
                        0.0
                    } else {
                        self.select(&res.next_state, &mut rng, false)?.2
                    };
                    let mut roll = std::mem::take(&mut rolls[i]);
                    self.update(&mut roll, cfg.epochs, cfg.gamma, cfg.lam, last_v)?;
                }
            }
            states = venv.states();
        }
        curve.truncate(cfg.episodes);
        Ok(curve)
    }

    /// Greedy policy rollout for evaluation.
    pub fn policy_offload(&mut self, env: &mut Env) -> crate::Result<()> {
        let mut rng = Rng::seed_from(0);
        env.reset();
        while !env.finished() {
            let (a, _, _) = self.select(&env.state(), &mut rng, true)?;
            env.step(a);
        }
        Ok(())
    }
}
