//! Vectorized multi-episode environments: E independent episodes
//! stepped as a batch — of one shared scenario
//! ([`VecEnv::replicate`]) or of E *distinct* scenarios
//! ([`VecEnv::from_scenario_set`]).
//!
//! DRLGO (Algorithm 2) trains one episode at a time, which leaves the
//! learner idle between gradient steps and samples every transition
//! from a single churn trajectory.  [`VecEnv`] runs `E` *episode
//! slots* instead:
//!
//! * in **replicate mode** the scenario is shared immutably — every
//!   slot starts from a clone of the same dataset sample, edge
//!   topology, link draws and system parameters, so the batch trains
//!   one policy on one problem instance;
//! * in **scenario-diversity mode** each slot owns its *own*
//!   generated [`crate::scenario::Scenario`] — its own graph, user
//!   count, positions, bandwidth and CPU-rate draws — so one policy
//!   trains across heterogeneous topologies (the generalization §5's
//!   dynamic-adaptation claim rests on).  The only cross-slot
//!   invariant is the agent count M (fixed by
//!   [`crate::net::params::SystemParams`]; asserted at construction):
//!   the batch state stays one dense `E × M × OBS` matrix with **no
//!   padding and no masked rows**, because state rows are per-*server*
//!   and per-slot user counts surface only as episode lengths (see
//!   the padding/masking contract in [`crate::scenario`]);
//! * each slot owns an **independent churn stream** — slot `i`'s RNG
//!   is the `i`-th [`Rng::fork`] of `Rng::seed_from(seed)` — so after
//!   the first auto-reset replicated slots diverge into E distinct
//!   dynamic trajectories (and diverse slots churn their own
//!   scenarios independently);
//! * stepping **fans out across worker threads** via
//!   [`ThreadPool::map_scoped_mut`]: each slot is visited by exactly
//!   one worker with exclusive access, so rollouts are deterministic
//!   and *worker-count invariant* (`tests/properties.rs` proves both,
//!   plus that an `E = 1` vector is trajectory-identical to a plain
//!   [`Env`]);
//! * finished episodes **auto-reset** (churn via the slot stream, then
//!   `reset`), so the batch never shrinks mid-rollout — the
//!   [`VecStep`] returned for the boundary step carries the terminal
//!   state and evaluated system cost from *before* the reset.
//!
//! [`VecEnv::states`] assembles the batch state as one `E × M × OBS`
//! row-major matrix (slot-major, then agent, then feature), which is
//! exactly the layout the batched `select_actions` paths in
//! [`crate::drl::maddpg`] / [`crate::drl::ppo`] slice per slot.
//!
//! Sharing/invalidation rules are the per-slot ones documented in
//! [`crate::drl::env`]: a slot's observation caches are refreshed by
//! its own `mutate`/`recut`/`reset` and are untouchable by siblings —
//! there is no cross-slot mutable state at all.

use crate::drl::env::{Env, EnvConfig, StepOutcome, OBS};
use crate::graph::geb::Dataset;
use crate::net::cost::CostBreakdown;
use crate::net::params::SystemParams;
use crate::partition::incremental::IncrementalConfig;
use crate::scenario::ScenarioSet;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;
use crate::util::trace;

/// One slot's result of a vector step.
#[derive(Clone, Debug)]
pub struct VecStep {
    /// The underlying environment step.
    pub outcome: StepOutcome,
    /// State after the step and *before* any auto-reset — the `s2` of
    /// the transition this step generated.
    pub next_state: Vec<f32>,
    /// The episode finished and the slot auto-reset (churn + reset).
    pub reset: bool,
    /// Evaluated total system cost of the completed offload; only
    /// meaningful when `reset` is true.
    pub terminal_cost: f64,
}

/// One episode slot: an environment plus its private churn stream.
struct Slot {
    env: Env,
    rng: Rng,
    episodes: usize,
}

/// A pool of E independent episodes — replicated from one shared
/// scenario, or each over its own generated scenario (same M).
pub struct VecEnv {
    slots: Vec<Slot>,
    /// Worker threads for per-slot fan-out (1 = caller's thread).
    workers: usize,
    /// Churn the slot's scenario on every auto-reset (dynamic
    /// training, Fig. 11); off = replay the same static episode.
    churn: bool,
}

impl VecEnv {
    /// Replicate a prototype environment into `envs` episode slots.
    ///
    /// Slot `i` starts from a clone of `proto` and owns the `i`-th
    /// [`Rng::fork`] of `Rng::seed_from(seed)` as its churn stream —
    /// the rule the E=1 equivalence property in `tests/properties.rs`
    /// pins down.
    pub fn replicate(proto: &Env, envs: usize, seed: u64) -> Self {
        Self::from_envs((0..envs).map(|_| proto.clone()).collect(), seed)
    }

    /// Wrap pre-built environments — one per slot, possibly of
    /// *different* scenarios (graphs, user counts, link draws).  Slot
    /// `i` owns the `i`-th [`Rng::fork`] of `Rng::seed_from(seed)` as
    /// its churn stream, exactly as in [`VecEnv::replicate`].  All
    /// slots must share the agent count M (the batch-matrix width).
    pub fn from_envs(envs: Vec<Env>, seed: u64) -> Self {
        assert!(!envs.is_empty(), "vector env needs at least one episode slot");
        let m = envs[0].agents();
        for (i, env) in envs.iter().enumerate() {
            assert_eq!(
                env.agents(),
                m,
                "slot {i} has {} agents, slot 0 has {m}: scenario sets must share M",
                env.agents()
            );
        }
        let mut seeder = Rng::seed_from(seed);
        let slots = envs
            .into_iter()
            .map(|env| Slot { env, rng: seeder.fork(), episodes: 0 })
            .collect();
        VecEnv { slots, workers: 1, churn: true }
    }

    /// Build a scenario-diverse vector: slot `i` gets its own
    /// environment from the set's train split (round-robin over
    /// [`ScenarioSet::train_scenario`]).  Environment construction —
    /// including each slot's initial HiCut — fans out over
    /// `build_workers` threads of the shared [`ThreadPool`] machinery;
    /// construction is deterministic, so the result is identical for
    /// every worker count.  `cfg` supplies the behavioral knobs
    /// (`use_hicut`, `use_rsp`, churn, …); each slot's user/assoc
    /// counts come from its scenario (see [`Env::from_scenario`]).
    pub fn from_scenario_set(
        set: &ScenarioSet,
        cfg: &EnvConfig,
        envs: usize,
        seed: u64,
        build_workers: usize,
    ) -> Self {
        assert!(envs >= 1, "vector env needs at least one episode slot");
        let picks: Vec<&crate::scenario::Scenario> =
            (0..envs).map(|i| set.train_scenario(i)).collect();
        Self::from_scenarios(&picks, cfg, seed, build_workers)
    }

    /// One slot per scenario, built in parallel — the shared
    /// construction fan-out behind [`VecEnv::from_scenario_set`]
    /// (train split) and
    /// [`crate::drl::baselines::run_greedy_eval_set`] (eval split).
    pub fn from_scenarios(
        scenarios: &[&crate::scenario::Scenario],
        cfg: &EnvConfig,
        seed: u64,
        build_workers: usize,
    ) -> Self {
        let built = ThreadPool::map_scoped(scenarios, build_workers.max(1), |sc| {
            Env::from_scenario(sc, cfg.clone())
        });
        Self::from_envs(built, seed)
    }

    /// The training loops' entry point: `replicate` mode (`None`,
    /// empty, or the literal `"replicate"`) clones `proto` into every
    /// slot — bit-identical to the pre-scenario-subsystem behavior —
    /// while any other spec string (see [`crate::scenario::set`])
    /// generates a [`ScenarioSet`] of exactly `envs` train scenarios
    /// (no held-out split: training never reads it — callers that
    /// want a holdout build their own set via
    /// [`ScenarioSet::from_spec`], whose train scenarios are identical
    /// because eval forks come after the train forks) and gives each
    /// slot its own scenario.
    pub fn for_training(
        proto: &Env,
        envs: usize,
        scenarios: Option<&str>,
        seed: u64,
    ) -> crate::Result<Self> {
        match scenarios.map(str::trim) {
            None | Some("") | Some("replicate") => Ok(Self::replicate(proto, envs, seed)),
            Some(spec) => {
                let specs = crate::scenario::parse_spec_list(
                    spec,
                    proto.cfg.n_users,
                    proto.cfg.n_assocs,
                )?;
                let set = ScenarioSet::generate(&specs, &proto.params, envs.max(1), 0, seed);
                // Salt the churn seeding (cf. `VecEnv::new`): with the
                // raw seed, slot i's churn stream would be the same
                // fork that just generated scenario i.
                let churn_seed = seed ^ 0x5CEA_A105;
                // Construction is worker-count invariant, so default
                // to one build worker per slot (each slot's initial
                // HiCut is the dominant cost); an explicit env-level
                // worker count still wins.
                let build_workers = if proto.workers > 1 { proto.workers } else { envs };
                Ok(Self::from_scenario_set(&set, &proto.cfg, envs, churn_seed, build_workers))
            }
        }
    }

    /// Build a fresh prototype from a dataset sample and replicate it
    /// (`Env::new` + [`VecEnv::replicate`] with a salted churn seed).
    pub fn new(
        dataset: &Dataset,
        params: SystemParams,
        cfg: EnvConfig,
        envs: usize,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::seed_from(seed);
        let proto = Env::new(dataset, params, cfg, &mut rng);
        Self::replicate(&proto, envs, seed ^ 0x5EED_C0DE)
    }

    /// Number of episode slots E.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Agents per slot (M; identical across slots by construction).
    pub fn agents(&self) -> usize {
        self.slots[0].env.agents()
    }

    /// Per-slot state width (M·OBS) — one row of the batch matrix.
    pub fn state_dim(&self) -> usize {
        self.agents() * OBS
    }

    /// Set the fan-out worker count (`0` = one worker per slot).  The
    /// rollout is identical for every value; this only changes how the
    /// slots are spread over threads.
    pub fn set_workers(&mut self, workers: usize) {
        self.workers = if workers == 0 {
            self.slots.len()
        } else {
            workers.max(1)
        };
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Churn each slot's scenario on auto-reset (default on).
    pub fn set_churn(&mut self, churn: bool) {
        self.churn = churn;
    }

    /// Completed episodes across all slots.
    pub fn episodes_completed(&self) -> usize {
        self.slots.iter().map(|s| s.episodes).sum()
    }

    pub fn env(&self, i: usize) -> &Env {
        &self.slots[i].env
    }

    pub fn env_mut(&mut self, i: usize) -> &mut Env {
        &mut self.slots[i].env
    }

    /// Unwrap slot 0's environment (hand the trained-on scenario back
    /// to single-env consumers like `run_scenario`).
    pub fn into_first(self) -> Env {
        self.slots.into_iter().next().expect("at least one slot").env
    }

    /// Switch every slot to delta-driven layout maintenance (see
    /// [`Env::enable_incremental`]); the maintenance observation slots
    /// start reporting per-slot repair telemetry.
    pub fn enable_incremental(&mut self, cfg: IncrementalConfig) {
        for slot in &mut self.slots {
            slot.env.enable_incremental(cfg.clone());
        }
    }

    /// Start a fresh episode in every slot (no churn).
    pub fn reset_all(&mut self) {
        for slot in &mut self.slots {
            slot.env.reset();
        }
    }

    /// Assemble the batch state: an `E × M × OBS` row-major matrix,
    /// slot-major.  One O(M·OBS) copy per slot off the per-slot
    /// observation engines.
    pub fn states(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.slots.len() * self.state_dim());
        for slot in &self.slots {
            slot.env.state_into(&mut out);
        }
        out
    }

    /// Step every slot with a joint per-agent action (Eq. 22 decode),
    /// one action matrix per slot.
    pub fn step(&mut self, actions: &[Vec<[f32; 2]>]) -> Vec<VecStep> {
        assert_eq!(actions.len(), self.slots.len(), "one joint action per slot");
        self.step_with(|i, env| env.decode_action(&actions[i]))
    }

    /// Step every slot with an already-chosen server index (the PTOM
    /// path; capacity redirects still apply inside [`Env::step`]).
    pub fn step_servers(&mut self, servers: &[usize]) -> Vec<VecStep> {
        assert_eq!(servers.len(), self.slots.len(), "one server per slot");
        self.step_with(|i, _| servers[i])
    }

    /// The per-slot step body, fanned out across the worker threads:
    /// pick a server, step, capture the post-step state, and auto-reset
    /// finished episodes (churning through the slot's private stream
    /// when enabled).  All randomness lives in the slot, so the result
    /// is independent of the worker count.
    fn step_with(&mut self, pick: impl Fn(usize, &Env) -> usize + Sync) -> Vec<VecStep> {
        let churn = self.churn;
        let _step_span =
            trace::span_with("vec_env.step", &[("envs", self.slots.len() as f64)]);
        ThreadPool::map_scoped_mut(&mut self.slots, self.workers, |i, slot| {
            // Worker-thread spans are roots of their own thread's
            // stream; `vec_env.step` on the caller brackets them in
            // time, not by parent id.
            let _slot_span =
                trace::span_with("vec_env.slot_step", &[("slot", i as f64)]);
            if slot.env.finished() {
                // Degenerate guard: a slot whose episode emptied out
                // (e.g. churn removed every active user) resettles
                // instead of panicking the whole batch.
                if churn {
                    slot.env.mutate(&mut slot.rng);
                }
                slot.env.reset();
            }
            let server = pick(i, &slot.env);
            let outcome = slot.env.step(server);
            let next_state = slot.env.state();
            let mut reset = false;
            let mut terminal_cost = 0.0;
            if outcome.finished {
                terminal_cost = slot.env.evaluate().total();
                slot.episodes += 1;
                if churn {
                    slot.env.mutate(&mut slot.rng);
                }
                slot.env.reset();
                reset = true;
            }
            VecStep { outcome, next_state, reset, terminal_cost }
        })
    }

    /// Run an arbitrary single-env policy to completion in every slot
    /// concurrently and evaluate the resulting offloads (Eqs. 12–13).
    /// Unlike [`VecEnv::step`] this neither churns nor counts episodes
    /// — it is the batched *evaluation* entry point
    /// ([`crate::drl::baselines::run_greedy_vec`] rides it).
    pub fn evaluate_with(&mut self, policy: impl Fn(usize, &mut Env) + Sync) -> Vec<CostBreakdown> {
        ThreadPool::map_scoped_mut(&mut self.slots, self.workers, |i, slot| {
            policy(i, &mut slot.env);
            slot.env.evaluate()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::drl::env::testutil::{small_env, tiny_dataset};

    fn small_vec(seed: u64, envs: usize) -> VecEnv {
        let proto = small_env(seed);
        VecEnv::replicate(&proto, envs, seed ^ 0xABCD)
    }

    #[test]
    fn replicated_slots_share_the_scenario() {
        let venv = small_vec(41, 3);
        let a = venv.env(0);
        for i in 1..venv.len() {
            let b = venv.env(i);
            assert_eq!(a.order, b.order);
            assert_eq!(a.subgraph_of, b.subgraph_of);
            assert_eq!(a.users.active_count(), b.users.active_count());
        }
        assert_eq!(venv.state_dim(), venv.agents() * OBS);
    }

    #[test]
    fn states_concatenate_slot_states() {
        let venv = small_vec(42, 4);
        let s = venv.states();
        let sd = venv.state_dim();
        assert_eq!(s.len(), 4 * sd);
        for i in 0..4 {
            assert_eq!(&s[i * sd..(i + 1) * sd], &venv.env(i).state()[..]);
        }
    }

    #[test]
    fn auto_reset_keeps_the_batch_full() {
        let mut venv = small_vec(43, 2);
        // Static episodes (no churn) so every episode has exactly
        // `active` steps and the reset count below is exact.
        venv.set_churn(false);
        venv.reset_all();
        let active = venv.env(0).users.active_count();
        let agents = venv.agents();
        let mut resets = 0;
        // Two full episodes' worth of vector steps: every slot must
        // reset exactly twice and never report a finished state.
        for step in 0..2 * active {
            let servers: Vec<usize> = (0..venv.len()).map(|i| (step + i) % agents).collect();
            for res in venv.step_servers(&servers) {
                if res.reset {
                    resets += 1;
                    assert!(res.terminal_cost > 0.0, "terminal cost must be evaluated");
                }
            }
            for i in 0..venv.len() {
                assert!(!venv.env(i).finished(), "auto-reset must refill slot {i}");
            }
        }
        assert_eq!(resets, 2 * venv.len());
        assert_eq!(venv.episodes_completed(), resets);
    }

    #[test]
    fn churned_slots_diverge_after_reset() {
        let mut venv = small_vec(44, 2);
        venv.set_churn(true);
        venv.reset_all();
        let active = venv.env(0).users.active_count();
        for _ in 0..active {
            venv.step_servers(&[0, 0]);
        }
        assert_eq!(venv.episodes_completed(), 2);
        // Distinct churn streams: the slots' scenarios have diverged
        // (different survivors, admissions or at least random-walk
        // positions).
        let fingerprint = |env: &Env| {
            let mut fp: Vec<u64> = Vec::new();
            fp.extend(env.users.active_users().iter().map(|&u| u as u64));
            fp.extend(env.order.iter().map(|&u| u as u64));
            for u in 0..env.users.capacity() {
                let p = env.users.pos(u);
                fp.push(p.x.to_bits());
                fp.push(p.y.to_bits());
            }
            fp
        };
        assert_ne!(
            fingerprint(venv.env(0)),
            fingerprint(venv.env(1)),
            "independent churn streams should diverge the slots"
        );
    }

    #[test]
    fn evaluate_with_runs_policies_in_every_slot() {
        let mut venv = small_vec(45, 3);
        let costs = venv.evaluate_with(|_, env| {
            env.reset();
            while !env.finished() {
                env.step(0);
            }
        });
        assert_eq!(costs.len(), 3);
        for (i, c) in costs.iter().enumerate() {
            assert!(c.total() > 0.0, "slot {i} cost not evaluated");
            assert!(venv.env(i).finished());
        }
    }

    #[test]
    fn scenario_set_slots_hold_distinct_scenarios() {
        use crate::scenario::ScenarioSet;
        let params = SystemParams::default();
        // Two entries with *different user counts*: slots must differ
        // in episode length yet share the batch-matrix width.
        let spec = "uniform@30x60,clustered:3@50x120";
        let set = ScenarioSet::from_spec(spec, 0, 0, &params, 4, 51).unwrap();
        let cfg = EnvConfig { n_users: 0, n_assocs: 0, ..EnvConfig::default() };
        let mut venv = VecEnv::from_scenario_set(&set, &cfg, 4, 52, 1);
        assert_eq!(venv.len(), 4);
        assert_eq!(venv.env(0).users.capacity(), 30);
        assert_eq!(venv.env(1).users.capacity(), 50);
        assert_eq!(venv.env(2).users.capacity(), 30);
        assert_ne!(
            venv.env(0).users.graph().num_edges(),
            venv.env(1).users.graph().num_edges(),
            "slots should hold different graphs"
        );
        // Per-slot cfg mirrors the slot's own scenario.
        assert_eq!(venv.env(0).cfg.n_users, 30);
        assert_eq!(venv.env(1).cfg.n_users, 50);
        // One dense batch matrix, no padding: rows are per-server.
        let sd = venv.state_dim();
        assert_eq!(venv.states().len(), 4 * sd);

        // Mixed-slot stepping with auto-reset: the short slots finish
        // earlier and reset while the long ones keep going.
        venv.set_churn(false);
        venv.reset_all();
        let agents = venv.agents();
        let mut resets = vec![0usize; 4];
        for step in 0..60usize {
            let servers: Vec<usize> = (0..4).map(|i| (step + i) % agents).collect();
            for (i, res) in venv.step_servers(&servers).iter().enumerate() {
                assert_eq!(res.next_state.len(), sd);
                if res.reset {
                    resets[i] += 1;
                }
            }
        }
        // 60 steps = two full episodes of the 30-user slots, one of
        // the 50-user slots (60 / 50 = 1).
        assert_eq!(resets, vec![2, 1, 2, 1]);
    }

    #[test]
    fn for_training_replicate_matches_replicate_bit_for_bit() {
        // The single-scenario mode of the training entry point must be
        // indistinguishable from the pre-scenario-subsystem replicate.
        let proto = small_env(61);
        for spec in [None, Some(""), Some("replicate")] {
            let mut a = VecEnv::for_training(&proto, 2, spec, 0x5E).unwrap();
            let mut b = VecEnv::replicate(&proto, 2, 0x5E);
            a.reset_all();
            b.reset_all();
            for step in 0..40usize {
                let servers = vec![step % a.agents(); 2];
                let ra = a.step_servers(&servers);
                let rb = b.step_servers(&servers);
                for (x, y) in ra.iter().zip(&rb) {
                    assert_eq!(x.outcome.assigned, y.outcome.assigned);
                    assert_eq!(x.reset, y.reset);
                    assert_eq!(x.next_state, y.next_state);
                }
            }
        }
    }

    #[test]
    fn for_training_spec_builds_a_diverse_vector() {
        let proto = small_env(62);
        let venv = VecEnv::for_training(&proto, 4, Some("mixed"), 0x5F).unwrap();
        assert_eq!(venv.len(), 4);
        assert_eq!(venv.agents(), proto.agents());
        // Generated slots, not clones of the prototype.
        let (e0, e1) = (venv.env(0), venv.env(1));
        assert_ne!(e0.users.graph().num_edges(), e1.users.graph().num_edges());
        assert!(VecEnv::for_training(&proto, 2, Some("warp-drive"), 1).is_err());
    }

    #[test]
    fn new_builds_from_a_dataset_sample() {
        let ds = tiny_dataset(200);
        let cfg = EnvConfig { n_users: 30, n_assocs: 60, ..EnvConfig::default() };
        let mut venv = VecEnv::new(&ds, SystemParams::default(), cfg, 2, 46);
        venv.set_workers(0);
        assert_eq!(venv.workers(), 2);
        venv.reset_all();
        let res = venv.step_servers(&[0, 1]);
        assert_eq!(res.len(), 2);
        assert_eq!(res[0].next_state.len(), venv.state_dim());
    }
}
