//! Experience replay buffer D (§5.3).
//!
//! Stores flattened MAMDP transitions and samples uniform mini-batches
//! as contiguous f32 blocks ready to become PJRT literals.

use crate::util::rng::Rng;

/// One transition, flattened (lengths fixed by the environment).
#[derive(Clone, Debug)]
pub struct Transition {
    pub s: Vec<f32>,     // [STATE]
    pub a: Vec<f32>,     // [M*ACT]
    pub r: Vec<f32>,     // [M]
    pub s2: Vec<f32>,    // [STATE]
    pub done: Vec<f32>,  // [M]
    pub obs: Vec<f32>,   // [M*OBS]
    pub obs2: Vec<f32>,  // [M*OBS]
}

/// Ring-buffer replay store.
pub struct Replay {
    cap: usize,
    buf: Vec<Transition>,
    next: usize,
}

/// A sampled batch, already laid out for the train-step literals.
pub struct Batch {
    pub s: Vec<f32>,
    pub a: Vec<f32>,
    pub r: Vec<f32>,
    pub s2: Vec<f32>,
    pub done: Vec<f32>,
    pub obs: Vec<f32>,
    pub obs2: Vec<f32>,
    pub len: usize,
}

impl Replay {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Replay { cap, buf: Vec::with_capacity(cap.min(4096)), next: 0 }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn push(&mut self, t: Transition) {
        if self.buf.len() < self.cap {
            self.buf.push(t);
        } else {
            self.buf[self.next] = t;
            self.next = (self.next + 1) % self.cap;
        }
    }

    /// Uniform sample with replacement of `batch` transitions.
    pub fn sample(&self, batch: usize, rng: &mut Rng) -> Batch {
        assert!(!self.buf.is_empty(), "sampling empty replay buffer");
        let mut out = Batch {
            s: Vec::with_capacity(batch * self.buf[0].s.len()),
            a: Vec::with_capacity(batch * self.buf[0].a.len()),
            r: Vec::with_capacity(batch * self.buf[0].r.len()),
            s2: Vec::with_capacity(batch * self.buf[0].s2.len()),
            done: Vec::with_capacity(batch * self.buf[0].done.len()),
            obs: Vec::with_capacity(batch * self.buf[0].obs.len()),
            obs2: Vec::with_capacity(batch * self.buf[0].obs2.len()),
            len: batch,
        };
        for _ in 0..batch {
            let t = &self.buf[rng.below(self.buf.len())];
            out.s.extend_from_slice(&t.s);
            out.a.extend_from_slice(&t.a);
            out.r.extend_from_slice(&t.r);
            out.s2.extend_from_slice(&t.s2);
            out.done.extend_from_slice(&t.done);
            out.obs.extend_from_slice(&t.obs);
            out.obs2.extend_from_slice(&t.obs2);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: f32) -> Transition {
        Transition {
            s: vec![v; 4],
            a: vec![v; 2],
            r: vec![v; 2],
            s2: vec![v; 4],
            done: vec![0.0; 2],
            obs: vec![v; 6],
            obs2: vec![v; 6],
        }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut r = Replay::new(3);
        for i in 0..5 {
            r.push(t(i as f32));
        }
        assert_eq!(r.len(), 3);
        // Contents are {2,3,4} in some ring order.
        let vals: std::collections::HashSet<i32> = r.buf.iter().map(|x| x.s[0] as i32).collect();
        assert_eq!(vals, [2, 3, 4].into_iter().collect());
    }

    #[test]
    fn sample_shapes() {
        let mut r = Replay::new(10);
        for i in 0..10 {
            r.push(t(i as f32));
        }
        let mut rng = Rng::seed_from(0);
        let b = r.sample(32, &mut rng);
        assert_eq!(b.len, 32);
        assert_eq!(b.s.len(), 32 * 4);
        assert_eq!(b.a.len(), 32 * 2);
        assert_eq!(b.obs.len(), 32 * 6);
    }

    #[test]
    #[should_panic(expected = "empty replay")]
    fn sample_empty_panics() {
        let r = Replay::new(4);
        let mut rng = Rng::seed_from(0);
        r.sample(1, &mut rng);
    }

    #[test]
    fn sample_batch_larger_than_len_resamples() {
        // Uniform sampling is with replacement: a batch bigger than
        // the store must still fill completely, drawing only stored
        // transitions.
        let mut r = Replay::new(16);
        for i in 0..3 {
            r.push(t(i as f32));
        }
        let mut rng = Rng::seed_from(7);
        let b = r.sample(10, &mut rng);
        assert_eq!(b.len, 10);
        assert_eq!(b.s.len(), 10 * 4);
        for chunk in b.s.chunks(4) {
            assert!((0.0..=2.0).contains(&chunk[0]), "sampled unknown value");
        }
    }

    #[test]
    fn eviction_is_fifo_oldest_first() {
        let mut r = Replay::new(4);
        for i in 0..4 {
            r.push(t(i as f32));
        }
        // One over capacity: exactly transition 0 must be evicted.
        r.push(t(4.0));
        let vals: std::collections::HashSet<i32> = r.buf.iter().map(|x| x.s[0] as i32).collect();
        assert_eq!(vals, [1, 2, 3, 4].into_iter().collect());
        // Two more: 1 and 2 go next, in order.
        r.push(t(5.0));
        r.push(t(6.0));
        let vals: std::collections::HashSet<i32> = r.buf.iter().map(|x| x.s[0] as i32).collect();
        assert_eq!(vals, [3, 4, 5, 6].into_iter().collect());
        assert_eq!(r.len(), 4);
    }

    #[test]
    fn sampling_is_deterministic_under_a_fixed_seed() {
        let mut r = Replay::new(32);
        for i in 0..20 {
            r.push(t(i as f32));
        }
        let mut rng_a = Rng::seed_from(0xD5);
        let mut rng_b = Rng::seed_from(0xD5);
        let a = r.sample(64, &mut rng_a);
        let b = r.sample(64, &mut rng_b);
        assert_eq!(a.s, b.s);
        assert_eq!(a.a, b.a);
        assert_eq!(a.obs2, b.obs2);
        // A different stream (almost surely) draws a different batch.
        let mut rng_c = Rng::seed_from(0xD6);
        let c = r.sample(64, &mut rng_c);
        assert_ne!(a.s, c.s, "independent seeds produced identical batches");
    }
}
