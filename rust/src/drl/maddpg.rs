//! DRLGO — the MADDPG-based graph offloading trainer (Algorithm 2).
//!
//! The trainer owns host-side copies of every agent's parameters and
//! Adam state; the actual math is two runtime artifacts (native
//! kernels by default, PJRT under `--features xla`):
//!
//! * `actor_fwd`  — π_m(O_m) for all M agents in one call (rollout);
//!   on a dynamic-batch backend one call covers *all E slots* of a
//!   [`VecEnv`] round,
//! * `maddpg_train` — one full update (critic + actor + soft targets)
//!   for all M agents on a replay mini-batch.
//!
//! Exploration follows §6.1's rate of 0.1: Gaussian noise with σ =
//! `explore_sigma` added to actions and clipped to [0, 1].  Each
//! episode first churns the scenario (Algorithm 2 line 8), re-runs
//! HiCut, then offloads users one by one.
//!
//! Training consumes **vectorized rollouts**: [`MaddpgTrainer::train`]
//! replicates the environment into `MaddpgConfig::envs` episode slots
//! (a [`VecEnv`]) and [`MaddpgTrainer::train_vec`] drives one
//! `select_actions` round and at most one `train_step` per *vector*
//! step, pushing the transitions of all E episodes into the shared
//! replay buffer.  Finished slots auto-reset (churn + fresh episode)
//! so the batch never shrinks; E = 1 reproduces the classic
//! one-episode-at-a-time loop.

use std::sync::Arc;

use anyhow::Context;

use crate::runtime::{mat, mat_scalar, Executable, Runtime};
use crate::tensor::{Archive, Matrix, Tensor};
use crate::util::rng::Rng;
use crate::util::trace;

use super::env::{Env, OBS};
use super::replay::{Replay, Transition};
use super::vec_env::VecEnv;

/// Training configuration (defaults follow Table 2 / §6.1).
#[derive(Clone, Debug)]
pub struct MaddpgConfig {
    pub episodes: usize,
    /// Environment steps between train-step executions.
    pub train_every: usize,
    /// Minimum replay size before learning starts.
    pub warmup: usize,
    /// Exploration noise σ (exploration rate 0.1 per §6.1).
    pub explore_sigma: f64,
    pub replay_cap: usize,
    /// Churn the scenario between episodes (dynamic training, Fig. 11).
    pub churn: bool,
    /// Parallel episode slots per vector step (`--envs`; 1 = the
    /// classic single-episode loop).
    pub envs: usize,
    /// Scenario-diversity spec (`--scenarios`; see
    /// [`crate::scenario::set`]): `None`/`"replicate"` clones one
    /// sampled scenario into every slot, any other spec generates a
    /// [`crate::scenario::ScenarioSet`] and gives each slot its own
    /// topology.
    pub scenarios: Option<String>,
    pub seed: u64,
}

impl Default for MaddpgConfig {
    fn default() -> Self {
        MaddpgConfig {
            episodes: 150,
            train_every: 4,
            warmup: 512,
            explore_sigma: 0.1,
            replay_cap: 100_000,
            churn: true,
            envs: 1,
            scenarios: None,
            seed: 0xD71,
        }
    }
}

/// Per-episode training record.
#[derive(Clone, Copy, Debug)]
pub struct EpisodeStats {
    pub episode: usize,
    /// Global reward R = Σ_m R_m accumulated over the episode.
    pub reward: f64,
    /// Final evaluated system cost C of the episode's offload.
    pub system_cost: f64,
    pub critic_loss: f64,
    pub actor_loss: f64,
    pub steps: usize,
    /// Layout drift of the episode's environment at episode end (cut
    /// edges vs the incremental reference; 0 without incremental).
    pub drift: f64,
}

impl EpisodeStats {
    /// Emit this record as a `train.episode` trace instant (the
    /// training-telemetry series; see [`crate::drl::telemetry`]).
    pub fn record(&self, slot: usize) {
        trace::instant(
            "train.episode",
            &[
                ("episode", self.episode as f64),
                ("slot", slot as f64),
                ("reward", self.reward),
                ("system_cost", self.system_cost),
                ("critic_loss", self.critic_loss),
                ("actor_loss", self.actor_loss),
                ("steps", self.steps as f64),
                ("drift", self.drift),
            ],
        );
    }
}

pub struct MaddpgTrainer<'rt> {
    /// Keeps the runtime (and thus the PJRT client) alive for the
    /// lifetime of the cached executables.
    _rt: &'rt Runtime,
    actor_fwd: Arc<Executable>,
    train_exe: Arc<Executable>,
    pub m: usize,
    pub pa: usize,
    pub pc: usize,
    pub batch: usize,
    pub state_dim: usize,
    // Host-side parameter store (flat, row-major [M, P]).
    actor: Vec<f32>,
    critic: Vec<f32>,
    t_actor: Vec<f32>,
    t_critic: Vec<f32>,
    m_a: Vec<f32>,
    v_a: Vec<f32>,
    m_c: Vec<f32>,
    v_c: Vec<f32>,
    step: f32,
    /// Cached actor parameter matrix (rebuilt after each train step).
    actor_mat: Option<Matrix>,
    replay: Replay,
    pub losses: (f64, f64),
}

impl<'rt> MaddpgTrainer<'rt> {
    /// Load executables + initial parameters from the artifacts.
    pub fn new(rt: &'rt Runtime, replay_cap: usize) -> crate::Result<Self> {
        let actor_fwd = rt.load("actor_fwd")?;
        let train_exe = rt.load("maddpg_train")?;
        let m = rt.manifest.constant("m_agents")?;
        let pa = rt.manifest.constant("p_actor")?;
        let pc = rt.manifest.constant("p_critic")?;
        let batch = rt.manifest.constant("batch")?;
        let state_dim = rt.manifest.constant("state_dim")?;
        let obs = rt.manifest.constant("obs_dim")?;
        anyhow::ensure!(obs == OBS, "manifest obs_dim {obs} != env OBS {OBS}");
        let init = rt.load_archive("drl/drl_init.gta")?;
        let take = |name: &str, len: usize| -> crate::Result<Vec<f32>> {
            let t = init.get(name)?;
            anyhow::ensure!(t.f32_data.len() == len, "{name}: {} != {len}", t.f32_data.len());
            Ok(t.f32_data.clone())
        };
        Ok(MaddpgTrainer {
            _rt: rt,
            actor_fwd,
            train_exe,
            m,
            pa,
            pc,
            batch,
            state_dim,
            actor: take("actor", m * pa)?,
            critic: take("critic", m * pc)?,
            t_actor: take("t_actor", m * pa)?,
            t_critic: take("t_critic", m * pc)?,
            m_a: take("m_a", m * pa)?,
            v_a: take("v_a", m * pa)?,
            m_c: take("m_c", m * pc)?,
            v_c: take("v_c", m * pc)?,
            step: init.get("step")?.f32_data[0],
            actor_mat: None,
            replay: Replay::new(replay_cap),
            losses: (0.0, 0.0),
        })
    }

    fn actor_matrix(&mut self) -> crate::Result<&Matrix> {
        if self.actor_mat.is_none() {
            self.actor_mat = Some(mat(&[self.m, self.pa], self.actor.clone())?);
        }
        Ok(self.actor_mat.as_ref().unwrap())
    }

    /// π(O) for all agents; optional exploration noise.
    pub fn select_actions(
        &mut self,
        obs_flat: &[f32],
        sigma: f64,
        rng: &mut Rng,
    ) -> crate::Result<Vec<[f32; 2]>> {
        anyhow::ensure!(obs_flat.len() == self.m * OBS);
        let m = self.m;
        let obs_mat = mat(&[m, OBS], obs_flat.to_vec())?;
        let exe = self.actor_fwd.clone();
        let actor = self.actor_matrix()?;
        let out = exe.run(&[actor, &obs_mat])?;
        let acts = &out[0].data;
        let mut result = Vec::with_capacity(m);
        for i in 0..m {
            let mut a = [acts[2 * i], acts[2 * i + 1]];
            if sigma > 0.0 {
                for v in &mut a {
                    *v = (*v + rng.normal_ms(0.0, sigma) as f32).clamp(0.0, 1.0);
                }
            }
            result.push(a);
        }
        Ok(result)
    }

    /// π(O) for all agents of all E slots in one round: `states` is
    /// the `E × M × OBS` batch matrix a [`VecEnv`] assembles (each
    /// slot's state *is* its concatenated observations, Eq. 19).  On
    /// a dynamic-batch backend (native) the whole round is **one**
    /// `actor_fwd` call over an `[E·M, OBS]` matrix — row r runs
    /// agent `r mod M`, exactly the slot-major layout `states` is
    /// already in; fixed-shape backends fall back to one forward per
    /// slot.
    pub fn select_actions_batch(
        &mut self,
        states: &[f32],
        envs: usize,
        sigma: f64,
        rng: &mut Rng,
    ) -> crate::Result<Vec<Vec<[f32; 2]>>> {
        let per = self.m * OBS;
        anyhow::ensure!(
            states.len() == envs * per,
            "batch states {} != {envs} slots x {per}",
            states.len()
        );
        if !self.actor_fwd.dynamic_batch() {
            let mut out = Vec::with_capacity(envs);
            for i in 0..envs {
                out.push(self.select_actions(&states[i * per..(i + 1) * per], sigma, rng)?);
            }
            return Ok(out);
        }
        let m = self.m;
        let obs_mat = mat(&[envs * m, OBS], states.to_vec())?;
        let exe = self.actor_fwd.clone();
        let actor = self.actor_matrix()?;
        let out = exe.run(&[actor, &obs_mat])?;
        let acts = &out[0].data;
        anyhow::ensure!(acts.len() == envs * m * 2, "actor_fwd batch output {}", acts.len());
        let mut result = Vec::with_capacity(envs);
        for i in 0..envs {
            let mut slot = Vec::with_capacity(m);
            for j in 0..m {
                let base = 2 * (i * m + j);
                let mut a = [acts[base], acts[base + 1]];
                if sigma > 0.0 {
                    for v in &mut a {
                        *v = (*v + rng.normal_ms(0.0, sigma) as f32).clamp(0.0, 1.0);
                    }
                }
                slot.push(a);
            }
            result.push(slot);
        }
        Ok(result)
    }

    /// One MADDPG update on a replay mini-batch (Algorithm 2 l.15–20).
    pub fn train_step(&mut self, rng: &mut Rng) -> crate::Result<(f64, f64)> {
        let b = self.replay.sample(self.batch, rng);
        let m = self.m;
        let inputs = vec![
            mat(&[m, self.pa], self.actor.clone())?,
            mat(&[m, self.pc], self.critic.clone())?,
            mat(&[m, self.pa], self.t_actor.clone())?,
            mat(&[m, self.pc], self.t_critic.clone())?,
            mat(&[m, self.pa], self.m_a.clone())?,
            mat(&[m, self.pa], self.v_a.clone())?,
            mat(&[m, self.pc], self.m_c.clone())?,
            mat(&[m, self.pc], self.v_c.clone())?,
            mat_scalar(self.step),
            mat(&[self.batch, self.state_dim], b.s)?,
            mat(&[self.batch, m, 2], b.a)?,
            mat(&[self.batch, m], b.r)?,
            mat(&[self.batch, self.state_dim], b.s2)?,
            mat(&[self.batch, m], b.done)?,
            mat(&[self.batch, m, OBS], b.obs)?,
            mat(&[self.batch, m, OBS], b.obs2)?,
        ];
        let refs: Vec<&Matrix> = inputs.iter().collect();
        let exe = self.train_exe.clone();
        let out = exe.run(&refs)?;
        anyhow::ensure!(out.len() == 11, "maddpg_train returned {} outputs", out.len());
        let mut out = out.into_iter().map(|o| o.data);
        let mut next = || out.next().context("maddpg_train output missing");
        self.actor = next()?;
        self.critic = next()?;
        self.t_actor = next()?;
        self.t_critic = next()?;
        self.m_a = next()?;
        self.v_a = next()?;
        self.m_c = next()?;
        self.v_c = next()?;
        self.step = next()?[0];
        self.actor_mat = None; // parameters changed
        let closs = next()?;
        let aloss = next()?;
        let c = closs.iter().map(|&x| x as f64).sum::<f64>() / m as f64;
        let a = aloss.iter().map(|&x| x as f64).sum::<f64>() / m as f64;
        self.losses = (c, a);
        Ok((c, a))
    }

    /// Play one episode; optionally explore and learn.
    pub fn run_episode(
        &mut self,
        env: &mut Env,
        cfg: &MaddpgConfig,
        learn: bool,
        rng: &mut Rng,
    ) -> crate::Result<EpisodeStats> {
        env.reset();
        let mut reward = 0.0;
        let mut steps = 0usize;
        let sigma = if learn { cfg.explore_sigma } else { 0.0 };
        // Eq. 19: the global state is exactly the concatenation of the
        // local observations — and the post-step state doubles as the
        // next step's pre-step state, so each env step builds exactly
        // one state (the observation engine makes it an O(M·OBS)
        // copy, but there is still no reason to do it twice).
        let mut obs = env.state();
        while !env.finished() {
            let actions = self.select_actions(&obs, sigma, rng)?;
            let server = env.decode_action(&actions);
            let outcome = env.step(server);
            reward += outcome.rewards.iter().sum::<f64>();
            steps += 1;
            let obs2 = env.state();
            if learn {
                self.replay.push(Transition {
                    s: obs.clone(),
                    a: actions.iter().flat_map(|a| a.iter().copied()).collect(),
                    r: outcome.rewards.iter().map(|&r| r as f32).collect(),
                    s2: obs2.clone(),
                    done: outcome.done.iter().map(|&d| d as u8 as f32).collect(),
                    obs,
                    obs2: obs2.clone(),
                });
                if self.replay.len() >= cfg.warmup && steps % cfg.train_every == 0 {
                    self.train_step(rng)?;
                }
            }
            obs = obs2;
        }
        let stats = EpisodeStats {
            episode: 0,
            reward,
            system_cost: env.evaluate().total(),
            critic_loss: self.losses.0,
            actor_loss: self.losses.1,
            steps,
            drift: env.layout_maintenance_stats(0).2,
        };
        stats.record(0);
        Ok(stats)
    }

    /// Full training run; returns the per-episode reward curve
    /// (Fig. 11's DRLGO series).  Builds the `cfg.envs`-slot vector
    /// via [`VecEnv::for_training`] — replicating `env` in
    /// single-scenario mode, or giving each slot its own generated
    /// scenario when `cfg.scenarios` holds a spec — trains via
    /// [`MaddpgTrainer::train_vec`], and leaves `env` holding slot 0's
    /// final scenario so downstream evaluation keeps working.
    pub fn train(&mut self, env: &mut Env, cfg: &MaddpgConfig) -> crate::Result<Vec<EpisodeStats>> {
        let mut venv =
            VecEnv::for_training(env, cfg.envs.max(1), cfg.scenarios.as_deref(), cfg.seed)?;
        let curve = self.train_vec(&mut venv, cfg)?;
        *env = venv.into_first();
        Ok(curve)
    }

    /// The vectorized training loop: one batched action-selection
    /// round and at most one gradient step per *vector* step, with the
    /// transitions of all E slots pushed into the shared replay
    /// buffer.  Runs until `cfg.episodes` episodes have completed
    /// across the batch (auto-reset keeps every slot live).
    pub fn train_vec(
        &mut self,
        venv: &mut VecEnv,
        cfg: &MaddpgConfig,
    ) -> crate::Result<Vec<EpisodeStats>> {
        anyhow::ensure!(
            venv.agents() == self.m,
            "vec env has {} agents, manifest wants {}",
            venv.agents(),
            self.m
        );
        let mut rng = Rng::seed_from(cfg.seed);
        venv.set_churn(cfg.churn);
        venv.reset_all();
        let e = venv.len();
        let sd = self.m * OBS;
        let mut curve: Vec<EpisodeStats> = Vec::with_capacity(cfg.episodes);
        let mut ep_reward = vec![0.0f64; e];
        let mut ep_steps = vec![0usize; e];
        let mut states = venv.states();
        let mut vstep = 0usize;
        while curve.len() < cfg.episodes {
            let actions = self.select_actions_batch(&states, e, cfg.explore_sigma, &mut rng)?;
            let results = venv.step(&actions);
            vstep += 1;
            for (i, res) in results.iter().enumerate() {
                let s = states[i * sd..(i + 1) * sd].to_vec();
                ep_reward[i] += res.outcome.rewards.iter().sum::<f64>();
                ep_steps[i] += 1;
                self.replay.push(Transition {
                    s: s.clone(),
                    a: actions[i].iter().flat_map(|a| a.iter().copied()).collect(),
                    r: res.outcome.rewards.iter().map(|&r| r as f32).collect(),
                    s2: res.next_state.clone(),
                    done: res.outcome.done.iter().map(|&d| d as u8 as f32).collect(),
                    obs: s,
                    obs2: res.next_state.clone(),
                });
                if res.reset {
                    let stats = EpisodeStats {
                        episode: curve.len(),
                        reward: ep_reward[i],
                        system_cost: res.terminal_cost,
                        critic_loss: self.losses.0,
                        actor_loss: self.losses.1,
                        steps: ep_steps[i],
                        // The slot has already auto-reset, so this is
                        // the drift entering the *next* episode — the
                        // closest per-slot reading available here.
                        drift: venv.env(i).layout_maintenance_stats(0).2,
                    };
                    stats.record(i);
                    log::debug!(
                        "maddpg ep {} (slot {i}): reward {:.3} cost {:.3} closs {:.4}",
                        stats.episode,
                        stats.reward,
                        stats.system_cost,
                        stats.critic_loss
                    );
                    curve.push(stats);
                    ep_reward[i] = 0.0;
                    ep_steps[i] = 0;
                }
            }
            if self.replay.len() >= cfg.warmup && vstep % cfg.train_every == 0 {
                self.train_step(&mut rng)?;
            }
            states = venv.states();
        }
        curve.truncate(cfg.episodes);
        Ok(curve)
    }

    /// Deterministic policy rollout (evaluation): fills `env.offload`.
    pub fn policy_offload(&mut self, env: &mut Env) -> crate::Result<()> {
        let mut rng = Rng::seed_from(0);
        env.reset();
        while !env.finished() {
            let obs = env.state();
            let actions = self.select_actions(&obs, 0.0, &mut rng)?;
            let server = env.decode_action(&actions);
            env.step(server);
        }
        Ok(())
    }

    /// Checkpoint the full learner state.
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        let t = |name: &str, shape: Vec<usize>, data: &[f32]| Tensor {
            name: name.into(),
            shape,
            f32_data: data.to_vec(),
            is_int: false,
        };
        let a = Archive {
            tensors: vec![
                t("actor", vec![self.m, self.pa], &self.actor),
                t("critic", vec![self.m, self.pc], &self.critic),
                t("t_actor", vec![self.m, self.pa], &self.t_actor),
                t("t_critic", vec![self.m, self.pc], &self.t_critic),
                t("m_a", vec![self.m, self.pa], &self.m_a),
                t("v_a", vec![self.m, self.pa], &self.v_a),
                t("m_c", vec![self.m, self.pc], &self.m_c),
                t("v_c", vec![self.m, self.pc], &self.v_c),
                t("step", vec![], &[self.step]),
            ],
        };
        a.save(path).context("saving MADDPG checkpoint")?;
        Ok(())
    }

    /// Restore a checkpoint produced by [`Self::save`].
    pub fn restore(&mut self, path: &std::path::Path) -> crate::Result<()> {
        let a = Archive::load(path)?;
        self.actor = a.get_shaped("actor", &[self.m, self.pa])?.f32_data.clone();
        self.critic = a.get_shaped("critic", &[self.m, self.pc])?.f32_data.clone();
        self.t_actor = a.get_shaped("t_actor", &[self.m, self.pa])?.f32_data.clone();
        self.t_critic = a.get_shaped("t_critic", &[self.m, self.pc])?.f32_data.clone();
        self.m_a = a.get_shaped("m_a", &[self.m, self.pa])?.f32_data.clone();
        self.v_a = a.get_shaped("v_a", &[self.m, self.pa])?.f32_data.clone();
        self.m_c = a.get_shaped("m_c", &[self.m, self.pc])?.f32_data.clone();
        self.v_c = a.get_shaped("v_c", &[self.m, self.pc])?.f32_data.clone();
        self.step = a.get("step")?.f32_data[0];
        self.actor_mat = None;
        Ok(())
    }

    pub fn replay_len(&self) -> usize {
        self.replay.len()
    }
}
