//! The MAMDP environment of §5.2.
//!
//! One *episode* offloads every active user, one user per step
//! (Algorithm 2's inner while-loop).  At each step all M agents emit a
//! two-dimensional action (Eq. 22); the environment assigns the user
//! to the capacity-feasible server whose agent expressed the strongest
//! preference, pays the marginal system cost (the C_m of Eq. 24), and
//! adds the subgraph-split penalty R_sp = ζ·N_s/N_c (Eq. 25) that
//! pushes users of one HiCut subgraph onto one server.
//!
//! Observation layout (OBS = 21 per agent, all values normalized to
//! ~[0, 1]; mirrored by `python/compile/drl.py::OBS`):
//!
//! | idx | meaning                                        | class   |
//! |-----|------------------------------------------------|---------|
//! | 0,1 | current user position x, y / plane             | static  |
//! | 2   | current user active degree / 20                | static  |
//! | 3   | current user task size / 1.5 Mb                | static  |
//! | 4   | user's subgraph size / N                       | static  |
//! | 5   | fraction of that subgraph already on server m  | dynamic |
//! | 6   | remaining capacity of m / capacity             | dynamic |
//! | 7   | load of m / N                                  | dynamic |
//! | 8   | B_{i,m} / 50 MHz                               | static  |
//! | 9   | uplink rate / 1 Gbit/s                         | static  |
//! | 10  | distance(user, m) / plane                      | static  |
//! | 11  | f_m / 10 GHz                                   | static  |
//! | 12,13 | server m position x, y / plane               | static  |
//! | 14  | users not yet offloaded (incl. current) / N    | dynamic |
//! | 15  | est. upload time / 0.1 s                       | static  |
//! | 16  | est. compute time / 0.01 s                     | static  |
//! | 17  | fraction of user's placed neighbors on m       | dynamic |
//! | 18  | repair fraction of the last layout maintenance | maint   |
//! | 19  | cut drift vs the monitor's reference cut       | maint   |
//! | 20  | re-cut intensity of the last maintenance batch | maint   |
//!
//! # The incremental observation engine
//!
//! [`Env::obs`] / [`Env::state`] are the innermost loop of Algorithm 2
//! (one `state()` per environment step, M·OBS floats each), so the
//! environment maintains an `ObsState` instead of recomputing every
//! feature per query:
//!
//! * **Static features** (the `static` rows above — positions,
//!   bandwidths, uplink rates, distances, CPU rates, subgraph sizes)
//!   only change when the *topology* changes.  They are precomputed
//!   into a flat `capacity × M` table of per-(user, server) OBS-row
//!   templates.
//! * **Dynamic features** change per step.  `loads` and
//!   `sub_server_count` were already O(1) lookups; the placed-neighbor
//!   fraction (obs\[17\]) and the remaining-user count (obs\[14\]) are
//!   now maintained as counters: [`Env::step`] applies an O(deg)
//!   update when it places a user, instead of `obs` re-scanning the
//!   neighborhood per agent and `remaining` re-scanning the whole
//!   iteration order.
//! * **Maintenance features** (`maint` rows above) describe the last
//!   layout-maintenance batch — the [`RepairStats`] of the most recent
//!   incremental `mutate`.  obs\[18\] is the fraction of users the
//!   repair touched (joins, departures, refinement migrations and
//!   region re-cut vertices over N), obs\[19\] the relative cut drift
//!   above the drift monitor's reference cut, and obs\[20\] the re-cut
//!   intensity (1 for a full-HiCut fallback, else local regions / 8,
//!   both clamped to \[0, 1\]).  They are identical for every agent,
//!   constant within an episode, and **zero whenever incremental
//!   maintenance is off** — the policy sees how much the layout under
//!   its feet just moved, without paying anything per step.
//!
//! With that split, `state()` is a straight O(M·OBS) copy.
//!
//! **Invalidation rules.**  Staleness is *versioned*, not
//! choke-pointed (see [`crate::util::version`]): the static table and
//! the Eq. 3/6 rate tables live in [`Memoized`] cells keyed on the
//! producers' version stamps — [`DynamicGraph`] bumps its topology
//! version on every mutation, `install_partition` bumps the layout
//! version (every layout-changing path — `recut`, `mutate`,
//! `enable_incremental` — funnels through it), and the params/network
//! version is pinned once at assembly.  A read whose key moved
//! rebuilds lazily; nothing is rebuilt eagerly or by hand.  The
//! *counters* stay eager: `install_partition` recomputes the dynamic
//! counters from scratch and refreshes the cached maintenance slots;
//! `disable_incremental` zeroes the maintenance slots in place;
//! `reset` re-derives the dynamic counters for the fresh episode.
//! Code that mutates `env.users` directly (e.g. `scatter_users` in
//! the figure benches) still needs [`Env::recut`] for the *layout* to
//! follow the graph — but the memoized tables now track even a
//! missing recut, because the topology bump alone invalidates them.
//!
//! **Vectorized rollout.**  [`crate::drl::vec_env::VecEnv`] runs E
//! independent episode slots — clones of one environment (replicate
//! mode) or one [`Env::from_scenario`] per generated
//! [`crate::scenario::Scenario`] (scenario-diversity mode).  Either
//! way each slot owns its `Env`, its churn RNG stream and therefore
//! its own `ObsState`, so per-slot stepping parallelizes without any
//! cross-slot invalidation.  The sharing rule is exactly the
//! invalidation rule above, applied per slot: a slot's caches are
//! refreshed by *its own* `mutate`/`recut`/`reset`, and nothing a
//! sibling slot does can touch them.
//!
//! The pre-engine implementation survives as [`Env::obs_recompute`] /
//! [`Env::state_recompute`]; `tests/properties.rs` proves the cached
//! path bit-identical to it across interleaved churn/reset/step
//! sequences, and `benches/env_step.rs` times one against the other.

use crate::graph::dynamic::{ChurnConfig, DynamicGraph};
use crate::graph::geb::Dataset;
use crate::graph::sample::{sample_scenario, Scenario};
use crate::net::cost::{CostModel, GnnProfile, Offload, RateTables, UNASSIGNED};
use crate::net::params::SystemParams;
use crate::net::topology::{EdgeNetwork, UserLinks};
use crate::partition::incremental::{IncrementalConfig, IncrementalPartitioner, RepairStats};
use crate::partition::{hicut, parallel_hicut, Partition};
use crate::util::rng::Rng;
use crate::util::version::{Memoized, Version};

/// Per-agent observation width (must equal drl.py::OBS).
pub const OBS: usize = 21;

/// Normalizer for the obs\[20\] re-cut intensity: local re-cut batches
/// of this many regions (or more) saturate the slot at 1.
const RECUT_NORM: f32 = 8.0;

/// Environment construction knobs.
#[derive(Clone, Debug)]
pub struct EnvConfig {
    pub n_users: usize,
    pub n_assocs: usize,
    /// Run HiCut and order users subgraph-by-subgraph (DRLGO); false
    /// for the DRL-only ablation and PTOM.
    pub use_hicut: bool,
    /// Apply the R_sp subgraph-split penalty (Eq. 25).
    pub use_rsp: bool,
    /// ζ of Eq. 25.
    pub zeta_sp: f64,
    /// Reward scale on the marginal cost (keeps rewards O(1)).
    pub cost_scale: f64,
    pub churn: ChurnConfig,
}

impl Default for EnvConfig {
    fn default() -> Self {
        EnvConfig {
            n_users: 300,
            n_assocs: 4800,
            use_hicut: true,
            use_rsp: true,
            zeta_sp: 0.5,
            cost_scale: 10.0,
            churn: ChurnConfig::default(),
        }
    }
}

/// Result of one environment step.
#[derive(Clone, Debug)]
pub struct StepOutcome {
    /// Per-agent rewards R_m (Eq. 24).
    pub rewards: Vec<f64>,
    /// Per-agent done flags (server at capacity, or episode over).
    pub done: Vec<bool>,
    /// All users offloaded?
    pub finished: bool,
    /// Server the user was actually assigned to.
    pub assigned: usize,
    /// Raw marginal cost paid this step.
    pub marginal_cost: f64,
}

/// Incrementally maintained observation state (see the module docs).
///
/// The static per-(user, server) feature templates live next door in
/// `Env::obs_templates` — a version-keyed `Memoized` cell rebuilt
/// lazily on (topology, layout, params) change; `obs` copies a cached
/// row and patches the five dynamic slots from the counters here,
/// which mirror what the pre-engine implementation recomputed per
/// query:
///
/// * `placed[u]` — active, already-placed neighbors of `u`,
/// * `placed_here[u·M + m]` — the subset of those on server `m`,
/// * `remaining` — active users at or after the episode cursor
///   (obs\[14\]'s numerator, *including* the current user),
/// * `repair` — the three maintenance slots (obs\[18..21\]), derived
///   from the last [`RepairStats`] on every layout install.
#[derive(Clone, Debug, Default)]
struct ObsState {
    placed: Vec<u32>,
    placed_here: Vec<u32>,
    remaining: usize,
    repair: [f32; 3],
}

/// The environment.
#[derive(Clone)]
pub struct Env {
    pub cfg: EnvConfig,
    /// GNN architecture whose compute profile drives Eqs. 10–11.
    pub profile: GnnProfile,
    pub params: SystemParams,
    pub net: EdgeNetwork,
    pub links: UserLinks,
    pub users: DynamicGraph,
    pub scenario: Scenario,
    pub layer_dims: Vec<usize>,
    /// Subgraph id per scenario user (identity w/o HiCut).
    pub subgraph_of: Vec<usize>,
    pub subgraph_size: Vec<usize>,
    /// Episode iteration order.
    pub order: Vec<usize>,
    // --- per-episode state ---
    pub offload: Offload,
    pub loads: Vec<usize>,
    cursor: usize,
    /// Per subgraph: per-server assigned counts.
    sub_server_count: Vec<Vec<usize>>,
    sub_offloaded: Vec<usize>,
    /// Overflow assignments (capacity exceeded because nothing was free).
    pub overflow: usize,
    /// Delta-driven layout maintenance (None = full recut per mutate).
    pub incremental: Option<IncrementalPartitioner>,
    /// Repair telemetry of the last incremental `mutate`.
    pub last_repair: Option<RepairStats>,
    /// Layout-maintenance worker threads (`--workers`): full recuts run
    /// through [`crate::partition::parallel`] and the incremental
    /// partitioner re-cuts independent dirty regions concurrently.
    /// `1` = everything on the caller's thread; the layout is
    /// identical for every value.
    pub workers: usize,
    /// Incremental observation engine (see the module docs).
    obs_state: ObsState,
    // --- versioned compute plane (util::version) ---
    /// Bumped by every `install_partition` (full recut, incremental
    /// repair, ablation identity layout alike).
    layout: Version,
    /// Pinned once per `SystemParams`/`EdgeNetwork` setup in
    /// `assemble`; nothing re-bumps it today, so params-keyed caches
    /// are effectively immortal until a hot-reload path appears.
    params_ver: Version,
    /// Topology stamp the current layout was installed against — the
    /// "is this layout current?" comparand behind [`Env::layout_lag`].
    layout_at: Version,
    /// Static OBS-row templates, keyed on (topology, layout, params).
    obs_templates: Memoized<Vec<[f32; OBS]>>,
    /// Eq. 3/6 rate tables for the cost hot loops, keyed on
    /// (topology, params) — uplink rates move with user positions,
    /// server compute rates only with the drawn network.
    rates: Memoized<RateTables>,
}

impl Env {
    /// Build a fresh environment from a dataset sample.
    pub fn new(dataset: &Dataset, params: SystemParams, cfg: EnvConfig, rng: &mut Rng) -> Self {
        let scenario = sample_scenario(dataset, cfg.n_users, cfg.n_assocs, rng);
        let net = EdgeNetwork::build(&params, cfg.n_users, rng);
        let links = UserLinks::draw(&params, cfg.n_users, net.len(), rng);
        let task_mb: Vec<f64> = (0..cfg.n_users).map(|_| dataset.task_mbit(0)).collect();
        let users = DynamicGraph::new(scenario.graph.clone(), task_mb, params.plane_m, rng);
        let layer_dims = vec![dataset.feat_dim.min(1500), 64, dataset.classes];
        Self::assemble(cfg, params, net, links, users, scenario, layer_dims)
    }

    /// Shared constructor tail: zero the episode state, run the
    /// initial layout cut and start the first episode.  Both
    /// construction paths ([`Env::new`], [`Env::from_scenario`])
    /// funnel through here so new fields get one initialization site.
    fn assemble(
        cfg: EnvConfig,
        params: SystemParams,
        net: EdgeNetwork,
        links: UserLinks,
        users: DynamicGraph,
        scenario: Scenario,
        layer_dims: Vec<usize>,
    ) -> Self {
        let mut cfg = cfg;
        // Churn must walk the same plane the positions and the
        // obs-normalizers live on; `ChurnConfig::default()` only
        // matches the default Table 2 plane.
        cfg.churn.plane_m = params.plane_m;
        let mut env = Env {
            cfg,
            profile: GnnProfile::Gcn,
            params,
            net,
            links,
            users,
            scenario,
            layer_dims,
            subgraph_of: Vec::new(),
            subgraph_size: Vec::new(),
            order: Vec::new(),
            offload: Offload::empty(0),
            loads: Vec::new(),
            cursor: 0,
            sub_server_count: Vec::new(),
            sub_offloaded: Vec::new(),
            overflow: 0,
            incremental: None,
            last_repair: None,
            workers: 1,
            obs_state: ObsState::default(),
            layout: Version::ZERO,
            params_ver: Version::ZERO,
            layout_at: Version::ZERO,
            obs_templates: Memoized::new(),
            rates: Memoized::new(),
        };
        // Pin the params/network draw: one bump distinguishes "this
        // assembled system" from `Version::ZERO` defaults, so a cell
        // cloned out of a different Env never reads as current here.
        env.params_ver.bump();
        env.recut();
        env.reset();
        env
    }

    /// Build an environment from a *generated* scenario
    /// ([`crate::scenario::Scenario`]): the topology, positions,
    /// per-scenario server draws, link draws and task sizes all come
    /// from the scenario, so two environments built from equal
    /// fingerprints are identical.  `cfg.n_users` / `cfg.n_assocs` are
    /// overridden by the scenario's own shape (they normalize the
    /// observations, so they must describe *this* slot, not the run's
    /// nominal size); the behavioral knobs (`use_hicut`, `use_rsp`,
    /// churn rates, …) are taken from `cfg` as given.
    pub fn from_scenario(sc: &crate::scenario::Scenario, cfg: EnvConfig) -> Self {
        let mut cfg = cfg;
        cfg.n_users = sc.n_users();
        cfg.n_assocs = sc.graph.num_edges();
        let users = DynamicGraph::with_positions(
            sc.graph.clone(),
            sc.task_mb.clone(),
            sc.positions.clone(),
        );
        // Generated scenarios have no backing dataset, so the user map
        // is all-sentinel: the only readers are the fleet-inference
        // paths, and `Controller::run_scenario` rejects inference on
        // out-of-range users — deterministically, thanks to the
        // sentinel — instead of scoring against unrelated dataset
        // rows.
        let scenario = Scenario {
            users: vec![u32::MAX; sc.n_users()],
            graph: sc.graph.clone(),
        };
        Self::assemble(
            cfg,
            sc.params.clone(),
            sc.net.clone(),
            sc.links.clone(),
            users,
            scenario,
            sc.layer_dims.clone(),
        )
    }

    pub fn agents(&self) -> usize {
        self.net.len()
    }

    /// Re-run the graph-layout optimization after topology changes
    /// (Algorithm 2 line 8) and rebuild the iteration order.
    pub fn recut(&mut self) {
        let partition: Partition = {
            let users = &self.users;
            if self.cfg.use_hicut {
                if self.workers > 1 {
                    parallel_hicut(users.graph(), |v| users.is_active(v), self.workers)
                } else {
                    hicut(users.graph(), |v| users.is_active(v))
                }
            } else {
                // Ablation: each active user its own "subgraph".
                Partition {
                    subgraphs: users.active_users().into_iter().map(|v| vec![v]).collect(),
                }
            }
        };
        // Keep the incremental partitioner (when enabled) in sync with
        // the freshly computed layout — a full recut is its reference.
        if let Some(inc) = self.incremental.as_mut() {
            inc.adopt(self.users.graph(), partition.subgraphs.clone());
            inc.note_repaired(self.users.topology_version());
        }
        self.install_partition(&partition);
    }

    /// Switch layout maintenance to delta-driven repair: the dynamic
    /// graph starts recording [`crate::graph::dynamic::GraphDelta`]s
    /// and every `mutate` repairs the live partition (full HiCut stays
    /// as the drift-monitor fallback).  Only meaningful with
    /// `use_hicut`; the ablation path keeps singleton subgraphs.
    pub fn enable_incremental(&mut self, cfg: IncrementalConfig) {
        self.users.record_deltas(true);
        let mut cfg = cfg;
        if self.workers > 1 && cfg.workers <= 1 {
            // The env-level knob reaches the repair layer unless the
            // caller pinned an explicit worker count of its own.
            cfg.workers = self.workers;
        }
        let inc = IncrementalPartitioner::from_users(&self.users, cfg);
        let partition = inc.partition();
        self.incremental = Some(inc);
        self.install_partition(&partition);
    }

    /// Set the layout-maintenance worker count (see [`Env::workers`])
    /// and propagate it into an already-enabled incremental
    /// partitioner.  Mirrors [`Env::enable_incremental`]'s rule: an
    /// explicit parallel request (`workers > 1`) always reaches the
    /// repair layer, but the sequential default never clobbers a
    /// worker count the caller pinned in its own
    /// [`IncrementalConfig`].
    pub fn set_workers(&mut self, workers: usize) {
        self.workers = workers.max(1);
        if let Some(inc) = self.incremental.as_mut() {
            if self.workers > 1 || inc.cfg.workers <= 1 {
                inc.cfg.workers = self.workers;
            }
        }
    }

    /// Back to full-recut maintenance: drop the partitioner and stop
    /// recording deltas (the journal is cleared).  The maintenance
    /// observation slots (obs\[18..21\]) are zeroed in place — they
    /// describe incremental repair, which no longer runs.
    pub fn disable_incremental(&mut self) {
        self.incremental = None;
        self.last_repair = None;
        self.users.record_deltas(false);
        self.obs_state.repair = [0.0; 3];
    }

    /// Layout-maintenance telemetry: `(full_recuts, local_recuts,
    /// drift, cut_edges)`.  Without a partitioner every one of the
    /// `steps` mutates was a full recut and drift is zero by
    /// definition.
    pub fn layout_maintenance_stats(&self, steps: usize) -> (usize, usize, f64, usize) {
        match &self.incremental {
            Some(inc) => (
                inc.full_recuts,
                inc.local_recuts,
                inc.monitor().drift(inc.cut_edges_now()),
                inc.cut_edges_now(),
            ),
            None => (steps, 0, 0.0, self.layout_cut_edges()),
        }
    }

    /// Install a computed layout into the episode bookkeeping.
    ///
    /// Every layout-changing path (`recut`, `mutate`,
    /// `enable_incremental`) funnels through here, which makes it the
    /// observation engine's invalidation point: the layout version is
    /// bumped (so the memoized static feature table rebuilds on its
    /// next read) and the dynamic counters are recomputed against the
    /// (unchanged) live offload.
    fn install_partition(&mut self, partition: &Partition) {
        let n = self.users.capacity();
        self.subgraph_of = partition.assignment(n);
        self.subgraph_size = partition.subgraphs.iter().map(|s| s.len()).collect();
        // Iterate subgraph by subgraph so colocation is learnable.
        self.order = partition.subgraphs.iter().flatten().copied().collect();
        self.sub_server_count = vec![vec![0; self.net.len()]; partition.subgraphs.len()];
        self.sub_offloaded = vec![0; partition.subgraphs.len()];
        self.layout.bump();
        self.layout_at = self.users.topology_version();
        self.recompute_obs_dynamics();
        self.obs_state.repair = self.repair_slots_now();
    }

    /// The maintenance observation slots (obs\[18..21\]), computed from
    /// scratch off [`Env::last_repair`]: all-zero unless incremental
    /// maintenance is enabled *and* a repair has run.  Shared by the
    /// cache refresh in `install_partition` and the
    /// [`Env::obs_recompute`] reference path, so the two stay
    /// bit-identical by construction.
    fn repair_slots_now(&self) -> [f32; 3] {
        if self.incremental.is_none() {
            return [0.0; 3];
        }
        let Some(st) = self.last_repair else { return [0.0; 3] };
        let n = self.cfg.n_users.max(1) as f32;
        let touched = (st.joined + st.left + st.refine_moves + st.region_vertices) as f32;
        let reference = st.reference_cut.max(1) as f32;
        let drift = ((st.cut_edges as f32 - reference) / reference).clamp(0.0, 1.0);
        let recut = if st.full_recut {
            1.0
        } else {
            (st.regions as f32 / RECUT_NORM).min(1.0)
        };
        [(touched / n).min(1.0), drift, recut]
    }

    /// Build the static per-(user, server) observation table: one
    /// OBS-row template per active user and server, dynamic slots
    /// zeroed.  O(N·M) with one uplink-rate lookup per entry — called
    /// only from the `obs_templates` memo cell's rebuild closure, so
    /// the cost is paid once per (topology, layout, params) change
    /// instead of once per `obs` query.
    fn build_obs_templates(&self) -> Vec<[f32; OBS]> {
        let m_agents = self.net.len();
        let n_cap = self.users.capacity();
        let plane = self.params.plane_m;
        let n = self.cfg.n_users as f32;
        let mut templates = vec![[0.0f32; OBS]; n_cap * m_agents];
        let tables = self.rate_tables();
        let cm = CostModel::new(
            &self.params,
            &self.net,
            &self.links,
            &self.users,
            &self.layer_dims,
        )
        .with_tables(&tables);
        for u in 0..n_cap {
            if !self.users.is_active(u) {
                continue;
            }
            let pos = self.users.pos(u);
            let deg = self.users.active_degree(u) as f32 / 20.0;
            let task = self.users.task_mb(u);
            let sg = self.subgraph_of[u];
            let sg_size = if sg == usize::MAX {
                1
            } else {
                self.subgraph_size[sg]
            };
            for (m, server) in self.net.servers.iter().enumerate() {
                let rate = cm.uplink_rate(u, m);
                let o = &mut templates[u * m_agents + m];
                o[0] = (pos.x / plane) as f32;
                o[1] = (pos.y / plane) as f32;
                o[2] = deg;
                o[3] = task as f32 / 1.5;
                o[4] = sg_size as f32 / n;
                o[8] = (self.links.bw_hz[u][m] / 50e6) as f32;
                o[9] = (rate / 1e9) as f32;
                o[10] = (pos.dist(&server.pos) / plane) as f32;
                o[11] = (server.f_hz / 10e9) as f32;
                o[12] = (server.pos.x / plane) as f32;
                o[13] = (server.pos.y / plane) as f32;
                o[15] = (task * 1e6 / rate / 0.1) as f32;
                o[16] = (task * 1e6 / server.f_hz / 0.01) as f32;
            }
        }
        templates
    }

    /// Recompute the dynamic observation counters from scratch against
    /// the live offload: the placed-neighbor tallies behind obs\[17\]
    /// and the remaining-user count behind obs\[14\].  O(N·deg) — the
    /// cost of *one* pre-engine `obs` scan — paid per layout install
    /// and per `reset`; [`Env::step`] maintains the counters in O(deg)
    /// in between.
    fn recompute_obs_dynamics(&mut self) {
        let m_agents = self.net.len();
        let n_cap = self.users.capacity();
        self.obs_state.placed.clear();
        self.obs_state.placed.resize(n_cap, 0);
        self.obs_state.placed_here.clear();
        self.obs_state.placed_here.resize(n_cap * m_agents, 0);
        // A pre-reset offload (from `Env::new`) has no slots yet.
        if self.offload.server.len() == n_cap {
            for v in 0..n_cap {
                if !self.users.is_active(v) {
                    continue;
                }
                let s = self.offload.server[v];
                if s == UNASSIGNED {
                    continue;
                }
                for &nb in self.users.graph().neighbors(v) {
                    let nb = nb as usize;
                    if !self.users.is_active(nb) {
                        continue;
                    }
                    self.obs_state.placed[nb] += 1;
                    self.obs_state.placed_here[nb * m_agents + s] += 1;
                }
            }
        }
        self.obs_state.remaining = self.remaining_scan();
    }

    /// Apply one scenario churn step and re-optimize the layout —
    /// incrementally (delta repair) when enabled, else by full recut.
    pub fn mutate(&mut self, rng: &mut Rng) {
        let churn = self.cfg.churn;
        self.users.step(&churn, rng);
        let deltas = if self.users.recording() {
            self.users.drain_deltas()
        } else {
            Vec::new()
        };
        if self.cfg.use_hicut {
            if let Some(inc) = self.incremental.as_mut() {
                let stats = inc.apply(&self.users, &deltas);
                let partition = inc.partition();
                self.last_repair = Some(stats);
                self.install_partition(&partition);
                return;
            }
        }
        self.recut();
    }

    /// Start a new episode (offloading round) on the current topology.
    pub fn reset(&mut self) {
        let n = self.users.capacity();
        self.offload = Offload::empty(n);
        self.loads = vec![0; self.net.len()];
        self.cursor = 0;
        for counts in &mut self.sub_server_count {
            counts.fill(0);
        }
        self.sub_offloaded.fill(0);
        self.overflow = 0;
        self.skip_inactive();
        self.recompute_obs_dynamics();
    }

    fn skip_inactive(&mut self) {
        while self.cursor < self.order.len()
            && !self.users.is_active(self.order[self.cursor])
        {
            self.cursor += 1;
        }
    }

    pub fn finished(&self) -> bool {
        self.cursor >= self.order.len()
    }

    pub fn current_user(&self) -> Option<usize> {
        self.order.get(self.cursor).copied()
    }

    /// Users not yet offloaded, *including* the current one — the
    /// obs\[14\] numerator.  O(1): the count is maintained by the
    /// observation engine (decremented per `step`, re-derived on
    /// `reset` and on every layout install).
    pub fn remaining(&self) -> usize {
        self.obs_state.remaining
    }

    /// Reference implementation of [`Env::remaining`]: re-scan the
    /// iteration order.  Feeds the counter recomputation and the
    /// equivalence tests.
    fn remaining_scan(&self) -> usize {
        self.order[self.cursor.min(self.order.len())..]
            .iter()
            .filter(|&&u| self.users.is_active(u))
            .count()
    }

    /// Untabled cost model: every rate evaluated from the Eq. 3/6
    /// formulas.  The from-scratch reference paths
    /// ([`Env::obs_recompute`], [`Env::state_recompute`]) and the memo
    /// rebuild closures use this directly; the hot paths (`step`,
    /// `evaluate`, the template builder) attach the memoized
    /// [`RateTables`] on top via [`CostModel::with_tables`].
    fn cost_model(&self) -> CostModel<'_> {
        CostModel::new(
            &self.params,
            &self.net,
            &self.links,
            &self.users,
            &self.layer_dims,
        )
        .with_profile(self.profile)
    }

    /// The memoized Eq. 3/6 rate tables, rebuilt iff the (topology,
    /// params) key moved since the last read.  The returned guard is a
    /// `RefCell` borrow: drop it before any `&mut self` call.
    pub fn rate_tables(&self) -> std::cell::Ref<'_, RateTables> {
        let key = [self.users.topology_version(), self.params_ver];
        self.rates
            .get_or_rebuild(&key, || RateTables::build(&self.cost_model()))
    }

    /// The memoized static observation table (see
    /// [`Env::build_obs_templates`]), keyed on (topology, layout,
    /// params).
    fn obs_templates(&self) -> std::cell::Ref<'_, Vec<[f32; OBS]>> {
        let key = [
            self.users.topology_version(),
            self.layout,
            self.params_ver,
        ];
        self.obs_templates
            .get_or_rebuild(&key, || self.build_obs_templates())
    }

    /// Topology version of the live dynamic graph (bumped per
    /// mutation by [`DynamicGraph`]).
    pub fn topology_version(&self) -> Version {
        self.users.topology_version()
    }

    /// Layout version: bumped once per installed partition.
    pub fn layout_version(&self) -> Version {
        self.layout
    }

    /// Params/network version: pinned at assembly, never re-bumped.
    pub fn params_version(&self) -> Version {
        self.params_ver
    }

    /// How many topology mutations the installed layout trails the
    /// live graph by — 0 whenever a recut/repair ran after the latest
    /// churn, which every `mutate` guarantees.  Exposed so the serving
    /// loop can publish it as the `version.lag.layout` gauge.
    pub fn layout_lag(&self) -> u64 {
        self.layout_at.lag(self.users.topology_version())
    }

    /// Memo-cell telemetry: `(template_reads, template_rebuilds,
    /// rate_reads, rate_rebuilds)` — the benches' hit-rate numerator
    /// and denominator.
    pub fn memo_counters(&self) -> (u64, u64, u64, u64) {
        (
            self.obs_templates.reads(),
            self.obs_templates.rebuilds(),
            self.rates.reads(),
            self.rates.rebuilds(),
        )
    }

    /// Per-agent observation O_m (Eq. 20) for the current user: an
    /// O(OBS) copy of the cached static row plus the five dynamic
    /// features (see the module docs).
    pub fn obs(&self, m: usize) -> [f32; OBS] {
        let Some(u) = self.current_user() else { return [0.0f32; OBS] };
        let m_agents = self.net.len();
        let mut o = self.obs_templates()[u * m_agents + m];
        let n = self.cfg.n_users as f32;
        let server = &self.net.servers[m];
        let sg = self.subgraph_of[u];
        o[5] = if sg != usize::MAX && self.sub_offloaded[sg] > 0 {
            self.sub_server_count[sg][m] as f32 / self.sub_offloaded[sg] as f32
        } else {
            0.0
        };
        o[6] = (server.capacity.saturating_sub(self.loads[m])) as f32
            / server.capacity.max(1) as f32;
        o[7] = self.loads[m] as f32 / n;
        o[14] = self.obs_state.remaining as f32 / n;
        let placed = self.obs_state.placed[u];
        o[17] = if placed > 0 {
            self.obs_state.placed_here[u * m_agents + m] as f32 / placed as f32
        } else {
            0.0
        };
        o[18..].copy_from_slice(&self.obs_state.repair);
        o
    }

    /// Global state S (Eq. 19): concatenated agent observations.
    pub fn state(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.agents() * OBS);
        self.state_into(&mut out);
        out
    }

    /// Append the global state to `out` (the allocation-free form of
    /// [`Env::state`] — the vectorized environment assembles its
    /// `E × M × OBS` batch through this).
    pub fn state_into(&self, out: &mut Vec<f32>) {
        for m in 0..self.agents() {
            out.extend_from_slice(&self.obs(m));
        }
    }

    /// From-scratch reference for [`Env::obs`] — the pre-engine
    /// implementation (cost model per call, O(N) remaining scan,
    /// O(deg) neighborhood scan per agent).  Kept public so the
    /// `tests/properties.rs` bit-equivalence property and
    /// `benches/env_step.rs` can compare against it.
    pub fn obs_recompute(&self, m: usize) -> [f32; OBS] {
        let mut o = [0.0f32; OBS];
        let Some(u) = self.current_user() else { return o };
        let cm = self.cost_model();
        let plane = self.params.plane_m;
        let pos = self.users.pos(u);
        let server = &self.net.servers[m];
        let sg = self.subgraph_of[u];
        let sg_size = if sg == usize::MAX {
            1
        } else {
            self.subgraph_size[sg]
        };
        let n = self.cfg.n_users as f32;
        let rate = cm.uplink_rate(u, m);

        o[0] = (pos.x / plane) as f32;
        o[1] = (pos.y / plane) as f32;
        o[2] = self.users.active_degree(u) as f32 / 20.0;
        o[3] = self.users.task_mb(u) as f32 / 1.5;
        o[4] = sg_size as f32 / n;
        o[5] = if sg != usize::MAX && self.sub_offloaded[sg] > 0 {
            self.sub_server_count[sg][m] as f32 / self.sub_offloaded[sg] as f32
        } else {
            0.0
        };
        o[6] = (server.capacity.saturating_sub(self.loads[m])) as f32
            / server.capacity.max(1) as f32;
        o[7] = self.loads[m] as f32 / n;
        o[8] = (self.links.bw_hz[u][m] / 50e6) as f32;
        o[9] = (rate / 1e9) as f32;
        o[10] = (pos.dist(&server.pos) / plane) as f32;
        o[11] = (server.f_hz / 10e9) as f32;
        o[12] = (server.pos.x / plane) as f32;
        o[13] = (server.pos.y / plane) as f32;
        o[14] = self.remaining_scan() as f32 / n;
        o[15] = (self.users.task_mb(u) * 1e6 / rate / 0.1) as f32;
        o[16] = (self.users.task_mb(u) * 1e6 / server.f_hz / 0.01) as f32;
        let (mut placed, mut placed_here) = (0f32, 0f32);
        for &nb in self.users.graph().neighbors(u) {
            let nb = nb as usize;
            if !self.users.is_active(nb) {
                continue;
            }
            let s = self.offload.server[nb];
            if s != UNASSIGNED {
                placed += 1.0;
                if s == m {
                    placed_here += 1.0;
                }
            }
        }
        o[17] = if placed > 0.0 {
            placed_here / placed
        } else {
            0.0
        };
        o[18..].copy_from_slice(&self.repair_slots_now());
        o
    }

    /// From-scratch reference for [`Env::state`] (see
    /// [`Env::obs_recompute`]).
    pub fn state_recompute(&self) -> Vec<f32> {
        (0..self.agents()).flat_map(|m| self.obs_recompute(m)).collect()
    }

    /// Servers that can still accept a task.
    pub fn eligible(&self) -> Vec<usize> {
        (0..self.agents())
            .filter(|&m| self.loads[m] < self.net.servers[m].capacity)
            .collect()
    }

    /// Decode the joint action (Eq. 22): among capacity-feasible
    /// servers, the agent with the largest preference margin
    /// `a[m][0] − a[m][1]` wins; if none is feasible the least-loaded
    /// server takes the task (counted in `overflow`).
    ///
    /// Margins are compared under IEEE 754 `total_cmp`, so a policy
    /// that emits NaN/±∞ (diverged training, corrupted checkpoint)
    /// yields a deterministic feasible pick instead of panicking
    /// mid-episode (NaN sorts above +∞ in that order).
    pub fn decode_action(&self, actions: &[[f32; 2]]) -> usize {
        let eligible = self.eligible();
        if eligible.is_empty() {
            return (0..self.agents())
                .min_by_key(|&m| self.loads[m])
                .unwrap();
        }
        *eligible
            .iter()
            .max_by(|&&a, &&b| {
                let ma = actions[a][0] - actions[a][1];
                let mb = actions[b][0] - actions[b][1];
                ma.total_cmp(&mb)
            })
            .unwrap()
    }

    /// Assign the current user to `server` and advance the episode.
    ///
    /// Capacity is a hard constraint for every method (the paper's
    /// done_m semantics): a full server redirects the task to the
    /// least-loaded server with room; only when *every* server is full
    /// does the assignment overflow (counted in `self.overflow`).
    pub fn step(&mut self, requested: usize) -> StepOutcome {
        let m_agents = self.agents();
        let u = self.current_user().expect("step after episode end");
        let mut server = requested;
        if self.loads[server] >= self.net.servers[server].capacity {
            let eligible = self.eligible();
            if let Some(&alt) = eligible
                .iter()
                .min_by_key(|&&m| self.loads[m])
            {
                server = alt;
            } else {
                self.overflow += 1;
            }
        }
        let marginal = {
            // Table-backed rates; the `Ref` guard must die in this
            // block — the mutations below take `&mut self`.
            let tables = self.rate_tables();
            let cm = self.cost_model().with_tables(&tables);
            cm.marginal_cost(&self.offload, u, server)
        };
        self.offload.server[u] = server;
        self.loads[server] += 1;
        // O(deg) observation maintenance: u's placement becomes part
        // of every active neighbor's placed-fraction feature (obs[17]).
        for &nb in self.users.graph().neighbors(u) {
            let nb = nb as usize;
            if !self.users.is_active(nb) {
                continue;
            }
            self.obs_state.placed[nb] += 1;
            self.obs_state.placed_here[nb * m_agents + server] += 1;
        }

        // Subgraph-split penalty (Eq. 25).
        let mut rsp = 0.0;
        let sg = self.subgraph_of[u];
        if sg != usize::MAX {
            self.sub_server_count[sg][server] += 1;
            self.sub_offloaded[sg] += 1;
            if self.cfg.use_rsp {
                let ns = self.sub_server_count[sg].iter().filter(|&&c| c > 0).count();
                let nc = self.sub_offloaded[sg];
                // ζ·N_s/N_c, shifted so perfect colocation costs 0.
                rsp = self.cfg.zeta_sp * (ns as f64 - 1.0) / nc as f64;
            }
        }

        // The current user leaves the remaining pool (obs[14]); the
        // inactive entries `skip_inactive` hops over were never in it.
        self.obs_state.remaining = self.obs_state.remaining.saturating_sub(1);
        self.cursor += 1;
        self.skip_inactive();
        let finished = self.finished();

        let mut rewards = vec![0.0f64; m_agents];
        rewards[server] = -(marginal * self.cfg.cost_scale + rsp);
        let done: Vec<bool> = (0..m_agents)
            .map(|m| finished || self.loads[m] >= self.net.servers[m].capacity)
            .collect();
        StepOutcome { rewards, done, finished, assigned: server, marginal_cost: marginal }
    }

    /// Evaluate the completed (or partial) offload with the full cost
    /// model (Eqs. 12–13).
    pub fn evaluate(&self) -> crate::net::cost::CostBreakdown {
        let tables = self.rate_tables();
        self.cost_model().with_tables(&tables).evaluate(&self.offload)
    }

    /// Cut quality of the current layout (diagnostics).
    pub fn layout_cut_edges(&self) -> usize {
        let a = &self.subgraph_of;
        self.users
            .graph()
            .edge_list()
            .iter()
            .filter(|&&(x, y)| {
                let (sx, sy) = (a[x as usize], a[y as usize]);
                sx != usize::MAX && sy != usize::MAX && sx != sy
            })
            .count()
    }
}

#[cfg(test)]
pub mod testutil {
    use super::*;

    /// Small synthetic dataset for environment tests.
    pub fn tiny_dataset(n: usize) -> Dataset {
        let mut rng = Rng::seed_from(1234);
        Dataset::synthetic(n, &mut rng)
    }

    pub fn small_env(seed: u64) -> Env {
        let ds = tiny_dataset(200);
        let cfg = EnvConfig {
            n_users: 40,
            n_assocs: 80,
            ..EnvConfig::default()
        };
        let mut rng = Rng::seed_from(seed);
        Env::new(&ds, SystemParams::default(), cfg, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::small_env;
    use super::*;

    #[test]
    fn episode_assigns_every_active_user() {
        let mut env = small_env(1);
        let mut steps = 0;
        while !env.finished() {
            let server = steps % env.agents();
            env.step(server);
            steps += 1;
        }
        assert_eq!(steps, env.users.active_count());
        assert!(env.offload.all_assigned(&env.users.active_users()));
    }

    #[test]
    fn observations_are_bounded() {
        let mut env = small_env(2);
        for _ in 0..10 {
            for m in 0..env.agents() {
                let o = env.obs(m);
                for (i, v) in o.iter().enumerate() {
                    assert!(v.is_finite(), "obs[{i}] not finite");
                    assert!((-0.01..=5.0).contains(v), "obs[{i}] = {v}");
                }
            }
            env.step(0);
        }
    }

    #[test]
    fn state_is_concat_of_obs() {
        let env = small_env(3);
        let s = env.state();
        assert_eq!(s.len(), env.agents() * OBS);
        let o1 = env.obs(1);
        assert_eq!(&s[OBS..2 * OBS], &o1[..]);
    }

    #[test]
    fn decode_action_respects_capacity() {
        let mut env = small_env(4);
        // Saturate server 0.
        let cap0 = env.net.servers[0].capacity;
        for _ in 0..cap0 {
            if env.finished() {
                break;
            }
            env.step(0);
        }
        if !env.finished() {
            // Even with max preference for 0, decode must avoid it.
            let mut acts = vec![[0.0f32, 1.0]; env.agents()];
            acts[0] = [1.0, 0.0];
            let chosen = env.decode_action(&acts);
            assert_ne!(chosen, 0);
        }
    }

    #[test]
    fn decode_action_survives_nan_and_inf_actions() {
        // Regression: `partial_cmp(..).unwrap()` panicked the moment a
        // diverged policy emitted a NaN margin.  total_cmp must yield
        // a deterministic feasible server instead.
        let env = small_env(21);
        let agents = env.agents();
        let eligible = env.eligible();
        assert!(!eligible.is_empty());

        // One NaN agent among finite ones.
        let mut acts = vec![[0.2f32, 0.1]; agents];
        acts[1] = [f32::NAN, 0.0];
        let pick = env.decode_action(&acts);
        assert!(eligible.contains(&pick));
        assert_eq!(pick, env.decode_action(&acts), "must be deterministic");

        // All-NaN joint action.
        let nan_acts = vec![[f32::NAN, f32::NAN]; agents];
        let pick = env.decode_action(&nan_acts);
        assert!(eligible.contains(&pick));

        // ±∞ margins order sensibly: +∞ beats every finite margin.
        let mut inf_acts = vec![[0.0f32, 1.0]; agents];
        inf_acts[2] = [f32::INFINITY, 0.0];
        inf_acts[0] = [f32::NEG_INFINITY, 0.0];
        assert_eq!(env.decode_action(&inf_acts), 2);
    }

    #[test]
    fn remaining_includes_current_user() {
        // Pins the obs[14] semantics: `remaining()` counts the users
        // not yet offloaded *including* the one currently being
        // decided, so it starts at the full active count.
        let mut env = small_env(22);
        let active = env.users.active_count();
        assert_eq!(env.remaining(), active);
        env.step(0);
        assert_eq!(env.remaining(), active - 1);
        while !env.finished() {
            env.step(1);
        }
        assert_eq!(env.remaining(), 0);
        env.reset();
        assert_eq!(env.remaining(), active);
    }

    #[test]
    fn cached_obs_matches_recompute_through_an_episode() {
        // The heavyweight multi-seed interleaving lives in
        // tests/properties.rs; this is the in-crate smoke check.
        let mut env = small_env(23);
        let mut step = 0;
        while !env.finished() {
            assert_eq!(env.remaining(), env.remaining_scan());
            let state = env.state();
            let reference = env.state_recompute();
            for (i, (a, b)) in state.iter().zip(&reference).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "state[{i}] diverged at step {step}: {a} vs {b}"
                );
            }
            env.step(step % env.agents());
            step += 1;
        }
    }

    #[test]
    fn version_stamps_and_memo_cells_track_churn() {
        let mut env = small_env(31);
        assert_eq!(env.params_version().value(), 1);
        assert_eq!(env.layout_lag(), 0, "assemble ends with a fresh recut");

        // Repeated reads on an unchanged env hit the same build.
        let _ = env.state();
        let (_, template_builds, _, rate_builds) = env.memo_counters();
        let _ = env.state();
        let _ = env.evaluate();
        let after = env.memo_counters();
        assert_eq!(after.1, template_builds, "re-read must not rebuild templates");
        assert_eq!(after.3, rate_builds, "re-read must not rebuild rate tables");

        // Churn bumps topology, mutate reinstalls → lag back to 0,
        // both cells rebuild on their next read.
        let (topo0, layout0) = (env.topology_version(), env.layout_version());
        let mut rng = Rng::seed_from(7);
        // A churn step can come up empty; mutate until one lands.
        for _ in 0..16 {
            env.mutate(&mut rng);
            if env.topology_version() > topo0 {
                break;
            }
        }
        env.reset();
        assert!(env.topology_version() > topo0, "churn must bump topology");
        assert!(env.layout_version() > layout0, "install must bump layout");
        assert_eq!(env.layout_lag(), 0, "mutate repairs to the live topology");
        let _ = env.state();
        let _ = env.evaluate();
        let rebuilt = env.memo_counters();
        assert_eq!(rebuilt.1, after.1 + 1, "stale templates rebuild exactly once");
        assert_eq!(rebuilt.3, after.3 + 1, "stale rate tables rebuild exactly once");
        assert_eq!(env.params_version().value(), 1, "params stay pinned");
    }

    #[test]
    fn rsp_penalizes_subgraph_splits() {
        let mut env = small_env(5);
        // Find a subgraph with >= 2 users in iteration order (adjacent).
        let u0 = env.current_user().unwrap();
        let sg = env.subgraph_of[u0];
        let r0 = env.step(0);
        assert!(r0.rewards[0] <= 0.0);
        if let Some(u1) = env.current_user() {
            if env.subgraph_of[u1] == sg {
                // Splitting to a new server must cost extra vs colocating.
                let mut env2 = small_env(5);
                env2.step(0);
                let together = env2.step(0).rewards[0];
                let mut env3 = small_env(5);
                env3.step(0);
                let split = env3.step(1).rewards[1];
                // Same marginal structure, but split pays R_sp.
                assert!(
                    split < together + 1e-12,
                    "split {split} should be <= colocated {together}"
                );
            }
        }
    }

    #[test]
    fn ablation_disables_hicut_and_rsp() {
        let ds = testutil::tiny_dataset(150);
        let cfg = EnvConfig {
            n_users: 30,
            n_assocs: 60,
            use_hicut: false,
            use_rsp: false,
            ..EnvConfig::default()
        };
        let mut rng = Rng::seed_from(6);
        let mut env = Env::new(&ds, SystemParams::default(), cfg, &mut rng);
        // Every subgraph is a singleton.
        assert!(env.subgraph_size.iter().all(|&s| s == 1));
        let out = env.step(1);
        // Singleton subgraphs → N_s = 1 → rsp = 0; reward is pure cost.
        assert!(out.rewards[1] < 0.0);
    }

    #[test]
    fn mutate_keeps_env_consistent() {
        let mut env = small_env(7);
        let mut rng = Rng::seed_from(8);
        for _ in 0..5 {
            env.mutate(&mut rng);
            env.reset();
            assert_eq!(env.subgraph_of.len(), env.users.capacity());
            // Order covers exactly the active users.
            let active: std::collections::HashSet<usize> =
                env.users.active_users().into_iter().collect();
            let in_order: std::collections::HashSet<usize> =
                env.order.iter().copied().filter(|&u| active.contains(&u)).collect();
            assert_eq!(active, in_order);
            while !env.finished() {
                env.step(0);
            }
            assert!(env.evaluate().total() > 0.0);
        }
    }

    #[test]
    fn worker_sharded_layout_matches_sequential_layout() {
        // Same scenario + churn stream, different worker counts: the
        // installed layouts must be identical step for step (the
        // partition::parallel equivalence, seen from the env).
        let mut a = small_env(13);
        let mut b = small_env(13);
        b.set_workers(4);
        b.recut();
        assert_eq!(a.subgraph_of, b.subgraph_of);
        assert_eq!(a.order, b.order);
        let mut rng_a = Rng::seed_from(14);
        let mut rng_b = Rng::seed_from(14);
        for _ in 0..3 {
            a.mutate(&mut rng_a);
            b.mutate(&mut rng_b);
            assert_eq!(a.subgraph_of, b.subgraph_of);
            assert_eq!(a.order, b.order);
            assert_eq!(a.subgraph_size, b.subgraph_size);
        }
    }

    #[test]
    fn incremental_mutate_matches_full_recut_invariants() {
        let mut env = small_env(11);
        env.enable_incremental(crate::partition::IncrementalConfig::default());
        let mut rng = Rng::seed_from(12);
        for _ in 0..5 {
            env.mutate(&mut rng);
            let stats = env.last_repair.expect("incremental path must report");
            let inc = env.incremental.as_ref().unwrap();
            assert!(inc.is_valid_cover(&env.users));
            assert_eq!(stats.cut_edges, env.layout_cut_edges());
            // Episode bookkeeping mirrors the repaired layout.
            assert_eq!(env.subgraph_of.len(), env.users.capacity());
            let active: std::collections::HashSet<usize> =
                env.users.active_users().into_iter().collect();
            let in_order: std::collections::HashSet<usize> = env.order.iter().copied().collect();
            assert_eq!(active, in_order);
            env.reset();
            while !env.finished() {
                env.step(0);
            }
        }
    }

    #[test]
    fn repair_slots_zero_without_incremental_maintenance() {
        // The maintenance observations (obs[18..21]) describe delta
        // repair; in full-recut mode they must stay exactly zero
        // through arbitrary churn/step interleavings.
        let mut env = small_env(31);
        let mut rng = Rng::seed_from(32);
        for _ in 0..3 {
            env.mutate(&mut rng);
            env.reset();
            for _ in 0..5 {
                if env.finished() {
                    break;
                }
                for m in 0..env.agents() {
                    let o = env.obs(m);
                    assert_eq!(&o[18..], &[0.0f32; 3], "maint slots leaked");
                }
                env.step(0);
            }
        }
    }

    #[test]
    fn repair_slots_refresh_after_incremental_mutate() {
        let mut env = small_env(33);
        env.enable_incremental(crate::partition::IncrementalConfig::default());
        // Enabled but no repair yet: still zero.
        assert_eq!(&env.obs(0)[18..], &[0.0f32; 3]);
        let mut rng = Rng::seed_from(34);
        let mut saw_touch = false;
        for _ in 0..6 {
            env.mutate(&mut rng);
            env.reset();
            let st = env.last_repair.expect("incremental mutate must report");
            let o = env.obs(0);
            // Every agent sees the same maintenance slots.
            for m in 1..env.agents() {
                assert_eq!(&env.obs(m)[18..], &o[18..]);
            }
            let touched = st.joined + st.left + st.refine_moves + st.region_vertices;
            if touched > 0 {
                saw_touch = true;
                assert!(o[18] > 0.0, "repair touched {touched} users but obs[18] == 0");
            } else {
                assert_eq!(o[18], 0.0);
            }
            if st.full_recut || st.regions > 0 {
                assert!(o[20] > 0.0, "re-cuts ran but obs[20] == 0");
            }
            for v in &o[18..] {
                assert!((0.0..=1.0).contains(v), "maint slot out of range: {v}");
            }
            // The cached slots match the from-scratch reference bit
            // for bit (the property tests cover full interleavings).
            let r = env.obs_recompute(0);
            assert_eq!(&o[18..], &r[18..]);
        }
        assert!(saw_touch, "churn never produced a repair — test is vacuous");
        // Disabling zeroes the slots in place.
        env.disable_incremental();
        assert_eq!(&env.obs(0)[18..], &[0.0f32; 3]);
        assert_eq!(&env.obs_recompute(0)[18..], &[0.0f32; 3]);
    }

    #[test]
    fn evaluate_reflects_colocation_benefit() {
        let mut a = small_env(9);
        while !a.finished() {
            a.step(0); // everyone on one server: no transfers
        }
        let mut b = small_env(9);
        let mut i = 0;
        while !b.finished() {
            b.step(i % b.agents()); // round-robin: many cross edges
            i += 1;
        }
        let ca = a.evaluate();
        let cb = b.evaluate();
        // Capacity redirects keep "all on one server" from being literal,
        // but the colocating policy must still cut far fewer edges than
        // round-robin and pay less transfer energy.
        assert!(ca.cross_edges < cb.cross_edges);
        assert!(cb.i_transfer_j > ca.i_transfer_j);
    }
}
