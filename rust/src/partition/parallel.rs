//! Sharded HiCut: the §4 layout optimization across worker threads.
//!
//! `hicut` is O(N² + N·E) (§4.4) on a single thread — the wall every
//! >100k-user edge scenario hits first.  This module shards the cut
//! across workers while staying **provably equivalent** to the
//! sequential algorithm, so every consumer (offloading, serving,
//! incremental repair reference cuts) can switch on `--workers N`
//! without a quality audit.
//!
//! # Shard / merge equivalence argument
//!
//! The shard unit is the **connected component of the alive-induced
//! subgraph** (each component is one natural seed-vertex stripe):
//!
//! 1. `layer_cut`'s BFS only follows edges between alive unassigned
//!    vertices, and its `d_n` association counts only such edges — a
//!    traversal started inside a component can neither visit nor count
//!    anything outside it.  Subgraphs of distinct components therefore
//!    never interact.
//! 2. Sequential [`hicut`] scans seeds in ascending vertex order.
//!    When the loop reaches `start`, every smaller vertex is assigned
//!    or dead, so each produced subgraph's first (and minimal) vertex
//!    is its seed.  Restricted to one component, the seed sequence is
//!    exactly "ascending vertex order within the component".
//! 3. [`hicut_region`] over a whole component (or a union of whole
//!    components) takes its starts in ascending vertex order, so per
//!    component it reproduces the sequential subgraphs *bit for bit* —
//!    same vertex lists, same internal BFS commit order.
//! 4. Sequential `hicut` emits subgraphs in ascending seed order
//!    (seeds are minimal and encountered ascending), so sorting the
//!    merged shard outputs by first vertex reproduces the sequential
//!    subgraph order exactly.
//!
//! Hence [`parallel_hicut`] returns a [`Partition`] **identical** to
//! `hicut`'s — identical vertex cover, identical `cut_edges`,
//! identical subgraph order — for any worker count.  The property
//! tests below assert full structural equality on random and
//! preferential-attachment graphs under random alive masks.
//!
//! # Limits
//!
//! Parallelism is bounded by the component structure: a single giant
//! connected component degrades to the sequential cut (the fallback is
//! explicit, not a slow path).  Edge-user topologies are typically
//! fragmented — geographic clusters, churn-masked vertices — which is
//! where the sharding pays off; intra-component seed striping without
//! the equivalence guarantee is a ROADMAP follow-up.
//!
//! Shards are balanced with an LPT greedy bin-packing over a
//! `|V_c| + deg-sum` cost estimate, then dispatched either onto a
//! caller-owned [`ThreadPool`] ([`parallel_hicut_pool`], the serving
//! path) or onto scoped workers borrowing the graph in place
//! ([`parallel_hicut`], the churn-step path where cloning would eat
//! the speedup).

use std::sync::mpsc;
use std::sync::Arc;

use super::hicut::{hicut, hicut_region};
use super::Partition;
use crate::graph::Graph;
use crate::util::threadpool::ThreadPool;

/// Run HiCut sharded over `workers` scoped worker threads.
///
/// Equivalent to `hicut(g, alive)` for every `workers` value (see the
/// module docs for the argument); `workers <= 1` — or a layout with a
/// single alive component — runs the sequential cut directly.
// analyze:allow(panic) — `mask` is sized g.len() and is only indexed by graph vertex ids < g.len().
pub fn parallel_hicut(
    g: &Graph,
    alive: impl Fn(usize) -> bool + Sync,
    workers: usize,
) -> Partition {
    let mask: Vec<bool> = (0..g.len()).map(&alive).collect();
    let comps = g.components(|v| mask[v]);
    let k = workers.min(comps.len());
    if k <= 1 {
        return hicut(g, |v| mask[v]);
    }
    let shards = pack_shards(g, &comps, k);
    let per_shard = ThreadPool::map_scoped(&shards, k, |shard| hicut_region(g, shard, |v| mask[v]));
    merge(per_shard)
}

/// Run HiCut sharded across a caller-owned [`ThreadPool`].
///
/// The pool's jobs must be `'static`, so the graph and alive mask are
/// snapshotted behind `Arc`s — an O(N + E) copy, noise next to the
/// O(N² + N·E) cut itself.  Prefer [`parallel_hicut`] on hot churn
/// paths where even that copy matters.
// analyze:allow(panic) — `mask` indexes are vertex ids < g.len(), `per_shard[i]` comes from enumerate over n_shards, and the lost-shard assert deliberately re-raises a pool-job panic instead of returning a silently truncated layout.
pub fn parallel_hicut_pool(
    g: &Graph,
    alive: impl Fn(usize) -> bool,
    pool: &ThreadPool,
) -> Partition {
    let mask: Vec<bool> = (0..g.len()).map(&alive).collect();
    let comps = g.components(|v| mask[v]);
    let k = pool.workers().min(comps.len());
    if k <= 1 {
        return hicut(g, |v| mask[v]);
    }
    let shards = pack_shards(g, &comps, k);
    let n_shards = shards.len();
    let g_shared = Arc::new(g.clone());
    let mask = Arc::new(mask);
    let (tx, rx) = mpsc::channel::<(usize, Vec<Vec<usize>>)>();
    for (i, shard) in shards.into_iter().enumerate() {
        let g = Arc::clone(&g_shared);
        let mask = Arc::clone(&mask);
        let tx = tx.clone();
        pool.execute(move || {
            let subs = hicut_region(&g, &shard, |v| mask[v]);
            let _ = tx.send((i, subs));
        });
    }
    // Receive until every sender is dropped: a panicked job drops its
    // sender during unwind (the pool catches the panic), so this loop
    // terminates either way instead of deadlocking on a lost shard.
    drop(tx);
    let mut per_shard: Vec<Vec<Vec<usize>>> = vec![Vec::new(); n_shards];
    let mut received = 0usize;
    for (i, subs) in rx {
        per_shard[i] = subs;
        received += 1;
    }
    assert_eq!(
        received, n_shards,
        "lost {} shard result(s) to panicked pool jobs",
        n_shards - received
    );
    merge(per_shard)
}

/// LPT greedy packing of components into at most `k` shards, balancing
/// an `|V_c| + deg-sum` per-component cost estimate.  Each shard is a
/// union of whole components, returned as one ascending vertex list —
/// exactly the region shape for which [`hicut_region`] matches the
/// sequential cut.  Deterministic: ties break on component id, bins on
/// shard id.
// analyze:allow(panic) — `load`/`shards` are sized k (guarded ≥ 1) and `comps[i]` indexes come from enumerate over comps.
fn pack_shards(g: &Graph, comps: &[Vec<usize>], k: usize) -> Vec<Vec<usize>> {
    if k == 0 {
        return Vec::new();
    }
    let mut order: Vec<(usize, usize)> = comps
        .iter()
        .enumerate()
        .map(|(i, c)| (i, c.len() + c.iter().map(|&v| g.degree(v)).sum::<usize>()))
        .collect();
    order.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); k];
    let mut load = vec![0usize; k];
    for (i, w) in order {
        // k >= 1 is guarded above, so the min always exists.
        let lightest = (0..k).min_by_key(|&s| (load[s], s)).unwrap_or(0);
        load[lightest] += w.max(1);
        shards[lightest].extend_from_slice(&comps[i]);
    }
    shards.retain(|s| !s.is_empty());
    for shard in &mut shards {
        shard.sort_unstable();
    }
    shards
}

/// Deterministic merge: every subgraph's first vertex is its seed (the
/// subgraph minimum — module docs, point 2), and sequential `hicut`
/// emits subgraphs in ascending seed order, so one sort restores the
/// exact sequential ordering.  Seeds are distinct, so the order is
/// total.
// analyze:allow(panic) — `sub[0]` exists because layer_cut never emits an empty subgraph.
fn merge(per_shard: Vec<Vec<Vec<usize>>>) -> Partition {
    let mut subgraphs: Vec<Vec<usize>> = per_shard.into_iter().flatten().collect();
    subgraphs.sort_unstable_by_key(|sub| sub[0]);
    Partition { subgraphs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{preferential_attachment, uniform_random};
    use crate::util::proptest::check_seeds;
    use crate::util::rng::Rng;

    /// Disconnected "edge cluster" topology: `blocks` independent
    /// preferential-attachment communities laid out side by side.
    fn clustered(blocks: usize, block_n: usize, deg: usize, rng: &mut Rng) -> Graph {
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for b in 0..blocks {
            let off = (b * block_n) as u32;
            let g = preferential_attachment(block_n, deg, rng);
            edges.extend(g.edge_list().into_iter().map(|(u, v)| (u + off, v + off)));
        }
        Graph::from_edges(blocks * block_n, &edges)
    }

    fn assert_identical(par: &Partition, seq: &Partition, g: &Graph) {
        // Full structural equality — which subsumes the acceptance
        // criteria, asserted explicitly anyway: identical vertex
        // cover and identical cut_edges.
        assert_eq!(par.subgraphs, seq.subgraphs);
        assert_eq!(par.covered(), seq.covered());
        let (mut pv, mut sv): (Vec<usize>, Vec<usize>) = (
            par.subgraphs.iter().flatten().copied().collect(),
            seq.subgraphs.iter().flatten().copied().collect(),
        );
        pv.sort_unstable();
        sv.sort_unstable();
        assert_eq!(pv, sv);
        assert_eq!(par.cut_edges(g), seq.cut_edges(g));
    }

    #[test]
    fn matches_sequential_on_random_graphs_any_worker_count() {
        check_seeds(40, |rng| {
            let n = rng.range(2, 120);
            let e = rng.below((n * (n - 1) / 2).min(3 * n));
            let g = uniform_random(n, e, rng);
            let seq = hicut(&g, &|_| true);
            for workers in [1, 2, 3, 8] {
                let par = parallel_hicut(&g, |_| true, workers);
                assert_identical(&par, &seq, &g);
            }
            true
        });
    }

    #[test]
    fn matches_sequential_under_random_masks() {
        check_seeds(40, |rng| {
            let n = rng.range(4, 100);
            let e = rng.below((n * (n - 1) / 2).min(3 * n));
            let g = uniform_random(n, e, rng);
            let dead: std::collections::HashSet<usize> =
                (0..n).filter(|_| rng.chance(0.4)).collect();
            let alive = |v: usize| !dead.contains(&v);
            let seq = hicut(&g, &alive);
            let par = parallel_hicut(&g, &alive, 4);
            assert_identical(&par, &seq, &g);
            true
        });
    }

    #[test]
    fn matches_sequential_on_pa_clusters() {
        check_seeds(40, |rng| {
            let blocks = rng.range(1, 9);
            let block_n = rng.range(4, 40);
            let g = clustered(blocks, block_n, 3, rng);
            let seq = hicut(&g, &|_| true);
            let par = parallel_hicut(&g, |_| true, 6);
            assert_identical(&par, &seq, &g);
            true
        });
    }

    #[test]
    fn pool_path_matches_sequential() {
        let pool = ThreadPool::new(4);
        check_seeds(40, |rng| {
            let n = rng.range(4, 90);
            let e = rng.below((n * (n - 1) / 2).min(2 * n));
            let g = uniform_random(n, e, rng);
            let dead: std::collections::HashSet<usize> =
                (0..n).filter(|_| rng.chance(0.3)).collect();
            let alive = |v: usize| !dead.contains(&v);
            let seq = hicut(&g, &alive);
            let par = parallel_hicut_pool(&g, &alive, &pool);
            assert_identical(&par, &seq, &g);
            true
        });
        assert_eq!(pool.panicked(), 0);
    }

    #[test]
    fn giant_component_falls_back_to_sequential() {
        let mut rng = Rng::seed_from(9);
        let g = preferential_attachment(400, 4, &mut rng);
        let seq = hicut(&g, &|_| true);
        let par = parallel_hicut(&g, |_| true, 8);
        assert_identical(&par, &seq, &g);
    }

    #[test]
    fn empty_and_all_dead_graphs() {
        let g = Graph::new(0);
        assert!(parallel_hicut(&g, |_| true, 4).is_empty());
        let g = Graph::from_edges(5, &[(0, 1), (2, 3)]);
        assert!(parallel_hicut(&g, |_| false, 4).is_empty());
    }

    #[test]
    fn isolated_vertices_become_singletons_in_parallel() {
        let g = Graph::new(7);
        let p = parallel_hicut(&g, |_| true, 3);
        assert_eq!(p.len(), 7);
        assert!(p.subgraphs.iter().all(|s| s.len() == 1));
        assert_eq!(p.subgraphs, hicut(&g, &|_| true).subgraphs);
    }

    #[test]
    fn shards_partition_the_alive_vertices() {
        let mut rng = Rng::seed_from(21);
        let g = clustered(6, 20, 3, &mut rng);
        let comps = g.components(|_| true);
        let shards = pack_shards(&g, &comps, 4);
        let mut seen = vec![false; g.len()];
        for shard in &shards {
            assert!(shard.windows(2).all(|w| w[0] < w[1]), "shard not sorted");
            for &v in shard {
                assert!(!seen[v], "vertex {v} in two shards");
                seen[v] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
