//! Max-flow min-cut comparison baseline (the graph-cut method of
//! Zeng et al., "GNN at the edge" [36], as described in §6.2).
//!
//! The baseline partitions the user graph by *iterated s–t min cuts*:
//! each iteration picks a pair of edge servers, designates a source and
//! a sink vertex among the users of the (current) largest fragment, and
//! splits it along the minimum cut found by a max-flow computation.
//! The iteration count is driven by the number of edge servers (25 in
//! the Fig. 6 setup).  Complexity O(V²E) per the paper's comparison.
//!
//! The max-flow engine is Dinic's algorithm over an arena-allocated
//! residual graph (u32 arcs), which is what makes the 8M-edge
//! "non-sparse" Fig. 6 points tractable at all.

use std::collections::HashMap;

use super::Partition;
use crate::graph::Graph;
use crate::util::rng::Rng;

/// Dinic max-flow over a fixed vertex set.
pub struct Dinic {
    /// `head[v]` = first arc index, or `u32::MAX`.
    head: Vec<u32>,
    /// Arc arrays: to, next, cap (residual).
    to: Vec<u32>,
    next: Vec<u32>,
    cap: Vec<u64>,
    level: Vec<i32>,
    iter: Vec<u32>,
}

impl Dinic {
    pub fn new(n: usize) -> Self {
        Dinic {
            head: vec![u32::MAX; n],
            to: Vec::new(),
            next: Vec::new(),
            cap: Vec::new(),
            level: vec![-1; n],
            iter: vec![0; n],
        }
    }

    /// Add a directed arc u→v with capacity c (plus its 0-cap reverse).
    // analyze:allow(panic) — `head` is sized n at construction and callers add arcs only between vertices of that fixed set.
    pub fn add_arc(&mut self, u: usize, v: usize, c: u64) {
        let a = self.to.len() as u32;
        self.to.push(v as u32);
        self.next.push(self.head[u]);
        self.cap.push(c);
        self.head[u] = a;
        let b = self.to.len() as u32;
        self.to.push(u as u32);
        self.next.push(self.head[v]);
        self.cap.push(0);
        self.head[v] = b;
    }

    /// Undirected edge = two opposing arcs with the same capacity.
    // analyze:allow(panic) — `head` is sized n at construction and callers add arcs only between vertices of that fixed set.
    pub fn add_edge(&mut self, u: usize, v: usize, c: u64) {
        let a = self.to.len() as u32;
        self.to.push(v as u32);
        self.next.push(self.head[u]);
        self.cap.push(c);
        self.head[u] = a;
        let b = self.to.len() as u32;
        self.to.push(u as u32);
        self.next.push(self.head[v]);
        self.cap.push(c);
        self.head[v] = b;
    }

    // analyze:allow(panic) — arc ids walked from `head`/`next` chains only ever name arcs pushed by add_arc/add_edge, and `level` is sized n like `head`.
    fn bfs(&mut self, s: usize, t: usize) -> bool {
        self.level.fill(-1);
        let mut q = std::collections::VecDeque::from([s]);
        self.level[s] = 0;
        while let Some(u) = q.pop_front() {
            let mut a = self.head[u];
            while a != u32::MAX {
                let v = self.to[a as usize] as usize;
                if self.cap[a as usize] > 0 && self.level[v] < 0 {
                    self.level[v] = self.level[u] + 1;
                    q.push_back(v);
                }
                a = self.next[a as usize];
            }
        }
        self.level[t] >= 0
    }

    // analyze:allow(panic) — `iter` holds arc ids from `head`/`next` chains; `a ^ 1` is the paired reverse arc because add_arc/add_edge push the two directions adjacently.
    fn dfs(&mut self, u: usize, t: usize, f: u64) -> u64 {
        if u == t {
            return f;
        }
        while self.iter[u] != u32::MAX {
            let a = self.iter[u] as usize;
            let v = self.to[a] as usize;
            if self.cap[a] > 0 && self.level[v] == self.level[u] + 1 {
                let d = self.dfs(v, t, f.min(self.cap[a]));
                if d > 0 {
                    self.cap[a] -= d;
                    self.cap[a ^ 1] += d;
                    return d;
                }
            }
            self.iter[u] = self.next[a];
        }
        0
    }

    /// Max flow from s to t; residual capacities afterwards define the
    /// min cut (vertices reachable from s).  `s == t` has no cut and
    /// reads as zero flow.
    pub fn max_flow(&mut self, s: usize, t: usize) -> u64 {
        if s == t {
            return 0;
        }
        let mut flow = 0u64;
        while self.bfs(s, t) {
            self.iter.copy_from_slice(&self.head);
            loop {
                let f = self.dfs(s, t, u64::MAX);
                if f == 0 {
                    break;
                }
                flow += f;
            }
        }
        flow
    }

    /// Source side of the min cut (call after `max_flow`).
    // analyze:allow(panic) — `seen` is sized like `head` and arc ids walked from `head`/`next` chains only ever name arcs pushed by add_arc/add_edge.
    pub fn source_side(&self, s: usize) -> Vec<bool> {
        let mut seen = vec![false; self.head.len()];
        let mut q = std::collections::VecDeque::from([s]);
        seen[s] = true;
        while let Some(u) = q.pop_front() {
            let mut a = self.head[u];
            while a != u32::MAX {
                let v = self.to[a as usize] as usize;
                if self.cap[a as usize] > 0 && !seen[v] {
                    seen[v] = true;
                    q.push_back(v);
                }
                a = self.next[a as usize];
            }
        }
        seen
    }
}

/// Iterated min-cut partition: split fragments along s–t min cuts until
/// `servers` fragments exist (or nothing splittable remains).
///
/// Source/sink anchors are the two highest-degree vertices of the
/// fragment (the vertices "between" the chosen server pair in [36]).
// analyze:allow(panic) — `index` maps exactly the fragment's vertices, `by_deg[0..2]` exist because fragments are filtered to len ≥ 2, and `side` is sized to the fragment by source_side.
pub fn mincut_partition(
    g: &Graph,
    weights: &HashMap<(u32, u32), u32>,
    servers: usize,
    _rng: &mut Rng,
) -> Partition {
    // Start from connected components (cutting across components is free).
    let mut fragments: Vec<Vec<usize>> = g.components(|_| true);
    // One s–t cut per server pair, as in [36]: iterations ~ servers.
    while fragments.len() < servers {
        // Largest fragment with at least 2 vertices.
        let Some(idx) = fragments
            .iter()
            .enumerate()
            .filter(|(_, f)| f.len() >= 2)
            .max_by_key(|(_, f)| f.len())
            .map(|(i, _)| i)
        else {
            break;
        };
        let frag = fragments.swap_remove(idx);
        let index: HashMap<usize, usize> = frag.iter().enumerate().map(|(i, &v)| (v, i)).collect();
        let mut dinic = Dinic::new(frag.len());
        for &v in &frag {
            for &u in g.neighbors(v) {
                let u = u as usize;
                if u < v {
                    continue; // add each undirected edge once
                }
                if let Some(&lu) = index.get(&u) {
                    let lv = index[&v];
                    let key = (v.min(u) as u32, v.max(u) as u32);
                    let w = *weights.get(&key).unwrap_or(&1) as u64;
                    dinic.add_edge(lv, lu, w);
                }
            }
        }
        // Anchors: two highest-degree vertices (distinct).
        let mut by_deg: Vec<usize> = (0..frag.len()).collect();
        by_deg.sort_by_key(|&i| std::cmp::Reverse(g.degree(frag[i])));
        let (s, t) = (by_deg[0], by_deg[1]);
        dinic.max_flow(s, t);
        let side = dinic.source_side(s);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for (i, &v) in frag.iter().enumerate() {
            if side[i] {
                a.push(v);
            } else {
                b.push(v);
            }
        }
        if a.is_empty() || b.is_empty() {
            // Degenerate (shouldn't happen after max_flow); stop splitting.
            fragments.push(if a.is_empty() { b } else { a });
            break;
        }
        fragments.push(a);
        fragments.push(b);
    }
    Partition { subgraphs: fragments }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{random_weights, uniform_random};
    use crate::util::proptest::check_seeds;

    #[test]
    fn max_flow_textbook() {
        // Classic 6-node network with known max flow 23.
        let mut d = Dinic::new(6);
        d.add_arc(0, 1, 16);
        d.add_arc(0, 2, 13);
        d.add_arc(1, 2, 10);
        d.add_arc(2, 1, 4);
        d.add_arc(1, 3, 12);
        d.add_arc(3, 2, 9);
        d.add_arc(2, 4, 14);
        d.add_arc(4, 3, 7);
        d.add_arc(3, 5, 20);
        d.add_arc(4, 5, 4);
        assert_eq!(d.max_flow(0, 5), 23);
    }

    #[test]
    fn min_cut_separates_on_bridge() {
        // Two cliques joined by a light bridge: cut = bridge weight.
        let mut d = Dinic::new(6);
        for &(u, v) in &[(0, 1), (1, 2), (0, 2)] {
            d.add_edge(u, v, 100);
        }
        for &(u, v) in &[(3, 4), (4, 5), (3, 5)] {
            d.add_edge(u, v, 100);
        }
        d.add_edge(2, 3, 1);
        assert_eq!(d.max_flow(0, 5), 1);
        let side = d.source_side(0);
        assert_eq!(side, vec![true, true, true, false, false, false]);
    }

    #[test]
    fn flow_value_equals_cut_capacity_property() {
        // Weak duality sanity on random graphs: flow == weight of the
        // residual-reachability cut.
        check_seeds(20, |rng| {
            let n = rng.range(4, 30);
            let e = rng.range(n, (n * (n - 1) / 2).max(n + 1));
            let g = uniform_random(n, e.min(n * (n - 1) / 2), rng);
            let w = random_weights(&g, 1, 50, rng);
            let mut d = Dinic::new(n);
            for (u, v) in g.edge_list() {
                d.add_edge(u as usize, v as usize, w[&(u, v)] as u64);
            }
            let flow = d.max_flow(0, n - 1);
            let side = d.source_side(0);
            let cut: u64 = g
                .edge_list()
                .iter()
                .filter(|&&(u, v)| side[u as usize] != side[v as usize])
                .map(|e| w[e] as u64)
                .sum();
            flow == cut && !side[n - 1]
        });
    }

    #[test]
    fn mincut_partition_covers_everything() {
        check_seeds(15, |rng| {
            let n = rng.range(8, 80);
            let g = uniform_random(n, rng.range(n, 3 * n), rng);
            let w = random_weights(&g, 1, 100, rng);
            let p = mincut_partition(&g, &w, 6, rng);
            let mut seen = vec![false; n];
            for sub in &p.subgraphs {
                for &v in sub {
                    if seen[v] {
                        return false;
                    }
                    seen[v] = true;
                }
            }
            seen.iter().all(|&s| s)
        });
    }

    #[test]
    fn mincut_partition_reaches_server_count() {
        let mut rng = Rng::seed_from(5);
        let g = uniform_random(100, 300, &mut rng);
        let w = random_weights(&g, 1, 100, &mut rng);
        let p = mincut_partition(&g, &w, 8, &mut rng);
        assert!(p.len() >= 8, "got {} fragments", p.len());
    }
}
