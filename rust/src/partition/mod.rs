//! Graph-layout optimization: HiCut (the paper's §4 contribution), the
//! max-flow min-cut baseline it is compared against in Fig. 6, the
//! [`incremental`] maintenance subsystem that keeps a HiCut layout
//! live under §3.2 churn by repairing delta batches instead of
//! recutting the world, and the [`parallel`] sharding layer that
//! spreads full cuts (and independent dirty-region repairs) across
//! worker threads with a provably sequential-equivalent merge.
//!
//! All of them produce a [`Partition`]: a disjoint cover of the active
//! vertices by subgraphs ("weakly associated" in HiCut's case).
//! [`Partition::cut_edges`] — the number of associations crossing
//! subgraph boundaries — is the quantity that drives cross-server
//! message passing during distributed GNN inference (problem P1).

pub mod hicut;
pub mod incremental;
pub mod mincut;
pub mod parallel;

pub use hicut::{hicut, hicut_region};
pub use incremental::{DriftMonitor, IncrementalConfig, IncrementalPartitioner, RepairStats};
pub use mincut::{mincut_partition, Dinic};
pub use parallel::{parallel_hicut, parallel_hicut_pool};

use crate::graph::Graph;

/// A disjoint partition of (a subset of) the vertices of a graph.
#[derive(Clone, Debug, Default)]
pub struct Partition {
    /// Subgraphs as vertex lists, in creation order.
    pub subgraphs: Vec<Vec<usize>>,
}

impl Partition {
    /// Subgraph index of each vertex (usize::MAX for uncovered).
    /// Vertices outside `0..n` (a partition built for a larger graph)
    /// are ignored.
    pub fn assignment(&self, n: usize) -> Vec<usize> {
        let mut a = vec![usize::MAX; n];
        for (s, verts) in self.subgraphs.iter().enumerate() {
            for &v in verts {
                if let Some(slot) = a.get_mut(v) {
                    *slot = s;
                }
            }
        }
        a
    }

    pub fn len(&self) -> usize {
        self.subgraphs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.subgraphs.is_empty()
    }

    pub fn covered(&self) -> usize {
        self.subgraphs.iter().map(|s| s.len()).sum()
    }

    /// Number of edges crossing subgraph boundaries (the inference-time
    /// message-passing cost proxy minimized by P1).
    // analyze:allow(panic) — `a` is sized g.len() by assignment() and edge endpoints are < g.len().
    pub fn cut_edges(&self, g: &Graph) -> usize {
        let a = self.assignment(g.len());
        g.edge_list()
            .iter()
            .filter(|&&(u, v)| {
                let (au, av) = (a[u as usize], a[v as usize]);
                au != usize::MAX && av != usize::MAX && au != av
            })
            .count()
    }

    /// Weighted cut (Fig. 6's comparison uses integer edge weights).
    // analyze:allow(panic) — `a` is sized g.len() by assignment() and edge endpoints are < g.len().
    pub fn cut_weight(&self, g: &Graph, w: &std::collections::HashMap<(u32, u32), u32>) -> u64 {
        let a = self.assignment(g.len());
        g.edge_list()
            .iter()
            .filter(|&&(u, v)| {
                let (au, av) = (a[u as usize], a[v as usize]);
                au != usize::MAX && av != usize::MAX && au != av
            })
            .map(|e| *w.get(e).unwrap_or(&1) as u64)
            .sum()
    }

    /// Fraction of all (covered) edges that stay inside subgraphs.
    // analyze:allow(panic) — `a` is sized g.len() by assignment() and edge endpoints are < g.len().
    pub fn locality(&self, g: &Graph) -> f64 {
        let a = self.assignment(g.len());
        let mut inside = 0usize;
        let mut total = 0usize;
        for (u, v) in g.edge_list() {
            let (au, av) = (a[u as usize], a[v as usize]);
            if au == usize::MAX || av == usize::MAX {
                continue;
            }
            total += 1;
            if au == av {
                inside += 1;
            }
        }
        if total == 0 {
            1.0
        } else {
            inside as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cut_metrics() {
        // Two triangles joined by one bridge.
        let g = Graph::from_edges(
            6,
            &[(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5), (2, 3)],
        );
        let p = Partition { subgraphs: vec![vec![0, 1, 2], vec![3, 4, 5]] };
        assert_eq!(p.cut_edges(&g), 1);
        assert!((p.locality(&g) - 6.0 / 7.0).abs() < 1e-12);
        assert_eq!(p.covered(), 6);
        let mut w = std::collections::HashMap::new();
        w.insert((2u32, 3u32), 9u32);
        assert_eq!(p.cut_weight(&g, &w), 9 + 0); // others default 1 but inside
    }

    #[test]
    fn assignment_marks_uncovered() {
        let p = Partition { subgraphs: vec![vec![0, 2]] };
        let a = p.assignment(4);
        assert_eq!(a, vec![0, usize::MAX, 0, usize::MAX]);
    }
}
