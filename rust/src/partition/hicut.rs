//! HiCut — Hierarchical Traversal Graph Cut (paper §4, Algorithm 1).
//!
//! BFS-based layer-by-layer traversal; the cut is placed between the
//! two layers with the weakest association.  Per-layer association
//! strength is the number of edges `d_n` leaving the current layer
//! toward not-yet-assigned vertices:
//!
//! * `d_n` ≥ previous layer's `d_{n-1}` → association strengthening:
//!   the recorded candidate cut (if any, and strictly `<`) is taken and
//!   the subgraph closed; otherwise the layer joins the subgraph.
//! * `d_n` < `d_{n-1}` → a candidate cut: the layer is parked in
//!   `V_seg` and traversal continues looking for an even weaker spot.
//! * `d_n` = 0 → the frontier died out; everything parked joins and the
//!   subgraph closes.
//!
//! Repeating from every unassigned vertex yields the optimized layout
//! `G_sub` whose inter-subgraph association count — and therefore the
//! cross-server message-passing cost of distributed GNN inference — is
//! minimized.  Complexity O(N² + N·E) (§4.4).

use super::Partition;
use crate::graph::Graph;

/// Run HiCut over the vertices for which `alive` holds (the §3.2 mask).
///
/// `alive` is a generic bound (not `&dyn Fn`) so the per-neighbor mask
/// check on the traversal hot path is statically dispatched; `&closure`
/// arguments keep working through the blanket `Fn` impl for references.
// analyze:allow(panic) — `assigned` is sized g.len() and every index is a graph vertex id < g.len().
pub fn hicut(g: &Graph, alive: impl Fn(usize) -> bool) -> Partition {
    let n = g.len();
    // assigned[v] flips to true once v belongs to a finished subgraph
    // (subgraph ids are implied by push order into the partition).
    let mut assigned = vec![false; n];
    let mut partition = Partition::default();

    for start in 0..n {
        if assigned[start] || !alive(start) {
            continue;
        }
        let sub = layer_cut(g, start, &mut assigned, &alive);
        debug_assert!(!sub.is_empty());
        partition.subgraphs.push(sub);
    }
    partition
}

/// Re-run HiCut restricted to `region`: vertices outside the region are
/// treated as already assigned, so neither the traversal nor the `d_n`
/// association counts ever leave it.  Returns the region's new
/// subgraphs.  This is the local-repair primitive of
/// [`super::incremental`]: dirty subgraphs plus their cut-edge
/// neighbors are dissolved into a region and re-cut in place, leaving
/// the rest of the layout untouched.
///
/// Traversal starts are taken in **ascending vertex order**, whatever
/// order `region` arrives in (duplicates are ignored).  That makes the
/// result a pure function of the region *set*, which is what lets
/// [`super::parallel`] and the concurrent dirty-region repair dispatch
/// regions to workers without the journal/collection order leaking
/// into the layout.  It also mirrors full [`hicut`], whose outer loop
/// scans seeds in ascending vertex order — the shard-merge equivalence
/// proof leans on exactly this property.
// analyze:allow(panic) — `assigned` is sized g.len() and region entries are graph vertex ids < g.len().
pub fn hicut_region(g: &Graph, region: &[usize], alive: impl Fn(usize) -> bool) -> Vec<Vec<usize>> {
    let mut assigned = vec![true; g.len()];
    let mut starts: Vec<usize> = Vec::with_capacity(region.len());
    for &v in region {
        // `assigned[v]` doubles as a dedup mark here.
        if alive(v) && assigned[v] {
            assigned[v] = false;
            starts.push(v);
        }
    }
    starts.sort_unstable();
    let mut subgraphs = Vec::new();
    for &start in &starts {
        if assigned[start] {
            continue;
        }
        subgraphs.push(layer_cut(g, start, &mut assigned, &alive));
    }
    subgraphs
}

/// One graph-cut operation (Algorithm 1's `LayerCut`): BFS from
/// `start`, returning the vertices of the new subgraph (marked in
/// `assigned`).
// analyze:allow(panic) — `assigned` and `layer` are sized g.len(); the BFS only ever visits graph vertex ids < g.len().
fn layer_cut<F: Fn(usize) -> bool>(
    g: &Graph,
    start: usize,
    assigned: &mut [bool],
    alive: &F,
) -> Vec<usize> {
    let mut subgraph: Vec<usize> = Vec::new();
    let mut commit = |verts: &mut Vec<usize>, assigned: &mut [bool]| {
        for &v in verts.iter() {
            if !assigned[v] {
                assigned[v] = true;
                subgraph.push(v);
            }
        }
        verts.clear();
    };

    let mut queue = std::collections::VecDeque::from([start]);
    // BFS layer of each visited vertex (0 = unvisited in this call).
    let mut layer = vec![0u32; g.len()];
    layer[start] = 1;
    // V_begin joins immediately (Algorithm 1 line 9).
    let mut seed = vec![start];
    commit(&mut seed, assigned);

    let mut n_cur = 1usize; // vertices left in the current layer
    let mut l_cur = 1usize; // current layer number
    let mut v_cur: Vec<usize> = Vec::new(); // vertices of current layer
    let mut v_seg: Vec<usize> = Vec::new(); // parked candidate-cut layer
    let mut d_prev = 0usize;
    let mut d_n = 0usize;

    while let Some(vc) = queue.pop_front() {
        v_cur.push(vc);
        n_cur -= 1;
        for &vr in g.neighbors(vc) {
            let vr = vr as usize;
            if !alive(vr) || assigned[vr] {
                continue; // only unassigned alive vertices count (line 16)
            }
            if layer[vr] == 0 {
                layer[vr] = l_cur as u32 + 1;
                queue.push_back(vr);
            }
            // d_n counts the edges *between this layer and the next*
            // (Fig. 3: "the numbers on the edges represent the
            // traversal layer's number") — intra-layer and back edges
            // do not weaken the cut candidate.
            if layer[vr] == l_cur as u32 + 1 {
                d_n += 1;
            }
        }
        if n_cur > 0 {
            continue;
        }
        // ---- end of layer (Algorithm 1 lines 20–37) ----
        n_cur = queue.len();
        if d_n == 0 {
            // Frontier exhausted: everything parked + current joins.
            commit(&mut v_seg, assigned);
            commit(&mut v_cur, assigned);
            return subgraph;
        }
        if l_cur == 1 {
            d_prev = d_n;
            // Layer-1 vertices are the start vertex, already committed.
            v_cur.clear();
        } else if d_prev <= d_n {
            // Association strengthening again.
            if !v_seg.is_empty() && d_prev < d_n {
                // The parked layer was the weakest spot: cut there.
                commit(&mut v_seg, assigned);
                return subgraph;
            }
            d_prev = d_n;
            commit(&mut v_cur, assigned);
        } else {
            // d_prev > d_n: candidate cut — park this layer.
            commit(&mut v_seg, assigned);
            v_seg = std::mem::take(&mut v_cur);
            d_prev = d_n;
        }
        l_cur += 1;
        v_cur.clear();
        d_n = 0;
    }
    // Queue exhausted naturally: commit whatever is parked.
    commit(&mut v_seg, assigned);
    commit(&mut v_cur, assigned);
    subgraph
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{preferential_attachment, uniform_random};
    use crate::util::proptest::check_seeds;
    use crate::util::rng::Rng;

    fn is_partition(p: &Partition, g: &Graph, alive: &dyn Fn(usize) -> bool) -> bool {
        let mut seen = vec![0usize; g.len()];
        for sub in &p.subgraphs {
            if sub.is_empty() {
                return false;
            }
            for &v in sub {
                seen[v] += 1;
            }
        }
        (0..g.len()).all(|v| if alive(v) { seen[v] == 1 } else { seen[v] == 0 })
    }

    #[test]
    fn paper_figure3_example() {
        // The red-subgraph walkthrough of §4.2: layers from V1 with
        // edge counts d = [3, 2, 1, 4] ending in subgraph {V1..V6}.
        // Graph: V1-(V2,V3,V6); layer2 edges to layer3: V2-V4, V3-V5;
        // layer3 edge to layer4: V4-V7; layer4: V7 with 4 outgoing
        // edges to V8..V11.
        let edges: &[(u32, u32)] = &[
            (0, 1), (0, 2), (0, 5),          // V1 -> V2,V3,V6   (d1 = 3)
            (1, 3), (2, 4),                  // layer2 -> layer3 (d2 = 2)
            (3, 6),                          // layer3 -> layer4 (d3 = 1)
            (6, 7), (6, 8), (6, 9), (6, 10), // layer4 out       (d4 = 4)
        ];
        let g = Graph::from_edges(11, edges);
        let p = hicut(&g, &|_| true);
        // First subgraph must be exactly {V1..V6} = ids 0..=5.
        let mut first = p.subgraphs[0].clone();
        first.sort_unstable();
        assert_eq!(first, vec![0, 1, 2, 3, 4, 5]);
        assert!(is_partition(&p, &g, &|_| true));
    }

    #[test]
    fn isolated_vertices_become_singletons() {
        let g = Graph::new(5);
        let p = hicut(&g, &|_| true);
        assert_eq!(p.len(), 5);
        assert!(p.subgraphs.iter().all(|s| s.len() == 1));
    }

    #[test]
    fn respects_alive_mask() {
        let g = Graph::from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)]);
        let alive = |v: usize| v != 2;
        let p = hicut(&g, &alive);
        assert!(is_partition(&p, &g, &alive));
        assert!(p.subgraphs.iter().all(|s| !s.contains(&2)));
    }

    #[test]
    fn partition_invariant_random_graphs() {
        check_seeds(40, |rng| {
            let n = rng.range(2, 120);
            let e = rng.below((n * (n - 1) / 2).min(4 * n));
            let g = uniform_random(n, e, rng);
            let p = hicut(&g, &|_| true);
            is_partition(&p, &g, &|_| true)
        });
    }

    #[test]
    fn partition_invariant_with_random_masks() {
        check_seeds(40, |rng| {
            let n = rng.range(4, 100);
            let g = uniform_random(n, rng.below(3 * n), rng);
            let dead: std::collections::HashSet<usize> =
                (0..n).filter(|_| rng.chance(0.3)).collect();
            let alive = move |v: usize| !dead.contains(&v);
            let p = hicut(&g, &alive);
            is_partition(&p, &g, &alive)
        });
    }

    #[test]
    fn cut_beats_random_assignment_on_clustered_graphs() {
        // On a graph of dense communities with sparse bridges HiCut
        // should cut far fewer edges than a random 4-way split.
        let mut rng = Rng::seed_from(42);
        let mut edges: Vec<(u32, u32)> = Vec::new();
        let k = 8; // communities of 16
        for c in 0..k {
            let base = (c * 16) as u32;
            for i in 0..16u32 {
                for j in (i + 1)..16u32 {
                    if rng.chance(0.5) {
                        edges.push((base + i, base + j));
                    }
                }
            }
        }
        for c in 0..k - 1 {
            edges.push(((c * 16) as u32, ((c + 1) * 16) as u32)); // bridges
        }
        let g = Graph::from_edges(k * 16, &edges);
        let p = hicut(&g, &|_| true);
        let mut rand_assign = Partition { subgraphs: vec![vec![]; 4] };
        for v in 0..g.len() {
            rand_assign.subgraphs[rng.below(4)].push(v);
        }
        assert!(
            p.cut_edges(&g) < rand_assign.cut_edges(&g) / 4,
            "hicut {} vs random {}",
            p.cut_edges(&g),
            rand_assign.cut_edges(&g)
        );
    }

    #[test]
    fn region_cut_covers_exactly_the_region() {
        check_seeds(30, |rng| {
            let n = rng.range(6, 80);
            let g = uniform_random(n, rng.below(3 * n), rng);
            let region: Vec<usize> = (0..n).filter(|_| rng.chance(0.5)).collect();
            let subs = hicut_region(&g, &region, |_| true);
            let mut seen = vec![0usize; n];
            for sub in &subs {
                if sub.is_empty() {
                    return false;
                }
                for &v in sub {
                    seen[v] += 1;
                }
            }
            let in_region: std::collections::HashSet<usize> = region.iter().copied().collect();
            (0..n).all(|v| seen[v] == usize::from(in_region.contains(&v)))
        });
    }

    #[test]
    fn region_cut_respects_alive_mask() {
        let g = Graph::from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)]);
        let subs = hicut_region(&g, &[0, 1, 2, 3], |v| v != 2);
        let all: Vec<usize> = subs.iter().flatten().copied().collect();
        assert!(!all.contains(&2) && !all.contains(&4) && !all.contains(&5));
        let mut sorted = all.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), all.len()); // disjoint
        assert_eq!(sorted, vec![0, 1, 3]);
    }

    #[test]
    fn region_cut_is_independent_of_input_order() {
        // Same region *set*, different input orders (shuffled, reversed,
        // with duplicates) → byte-identical subgraph lists.  Required
        // before regions can be dispatched to pool workers, where the
        // collection order is an accident of journal replay.
        check_seeds(40, |rng| {
            let n = rng.range(6, 90);
            let e = rng.below((n * (n - 1) / 2).min(3 * n));
            let g = uniform_random(n, e, rng);
            let region: Vec<usize> = (0..n).filter(|_| rng.chance(0.6)).collect();
            let reference = hicut_region(&g, &region, |_| true);

            let mut shuffled = region.clone();
            rng.shuffle(&mut shuffled);
            let mut reversed = region.clone();
            reversed.reverse();
            let mut with_dups = shuffled.clone();
            with_dups.extend(region.iter().copied());

            hicut_region(&g, &shuffled, |_| true) == reference
                && hicut_region(&g, &reversed, |_| true) == reference
                && hicut_region(&g, &with_dups, |_| true) == reference
        });
    }

    #[test]
    fn scales_to_pa_graphs() {
        let mut rng = Rng::seed_from(3);
        let g = preferential_attachment(5000, 10, &mut rng);
        let p = hicut(&g, &|_| true);
        assert_eq!(p.covered(), 5000);
        assert!(p.locality(&g) > 0.0);
    }
}
