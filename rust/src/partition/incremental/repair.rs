//! Delta-driven repair of a live HiCut partition.
//!
//! The partitioner owns the layout as *slots* (subgraph vertex lists
//! with a free-list), a vertex→slot assignment, and per-slot boundary
//! counts, all maintained exactly under replayed
//! [`GraphDelta`] journals:
//!
//! * `Left` — unassign, fixing the cut counters from the adjacency
//!   recorded in the event (the edges died with the user).
//! * `Joined` — attach to the majority subgraph among live neighbors
//!   (a fresh singleton when isolated).
//! * `Rewired` — O(1) counter update; the cut only changes when both
//!   endpoints are assigned to different subgraphs.
//!
//! After replay, a bounded greedy refinement sweep migrates
//! delta-touched vertices whose cut contribution strictly improves,
//! dirty subgraphs get a local region re-cut
//! ([`crate::partition::hicut::hicut_region`]), and the
//! [`DriftMonitor`] orders a full HiCut when repair has drifted past
//! its bound.  Per batch the repair work is O(Δ·deg + dirty region)
//! versus the full cut's O(N² + N·E) (§4.4).

use std::collections::BTreeMap;

use once_cell::sync::Lazy;

use super::drift::DriftMonitor;
use super::IncrementalConfig;
use crate::graph::dynamic::{DynamicGraph, GraphDelta};
use crate::graph::Graph;
use crate::partition::hicut::{hicut, hicut_region};
use crate::partition::parallel::parallel_hicut;
use crate::partition::Partition;
use crate::util::metrics::{Gauge, GLOBAL as METRICS};
use crate::util::threadpool::ThreadPool;
use crate::util::trace;
use crate::util::version::Version;

static CUT_EDGES_GAUGE: Lazy<Gauge> =
    Lazy::new(|| METRICS.gauge_handle("partition.cut_edges"));
static DRIFT_PPM_GAUGE: Lazy<Gauge> =
    Lazy::new(|| METRICS.gauge_handle("partition.drift_ppm"));

const NONE: usize = usize::MAX;

/// What one [`IncrementalPartitioner::apply`] call did.
#[derive(Clone, Copy, Debug, Default)]
pub struct RepairStats {
    /// Journal length of the batch.
    pub deltas: usize,
    /// Users attached (joined) / unassigned (left).
    pub joined: usize,
    pub left: usize,
    /// Refinement migrations performed.
    pub refine_moves: usize,
    /// Local re-cuts, when any ran: independent (vertex-disjoint)
    /// dirty regions re-cut this batch — concurrently when
    /// `IncrementalConfig::workers > 1` — plus the totals of dissolved
    /// subgraphs and vertices across all of them.
    pub regions: usize,
    pub region_subgraphs: usize,
    pub region_vertices: usize,
    pub local_recut: bool,
    /// The drift monitor ordered a full HiCut fallback.
    pub full_recut: bool,
    /// Live cut-edge count after repair.
    pub cut_edges: usize,
    /// Monitor reference (cut edges at the last full cut).
    pub reference_cut: usize,
}

/// Owns the live [`Partition`] of a churning scenario and repairs it
/// from [`GraphDelta`] batches instead of recutting the world.
///
/// `Clone` clones the live layout and all bookkeeping — the vectorized
/// environment replicates a fully-configured [`crate::drl::Env`]
/// (partitioner included) into independent episode slots.
#[derive(Clone)]
pub struct IncrementalPartitioner {
    pub cfg: IncrementalConfig,
    monitor: DriftMonitor,
    /// Subgraph slots; an empty slot is free (listed in `free`).
    slots: Vec<Vec<usize>>,
    free: Vec<usize>,
    /// vertex → slot id (`usize::MAX` = unassigned).
    assignment: Vec<usize>,
    /// Index of each assigned vertex inside its slot (O(1) removal).
    pos_in_slot: Vec<usize>,
    /// Per-slot cut-edge count (both endpoints assigned, slots differ).
    boundary: Vec<usize>,
    /// Boundary at the slot's last full/local cut — dirty detection.
    baseline: Vec<usize>,
    /// Live inter-subgraph association count.
    cut: usize,
    /// Assigned-vertex count.
    covered: usize,
    /// Delta batches applied.
    pub steps: usize,
    /// Full HiCut runs (the constructor's reference cut counts as one).
    pub full_recuts: usize,
    /// Local region re-cuts performed.
    pub local_recuts: usize,
    /// Graph topology version this layout was last repaired/recut to
    /// (see [`crate::util::version`]): stamped by [`Self::apply`] and
    /// [`Self::full_recut`], and by [`Self::note_repaired`] when a
    /// caller adopts an externally computed layout.  `ZERO` until the
    /// first stamp.
    repaired_to: Version,
}

impl IncrementalPartitioner {
    pub fn new(cfg: IncrementalConfig) -> Self {
        let monitor = DriftMonitor::new(cfg.drift_bound, cfg.drift_slack);
        IncrementalPartitioner {
            cfg,
            monitor,
            slots: Vec::new(),
            free: Vec::new(),
            assignment: Vec::new(),
            pos_in_slot: Vec::new(),
            boundary: Vec::new(),
            baseline: Vec::new(),
            cut: 0,
            covered: 0,
            steps: 0,
            full_recuts: 0,
            local_recuts: 0,
            repaired_to: Version::ZERO,
        }
    }

    /// Build from the live scenario: one full HiCut as the reference.
    pub fn from_users(users: &DynamicGraph, cfg: IncrementalConfig) -> Self {
        let mut p = Self::new(cfg);
        p.full_recut(users);
        p
    }

    /// Throw incremental state away and re-run the §4 full HiCut —
    /// sharded across workers when configured (identical layout either
    /// way; see [`crate::partition::parallel`]).
    pub fn full_recut(&mut self, users: &DynamicGraph) {
        let mut span = trace::span("partition.full_recut");
        let g = users.graph();
        let p = if self.cfg.workers > 1 {
            parallel_hicut(g, |v| users.is_active(v), self.cfg.workers)
        } else {
            hicut(g, |v| users.is_active(v))
        };
        self.adopt(g, p.subgraphs);
        self.repaired_to = users.topology_version();
        span.field("vertices", self.covered as f64);
        span.field("cut_edges", self.cut as f64);
    }

    /// Adopt an externally computed layout as the new reference.
    pub fn adopt(&mut self, g: &Graph, subgraphs: Vec<Vec<usize>>) {
        let n = g.len();
        self.slots.clear();
        self.free.clear();
        self.boundary.clear();
        self.baseline.clear();
        self.assignment = vec![NONE; n];
        self.pos_in_slot = vec![0; n];
        self.covered = 0;
        for sub in subgraphs {
            if sub.is_empty() {
                continue;
            }
            let s = self.alloc_slot();
            for v in sub {
                self.assign(v, s);
            }
        }
        self.recount(g);
        self.baseline.copy_from_slice(&self.boundary);
        self.monitor.set_reference(self.cut);
        self.full_recuts += 1;
    }

    /// Repair the layout after one churn step described by `deltas`
    /// (the drained journal; `users` is the post-step graph).
    // analyze:allow(panic) — the capacity assert_eq is the documented API contract (the layout must match the scenario it was built for), and delta vertex ids are < n by that same contract.
    pub fn apply(&mut self, users: &DynamicGraph, deltas: &[GraphDelta]) -> RepairStats {
        let mut span = trace::span("partition.repair");
        let g = users.graph();
        assert_eq!(
            self.assignment.len(),
            g.len(),
            "partitioner was built for a different scenario capacity"
        );
        self.steps += 1;
        let mut stats = RepairStats { deltas: deltas.len(), ..RepairStats::default() };

        // 1. Replay the journal: exact counter maintenance.
        let mut pending: Vec<usize> = Vec::new();
        let mut touched = Touched::new(g.len());
        for delta in deltas {
            match delta {
                GraphDelta::Moved { .. } => {}
                GraphDelta::Joined { user, .. } => pending.push(*user),
                GraphDelta::Left { user, neighbors } => {
                    if let Some(i) = pending.iter().position(|&p| p == *user) {
                        pending.swap_remove(i);
                    }
                    for &nb in neighbors {
                        touched.mark(nb as usize);
                    }
                    self.unassign(*user, neighbors);
                    stats.left += 1;
                }
                GraphDelta::Rewired { a, b, added } => {
                    self.on_edge(*a, *b, *added);
                    touched.mark(*a);
                    touched.mark(*b);
                }
            }
        }

        // 2. Attach arrivals (their edges are live in `g` by now).
        // One scratch tally map serves every attach/refine call in the
        // batch — per-vertex map allocations would dominate the repair
        // cost at scale.  BTreeMap, not HashMap: the winner scan in
        // `neighbor_slots` iterates this map, and layout bit-identity
        // requires that walk to be order-deterministic.
        let mut scratch: BTreeMap<usize, usize> = BTreeMap::new();
        for &u in &pending {
            if !users.is_active(u) || self.assignment[u] != NONE {
                continue;
            }
            self.attach(u, g, &mut scratch);
            touched.mark(u);
            stats.joined += 1;
        }

        // 3. Greedy boundary refinement over delta-touched vertices.
        stats.refine_moves = self.refine(g, touched.list(), &mut scratch);

        // 4. Local re-cut of dirty subgraphs + their cut-edge neighbors.
        self.local_repair(users, &mut stats);

        // 5. Quality backstop: full HiCut when drift exceeds the bound.
        if self.monitor.exceeded(self.cut) {
            self.full_recut(users);
            stats.full_recut = true;
        }
        stats.cut_edges = self.cut;
        stats.reference_cut = self.monitor.reference();

        // Telemetry: the repair span's outcome, plus a drift instant
        // and the live layout gauges every batch.
        span.field("deltas", stats.deltas as f64);
        span.field("joined", stats.joined as f64);
        span.field("left", stats.left as f64);
        span.field("refine_moves", stats.refine_moves as f64);
        span.field("regions", stats.regions as f64);
        span.field("full_recut", f64::from(u8::from(stats.full_recut)));
        span.field("cut_edges", stats.cut_edges as f64);
        let drift = self.monitor.drift(self.cut);
        trace::instant(
            "partition.drift",
            &[
                ("drift", drift),
                ("cut_edges", self.cut as f64),
                ("reference", self.monitor.reference() as f64),
            ],
        );
        CUT_EDGES_GAUGE.set(self.cut as i64);
        DRIFT_PPM_GAUGE.set((drift * 1e6) as i64);
        self.repaired_to = users.topology_version();
        stats
    }

    // -- accessors ----------------------------------------------------------

    /// Materialize the live layout (compacted, creation order).
    pub fn partition(&self) -> Partition {
        Partition {
            subgraphs: self.slots.iter().filter(|s| !s.is_empty()).cloned().collect(),
        }
    }

    /// Live inter-subgraph association count.
    pub fn cut_edges_now(&self) -> usize {
        self.cut
    }

    /// Assigned (alive) vertex count.
    pub fn covered(&self) -> usize {
        self.covered
    }

    pub fn subgraph_count(&self) -> usize {
        self.slots.iter().filter(|s| !s.is_empty()).count()
    }

    /// Slot id of `v` (slot ids are stable between recuts but not
    /// compact; use [`Self::partition`] for consumer-facing layouts).
    pub fn slot_of(&self, v: usize) -> Option<usize> {
        match self.assignment.get(v) {
            Some(&s) if s != NONE => Some(s),
            _ => None,
        }
    }

    pub fn monitor(&self) -> &DriftMonitor {
        &self.monitor
    }

    /// Topology version the live layout corresponds to.
    pub fn repaired_to(&self) -> Version {
        self.repaired_to
    }

    /// Record that the live layout matches topology version `to` —
    /// for callers that computed a layout themselves and installed it
    /// via [`Self::adopt`] (which, taking only a [`Graph`], cannot
    /// stamp the version on its own).
    pub fn note_repaired(&mut self, to: Version) {
        self.repaired_to = to;
    }

    /// Is the layout current for `users`, i.e. repaired to its exact
    /// topology version?  The serve loop publishes the complementary
    /// lag ([`Version::lag`]) as the `version.lag.layout` gauge.
    pub fn is_current(&self, users: &DynamicGraph) -> bool {
        self.repaired_to == users.topology_version()
    }

    /// Debug/test support: do the incremental counters match a from-
    /// scratch recount of the current graph?
    pub fn counters_consistent(&self, g: &Graph) -> bool {
        let (cut, boundary) = self.count_from_scratch(g);
        cut == self.cut && boundary == self.boundary
    }

    /// Debug/test support: is this a disjoint cover of exactly the
    /// active vertices, with coherent internal indices?
    // analyze:allow(panic) — assignment/slots/pos_in_slot are kept index-consistent by assign/remove_from_slot.
    pub fn is_valid_cover(&self, users: &DynamicGraph) -> bool {
        let n = users.capacity();
        if self.assignment.len() != n {
            return false;
        }
        let mut seen = vec![0usize; n];
        for (s, slot) in self.slots.iter().enumerate() {
            for (i, &v) in slot.iter().enumerate() {
                if self.assignment[v] != s || self.pos_in_slot[v] != i {
                    return false;
                }
                seen[v] += 1;
            }
        }
        (0..n).all(|v| seen[v] == usize::from(users.is_active(v)))
    }

    // -- delta handlers -----------------------------------------------------

    /// Remove a departed vertex; `neighbors` is its adjacency at
    /// departure (from the `Left` event).
    // analyze:allow(panic) — vertex and neighbor ids are < n per apply's capacity contract; slot bookkeeping is index-consistent.
    fn unassign(&mut self, v: usize, neighbors: &[u32]) {
        let s = self.assignment[v];
        if s == NONE {
            return;
        }
        for &nb in neighbors {
            let t = self.assignment[nb as usize];
            if t != NONE && t != s {
                self.cut -= 1;
                self.boundary[s] -= 1;
                self.boundary[t] -= 1;
            }
        }
        self.remove_from_slot(v, s);
    }

    /// One association change between (possibly unassigned) endpoints.
    // analyze:allow(panic) — edge endpoints are < n per apply's capacity contract; boundary/baseline are sized with the slots.
    fn on_edge(&mut self, a: usize, b: usize, added: bool) {
        let (sa, sb) = (self.assignment[a], self.assignment[b]);
        if sa == NONE || sb == NONE || sa == sb {
            return;
        }
        if added {
            self.cut += 1;
            self.boundary[sa] += 1;
            self.boundary[sb] += 1;
        } else {
            self.cut -= 1;
            self.boundary[sa] -= 1;
            self.boundary[sb] -= 1;
        }
    }

    /// Tally the slots of `v`'s assigned neighbors into `scratch`
    /// (cleared first).  Returns `(neighbors in home, best other slot,
    /// its count)`; the winner is deterministic (max count, smallest
    /// slot id on ties).  `home = NONE` tallies everything as "other".
    // analyze:allow(panic) — neighbor ids are < n and `assignment` is sized n.
    fn neighbor_slots(
        &self,
        g: &Graph,
        v: usize,
        home: usize,
        scratch: &mut BTreeMap<usize, usize>,
    ) -> (usize, usize, usize) {
        scratch.clear();
        let mut here = 0usize;
        for &nb in g.neighbors(v) {
            let t = self.assignment[nb as usize];
            if t == NONE {
                continue;
            }
            if t == home {
                here += 1;
            } else {
                *scratch.entry(t).or_insert(0) += 1;
            }
        }
        let mut best = NONE;
        let mut best_c = 0usize;
        for (&t, &c) in scratch.iter() {
            if c > best_c || (c == best_c && c > 0 && t < best) {
                best = t;
                best_c = c;
            }
        }
        (here, best, best_c)
    }

    /// Attach an arrival to the majority subgraph among its assigned
    /// neighbors (locally minimizes new cut edges); singleton if none.
    // analyze:allow(panic) — slot ids come from alloc_slot and vertex ids are < n per the capacity contract.
    fn attach(&mut self, v: usize, g: &Graph, scratch: &mut BTreeMap<usize, usize>) {
        let (_, best, _) = self.neighbor_slots(g, v, NONE, scratch);
        let s = if best == NONE {
            self.alloc_slot()
        } else {
            best
        };
        self.assign(v, s);
        for &nb in g.neighbors(v) {
            let t = self.assignment[nb as usize];
            if t != NONE && t != s {
                self.cut += 1;
                self.boundary[s] += 1;
                self.boundary[t] += 1;
            }
        }
    }

    // -- refinement ---------------------------------------------------------

    /// Greedy migration sweeps over `touched`: move a vertex to the
    /// neighboring subgraph holding strictly more of its neighbors
    /// (classic LDG-style local search on the cut objective; strict
    /// improvement guarantees termination).
    // analyze:allow(panic) — candidate vertices come from live slots and boundary/baseline are sized with the slots.
    fn refine(
        &mut self,
        g: &Graph,
        touched: &[usize],
        scratch: &mut BTreeMap<usize, usize>,
    ) -> usize {
        if self.cfg.refine_passes == 0 || touched.is_empty() {
            return 0;
        }
        let cap = ((self.covered as f64 * self.cfg.max_subgraph_frac) as usize).max(8);
        let mut moves = 0;
        for _ in 0..self.cfg.refine_passes {
            let mut moved_any = false;
            for &v in touched {
                let s = self.assignment[v];
                if s == NONE {
                    continue;
                }
                let (here, best, best_c) = self.neighbor_slots(g, v, s, scratch);
                if best != NONE && best_c > here && self.slots[best].len() < cap {
                    self.migrate(v, s, best, g);
                    moves += 1;
                    moved_any = true;
                }
            }
            if !moved_any {
                break;
            }
        }
        moves
    }

    // analyze:allow(panic) — slot ids s/t are live (callers check) and vertex/neighbor ids are < n.
    fn migrate(&mut self, v: usize, s: usize, t: usize, g: &Graph) {
        for &nb in g.neighbors(v) {
            let u = self.assignment[nb as usize];
            if u == NONE {
                continue;
            }
            if u == s {
                // Was intra-s, becomes an s↔t cut edge.
                self.cut += 1;
                self.boundary[s] += 1;
                self.boundary[t] += 1;
            } else if u == t {
                // Was an s↔t cut edge, becomes intra-t.
                self.cut -= 1;
                self.boundary[s] -= 1;
                self.boundary[t] -= 1;
            } else {
                // Cross before and after; v's side moves s → t.
                self.boundary[s] -= 1;
                self.boundary[t] += 1;
            }
        }
        self.remove_from_slot(v, s);
        self.assign(v, t);
    }

    // -- local region re-cut ------------------------------------------------

    /// Dissolve degraded neighborhoods and re-cut them in place.
    ///
    /// Every subgraph whose boundary grew past the threshold seeds a
    /// *region*: the dirty slot plus the slots one cut edge away.
    /// Regions that share a slot would re-cut overlapping vertex sets,
    /// so they are coalesced first (union–find over shared slots);
    /// what remains is a list of vertex-**disjoint** regions whose
    /// [`hicut_region`] calls cannot interact — they are dispatched to
    /// scoped workers when `cfg.workers > 1`, and the result is
    /// identical to the sequential order for any worker count
    /// (regions are extracted, re-cut and re-slotted in one
    /// deterministic order; `hicut_region` itself is input-order
    /// independent).
    // analyze:allow(panic) — region vertices come from live slots; DisjointSets and Touched are sized g.len().
    fn local_repair(&mut self, users: &DynamicGraph, stats: &mut RepairStats) {
        let g = users.graph();
        let mut dirty: Vec<usize> = Vec::new();
        for s in 0..self.slots.len() {
            if self.slots[s].is_empty() {
                continue;
            }
            let base = self.baseline[s];
            let growth = ((base as f64 * self.cfg.local_growth) as usize)
                .max(self.cfg.local_slack);
            if self.boundary[s] > base + growth {
                dirty.push(s);
            }
        }
        if dirty.is_empty() {
            return;
        }
        // Region of each dirty slot: itself + cut-edge neighbor slots.
        let mut in_region = vec![false; self.slots.len()];
        let mut regions: Vec<Vec<usize>> = Vec::with_capacity(dirty.len());
        for &s in &dirty {
            let mut slots = vec![s];
            in_region[s] = true;
            for &v in &self.slots[s] {
                for &nb in g.neighbors(v) {
                    let t = self.assignment[nb as usize];
                    if t != NONE && !in_region[t] {
                        in_region[t] = true;
                        slots.push(t);
                    }
                }
            }
            for &t in &slots {
                in_region[t] = false; // reset the scratch marks
            }
            regions.push(slots);
        }
        // Coalesce regions that share any slot: their vertex sets
        // overlap, so their re-cuts are not independent.
        let mut sets = DisjointSets::new(regions.len());
        let mut owner = vec![NONE; self.slots.len()];
        for (i, slots) in regions.iter().enumerate() {
            for &t in slots {
                if owner[t] == NONE {
                    owner[t] = i;
                } else {
                    sets.union(i, owner[t]);
                }
            }
        }
        let mut grouped: Vec<Vec<usize>> = vec![Vec::new(); regions.len()];
        for (i, slots) in regions.into_iter().enumerate() {
            grouped[sets.find(i)].extend(slots);
        }
        // Disjoint, deterministically ordered regions (slot sets).
        let threshold = self.cfg.max_region_frac * self.covered as f64;
        let mut group_verts: Vec<Vec<usize>> = Vec::new();
        for slots in &mut grouped {
            if slots.is_empty() {
                continue;
            }
            slots.sort_unstable();
            slots.dedup();
            let n_verts: usize = slots.iter().map(|&s| self.slots[s].len()).sum();
            if n_verts as f64 > threshold {
                // Too big for surgery; the drift monitor decides what's
                // next for this neighborhood.
                continue;
            }
            stats.region_subgraphs += slots.len();
            stats.region_vertices += n_verts;
            // Extract the region's vertices and free its slots.
            let mut verts: Vec<usize> = Vec::with_capacity(n_verts);
            for &s in slots.iter() {
                let members = std::mem::take(&mut self.slots[s]);
                for &v in &members {
                    self.assignment[v] = NONE;
                }
                self.covered -= members.len();
                self.boundary[s] = 0;
                self.baseline[s] = 0;
                self.free.push(s);
                verts.extend(members);
            }
            group_verts.push(verts);
        }
        if group_verts.is_empty() {
            return;
        }
        stats.local_recut = true;
        stats.regions = group_verts.len();

        // Re-cut every region — concurrently when configured.  The
        // regions' vertex sets are disjoint and `hicut_region` treats
        // everything outside its region as assigned, so the calls are
        // independent; `map_scoped` returns results in input order.
        let workers = self.cfg.workers.min(group_verts.len());
        let recut: Vec<Vec<Vec<usize>>> = if workers > 1 {
            ThreadPool::map_scoped(&group_verts, workers, |verts| {
                hicut_region(g, verts, |v| users.is_active(v))
            })
        } else {
            group_verts
                .iter()
                .map(|verts| hicut_region(g, verts, |v| users.is_active(v)))
                .collect()
        };
        for subs in recut {
            for sub in subs {
                let s = self.alloc_slot();
                for v in sub {
                    self.assign(v, s);
                }
            }
        }
        // Region surgery invalidates the incremental counters: rebuild
        // them with one adjacency scan (O(N+E), far below a full cut).
        self.recount(g);
        self.baseline.copy_from_slice(&self.boundary);
        self.local_recuts += stats.regions;
    }

    // -- plumbing -----------------------------------------------------------

    // analyze:allow(panic) — free-list entries are valid slot indices by construction.
    fn alloc_slot(&mut self) -> usize {
        if let Some(s) = self.free.pop() {
            debug_assert!(self.slots[s].is_empty());
            s
        } else {
            self.slots.push(Vec::new());
            self.boundary.push(0);
            self.baseline.push(0);
            self.slots.len() - 1
        }
    }

    // analyze:allow(panic) — slot ids come from alloc_slot and v < n per the capacity contract.
    fn assign(&mut self, v: usize, s: usize) {
        self.assignment[v] = s;
        self.pos_in_slot[v] = self.slots[s].len();
        self.slots[s].push(v);
        self.covered += 1;
    }

    // analyze:allow(panic) — pos_in_slot[v] is maintained as v's exact position in slots[s] by assign and swap-removal.
    fn remove_from_slot(&mut self, v: usize, s: usize) {
        let idx = self.pos_in_slot[v];
        self.slots[s].swap_remove(idx);
        if idx < self.slots[s].len() {
            let moved = self.slots[s][idx];
            self.pos_in_slot[moved] = idx;
        }
        self.assignment[v] = NONE;
        self.covered -= 1;
        if self.slots[s].is_empty() {
            debug_assert_eq!(self.boundary[s], 0, "empty subgraph kept boundary");
            self.baseline[s] = 0;
            self.free.push(s);
        }
    }

    // analyze:allow(panic) — `assignment` is sized g.len() and edge endpoints are < g.len().
    fn count_from_scratch(&self, g: &Graph) -> (usize, Vec<usize>) {
        let mut cut = 0usize;
        let mut boundary = vec![0usize; self.slots.len()];
        for v in 0..self.assignment.len() {
            let s = self.assignment[v];
            if s == NONE {
                continue;
            }
            for &nb in g.neighbors(v) {
                let nb = nb as usize;
                if nb <= v {
                    continue;
                }
                let t = self.assignment[nb];
                if t != NONE && t != s {
                    cut += 1;
                    boundary[s] += 1;
                    boundary[t] += 1;
                }
            }
        }
        (cut, boundary)
    }

    fn recount(&mut self, g: &Graph) {
        let (cut, boundary) = self.count_from_scratch(g);
        self.cut = cut;
        self.boundary = boundary;
    }
}

/// Minimal union–find for coalescing overlapping repair regions.
/// Roots are the smallest member index, so group order (and therefore
/// the slot-allocation order after re-cuts) is deterministic.
struct DisjointSets(Vec<usize>);

impl DisjointSets {
    fn new(n: usize) -> Self {
        DisjointSets((0..n).collect())
    }

    // analyze:allow(panic) — `parent` is sized n and only ever stores indices < n.
    fn find(&mut self, mut i: usize) -> usize {
        while self.0[i] != i {
            self.0[i] = self.0[self.0[i]]; // path halving
            i = self.0[i];
        }
        i
    }

    // analyze:allow(panic) — roots returned by find are < n, within `rank`/`parent`.
    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            let (lo, hi) = if ra < rb { (ra, rb) } else { (rb, ra) };
            self.0[hi] = lo;
        }
    }
}

/// Dedup-marking visit list for delta-touched vertices.
struct Touched {
    mark: Vec<bool>,
    list: Vec<usize>,
}

impl Touched {
    fn new(n: usize) -> Self {
        Touched { mark: vec![false; n], list: Vec::new() }
    }

    // analyze:allow(panic) — `seen` is sized n and marks are vertex ids < n per the repair capacity contract.
    fn mark(&mut self, v: usize) {
        if !self.mark[v] {
            self.mark[v] = true;
            self.list.push(v);
        }
    }

    fn list(&self) -> &[usize] {
        &self.list
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dynamic::ChurnConfig;
    use crate::util::rng::Rng;

    fn two_triangles(rng: &mut Rng) -> DynamicGraph {
        // Two triangles joined by one bridge: HiCut cuts the bridge.
        let g = Graph::from_edges(
            6,
            &[(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5), (2, 3)],
        );
        DynamicGraph::new(g, vec![1.0; 6], 2000.0, rng)
    }

    #[test]
    fn from_users_matches_full_hicut() {
        let mut rng = Rng::seed_from(1);
        let users = two_triangles(&mut rng);
        let inc = IncrementalPartitioner::from_users(&users, IncrementalConfig::default());
        let fresh = hicut(users.graph(), |v| users.is_active(v));
        assert_eq!(inc.cut_edges_now(), fresh.cut_edges(users.graph()));
        assert_eq!(inc.covered(), 6);
        assert!(inc.is_valid_cover(&users));
        assert!(inc.counters_consistent(users.graph()));
        assert_eq!(inc.monitor().reference(), inc.cut_edges_now());
    }

    #[test]
    fn left_and_joined_deltas_keep_counters_exact() {
        let mut rng = Rng::seed_from(2);
        let mut users = two_triangles(&mut rng);
        users.record_deltas(true);
        let mut inc = IncrementalPartitioner::from_users(&users, IncrementalConfig::default());
        users.remove_users(&[2]);
        let added = users.add_users(1, &mut |_, _| crate::graph::dynamic::Pos {
            x: 0.0,
            y: 0.0,
        }, &mut rng);
        assert_eq!(added, vec![2]);
        assert!(users.add_association(2, 0));
        assert!(users.add_association(2, 1));
        let deltas = users.drain_deltas();
        let stats = inc.apply(&users, &deltas);
        assert_eq!((stats.left, stats.joined), (1, 1));
        assert!(inc.is_valid_cover(&users));
        assert!(inc.counters_consistent(users.graph()));
        // 2 rejoined attached to the {0,1} side; the old bridge died
        // with the departure, so the layout has no cut edges left.
        assert_eq!(inc.cut_edges_now(), 0);
    }

    #[test]
    fn rewired_deltas_update_cut_in_o1() {
        let mut rng = Rng::seed_from(3);
        let mut users = two_triangles(&mut rng);
        users.record_deltas(true);
        let mut inc = IncrementalPartitioner::from_users(&users, IncrementalConfig::default());
        let before = inc.cut_edges_now();
        // A second bridge between the triangles is a new cut edge.
        assert!(users.add_association(0, 5));
        let deltas = users.drain_deltas();
        let stats = inc.apply(&users, &deltas);
        // Refinement may immediately repair it by migrating a vertex;
        // either way the counters must be exact.
        assert!(inc.counters_consistent(users.graph()));
        assert!(stats.cut_edges <= before + 1);
    }

    #[test]
    fn disjoint_sets_coalesce_deterministically() {
        let mut s = DisjointSets::new(5);
        s.union(3, 1);
        s.union(4, 3);
        assert_eq!(s.find(4), 1);
        assert_eq!(s.find(3), 1);
        assert_eq!(s.find(0), 0);
        s.union(0, 4);
        assert_eq!(s.find(1), 0); // smallest member is always the root
        assert_eq!(s.find(2), 2);
    }

    /// Disconnected "edge cluster" scenario: many small communities, so
    /// dirty regions stay small and plural.
    fn clustered_users(blocks: usize, block_n: usize, rng: &mut Rng) -> DynamicGraph {
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for b in 0..blocks {
            let off = (b * block_n) as u32;
            let g = crate::graph::generate::preferential_attachment(block_n, 3, rng);
            edges.extend(g.edge_list().into_iter().map(|(u, v)| (u + off, v + off)));
        }
        let n = blocks * block_n;
        let g = Graph::from_edges(n, &edges);
        DynamicGraph::new(g, vec![1.0; n], 2000.0, rng)
    }

    #[test]
    fn parallel_region_repair_is_worker_count_invariant() {
        // Identical churn stream into a sequential (workers = 1) and a
        // concurrent (workers = 4) partitioner: the repaired layouts
        // must match slot for slot at every step.  Aggressive local
        // thresholds + a disabled drift fallback force the dirty-region
        // machinery to carry the whole repair.
        let mut rng = Rng::seed_from(77);
        let mut users = clustered_users(16, 20, &mut rng);
        users.record_deltas(true);
        let aggressive = IncrementalConfig {
            local_growth: 0.0,
            local_slack: 0,
            max_region_frac: 0.5,
            drift_bound: 1e9, // local repair only — no full-recut resets
            ..IncrementalConfig::default()
        };
        let mut seq = IncrementalPartitioner::from_users(&users, aggressive.clone());
        let mut par = IncrementalPartitioner::from_users(&users, IncrementalConfig {
            workers: 4,
            ..aggressive
        });
        let cfg = ChurnConfig::default();
        for _ in 0..12 {
            users.step(&cfg, &mut rng);
            let deltas = users.drain_deltas();
            let s = seq.apply(&users, &deltas);
            let p = par.apply(&users, &deltas);
            assert_eq!(seq.partition().subgraphs, par.partition().subgraphs);
            assert_eq!(s.cut_edges, p.cut_edges);
            assert_eq!(s.regions, p.regions);
            assert!(par.is_valid_cover(&users));
            assert!(par.counters_consistent(users.graph()));
        }
        assert_eq!(seq.local_recuts, par.local_recuts);
        assert!(
            par.local_recuts > 0,
            "churn never exercised the region re-cut path"
        );
    }

    #[test]
    fn repaired_to_tracks_the_topology_version() {
        let mut rng = Rng::seed_from(9);
        let mut users = two_triangles(&mut rng);
        users.record_deltas(true);
        let mut inc = IncrementalPartitioner::from_users(&users, IncrementalConfig::default());
        assert!(inc.is_current(&users), "from_users stamps the build version");

        // Churn without repair → stale; apply → current again.
        users.remove_users(&[5]);
        assert!(!inc.is_current(&users));
        assert!(inc.repaired_to() < users.topology_version());
        let deltas = users.drain_deltas();
        inc.apply(&users, &deltas);
        assert!(inc.is_current(&users));
        assert_eq!(inc.repaired_to().lag(users.topology_version()), 0);

        // External adopt can't see the graph's version; the caller
        // stamps it explicitly.
        users.remove_users(&[4]);
        let fresh = hicut(users.graph(), |v| users.is_active(v));
        inc.adopt(users.graph(), fresh.subgraphs);
        assert!(!inc.is_current(&users));
        inc.note_repaired(users.topology_version());
        assert!(inc.is_current(&users));
    }

    #[test]
    fn churn_sequence_respects_drift_limit() {
        let mut rng = Rng::seed_from(4);
        let g = crate::graph::generate::preferential_attachment(120, 4, &mut rng);
        let mut users = DynamicGraph::new(g, vec![1.0; 120], 2000.0, &mut rng);
        users.record_deltas(true);
        let mut inc = IncrementalPartitioner::from_users(&users, IncrementalConfig::default());
        let cfg = ChurnConfig::default();
        for _ in 0..10 {
            users.step(&cfg, &mut rng);
            let deltas = users.drain_deltas();
            let stats = inc.apply(&users, &deltas);
            assert!(inc.is_valid_cover(&users));
            assert!(inc.counters_consistent(users.graph()));
            assert!(
                stats.cut_edges <= inc.monitor().limit(),
                "drift limit violated: {} > {}",
                stats.cut_edges,
                inc.monitor().limit()
            );
        }
        assert_eq!(inc.steps, 10);
    }
}
