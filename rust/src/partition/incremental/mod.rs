//! Incremental partition maintenance: delta-driven HiCut repair.
//!
//! The seed coordinator re-ran full HiCut — O(N² + N·E), §4.4 — on
//! every §3.2 churn step, even though a 20% churn step perturbs only
//! part of the layout.  This subsystem keeps the optimized layout
//! *live* instead:
//!
//! 1. [`crate::graph::dynamic::DynamicGraph`] records a typed
//!    [`crate::graph::dynamic::GraphDelta`] journal (`Moved` / `Joined`
//!    / `Left` / `Rewired`) for every mutation.
//! 2. [`IncrementalPartitioner`] owns the live partition plus
//!    per-subgraph boundary (cut-edge) bookkeeping, and repairs each
//!    delta batch in O(Δ·deg): departures unassign with exact counter
//!    fixes, arrivals attach to the majority neighbor subgraph, and a
//!    bounded greedy refinement sweep over delta-touched vertices
//!    migrates vertices whose cut contribution strictly improves.
//! 3. Subgraphs whose boundary grew past a threshold since their last
//!    cut are *locally* re-cut: each dirty subgraph plus its cut-edge
//!    neighbors dissolves into a region, overlapping regions are
//!    coalesced, and [`crate::partition::hicut::hicut_region`] re-cuts
//!    the resulting vertex-disjoint regions in place — concurrently
//!    across workers when [`IncrementalConfig::workers`] > 1, with a
//!    layout identical to the sequential order.
//! 4. A [`DriftMonitor`] compares the live inter-subgraph association
//!    count against the last full HiCut and triggers a full recut when
//!    drift exceeds a configurable bound — so quality is never
//!    silently lost, and the O(N² + N·E) cost is paid only when the
//!    layout has genuinely eroded.

mod drift;
mod repair;

pub use drift::DriftMonitor;
pub use repair::{IncrementalPartitioner, RepairStats};

/// Tuning knobs for [`IncrementalPartitioner`].
#[derive(Clone, Debug)]
pub struct IncrementalConfig {
    /// Relative cut-quality drift tolerated before the full-HiCut
    /// fallback, measured against the cut-edge count of the last full
    /// cut (paper-default scenarios use 0.10).
    pub drift_bound: f64,
    /// Absolute slack on the drift limit so tiny reference cuts don't
    /// trip the monitor on single-edge noise.
    pub drift_slack: usize,
    /// Relative per-subgraph boundary growth (vs the boundary at its
    /// last cut) that marks a subgraph dirty for a local re-cut.
    pub local_growth: f64,
    /// Absolute slack on the dirty threshold.
    pub local_slack: usize,
    /// Local re-cut regions larger than this fraction of the covered
    /// vertices are skipped: at that size region surgery costs about as
    /// much as the full recut the drift monitor would order anyway.
    pub max_region_frac: f64,
    /// Greedy refinement sweeps over delta-touched vertices per batch.
    pub refine_passes: usize,
    /// Refinement never grows a subgraph past this fraction of the
    /// covered vertices (keeps greedy migration from agglomerating the
    /// layout into one giant subgraph that no edge server could host).
    pub max_subgraph_frac: f64,
    /// Worker threads for layout surgery: independent (vertex-disjoint)
    /// dirty regions are re-cut concurrently, and drift-monitor full
    /// recuts run through [`crate::partition::parallel`].  `1` keeps
    /// everything on the caller's thread; the repaired layout is
    /// identical for every value (see the shard/merge equivalence
    /// argument in `partition::parallel`).
    pub workers: usize,
}

impl Default for IncrementalConfig {
    fn default() -> Self {
        IncrementalConfig {
            drift_bound: 0.10,
            drift_slack: 16,
            local_growth: 0.5,
            local_slack: 4,
            max_region_frac: 0.2,
            refine_passes: 2,
            max_subgraph_frac: 0.25,
            workers: 1,
        }
    }
}
