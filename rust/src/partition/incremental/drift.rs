//! Cut-quality drift monitor: the "quality is never silently lost"
//! guarantee of the incremental path.
//!
//! Repair is a heuristic; over many churn steps its layout can erode.
//! The monitor tracks the live inter-subgraph association count
//! against the count recorded at the last full HiCut and reports when
//! the drift bound is exceeded, at which point the owner re-runs the
//! §4 full cut and resets the reference.

/// Watches the live cut-edge count against the last full-cut
/// reference.
#[derive(Clone, Debug)]
pub struct DriftMonitor {
    bound: f64,
    slack: usize,
    reference: usize,
    /// Drift evaluations performed.
    pub checks: usize,
    /// Times the bound was exceeded (each triggers a full recut).
    pub trips: usize,
}

impl DriftMonitor {
    pub fn new(bound: f64, slack: usize) -> Self {
        DriftMonitor { bound, slack, reference: 0, checks: 0, trips: 0 }
    }

    /// Record the cut-edge count of a fresh full cut.
    pub fn set_reference(&mut self, cut: usize) {
        self.reference = cut;
    }

    /// Cut-edge count of the last full cut.
    pub fn reference(&self) -> usize {
        self.reference
    }

    /// Highest tolerated cut-edge count before fallback.
    pub fn limit(&self) -> usize {
        (self.reference as f64 * (1.0 + self.bound)) as usize + self.slack
    }

    /// Relative drift of `cut` above the reference (0.0 at or below).
    pub fn drift(&self, cut: usize) -> f64 {
        cut.saturating_sub(self.reference) as f64 / self.reference.max(1) as f64
    }

    /// Evaluate one repaired layout; true means a full recut is due.
    pub fn exceeded(&mut self, cut: usize) -> bool {
        self.checks += 1;
        if cut > self.limit() {
            self.trips += 1;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trips_only_past_bound_plus_slack() {
        let mut m = DriftMonitor::new(0.10, 5);
        m.set_reference(100);
        assert_eq!(m.limit(), 115);
        assert!(!m.exceeded(100));
        assert!(!m.exceeded(115));
        assert!(m.exceeded(116));
        assert_eq!((m.checks, m.trips), (3, 1));
    }

    #[test]
    fn slack_covers_zero_reference() {
        let mut m = DriftMonitor::new(0.10, 8);
        assert!(!m.exceeded(8));
        assert!(m.exceeded(9));
    }

    #[test]
    fn drift_is_relative_overshoot() {
        let mut m = DriftMonitor::new(0.10, 0);
        m.set_reference(200);
        assert_eq!(m.drift(180), 0.0);
        assert!((m.drift(220) - 0.10).abs() < 1e-12);
    }
}
