//! PJRT [`Backend`] (cargo feature `xla`): compiles the AOT-lowered
//! HLO text through the PJRT C API and executes on whatever device
//! the linked `xla` crate provides.
//!
//! With the vendored `vendor/xla-stub` crate this compiles but every
//! compile/execute call returns a descriptive error; point the `xla`
//! dependency at a real `xla-rs` checkout (see the stub's crate docs)
//! to run artifacts through XLA.  Input/output matrices follow the
//! same flattening convention as [`super::mat`]; literals are
//! reshaped to the manifest shapes on the way in and flattened back
//! on the way out.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::Context;

use super::backend::Backend;
use super::manifest::ExeSpec;
use crate::tensor::Matrix;
use crate::util::metrics::GLOBAL as METRICS;

pub struct PjrtBackend {
    client: xla::PjRtClient,
    root: PathBuf,
    /// name → compiled executable, compiled lazily on first use.
    compiled: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtBackend {
    pub fn new(root: PathBuf) -> crate::Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "pjrt backend: platform {} ({} devices)",
            client.platform_name(),
            client.device_count()
        );
        Ok(PjrtBackend { client, root, compiled: Mutex::new(HashMap::new()) })
    }

    fn compile(&self, name: &str, spec: &ExeSpec) -> crate::Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.compiled.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let hlo_path = self.root.join(&spec.path);
        // lint:allow(wall-clock) — compile latency is a reported metric,
        // nothing deterministic branches on it.
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .with_context(|| format!("parsing HLO {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name} through PJRT"))?;
        METRICS.observe("runtime.compile", t0.elapsed().as_secs_f64());
        log::info!("pjrt backend: compiled {name} in {:.3}s", t0.elapsed().as_secs_f64());
        let exe = Arc::new(exe);
        self.compiled.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn execute(&self, name: &str, spec: &ExeSpec, inputs: &[&Matrix]) -> crate::Result<Vec<Matrix>> {
        let exe = self.compile(name, spec)?;
        let mut lits = Vec::with_capacity(inputs.len());
        for (m, ts) in inputs.iter().zip(&spec.inputs) {
            lits.push(to_literal(m, &ts.shape).with_context(|| {
                format!("{name}: binding input {:?} to shape {:?}", ts.name, ts.shape)
            })?);
        }
        let bufs = exe.execute(&lits).with_context(|| format!("executing {name}"))?;
        let device0 = bufs.into_iter().next().with_context(|| format!("{name}: no device output"))?;
        let mut lits_out = Vec::with_capacity(device0.len());
        for b in &device0 {
            lits_out.push(b.to_literal_sync().with_context(|| format!("{name}: readback"))?);
        }
        // Multi-output artifacts come back as a single tuple literal.
        if lits_out.len() == 1 && spec.outputs.len() > 1 {
            lits_out = lits_out[0].to_tuple().with_context(|| format!("{name}: untuple"))?;
        }
        lits_out.iter().map(to_matrix).collect()
    }
}

/// Matrix → device literal with the manifest's n-d shape.
fn to_literal(m: &Matrix, shape: &[usize]) -> crate::Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&m.data).reshape(&dims)?)
}

/// Literal → Matrix with [`super::mat`]'s flattening convention.
fn to_matrix(lit: &xla::Literal) -> crate::Result<Matrix> {
    let dims: Vec<usize> =
        lit.array_shape().context("output shape")?.dims().iter().map(|&d| d as usize).collect();
    super::mat(&dims, lit.to_vec::<f32>().context("output readback")?)
}
