//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! This is the only place the `xla` crate is touched.  The flow per
//! executable (see /opt/xla-example/load_hlo for the reference):
//!
//! ```text
//! HLO text --HloModuleProto::from_text_file--> proto
//!          --XlaComputation::from_proto------> computation
//!          --PjRtClient::compile-------------> PjRtLoadedExecutable
//! ```
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids.
//!
//! [`Runtime`] owns one CPU PJRT client, the parsed `manifest.json`,
//! and a lazy cache of compiled executables keyed by artifact name.
//! All executables are lowered with `return_tuple=True`, so results
//! come back as one tuple literal that [`Executable::run`] decomposes.

pub mod manifest;

pub use manifest::{ExeSpec, Manifest, TensorSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context};

use crate::tensor::Matrix;
use crate::util::metrics::{Histogram, GLOBAL as METRICS};
use crate::util::trace;

/// A compiled artifact plus its manifest binding.
pub struct Executable {
    pub name: String,
    pub spec: ExeSpec,
    exe: xla::PjRtLoadedExecutable,
    /// `runtime.exec.<name>` latency handle, interned once at load so
    /// the execute paths never allocate a metric key.
    exec_hist: Histogram,
}

impl Executable {
    /// Execute with positional literal inputs; returns the decomposed
    /// output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> crate::Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let _span = trace::span("runtime.exec");
        // lint:allow(wall-clock) — real XLA execution latency feeds
        // the exec histogram; nothing deterministic reads it.
        let t0 = std::time::Instant::now();
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        self.exec_hist.observe(t0.elapsed().as_secs_f64());
        Ok(tuple.to_tuple()?)
    }

    /// Like [`Self::run`] but with borrowed inputs — lets callers keep
    /// long-lived literals (e.g. model weights) without re-uploading.
    pub fn run_borrowed(&self, inputs: &[&xla::Literal]) -> crate::Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let _span = trace::span("runtime.exec");
        // lint:allow(wall-clock) — same exec-histogram timing as the
        // owned-literal path above.
        let t0 = std::time::Instant::now();
        let result = self.exe.execute::<&xla::Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        self.exec_hist.observe(t0.elapsed().as_secs_f64());
        Ok(tuple.to_tuple()?)
    }
}

/// The process-wide artifact runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    root: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Open the artifacts directory (must contain `manifest.json`).
    pub fn open(artifacts_dir: impl AsRef<Path>) -> crate::Result<Self> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let mpath = root.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} (run `make artifacts`)", mpath.display()))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "runtime: PJRT {} with {} device(s), {} executables in manifest",
            client.platform_name(),
            client.device_count(),
            manifest.executables.len()
        );
        Ok(Runtime { client, manifest, root, cache: Mutex::new(HashMap::new()) })
    }

    /// Default artifacts location: `$GRAPHEDGE_ARTIFACTS` or `artifacts/`.
    pub fn open_default() -> crate::Result<Self> {
        let dir = std::env::var("GRAPHEDGE_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    /// Fetch (compiling + caching on first use) an executable by name.
    pub fn load(&self, name: &str) -> crate::Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .executables
            .get(name)
            .with_context(|| format!("executable {name:?} not in manifest"))?
            .clone();
        let path = self.root.join(&spec.path);
        // lint:allow(wall-clock) — one-off compile timing for the log
        // line and the `runtime.compile` sample; cold path.
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        log::info!("runtime: compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        METRICS.observe("runtime.compile", t0.elapsed().as_secs_f64());
        let exec_hist = METRICS.histogram_handle(&format!("runtime.exec.{name}"));
        let executable =
            std::sync::Arc::new(Executable { name: name.to_string(), spec, exe, exec_hist });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), executable.clone());
        Ok(executable)
    }

    /// Load a `.gta` archive relative to the artifacts root.
    pub fn load_archive(&self, rel: &str) -> crate::Result<crate::tensor::Archive> {
        Ok(crate::tensor::Archive::load(self.root.join(rel))?)
    }

    pub fn artifacts_root(&self) -> &Path {
        &self.root
    }
}

// ---------------------------------------------------------------------------
// Literal construction helpers
// ---------------------------------------------------------------------------

/// f32 literal of arbitrary shape from a flat slice.
pub fn lit(shape: &[usize], data: &[f32]) -> crate::Result<xla::Literal> {
    let numel: usize = shape.iter().product::<usize>().max(1);
    if data.len() != numel {
        bail!("literal shape {shape:?} needs {numel} values, got {}", data.len());
    }
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Matrix → 2-D literal.
pub fn lit_matrix(m: &Matrix) -> crate::Result<xla::Literal> {
    lit(&[m.rows, m.cols], &m.data)
}

/// Scalar f32 literal.
pub fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Literal → flat f32 vector.
pub fn to_vec_f32(l: &xla::Literal) -> crate::Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// Literal → Matrix (must be 2-D).
pub fn to_matrix(l: &xla::Literal) -> crate::Result<Matrix> {
    let shape = l.array_shape()?;
    let dims = shape.dims();
    if dims.len() != 2 {
        bail!("expected rank-2 literal, got {:?}", dims);
    }
    Ok(Matrix { rows: dims[0] as usize, cols: dims[1] as usize, data: l.to_vec::<f32>()? })
}
