//! Artifact runtime: bind the AOT manifest and execute artifacts
//! through a pluggable [`Backend`].
//!
//! # Backend contract
//!
//! A [`Backend`] maps `(artifact name, manifest [`ExeSpec`], input
//! matrices)` to output matrices — nothing else.  [`Runtime`] owns
//! manifest lookup and artifact caching; [`Executable::run`] owns
//! input validation, `runtime.exec` tracing, and the per-artifact
//! latency histogram, so every backend gets identical observability.
//! Two backends exist:
//!
//! * [`native::NativeBackend`] — the **default**: pure-Rust CSR/dense
//!   kernels ported from the NumPy oracles in
//!   `python/compile/kernels/ref.py` and the flat-vector DRL math in
//!   `python/compile/drl.py`, row-parallel over the crate thread pool.
//!   Runs with zero toolchain: if no `artifacts/` tree exists,
//!   [`Runtime::open_default`] synthesizes one in memory
//!   ([`native::Store`]) with the same manifest vocabulary `aot.py`
//!   writes.
//! * `PjrtBackend` (cargo feature `xla`) — compiles the lowered HLO
//!   text through the PJRT C API; the accelerator path when a real
//!   `xla` crate is linked.
//!
//! # Artifact/manifest binding
//!
//! `manifest.json` names every executable's inputs (positionally,
//! with shapes), its outputs, and — for GNN models — which leading
//! inputs are graph tensors vs which trailing inputs come from the
//! weights archive.  [`Executable::run`] enforces arity and per-input
//! element counts against those shapes; backends reporting
//! [`Backend::supports_dynamic_batch`] (the native one) additionally
//! accept any leading/batch dimension whose trailing dimensions
//! match, which is what batches `actor_fwd` over the whole VecEnv.
//!
//! # Numeric parity
//!
//! Native kernels are pinned to `ref.py` by `tests/kernel_parity.rs`
//! against committed golden vectors at **1e-4 absolute tolerance**
//! (f32 kernels vs the oracle's f64), and are bit-identical across
//! worker counts.  The PJRT path is pinned to the same oracles by the
//! JAX-side tests under `python/compile/tests/`.

pub mod backend;
pub mod manifest;
pub mod native;
#[cfg(feature = "xla")]
pub mod pjrt;

pub use backend::Backend;
pub use manifest::{ExeSpec, Manifest, TensorSpec};
pub use native::NativeBackend;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context};

use crate::graph::geb::Dataset;
use crate::tensor::{Archive, Matrix};
use crate::util::metrics::{Histogram, GLOBAL as METRICS};
use crate::util::trace;

/// A loaded artifact: manifest binding + the backend that executes it.
pub struct Executable {
    pub name: String,
    pub spec: ExeSpec,
    backend: Arc<dyn Backend>,
    /// `runtime.exec.<name>` latency handle, interned once at load so
    /// the execute paths never allocate a metric key.
    exec_hist: Histogram,
}

impl Executable {
    /// Execute with positional matrix inputs; returns one matrix per
    /// manifest output.
    pub fn run(&self, inputs: &[&Matrix]) -> crate::Result<Vec<Matrix>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let dynamic = self.backend.supports_dynamic_batch();
        for (m, ts) in inputs.iter().zip(&self.spec.inputs) {
            let numel: usize = ts.shape.iter().product::<usize>().max(1);
            if m.data.len() == numel {
                continue;
            }
            let trailing: usize =
                ts.shape.get(1..).map(|s| s.iter().product()).unwrap_or(1).max(1);
            if dynamic && !ts.shape.is_empty() && m.data.len() % trailing == 0 {
                continue; // free batch dimension
            }
            bail!(
                "{}: input {:?} has {} elements, manifest shape {:?} needs {numel}",
                self.name,
                ts.name,
                m.data.len(),
                ts.shape
            );
        }
        let _span = trace::span("runtime.exec");
        // lint:allow(wall-clock) — real backend execution latency feeds
        // the exec histogram; nothing deterministic reads it.
        let t0 = std::time::Instant::now();
        let outs = self.backend.execute(&self.name, &self.spec, inputs)?;
        self.exec_hist.observe(t0.elapsed().as_secs_f64());
        Ok(outs)
    }

    /// Whether this executable accepts a free leading/batch dimension
    /// (see [`Backend::supports_dynamic_batch`]).
    pub fn dynamic_batch(&self) -> bool {
        self.backend.supports_dynamic_batch()
    }
}

/// The process-wide artifact runtime: one backend, one manifest, a
/// lazy per-artifact cache.
pub struct Runtime {
    backend: Arc<dyn Backend>,
    pub manifest: Manifest,
    root: PathBuf,
    /// In-memory artifact set when running without a disk tree.
    store: Option<native::Store>,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Fully self-contained native runtime: synthesized manifest,
    /// weights, DRL init state and datasets from [`native::Store`] —
    /// no filesystem, no Python toolchain.
    pub fn native() -> Self {
        let store = native::Store::build();
        let manifest = store.manifest.clone();
        log::info!(
            "runtime: native backend with synthesized store ({} executables)",
            manifest.executables.len()
        );
        Runtime {
            backend: Arc::new(NativeBackend::auto()),
            manifest,
            root: PathBuf::from("<native-store>"),
            store: Some(store),
            cache: Mutex::new(HashMap::new()),
        }
    }

    /// Open an on-disk artifacts directory (must contain
    /// `manifest.json`).  Executes through PJRT when the `xla`
    /// feature is enabled, through the native kernels otherwise (the
    /// native backend reads the same weights archives and datasets —
    /// only the HLO files go unused).
    pub fn open(artifacts_dir: impl AsRef<Path>) -> crate::Result<Self> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let mpath = root.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} (run `make artifacts`)", mpath.display()))?;
        let manifest = Manifest::parse(&text)?;
        let backend = disk_backend(&root)?;
        log::info!(
            "runtime: {} backend over {} ({} executables in manifest)",
            backend.name(),
            root.display(),
            manifest.executables.len()
        );
        Ok(Runtime { backend, manifest, root, store: None, cache: Mutex::new(HashMap::new()) })
    }

    /// Default runtime resolution, in order:
    /// 1. `GRAPHEDGE_BACKEND=native` forces the synthesized store;
    /// 2. `$GRAPHEDGE_ARTIFACTS` names a disk tree (must exist);
    /// 3. `artifacts/manifest.json` if present;
    /// 4. otherwise the self-contained [`Runtime::native`].
    pub fn open_default() -> crate::Result<Self> {
        if std::env::var("GRAPHEDGE_BACKEND").as_deref() == Ok("native") {
            return Ok(Self::native());
        }
        if let Ok(dir) = std::env::var("GRAPHEDGE_ARTIFACTS") {
            return Self::open(dir);
        }
        if Path::new("artifacts/manifest.json").exists() {
            return Self::open("artifacts");
        }
        Ok(Self::native())
    }

    /// Name of the executing backend ("native", "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Fetch (caching on first use) an executable by name.
    pub fn load(&self, name: &str) -> crate::Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .executables
            .get(name)
            .with_context(|| format!("executable {name:?} not in manifest"))?
            .clone();
        let exec_hist = METRICS.histogram_handle(&format!("runtime.exec.{name}"));
        let executable = Arc::new(Executable {
            name: name.to_string(),
            spec,
            backend: self.backend.clone(),
            exec_hist,
        });
        self.cache.lock().unwrap().insert(name.to_string(), executable.clone());
        Ok(executable)
    }

    /// Load a `.gta` archive by manifest-relative path — from the
    /// synthesized store, or from disk under the artifacts root.
    pub fn load_archive(&self, rel: &str) -> crate::Result<Archive> {
        if let Some(store) = &self.store {
            return store
                .archive(rel)
                .cloned()
                .with_context(|| format!("archive {rel:?} not in native store"));
        }
        Ok(Archive::load(self.root.join(rel))?)
    }

    /// Load a dataset by manifest name (`citeseer` / `cora` /
    /// `pubmed`) — from the synthesized store, or from its `.geb`
    /// file under the artifacts root.
    pub fn dataset(&self, name: &str) -> crate::Result<Dataset> {
        if let Some(store) = &self.store {
            return store
                .dataset(name)
                .cloned()
                .with_context(|| format!("dataset {name:?} not in native store"));
        }
        let spec = self
            .manifest
            .datasets
            .get(name)
            .with_context(|| format!("dataset {name:?} not in manifest"))?;
        Ok(Dataset::load(self.root.join(&spec.path), name)?)
    }

    pub fn artifacts_root(&self) -> &Path {
        &self.root
    }
}

/// Backend for an on-disk artifact tree, by compiled feature set.
#[cfg(feature = "xla")]
fn disk_backend(root: &Path) -> crate::Result<Arc<dyn Backend>> {
    Ok(Arc::new(pjrt::PjrtBackend::new(root.to_path_buf())?))
}

#[cfg(not(feature = "xla"))]
fn disk_backend(_root: &Path) -> crate::Result<Arc<dyn Backend>> {
    Ok(Arc::new(NativeBackend::auto()))
}

/// Build a [`Matrix`] carrying the row-major flattening of an
/// n-dimensional tensor: shape `[]` → 1×1, `[n]` → n×1, and
/// `[d0, d1, ...]` → `d0 × (d1·d2·…)`.  This is the shape convention
/// every [`Backend`] input/output uses.
///
/// ```
/// use graphedge::runtime::mat;
/// let m = mat(&[2, 3, 2], (0..12).map(|v| v as f32).collect()).unwrap();
/// assert_eq!((m.rows, m.cols), (2, 6));
/// assert!(mat(&[2, 2], vec![0.0; 3]).is_err());
/// ```
pub fn mat(shape: &[usize], data: Vec<f32>) -> crate::Result<Matrix> {
    let numel: usize = shape.iter().product::<usize>().max(1);
    if data.len() != numel {
        bail!("shape {shape:?} needs {numel} values, got {}", data.len());
    }
    let (rows, cols) = match shape.len() {
        0 => (1, 1),
        1 => (shape[0], 1),
        _ => (shape[0], shape[1..].iter().product()),
    };
    Ok(Matrix { rows, cols, data })
}

/// Scalar (`[]`-shaped) backend input.
pub fn mat_scalar(v: f32) -> Matrix {
    Matrix { rows: 1, cols: 1, data: vec![v] }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_flattening_convention() {
        assert_eq!(mat(&[], vec![7.0]).unwrap().rows, 1);
        let v = mat(&[5], vec![0.0; 5]).unwrap();
        assert_eq!((v.rows, v.cols), (5, 1));
        let t = mat(&[4, 3, 2], vec![0.0; 24]).unwrap();
        assert_eq!((t.rows, t.cols), (4, 6));
        assert!(mat(&[2], vec![0.0; 3]).is_err());
    }

    #[test]
    fn native_runtime_loads_and_validates_arity() {
        let rt = Runtime::native();
        assert_eq!(rt.backend_name(), "native");
        let exe = rt.load("sgc_pubmed").unwrap();
        assert_eq!(exe.spec.graph_inputs, vec!["x", "a_norm"]);
        let x = Matrix::zeros(4, 4);
        let err = exe.run(&[&x]).unwrap_err();
        assert!(format!("{err}").contains("expected 4 inputs"), "{err}");
    }

    #[test]
    fn shape_validation_allows_dynamic_batch_only() {
        let rt = Runtime::native();
        let exe = rt.load("actor_fwd").unwrap();
        assert!(exe.dynamic_batch());
        let m = rt.manifest.constant("m_agents").unwrap();
        let obs = rt.manifest.constant("obs_dim").unwrap();
        let p_actor = rt.manifest.constant("p_actor").unwrap();
        let actor = rt.load_archive("drl/drl_init.gta").unwrap();
        let actor = mat(&[m, p_actor], actor.get("actor").unwrap().f32_data.clone()).unwrap();
        // 3 env slots worth of observations: batch dim scales freely.
        let obs_in = Matrix::zeros(3 * m, obs);
        let out = exe.run(&[&actor, &obs_in]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!((out[0].rows, out[0].cols), (3 * m, 2));
        // A non-multiple of the trailing dims still fails.
        let ragged = Matrix { rows: 1, cols: obs - 1, data: vec![0.0; obs - 1] };
        assert!(exe.run(&[&actor, &ragged]).is_err());
    }

    #[test]
    fn runtime_dataset_and_archive_come_from_store() {
        let rt = Runtime::native();
        let ds = rt.dataset("citeseer").unwrap();
        assert_eq!(ds.n, 1200);
        assert!(rt.dataset("nope").is_err());
        assert!(rt.load_archive("models/gat_cora.weights.gta").is_ok());
        assert!(rt.load_archive("models/zzz.weights.gta").is_err());
    }
}
