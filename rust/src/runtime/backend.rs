//! The [`Backend`] trait: how the runtime executes a named artifact.
//!
//! A backend is a pure function from `(artifact name, manifest spec,
//! input matrices)` to output matrices.  Everything stateful —
//! manifest lookup, input-shape validation, execution tracing and
//! latency histograms — lives in [`crate::runtime::Executable`], so a
//! backend only implements the math.  Two implementations exist:
//!
//! * [`crate::runtime::native::NativeBackend`] (default) — pure-Rust
//!   CSR/dense kernels, row-parallel over the [`crate::util::threadpool`].
//! * `PjrtBackend` (cargo feature `xla`) — compiles the AOT-lowered
//!   HLO artifacts through the PJRT C API.
//!
//! Both consume the same artifact contract (see
//! [`crate::runtime::manifest`]) and are pinned to the same oracle,
//! `python/compile/kernels/ref.py` — the native backend via the
//! committed golden vectors in `tests/kernel_parity.rs` (tolerance
//! `1e-4` absolute), the PJRT path via the JAX tests in
//! `python/compile/tests/`.

use crate::runtime::manifest::ExeSpec;
use crate::tensor::Matrix;

/// Executes named artifacts against dense matrix inputs.
///
/// # Contract
///
/// * `execute` receives inputs in the exact order of
///   `spec.inputs`; each matrix's `data` holds the row-major
///   flattening of the tensor named there (see
///   [`crate::runtime::mat`] for the shape → matrix convention).
/// * Outputs are returned in the order of `spec.outputs`.
/// * A backend must be deterministic: same inputs, same outputs, for
///   any worker count (the xtask lint layer and
///   `tests/kernel_parity.rs` hold the native backend to this
///   bit-exactly).
/// * Implementations must be `Send + Sync`; one backend instance is
///   shared by every executable the runtime hands out.
pub trait Backend: Send + Sync {
    /// Short stable name for logs/metrics ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// Run artifact `name` (whose manifest entry is `spec`) on
    /// `inputs`, returning one matrix per `spec.outputs` entry.
    fn execute(&self, name: &str, spec: &ExeSpec, inputs: &[&Matrix]) -> crate::Result<Vec<Matrix>>;

    /// Whether leading-dimension (batch) sizes may differ from the
    /// manifest shapes.  The native kernels derive batch sizes from
    /// the inputs, so they accept any row count whose trailing
    /// dimensions match; AOT-compiled PJRT artifacts are fixed-shape.
    fn supports_dynamic_batch(&self) -> bool {
        false
    }
}
