//! Parsed form of `artifacts/manifest.json` (written by aot.py).

use std::collections::BTreeMap;

use anyhow::Context;

use crate::util::json::Value;

/// Shape/name of one executable input or output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One executable entry.
#[derive(Clone, Debug, Default)]
pub struct ExeSpec {
    /// HLO text path relative to the artifacts root.
    pub path: String,
    /// Weights archive path (GNN models only).
    pub weights: Option<String>,
    /// Graph-input names in positional order (GNN models only).
    pub graph_inputs: Vec<String>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<String>,
}

/// One dataset entry.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub path: String,
    pub n: usize,
    pub e: usize,
    pub feat: usize,
    pub feat_pad: usize,
    pub classes: usize,
}

/// The whole manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub constants: BTreeMap<String, f64>,
    pub datasets: BTreeMap<String, DatasetSpec>,
    pub executables: BTreeMap<String, ExeSpec>,
    pub accuracy: BTreeMap<String, f64>,
}

impl Manifest {
    pub fn parse(text: &str) -> crate::Result<Self> {
        let v = Value::parse(text).context("manifest.json")?;
        let mut m = Manifest::default();

        if let Some(consts) = v.get("constants").and_then(|c| c.as_obj()) {
            for (k, val) in consts {
                if let Some(n) = val.as_f64() {
                    m.constants.insert(k.clone(), n);
                }
            }
        }
        if let Some(acc) = v.get("accuracy").and_then(|c| c.as_obj()) {
            for (k, val) in acc {
                if let Some(n) = val.as_f64() {
                    m.accuracy.insert(k.clone(), n);
                }
            }
        }
        if let Some(ds) = v.get("datasets").and_then(|c| c.as_obj()) {
            for (k, val) in ds {
                m.datasets.insert(
                    k.clone(),
                    DatasetSpec {
                        path: val.get("path").and_then(|p| p.as_str()).unwrap_or("").into(),
                        n: val.get("n").and_then(|x| x.as_usize()).unwrap_or(0),
                        e: val.get("e").and_then(|x| x.as_usize()).unwrap_or(0),
                        feat: val.get("feat").and_then(|x| x.as_usize()).unwrap_or(0),
                        feat_pad: val.get("feat_pad").and_then(|x| x.as_usize()).unwrap_or(0),
                        classes: val.get("classes").and_then(|x| x.as_usize()).unwrap_or(0),
                    },
                );
            }
        }
        if let Some(exes) = v.get("executables").and_then(|c| c.as_obj()) {
            for (k, val) in exes {
                let inputs = val
                    .get("inputs")
                    .and_then(|i| i.as_arr())
                    .map(|arr| {
                        arr.iter()
                            .map(|t| TensorSpec {
                                name: t.get("name").and_then(|n| n.as_str()).unwrap_or("").into(),
                                shape: t
                                    .get("shape")
                                    .and_then(|s| s.as_usize_vec())
                                    .unwrap_or_default(),
                            })
                            .collect()
                    })
                    .unwrap_or_default();
                let outputs = val
                    .get("outputs")
                    .and_then(|o| o.as_arr())
                    .map(|arr| {
                        arr.iter()
                            .filter_map(|t| t.get("name").and_then(|n| n.as_str()))
                            .map(String::from)
                            .collect()
                    })
                    .unwrap_or_default();
                let graph_inputs = val
                    .get("graph_inputs")
                    .and_then(|g| g.as_arr())
                    .map(|arr| {
                        arr.iter().filter_map(|s| s.as_str()).map(String::from).collect()
                    })
                    .unwrap_or_default();
                m.executables.insert(
                    k.clone(),
                    ExeSpec {
                        path: val.get("path").and_then(|p| p.as_str()).unwrap_or("").into(),
                        weights: val.get("weights").and_then(|w| w.as_str()).map(String::from),
                        graph_inputs,
                        inputs,
                        outputs,
                    },
                );
            }
        }
        Ok(m)
    }

    pub fn constant(&self, name: &str) -> crate::Result<usize> {
        self.constants
            .get(name)
            .map(|&v| v as usize)
            .with_context(|| format!("manifest constant {name:?} missing"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "constants": {"n_max": 320, "m_agents": 4},
      "accuracy": {"gcn_cora": 0.65},
      "datasets": {"cora": {"path": "data/cora.geb", "n": 2708, "e": 5278,
                             "feat": 1433, "feat_pad": 1536, "classes": 7}},
      "executables": {
        "gcn_cora": {
          "path": "models/gcn_cora.hlo.txt",
          "weights": "models/gcn_cora.weights.gta",
          "graph_inputs": ["x", "a_norm"],
          "inputs": [{"name": "x", "shape": [320, 1536]},
                     {"name": "a_norm", "shape": [320, 320]},
                     {"name": "w0", "shape": [1536, 64]}],
          "outputs": [{"name": "logits"}]
        }
      }
    }"#;

    #[test]
    fn parses_all_sections() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.constant("n_max").unwrap(), 320);
        assert_eq!(m.datasets["cora"].classes, 7);
        let e = &m.executables["gcn_cora"];
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[0].name, "x");
        assert_eq!(e.inputs[0].shape, vec![320, 1536]);
        assert_eq!(e.graph_inputs, vec!["x", "a_norm"]);
        assert_eq!(e.weights.as_deref(), Some("models/gcn_cora.weights.gta"));
        assert_eq!(e.outputs, vec!["logits"]);
        assert!((m.accuracy["gcn_cora"] - 0.65).abs() < 1e-9);
    }

    #[test]
    fn missing_constant_errors() {
        let m = Manifest::parse("{}").unwrap();
        assert!(m.constant("nope").is_err());
    }
}
