//! Native implementations of the four DRL artifacts, ported from
//! `python/compile/drl.py`: `actor_fwd`, `maddpg_train`, `ppo_fwd`,
//! `ppo_train`.
//!
//! Each function mirrors the JAX graph closed-form — the forward
//! passes reuse [`super::mlp`], and the training steps implement the
//! gradients `jax.value_and_grad` derives for those graphs (MSE
//! critic loss, deterministic-policy-gradient actor loss through the
//! *post-update* critic, PPO clipped surrogate + value MSE − entropy
//! bonus), followed by `drl.py`'s Adam and soft-target updates.
//!
//! Dimensions are derived from the *input shapes*, not hard-coded:
//! the agent count from the reward width, the observation width from
//! `obs.cols / m`, and MLP output widths by solving the flat
//! parameter-vector length (every width is validated against
//! [`mlp::flat_len`] before use), so these kernels serve any manifest
//! whose tensors are internally consistent.  Batch (leading)
//! dimensions are free, which is what lets `actor_fwd` run one
//! `[E·M, OBS]` forward for the whole VecEnv instead of E per-slot
//! calls.

use anyhow::ensure;

use super::mlp::{self, Act};
use crate::tensor::Matrix;

const GAMMA: f32 = 0.99;
const TAU: f32 = 0.01;
const PPO_CLIP: f32 = 0.2;
const PPO_VCOEF: f32 = 0.5;
const PPO_ENTCOEF: f32 = 0.01;

/// Solve an MLP's output width from its flat parameter length and
/// input width, validating the result round-trips.
fn solve_out_dim(what: &str, p_len: usize, in_dim: usize) -> crate::Result<usize> {
    let h = mlp::HID;
    let fixed = in_dim * h + h + 2 * (h * h + h);
    ensure!(
        p_len > fixed && (p_len - fixed) % (h + 1) == 0,
        "{what}: flat param length {p_len} does not fit an {in_dim}->{h}^3->k MLP"
    );
    let out = (p_len - fixed) / (h + 1);
    ensure!(
        mlp::flat_len(&mlp::dims(in_dim, out)) == p_len,
        "{what}: inconsistent flat param length {p_len}"
    );
    Ok(out)
}

fn expect_inputs<'a>(
    what: &str,
    inputs: &'a [&'a Matrix],
    n: usize,
) -> crate::Result<&'a [&'a Matrix]> {
    ensure!(inputs.len() == n, "{what} expects {n} inputs, got {}", inputs.len());
    Ok(inputs)
}

/// Copy columns `[lo, lo+width)` of `src` into a fresh matrix.
fn col_block(src: &Matrix, lo: usize, width: usize) -> Matrix {
    let mut out = Matrix::zeros(src.rows, width);
    for r in 0..src.rows {
        out.row_mut(r).copy_from_slice(&src.row(r)[lo..lo + width]);
    }
    out
}

/// `[left | right]` horizontal concatenation.
fn hconcat(left: &Matrix, right: &Matrix) -> Matrix {
    assert_eq!(left.rows, right.rows);
    let mut out = Matrix::zeros(left.rows, left.cols + right.cols);
    for r in 0..left.rows {
        out.row_mut(r)[..left.cols].copy_from_slice(left.row(r));
        out.row_mut(r)[left.cols..].copy_from_slice(right.row(r));
    }
    out
}

fn scalar(v: f32) -> Matrix {
    Matrix { rows: 1, cols: 1, data: vec![v] }
}

/// `drl.py actor_fwd`: `actor [M, P_ACTOR]`, `obs [k·M, OBS]` →
/// `[k·M, ACT]`.  Row `r` uses actor `r % M`, so the single-env case
/// (`k = 1`) is exactly the vmapped JAX artifact and the VecEnv case
/// stacks one group of M rows per slot.
pub fn actor_fwd(inputs: &[&Matrix], workers: usize) -> crate::Result<Vec<Matrix>> {
    let inputs = expect_inputs("actor_fwd", inputs, 2)?;
    let (actor, obs) = (inputs[0], inputs[1]);
    let m = actor.rows;
    ensure!(m > 0, "actor_fwd: empty actor params");
    ensure!(
        obs.rows % m == 0,
        "actor_fwd: obs rows {} not a multiple of agent count {m}",
        obs.rows
    );
    let groups = obs.rows / m;
    let act = solve_out_dim("actor_fwd", actor.cols, obs.cols)?;
    let d = mlp::dims(obs.cols, act);
    let mut out = Matrix::zeros(obs.rows, act);
    for mi in 0..m {
        let mut sub = Matrix::zeros(groups, obs.cols);
        for g in 0..groups {
            sub.row_mut(g).copy_from_slice(obs.row(g * m + mi));
        }
        let cache = mlp::forward(actor.row(mi), &d, &sub, Act::Sigmoid, workers);
        for g in 0..groups {
            out.row_mut(g * m + mi).copy_from_slice(cache.output().row(g));
        }
    }
    Ok(vec![out])
}

/// `drl.py maddpg_train`: one full MADDPG update for all M agents.
///
/// Input order (matching the manifest):
/// `actor, critic, t_actor, t_critic, m_a, v_a, m_c, v_c, step,
///  s, a, r, s2, done, obs, obs2`; outputs the 8 updated parameter /
/// moment matrices, `step'`, and per-agent critic/actor losses.
pub fn maddpg_train(inputs: &[&Matrix], workers: usize) -> crate::Result<Vec<Matrix>> {
    let inputs = expect_inputs("maddpg_train", inputs, 16)?;
    let (actor, critic, t_actor, t_critic) = (inputs[0], inputs[1], inputs[2], inputs[3]);
    let (m_a, v_a, m_c, v_c, step) = (inputs[4], inputs[5], inputs[6], inputs[7], inputs[8]);
    let (s, a, r, s2, done, obs, obs2) =
        (inputs[9], inputs[10], inputs[11], inputs[12], inputs[13], inputs[14], inputs[15]);

    let batch = s.rows;
    let m = r.cols;
    ensure!(batch > 0 && m > 0, "maddpg_train: empty batch or agent set");
    ensure!(
        a.cols % m == 0 && obs.cols % m == 0,
        "maddpg_train: action/obs widths not divisible by agent count {m}"
    );
    let act = a.cols / m;
    let obs_dim = obs.cols / m;
    let state = s.cols;
    for (mat, rows, cols, what) in [
        (a, batch, m * act, "a"),
        (r, batch, m, "r"),
        (s2, batch, state, "s2"),
        (done, batch, m, "done"),
        (obs, batch, m * obs_dim, "obs"),
        (obs2, batch, m * obs_dim, "obs2"),
    ] {
        ensure!(
            mat.rows == rows && mat.cols == cols,
            "maddpg_train: {what} is [{}, {}], want [{rows}, {cols}]",
            mat.rows,
            mat.cols
        );
    }
    let adims = mlp::dims(obs_dim, act);
    let cdims = mlp::dims(state + m * act, 1);
    for (p, d, what) in [(actor, &adims, "actor"), (critic, &cdims, "critic")] {
        ensure!(
            p.rows == m && p.cols == mlp::flat_len(d),
            "maddpg_train: {what} params are [{}, {}], want [{m}, {}]",
            p.rows,
            p.cols,
            mlp::flat_len(d)
        );
    }
    let step2 = step.data.first().copied().unwrap_or(0.0) + 1.0;

    // Target actions A' from the target actors on obs2.
    let mut a2 = Matrix::zeros(batch, m * act);
    for mi in 0..m {
        let o2 = col_block(obs2, mi * obs_dim, obs_dim);
        let cache = mlp::forward(t_actor.row(mi), &adims, &o2, Act::Sigmoid, workers);
        for t in 0..batch {
            a2.row_mut(t)[mi * act..(mi + 1) * act].copy_from_slice(cache.output().row(t));
        }
    }
    let x2 = hconcat(s2, &a2);
    let x1 = hconcat(s, a);

    let mut actor2 = actor.clone();
    let mut critic2 = critic.clone();
    let mut t_actor2 = t_actor.clone();
    let mut t_critic2 = t_critic.clone();
    let mut m_a2 = m_a.clone();
    let mut v_a2 = v_a.clone();
    let mut m_c2 = m_c.clone();
    let mut v_c2 = v_c.clone();
    let mut closs = Matrix::zeros(m, 1);
    let mut aloss = Matrix::zeros(m, 1);

    let inv_b = 1.0 / batch as f32;
    for mi in 0..m {
        // Critic update: MSE against the frozen target y (Eq. 29/30).
        let q_next = mlp::forward(t_critic.row(mi), &cdims, &x2, Act::None, workers);
        let q = mlp::forward(critic.row(mi), &cdims, &x1, Act::None, workers);
        let mut dq = Matrix::zeros(batch, 1);
        let mut cl = 0.0f32;
        for t in 0..batch {
            let y = r.at(t, mi) + (1.0 - done.at(t, mi)) * GAMMA * q_next.output().at(t, 0);
            let e = q.output().at(t, 0) - y;
            cl += e * e;
            dq.set(t, 0, 2.0 * e * inv_b);
        }
        closs.set(mi, 0, cl * inv_b);
        let (cgrad, _) = mlp::backward(critic.row(mi), &cdims, &q, &dq, false, workers);
        mlp::adam(critic2.row_mut(mi), &cgrad, m_c2.row_mut(mi), v_c2.row_mut(mi), step2);

        // Actor update: -mean Q(s, joint with agent mi's slice replaced
        // by π_mi(obs_mi)), evaluated on the *updated* critic (Eq. 28).
        let o = col_block(obs, mi * obs_dim, obs_dim);
        let pi = mlp::forward(actor.row(mi), &adims, &o, Act::Sigmoid, workers);
        let mut xj = x1.clone();
        for t in 0..batch {
            let lo = state + mi * act;
            xj.row_mut(t)[lo..lo + act].copy_from_slice(pi.output().row(t));
        }
        let qj = mlp::forward(critic2.row(mi), &cdims, &xj, Act::None, workers);
        let mean_q: f32 = qj.output().data.iter().sum::<f32>() * inv_b;
        aloss.set(mi, 0, -mean_q);
        let dqj = Matrix { rows: batch, cols: 1, data: vec![-inv_b; batch] };
        let (_, dxj) = mlp::backward(critic2.row(mi), &cdims, &qj, &dqj, true, workers);
        let dxj = dxj.expect("backward(want_dx) returns dx");
        // Slice the joint-input gradient at agent mi's action columns
        // and fold the sigmoid derivative to reach pre-activations.
        let mut dpi = Matrix::zeros(batch, act);
        for t in 0..batch {
            for j in 0..act {
                let g = dxj.at(t, state + mi * act + j);
                let y = pi.output().at(t, j);
                dpi.set(t, j, g * y * (1.0 - y));
            }
        }
        let (agrad, _) = mlp::backward(actor.row(mi), &adims, &pi, &dpi, false, workers);
        mlp::adam(actor2.row_mut(mi), &agrad, m_a2.row_mut(mi), v_a2.row_mut(mi), step2);

        // Soft target updates (Eqs. 31-32), from the post-update nets.
        for (t, &p) in t_actor2.row_mut(mi).iter_mut().zip(actor2.row(mi)) {
            *t = TAU * p + (1.0 - TAU) * *t;
        }
        for (t, &p) in t_critic2.row_mut(mi).iter_mut().zip(critic2.row(mi)) {
            *t = TAU * p + (1.0 - TAU) * *t;
        }
    }

    Ok(vec![
        actor2,
        critic2,
        t_actor2,
        t_critic2,
        m_a2,
        v_a2,
        m_c2,
        v_c2,
        scalar(step2),
        closs,
        aloss,
    ])
}

/// `drl.py ppo_fwd`: `flat [P_PPO]`, `s [B, STATE]` →
/// `(logits [B, M], value [B])`.
pub fn ppo_fwd(inputs: &[&Matrix], workers: usize) -> crate::Result<Vec<Matrix>> {
    let inputs = expect_inputs("ppo_fwd", inputs, 2)?;
    let (flat, s) = (inputs[0], inputs[1]);
    let out_dim = solve_out_dim("ppo_fwd", flat.data.len(), s.cols)?;
    ensure!(out_dim >= 2, "ppo_fwd: output head needs >= 2 columns, got {out_dim}");
    let m = out_dim - 1;
    let d = mlp::dims(s.cols, out_dim);
    let cache = mlp::forward(&flat.data, &d, s, Act::None, workers);
    let out = cache.output();
    Ok(vec![col_block(out, 0, m), col_block(out, m, 1)])
}

/// `drl.py ppo_train`: one clipped-surrogate PPO epoch.
///
/// Inputs `flat, m_p, v_p, step, s, act_onehot, old_logp, adv, ret`;
/// outputs `flat', m', v', step', policy_loss, value_loss, entropy`.
pub fn ppo_train(inputs: &[&Matrix], workers: usize) -> crate::Result<Vec<Matrix>> {
    let inputs = expect_inputs("ppo_train", inputs, 9)?;
    let (flat, m_p, v_p, step) = (inputs[0], inputs[1], inputs[2], inputs[3]);
    let (s, onehot, old_logp, adv, ret) = (inputs[4], inputs[5], inputs[6], inputs[7], inputs[8]);
    let horizon = s.rows;
    ensure!(horizon > 0, "ppo_train: empty batch");
    let out_dim = solve_out_dim("ppo_train", flat.data.len(), s.cols)?;
    ensure!(out_dim >= 2, "ppo_train: output head needs >= 2 columns, got {out_dim}");
    let m = out_dim - 1;
    ensure!(
        onehot.rows == horizon && onehot.cols == m,
        "ppo_train: act_onehot is [{}, {}], want [{horizon}, {m}]",
        onehot.rows,
        onehot.cols
    );
    for (mat, what) in [(old_logp, "old_logp"), (adv, "adv"), (ret, "ret")] {
        ensure!(
            mat.data.len() == horizon,
            "ppo_train: {what} has {} elements, want {horizon}",
            mat.data.len()
        );
    }
    ensure!(
        m_p.data.len() == flat.data.len() && v_p.data.len() == flat.data.len(),
        "ppo_train: Adam moment length mismatch"
    );
    let step2 = step.data.first().copied().unwrap_or(0.0) + 1.0;
    let d = mlp::dims(s.cols, out_dim);
    let cache = mlp::forward(&flat.data, &d, s, Act::None, workers);
    let out = cache.output();

    let inv_t = 1.0 / horizon as f32;
    let mut dout = Matrix::zeros(horizon, out_dim);
    let (mut pl_sum, mut vl_sum, mut ent_sum) = (0.0f32, 0.0f32, 0.0f32);
    let mut logp_all = vec![0.0f32; m];
    for t in 0..horizon {
        let row = out.row(t);
        let logits = &row[..m];
        let value = row[m];
        // log_softmax.
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for &z in logits {
            sum += (z - max).exp();
        }
        let lse = max + sum.ln();
        for (lp, &z) in logp_all.iter_mut().zip(logits) {
            *lp = z - lse;
        }
        let logp: f32 =
            logp_all.iter().zip(onehot.row(t)).map(|(&lp, &oh)| lp * oh).sum();
        let adv_t = adv.data[t];
        let ratio = (logp - old_logp.data[t]).exp();
        let clipped = ratio.clamp(1.0 - PPO_CLIP, 1.0 + PPO_CLIP);
        let (surr1, surr2) = (ratio * adv_t, clipped * adv_t);
        pl_sum += -surr1.min(surr2);
        // min() routes the gradient to the ratio branch at ties; on the
        // strict clipped branch the clip is saturated, so d/dratio = 0.
        let dlogp = if surr1 <= surr2 { -adv_t * ratio * inv_t } else { 0.0 };
        let entropy: f32 = -logp_all.iter().map(|&lp| lp.exp() * lp).sum::<f32>();
        ent_sum += entropy;
        let v_err = value - ret.data[t];
        vl_sum += v_err * v_err;
        let drow = dout.row_mut(t);
        for j in 0..m {
            let p = logp_all[j].exp();
            // Surrogate through log-softmax + entropy-bonus gradient
            // (total loss carries -ENTCOEF * entropy).
            drow[j] = dlogp * (onehot.at(t, j) - p)
                + PPO_ENTCOEF * p * (logp_all[j] + entropy) * inv_t;
        }
        drow[m] = PPO_VCOEF * 2.0 * v_err * inv_t;
    }
    let (grad, _) = mlp::backward(&flat.data, &d, &cache, &dout, false, workers);
    let mut flat2 = flat.clone();
    let mut m2 = m_p.clone();
    let mut v2 = v_p.clone();
    mlp::adam(&mut flat2.data, &grad, &mut m2.data, &mut v2.data, step2);
    Ok(vec![
        flat2,
        m2,
        v2,
        scalar(step2),
        scalar(pl_sum * inv_t),
        scalar(vl_sum * inv_t),
        scalar(ent_sum * inv_t),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const OBS: usize = 21;
    const M: usize = 4;
    const ACT: usize = 2;
    const STATE: usize = M * OBS;

    fn randm(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for v in &mut m.data {
            *v = rng.range_f64(-0.5, 0.5) as f32;
        }
        m
    }

    fn stacked_params(n: usize, in_dim: usize, out_dim: usize, rng: &mut Rng) -> Matrix {
        let d = mlp::dims(in_dim, out_dim);
        let p = mlp::flat_len(&d);
        let mut m = Matrix::zeros(n, p);
        for r in 0..n {
            m.row_mut(r).copy_from_slice(&mlp::init_flat(&d, rng));
        }
        m
    }

    #[test]
    fn actor_fwd_batched_rows_match_per_group_calls() {
        let mut rng = Rng::seed_from(100);
        let actor = stacked_params(M, OBS, ACT, &mut rng);
        let obs = randm(3 * M, OBS, &mut rng);
        let batched = actor_fwd(&[&actor, &obs], 2).unwrap().remove(0);
        for g in 0..3 {
            let mut group = Matrix::zeros(M, OBS);
            for mi in 0..M {
                group.row_mut(mi).copy_from_slice(obs.row(g * M + mi));
            }
            let single = actor_fwd(&[&actor, &group], 1).unwrap().remove(0);
            for mi in 0..M {
                assert_eq!(single.row(mi), batched.row(g * M + mi), "group {g} agent {mi}");
            }
        }
    }

    #[test]
    fn maddpg_train_reduces_critic_loss_and_moves_targets() {
        let mut rng = Rng::seed_from(200);
        let batch = 16;
        let actor = stacked_params(M, OBS, ACT, &mut rng);
        let critic = stacked_params(M, STATE + M * ACT, 1, &mut rng);
        let zeros_a = Matrix::zeros(M, actor.cols);
        let zeros_c = Matrix::zeros(M, critic.cols);
        let s = randm(batch, STATE, &mut rng);
        let mut a = randm(batch, M * ACT, &mut rng);
        for v in &mut a.data {
            *v = (*v + 0.5).clamp(0.0, 1.0);
        }
        let r = randm(batch, M, &mut rng);
        let s2 = randm(batch, STATE, &mut rng);
        let done = Matrix::zeros(batch, M);
        let obs = randm(batch, M * OBS, &mut rng);
        let obs2 = randm(batch, M * OBS, &mut rng);
        let step = scalar(0.0);
        let run = |actor: &Matrix,
                   critic: &Matrix,
                   t_actor: &Matrix,
                   t_critic: &Matrix,
                   m_a: &Matrix,
                   v_a: &Matrix,
                   m_c: &Matrix,
                   v_c: &Matrix,
                   step: &Matrix| {
            maddpg_train(
                &[
                    actor, critic, t_actor, t_critic, m_a, v_a, m_c, v_c, step, &s, &a, &r,
                    &s2, &done, &obs, &obs2,
                ],
                2,
            )
            .unwrap()
        };
        let mut o = run(
            &actor, &critic, &actor, &critic, &zeros_a, &zeros_a, &zeros_c, &zeros_c, &step,
        );
        assert_eq!(o.len(), 11);
        assert_eq!(o[8].data[0], 1.0, "step increments");
        let first_closs: f32 = o[9].data.iter().sum::<f32>() / M as f32;
        // Target nets moved toward the updated nets but stay distinct.
        assert_ne!(o[2].data, o[0].data);
        assert_ne!(o[2].data, actor.data);
        // Iterate a few steps; the critic loss against the (slowly
        // moving) targets must drop.
        for _ in 0..30 {
            o = run(&o[0], &o[1], &o[2], &o[3], &o[4], &o[5], &o[6], &o[7], &o[8]);
        }
        let last_closs: f32 = o[9].data.iter().sum::<f32>() / M as f32;
        assert!(
            last_closs < first_closs,
            "critic loss should fall: {first_closs} -> {last_closs}"
        );
    }

    #[test]
    fn ppo_train_step_descends_total_objective() {
        let mut rng = Rng::seed_from(300);
        let horizon = 12;
        let d = mlp::dims(STATE, M + 1);
        let flat = Matrix { rows: mlp::flat_len(&d), cols: 1, data: mlp::init_flat(&d, &mut rng) };
        let zeros = Matrix::zeros(flat.rows, 1);
        let s = randm(horizon, STATE, &mut rng);
        let mut onehot = Matrix::zeros(horizon, M);
        for t in 0..horizon {
            onehot.set(t, t % M, 1.0);
        }
        let old_logp = Matrix {
            rows: horizon,
            cols: 1,
            data: (0..horizon).map(|_| rng.range_f64(-2.0, -1.0) as f32).collect(),
        };
        let adv = randm(horizon, 1, &mut rng);
        let ret = randm(horizon, 1, &mut rng);
        let total_loss = |f: &Matrix| -> f64 {
            // Recompute drl.py's total objective from a ppo_train run's
            // reported components: pl + VCOEF*vl - ENTCOEF*ent.
            let o = ppo_train(
                &[f, &zeros, &zeros, &scalar(0.0), &s, &onehot, &old_logp, &adv, &ret],
                1,
            )
            .unwrap();
            (o[4].data[0] + PPO_VCOEF * o[5].data[0] - PPO_ENTCOEF * o[6].data[0]) as f64
        };
        // The Adam first step moves every coordinate by ±LR·≈1 in the
        // direction opposing the gradient; verify descent.
        let before = total_loss(&flat);
        let o = ppo_train(
            &[&flat, &zeros, &zeros, &scalar(0.0), &s, &onehot, &old_logp, &adv, &ret],
            2,
        )
        .unwrap();
        let after = total_loss(&o[0]);
        assert!(after < before, "PPO step should descend: {before} -> {after}");
        assert_eq!(o[3].data[0], 1.0);
    }

    #[test]
    fn shape_validation_rejects_mismatched_inputs() {
        let mut rng = Rng::seed_from(400);
        let actor = stacked_params(M, OBS, ACT, &mut rng);
        let bad_obs = randm(3, OBS, &mut rng); // 3 not divisible by M=4
        assert!(actor_fwd(&[&actor, &bad_obs], 1).is_err());
        let truncated = Matrix::zeros(M, actor.cols - 1);
        let obs = randm(M, OBS, &mut rng);
        assert!(actor_fwd(&[&truncated, &obs], 1).is_err());
    }
}
