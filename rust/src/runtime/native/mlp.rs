//! Flat-parameter MLP forward/backward + Adam, ported from
//! `python/compile/drl.py`.
//!
//! The DRL artifacts (`actor_fwd`, `maddpg_train`, `ppo_fwd`,
//! `ppo_train`) all operate on MLPs stored as one flat `f32` vector
//! per network: for each layer, `din*dout` row-major weights followed
//! by `dout` biases.  Architecture is fixed at
//! `in → 64 → 64 → 64 → out` (ReLU hidden activations, output
//! activation per call site) and the Adam hyper-parameters are
//! `drl.py`'s: `lr 3e-4, β₁ 0.9, β₂ 0.999, ε 1e-8` with bias
//! correction `m̂ = m / (1 - β₁^step)`.
//!
//! `forward` keeps the post-activation output of every layer in a
//! [`Cache`] so `backward` can run the exact reverse pass the JAX
//! autodiff produces for this architecture: `dW = aᵀ @ δ`,
//! `db = colsum(δ)`, `δ_prev = (δ @ Wᵀ) ⊙ relu'(a_prev)`.

use crate::tensor::Matrix;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

pub use super::kernels::Act;

/// Hidden width of every DRL MLP (`drl.py HID`).
pub const HID: usize = 64;
/// Adam learning rate (`drl.py LR`).
pub const LR: f32 = 3e-4;
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Layer widths for a DRL MLP: `in → 64 → 64 → 64 → out`.
pub fn dims(in_dim: usize, out_dim: usize) -> Vec<usize> {
    vec![in_dim, HID, HID, HID, out_dim]
}

/// Flat parameter-vector length for the given layer widths.
pub fn flat_len(dims: &[usize]) -> usize {
    dims.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
}

/// `(weight, bias)` offsets of each layer inside the flat vector.
fn layer_offsets(dims: &[usize]) -> Vec<(usize, usize)> {
    let mut offs = Vec::with_capacity(dims.len() - 1);
    let mut at = 0;
    for w in dims.windows(2) {
        offs.push((at, at + w[0] * w[1]));
        at += w[0] * w[1] + w[1];
    }
    offs
}

/// Post-activation outputs of every layer; `acts[0]` is the input,
/// `acts[dims.len() - 1]` the network output.
pub struct Cache {
    pub acts: Vec<Matrix>,
}

impl Cache {
    /// The forward output (last activation).
    pub fn output(&self) -> &Matrix {
        self.acts.last().expect("cache holds at least the input")
    }
}

/// Forward pass over a batch `x` (`[B, dims[0]]`), returning the
/// `[B, dims.last()]` output and the activation cache.  Hidden layers
/// use ReLU; the output layer uses `out_act`.
pub fn forward(flat: &[f32], dims: &[usize], x: &Matrix, out_act: Act, workers: usize) -> Cache {
    assert_eq!(flat.len(), flat_len(dims), "flat param length mismatch");
    assert_eq!(x.cols, dims[0], "input width mismatch");
    let n_layers = dims.len() - 1;
    let mut acts = Vec::with_capacity(dims.len());
    acts.push(x.clone());
    for (l, (w_off, b_off)) in layer_offsets(dims).into_iter().enumerate() {
        let (din, dout) = (dims[l], dims[l + 1]);
        let w = &flat[w_off..w_off + din * dout];
        let b = &flat[b_off..b_off + dout];
        let act = if l + 1 < n_layers { Act::Relu } else { out_act };
        let h = linear(&acts[l], w, b, dout, act, workers);
        acts.push(h);
    }
    Cache { acts }
}

/// One layer `act(x @ W + b)` with `W` given as a `din*dout` row-major
/// flat slice.  Row-parallel over the batch.
fn linear(x: &Matrix, w: &[f32], b: &[f32], dout: usize, act: Act, workers: usize) -> Matrix {
    let mut out = Matrix::zeros(x.rows, dout);
    if x.rows == 0 || dout == 0 {
        return out;
    }
    let mut rows: Vec<&mut [f32]> = out.data.chunks_mut(dout).collect();
    ThreadPool::map_scoped_mut(&mut rows, workers.max(1), |i, out_row| {
        out_row.copy_from_slice(b);
        for (k, &xv) in x.row(i).iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[k * dout..(k + 1) * dout];
            for (o, &wv) in out_row.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
        if act != Act::None {
            for o in out_row.iter_mut() {
                *o = act.apply(*o);
            }
        }
    });
    out
}

/// Reverse pass.  `dout` is the gradient at the *pre-activation*
/// output of the final layer (the caller folds the output activation's
/// derivative in, since it also owns the loss).  Returns the flat
/// parameter gradient and, when `want_dx`, the gradient w.r.t. the
/// network input.
pub fn backward(
    flat: &[f32],
    dims: &[usize],
    cache: &Cache,
    dout: &Matrix,
    want_dx: bool,
    workers: usize,
) -> (Vec<f32>, Option<Matrix>) {
    assert_eq!(flat.len(), flat_len(dims), "flat param length mismatch");
    let n_layers = dims.len() - 1;
    assert_eq!(cache.acts.len(), dims.len(), "cache depth mismatch");
    assert_eq!(dout.cols, dims[n_layers], "dout width mismatch");
    let batch = dout.rows;
    let offs = layer_offsets(dims);
    let mut grad = vec![0.0f32; flat.len()];
    let mut delta = dout.clone();
    let mut dx_out = None;
    for l in (0..n_layers).rev() {
        let (din, dl) = (dims[l], dims[l + 1]);
        let (w_off, b_off) = offs[l];
        let a_prev = &cache.acts[l];
        // dW = a_prevᵀ @ δ, parallel over the din weight rows.
        {
            let gw = &mut grad[w_off..w_off + din * dl];
            let mut wrows: Vec<&mut [f32]> = gw.chunks_mut(dl).collect();
            let delta_ref = &delta;
            ThreadPool::map_scoped_mut(&mut wrows, workers.max(1), |i, grow| {
                for t in 0..batch {
                    let av = a_prev.at(t, i);
                    if av == 0.0 {
                        continue;
                    }
                    for (g, &dv) in grow.iter_mut().zip(delta_ref.row(t)) {
                        *g += av * dv;
                    }
                }
            });
        }
        // db = column sums of δ.
        {
            let gb = &mut grad[b_off..b_off + dl];
            for t in 0..batch {
                for (g, &dv) in gb.iter_mut().zip(delta.row(t)) {
                    *g += dv;
                }
            }
        }
        if l == 0 && !want_dx {
            break;
        }
        // δ_prev = δ @ Wᵀ, then fold relu' for hidden layers.
        let w = &flat[w_off..w_off + din * dl];
        let mut dx = Matrix::zeros(batch, din);
        {
            let mut rows: Vec<&mut [f32]> = dx.data.chunks_mut(din.max(1)).collect();
            let delta_ref = &delta;
            ThreadPool::map_scoped_mut(&mut rows, workers.max(1), |t, dx_row| {
                let drow = delta_ref.row(t);
                for (i, o) in dx_row.iter_mut().enumerate() {
                    let wrow = &w[i * dl..(i + 1) * dl];
                    let mut s = 0.0f32;
                    for (&wv, &dv) in wrow.iter().zip(drow) {
                        s += wv * dv;
                    }
                    *o = s;
                }
                if l > 0 {
                    // acts[l] is the post-ReLU output of layer l-1.
                    for (o, &av) in dx_row.iter_mut().zip(a_prev.row(t)) {
                        if av <= 0.0 {
                            *o = 0.0;
                        }
                    }
                }
            });
        }
        if l == 0 {
            dx_out = Some(dx);
        } else {
            delta = dx;
        }
    }
    (grad, dx_out)
}

/// One Adam update in place, `step` already incremented (1-based).
pub fn adam(p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], step: f32) {
    assert!(p.len() == g.len() && p.len() == m.len() && p.len() == v.len());
    let bc1 = 1.0 - ADAM_B1.powf(step);
    let bc2 = 1.0 - ADAM_B2.powf(step);
    for i in 0..p.len() {
        m[i] = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * g[i];
        v[i] = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * g[i] * g[i];
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        p[i] -= LR * mhat / (vhat.sqrt() + ADAM_EPS);
    }
}

/// He-uniform initialisation (`drl.py init_mlp`): weights uniform in
/// `±√(6 / fan_in)`, biases zero.
pub fn init_flat(dims: &[usize], rng: &mut Rng) -> Vec<f32> {
    let mut flat = Vec::with_capacity(flat_len(dims));
    for w in dims.windows(2) {
        let bound = (6.0 / w[0] as f64).sqrt();
        for _ in 0..w[0] * w[1] {
            flat.push(rng.range_f64(-bound, bound) as f32);
        }
        flat.resize(flat.len() + w[1], 0.0);
    }
    flat
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed_from(seed);
        let mut m = Matrix::zeros(rows, cols);
        for v in &mut m.data {
            *v = rng.range_f64(-1.0, 1.0) as f32;
        }
        m
    }

    #[test]
    fn flat_len_counts_weights_and_biases() {
        // 3 → 64 → 64 → 64 → 2
        let d = dims(3, 2);
        assert_eq!(flat_len(&d), 3 * 64 + 64 + 2 * (64 * 64 + 64) + 64 * 2 + 2);
    }

    #[test]
    fn forward_is_worker_count_invariant() {
        let d = dims(5, 3);
        let mut rng = Rng::seed_from(17);
        let flat = init_flat(&d, &mut rng);
        let x = randm(9, 5, 4);
        let base = forward(&flat, &d, &x, Act::Sigmoid, 1);
        for workers in [2usize, 3, 8] {
            let got = forward(&flat, &d, &x, Act::Sigmoid, workers);
            assert_eq!(got.output(), base.output(), "workers = {workers}");
        }
    }

    #[test]
    fn sigmoid_output_in_unit_interval() {
        let d = dims(4, 2);
        let mut rng = Rng::seed_from(3);
        let flat = init_flat(&d, &mut rng);
        let x = randm(6, 4, 8);
        let out = forward(&flat, &d, &x, Act::Sigmoid, 2);
        assert!(out.output().data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    /// Central-difference check of the full backward pass: perturb a
    /// handful of parameters and compare analytic vs numeric gradient
    /// of the scalar loss `sum(out)`.
    #[test]
    fn backward_matches_finite_differences() {
        let d = vec![4, 8, 8, 8, 2];
        let mut rng = Rng::seed_from(21);
        let flat = init_flat(&d, &mut rng);
        let x = randm(5, 4, 30);
        let cache = forward(&flat, &d, &x, Act::None, 1);
        let ones = Matrix { rows: 5, cols: 2, data: vec![1.0; 10] };
        let (grad, dx) = backward(&flat, &d, &cache, &ones, true, 1);
        let loss = |f: &[f32]| -> f64 {
            forward(f, &d, &x, Act::None, 1).output().data.iter().map(|&v| v as f64).sum()
        };
        let eps = 1e-3f32;
        let mut probe = Rng::seed_from(77);
        for _ in 0..24 {
            let i = probe.below(flat.len());
            let mut lo = flat.clone();
            let mut hi = flat.clone();
            lo[i] -= eps;
            hi[i] += eps;
            let num = (loss(&hi) - loss(&lo)) / (2.0 * eps as f64);
            let ana = grad[i] as f64;
            assert!(
                (num - ana).abs() < 5e-2 * (1.0 + num.abs().max(ana.abs())),
                "param {i}: numeric {num} vs analytic {ana}"
            );
        }
        // Input gradient via the same probe.
        let dx = dx.expect("asked for dx");
        let loss_x = |xs: &Matrix| -> f64 {
            forward(&flat, &d, xs, Act::None, 1).output().data.iter().map(|&v| v as f64).sum()
        };
        for _ in 0..8 {
            let i = probe.below(x.data.len());
            let mut lo = x.clone();
            let mut hi = x.clone();
            lo.data[i] -= eps;
            hi.data[i] += eps;
            let num = (loss_x(&hi) - loss_x(&lo)) / (2.0 * eps as f64);
            let ana = dx.data[i] as f64;
            assert!(
                (num - ana).abs() < 5e-2 * (1.0 + num.abs().max(ana.abs())),
                "input {i}: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn adam_moves_params_against_gradient() {
        let mut p = vec![1.0f32; 4];
        let g = vec![1.0f32, -1.0, 1.0, -1.0];
        let mut m = vec![0.0f32; 4];
        let mut v = vec![0.0f32; 4];
        adam(&mut p, &g, &mut m, &mut v, 1.0);
        assert!(p[0] < 1.0 && p[1] > 1.0);
        // First-step magnitude is ~lr regardless of gradient scale.
        assert!((p[0] - (1.0 - LR)).abs() < 1e-5);
    }
}
