//! Synthesized in-memory artifact set for the native backend.
//!
//! When no on-disk `artifacts/` directory exists (the normal case —
//! building the Python artifact tree needs a JAX toolchain), the
//! native runtime serves manifest, weights, DRL initial state and
//! datasets from this store instead of the filesystem.  The layout
//! mirrors `python/compile/aot.py` exactly: same executable names,
//! same input/output orders, same constants vocabulary, same
//! `drl_init.gta` tensor names — so every caller binds identically
//! whether artifacts came from disk or from here.
//!
//! Differences from the AOT tree, chosen to keep debug-build test
//! runs fast: `n_max` 192 (vs 320), `batch` 128 (vs 256), smaller
//! synthetic citation graphs, and *random* (He-uniform) GNN weights —
//! the store publishes an empty `accuracy` table, which is how tests
//! know not to assert pre-trained classification quality.
//! Everything is deterministic from fixed per-key seeds.

use std::collections::BTreeMap;

use crate::graph::geb::Dataset;
use crate::graph::generate;
use crate::runtime::manifest::{DatasetSpec, ExeSpec, Manifest, TensorSpec};
use crate::tensor::gta::{Archive, Tensor};
use crate::util::rng::Rng;

use super::mlp;

/// Padded vertex capacity of every synthesized GNN executable.
pub const N_MAX: usize = 192;
/// Padded class width (`model.py C_PAD`).
pub const C_PAD: usize = 8;
/// GNN hidden width (`model.py HIDDEN`).
pub const HIDDEN: usize = 64;
/// Agent count (`drl.py M`).
pub const M_AGENTS: usize = 4;
/// Replay mini-batch (reduced from `drl.py BATCH` for test speed).
pub const BATCH: usize = 128;
/// Per-agent action width (`drl.py ACT`, paper Eq. 22).
pub const ACT_DIM: usize = 2;

const MODELS: [&str; 4] = ["gcn", "gat", "sage", "sgc"];
/// `(name, vertices, real feat dim, padded feat dim, classes)`.
const DATASETS: [(&str, usize, usize, usize, usize); 3] = [
    ("citeseer", 1200, 120, 128, 6),
    ("cora", 1400, 90, 96, 7),
    ("pubmed", 1000, 64, 64, 3),
];

/// In-memory equivalent of the `artifacts/` tree.
pub struct Store {
    pub manifest: Manifest,
    /// Archives keyed by manifest-relative path
    /// (`models/<key>.weights.gta`, `drl/drl_init.gta`).
    archives: BTreeMap<String, Archive>,
    datasets: BTreeMap<String, Dataset>,
}

impl Store {
    pub fn archive(&self, path: &str) -> Option<&Archive> {
        self.archives.get(path)
    }

    pub fn dataset(&self, name: &str) -> Option<&Dataset> {
        self.datasets.get(name)
    }

    /// Build the full synthesized artifact set (deterministic).
    pub fn build() -> Store {
        let obs = crate::drl::env::OBS;
        let state = M_AGENTS * obs;
        let p_actor = mlp::flat_len(&mlp::dims(obs, ACT_DIM));
        let p_critic = mlp::flat_len(&mlp::dims(state + M_AGENTS * ACT_DIM, 1));
        let p_ppo = mlp::flat_len(&mlp::dims(state, M_AGENTS + 1));

        let mut manifest = Manifest::default();
        for (k, v) in [
            ("n_max", N_MAX),
            ("hidden", HIDDEN),
            ("c_pad", C_PAD),
            ("m_agents", M_AGENTS),
            ("obs_dim", obs),
            ("act_dim", ACT_DIM),
            ("state_dim", state),
            ("batch", BATCH),
            ("p_actor", p_actor),
            ("p_critic", p_critic),
            ("p_ppo", p_ppo),
        ] {
            manifest.constants.insert(k.into(), v as f64);
        }

        let mut archives = BTreeMap::new();
        let mut datasets = BTreeMap::new();
        for (name, n, feat, feat_pad, classes) in DATASETS {
            let ds = synth_dataset(name, n, feat, classes);
            manifest.datasets.insert(
                name.into(),
                DatasetSpec {
                    path: format!("data/{name}.geb"),
                    n,
                    e: ds.e,
                    feat,
                    feat_pad,
                    classes,
                },
            );
            datasets.insert(name.to_string(), ds);
            for model in MODELS {
                let key = format!("{model}_{name}");
                let wpath = format!("models/{key}.weights.gta");
                let pspecs = param_specs(model, feat_pad);
                let mut inputs: Vec<TensorSpec> = model_inputs(model)
                    .iter()
                    .map(|&gi| TensorSpec {
                        name: gi.into(),
                        shape: match gi {
                            "x" => vec![N_MAX, feat_pad],
                            "inv_deg" => vec![N_MAX, 1],
                            _ => vec![N_MAX, N_MAX], // a_norm / adj
                        },
                    })
                    .collect();
                let mut rng = Rng::seed_from(seed_of(&key));
                let mut tensors = Vec::with_capacity(pspecs.len());
                for (pname, shape) in &pspecs {
                    inputs.push(TensorSpec { name: (*pname).into(), shape: shape.to_vec() });
                    tensors.push(init_tensor(pname, shape, &mut rng));
                }
                manifest.executables.insert(
                    key.clone(),
                    ExeSpec {
                        path: format!("models/{key}.hlo.txt"),
                        weights: Some(wpath.clone()),
                        graph_inputs: model_inputs(model).iter().map(|&s| s.into()).collect(),
                        inputs,
                        outputs: vec!["logits".into()],
                    },
                );
                archives.insert(wpath, Archive { tensors });
            }
        }

        drl_entries(&mut manifest, &mut archives, obs, state, p_actor, p_critic, p_ppo);
        Store { manifest, archives, datasets }
    }
}

/// `model.py MODEL_INPUTS`.
fn model_inputs(model: &str) -> &'static [&'static str] {
    match model {
        "sage" => &["x", "adj", "inv_deg"],
        "gat" => &["x", "adj"],
        // gcn / sgc propagate over the normalized adjacency.
        _ => &["x", "a_norm"],
    }
}

/// `model.py param_specs`.
fn param_specs(model: &str, feat_pad: usize) -> Vec<(&'static str, [usize; 2])> {
    let (h, c, f) = (HIDDEN, C_PAD, feat_pad);
    match model {
        "gcn" => vec![("w0", [f, h]), ("b0", [1, h]), ("w1", [h, c]), ("b1", [1, c])],
        "sgc" => vec![("w", [f, c]), ("b", [1, c])],
        "sage" => vec![
            ("ws0", [f, h]),
            ("wn0", [f, h]),
            ("b0", [1, h]),
            ("ws1", [h, c]),
            ("wn1", [h, c]),
            ("b1", [1, c]),
        ],
        "gat" => vec![
            ("w0", [f, h]),
            ("al0", [h, 1]),
            ("ar0", [h, 1]),
            ("b0", [1, h]),
            ("w1", [h, c]),
            ("al1", [c, 1]),
            ("ar1", [c, 1]),
            ("b1", [1, c]),
        ],
        other => unreachable!("unknown model {other}"),
    }
}

/// He-uniform weights, zero biases (names starting with `b`).
fn init_tensor(name: &str, shape: &[usize; 2], rng: &mut Rng) -> Tensor {
    let numel = shape[0] * shape[1];
    let data = if name.starts_with('b') {
        vec![0.0; numel]
    } else {
        let bound = (6.0 / shape[0] as f64).sqrt();
        (0..numel).map(|_| rng.range_f64(-bound, bound) as f32).collect()
    };
    Tensor { name: name.into(), shape: shape.to_vec(), f32_data: data, is_int: false }
}

/// Synthetic citation dataset: preferential-attachment topology,
/// cyclic labels, three sparse features per vertex of which one is
/// label-correlated (so even untrained models see class structure).
fn synth_dataset(name: &str, n: usize, feat: usize, classes: usize) -> Dataset {
    let mut rng = Rng::seed_from(seed_of(name));
    let graph = generate::preferential_attachment(n, 6, &mut rng);
    let block = (feat / classes).max(1);
    let mut feat_idx = Vec::with_capacity(3 * n);
    for i in 0..n {
        let lbl = i % classes;
        feat_idx.push(((lbl * block + (i / classes) % block) % feat) as u16);
        feat_idx.push(((i * 7 + 3) % feat) as u16);
        feat_idx.push(((i * 13 + lbl) % feat) as u16);
    }
    Dataset {
        name: name.into(),
        n,
        e: graph.num_edges(),
        feat_dim: feat,
        classes,
        labels: (0..n).map(|i| (i % classes) as u8).collect(),
        feat_ptr: (0..=n as u32).map(|i| 3 * i).collect(),
        feat_idx,
        graph,
    }
}

/// The four DRL executables + `drl/drl_init.gta`, mirroring
/// `aot.py drl_entries`.
fn drl_entries(
    manifest: &mut Manifest,
    archives: &mut BTreeMap<String, Archive>,
    obs: usize,
    state: usize,
    p_actor: usize,
    p_critic: usize,
    p_ppo: usize,
) {
    let (m, act, b) = (M_AGENTS, ACT_DIM, BATCH);
    let entry = |name: &str, ins: Vec<(&str, Vec<usize>)>, outs: &[&str]| ExeSpec {
        path: format!("drl/{name}.hlo.txt"),
        weights: None,
        graph_inputs: Vec::new(),
        inputs: ins
            .into_iter()
            .map(|(n, shape)| TensorSpec { name: n.into(), shape })
            .collect(),
        outputs: outs.iter().map(|&s| s.into()).collect(),
    };

    manifest.executables.insert(
        "actor_fwd".into(),
        entry(
            "actor_fwd",
            vec![("actor", vec![m, p_actor]), ("obs", vec![m, obs])],
            &["actions"],
        ),
    );
    manifest.executables.insert(
        "maddpg_train".into(),
        entry(
            "maddpg_train",
            vec![
                ("actor", vec![m, p_actor]),
                ("critic", vec![m, p_critic]),
                ("t_actor", vec![m, p_actor]),
                ("t_critic", vec![m, p_critic]),
                ("m_a", vec![m, p_actor]),
                ("v_a", vec![m, p_actor]),
                ("m_c", vec![m, p_critic]),
                ("v_c", vec![m, p_critic]),
                ("step", vec![]),
                ("s", vec![b, state]),
                ("a", vec![b, m, act]),
                ("r", vec![b, m]),
                ("s2", vec![b, state]),
                ("done", vec![b, m]),
                ("obs", vec![b, m, obs]),
                ("obs2", vec![b, m, obs]),
            ],
            &[
                "actor",
                "critic",
                "t_actor",
                "t_critic",
                "m_a",
                "v_a",
                "m_c",
                "v_c",
                "step",
                "critic_loss",
                "actor_loss",
            ],
        ),
    );
    manifest.executables.insert(
        "ppo_fwd".into(),
        entry("ppo_fwd", vec![("ppo", vec![p_ppo]), ("s", vec![1, state])], &["logits", "value"]),
    );
    manifest.executables.insert(
        "ppo_train".into(),
        entry(
            "ppo_train",
            vec![
                ("ppo", vec![p_ppo]),
                ("m_p", vec![p_ppo]),
                ("v_p", vec![p_ppo]),
                ("step", vec![]),
                ("s", vec![b, state]),
                ("act_onehot", vec![b, m]),
                ("old_logp", vec![b]),
                ("adv", vec![b]),
                ("ret", vec![b]),
            ],
            &["ppo", "m_p", "v_p", "step", "policy_loss", "value_loss", "entropy"],
        ),
    );

    // Initial parameters + optimizer state (drl_init.gta).
    let mut rng = Rng::seed_from(seed_of("drl_init"));
    let stacked = |rows: usize, in_dim: usize, out_dim: usize, rng: &mut Rng| -> Vec<f32> {
        let d = mlp::dims(in_dim, out_dim);
        let mut flat = Vec::with_capacity(rows * mlp::flat_len(&d));
        for _ in 0..rows {
            flat.extend(mlp::init_flat(&d, rng));
        }
        flat
    };
    let actor = stacked(m, obs, act, &mut rng);
    let critic = stacked(m, state + m * act, 1, &mut rng);
    let ppo = stacked(1, state, m + 1, &mut rng);
    let t = |name: &str, shape: Vec<usize>, data: Vec<f32>| Tensor {
        name: name.into(),
        shape,
        f32_data: data,
        is_int: false,
    };
    let tensors = vec![
        t("actor", vec![m, p_actor], actor.clone()),
        t("critic", vec![m, p_critic], critic.clone()),
        t("t_actor", vec![m, p_actor], actor.clone()),
        t("t_critic", vec![m, p_critic], critic.clone()),
        t("m_a", vec![m, p_actor], vec![0.0; m * p_actor]),
        t("v_a", vec![m, p_actor], vec![0.0; m * p_actor]),
        t("m_c", vec![m, p_critic], vec![0.0; m * p_critic]),
        t("v_c", vec![m, p_critic], vec![0.0; m * p_critic]),
        t("step", vec![], vec![0.0]),
        t("ppo", vec![p_ppo], ppo.clone()),
        t("ppo_m", vec![p_ppo], vec![0.0; p_ppo]),
        t("ppo_v", vec![p_ppo], vec![0.0; p_ppo]),
        t("ppo_step", vec![], vec![0.0]),
    ];
    archives.insert("drl/drl_init.gta".into(), Archive { tensors });
}

/// FNV-1a of a key string — stable per-artifact seeds.
fn seed_of(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_is_deterministic() {
        let a = Store::build();
        let b = Store::build();
        assert_eq!(a.manifest.executables.len(), b.manifest.executables.len());
        let wa = a.archive("models/gcn_cora.weights.gta").unwrap();
        let wb = b.archive("models/gcn_cora.weights.gta").unwrap();
        assert_eq!(wa.get("w0").unwrap().f32_data, wb.get("w0").unwrap().f32_data);
        assert_eq!(
            a.dataset("pubmed").unwrap().graph.num_edges(),
            b.dataset("pubmed").unwrap().graph.num_edges()
        );
    }

    #[test]
    fn manifest_mirrors_aot_layout() {
        let s = Store::build();
        assert_eq!(s.manifest.executables.len(), 12 + 4);
        assert_eq!(s.manifest.datasets.len(), 3);
        assert!(s.manifest.accuracy.is_empty(), "random weights must not claim accuracy");
        let gcn = &s.manifest.executables["gcn_cora"];
        assert_eq!(gcn.graph_inputs, vec!["x", "a_norm"]);
        assert_eq!(gcn.inputs.len(), 2 + 4);
        assert_eq!(gcn.inputs[0].shape, vec![N_MAX, 96]);
        let train = &s.manifest.executables["maddpg_train"];
        assert_eq!(train.inputs.len(), 16);
        assert_eq!(train.outputs.len(), 11);
        assert_eq!(train.inputs[8].shape, Vec::<usize>::new()); // step scalar
    }

    #[test]
    fn weights_match_their_manifest_specs() {
        let s = Store::build();
        for (key, exe) in &s.manifest.executables {
            let Some(wpath) = &exe.weights else { continue };
            let arch = s.archive(wpath).unwrap_or_else(|| panic!("{key}: missing {wpath}"));
            for ts in exe.inputs.iter().skip(exe.graph_inputs.len()) {
                let t = arch.get_shaped(&ts.name, &ts.shape);
                assert!(t.is_ok(), "{key}: weight {} mismatch: {t:?}", ts.name);
            }
        }
    }

    #[test]
    fn drl_init_matches_param_sizes() {
        let s = Store::build();
        let init = s.archive("drl/drl_init.gta").unwrap();
        let p_actor = s.manifest.constant("p_actor").unwrap();
        let p_critic = s.manifest.constant("p_critic").unwrap();
        assert_eq!(init.get("actor").unwrap().shape, vec![M_AGENTS, p_actor]);
        assert_eq!(init.get("t_critic").unwrap().shape, vec![M_AGENTS, p_critic]);
        assert_eq!(init.get("step").unwrap().numel(), 1);
        // Targets start as exact copies.
        assert_eq!(init.get("actor").unwrap().f32_data, init.get("t_actor").unwrap().f32_data);
    }

    #[test]
    fn datasets_have_connected_topology_and_valid_features() {
        let s = Store::build();
        for (name, n, feat, _pad, classes) in DATASETS {
            let d = s.dataset(name).unwrap();
            assert_eq!(d.n, n);
            assert_eq!(d.classes, classes);
            assert!(d.e >= n - 1, "{name}: too few edges");
            for v in 0..n {
                assert_eq!(d.features_of(v).len(), 3);
                assert!(d.features_of(v).iter().all(|&f| (f as usize) < feat));
            }
        }
    }
}
