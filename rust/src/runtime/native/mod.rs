//! The default pure-Rust inference backend.
//!
//! [`NativeBackend`] implements [`Backend`] by dispatching artifact
//! names to hand-ported kernels instead of compiled HLO:
//!
//! * GNN forwards (`{gcn,gat,sage,sgc}_<dataset>`) → [`kernels`]
//!   (CSR SpMM + dense matmul/bias/activation, ported from
//!   `python/compile/kernels/ref.py`);
//! * DRL artifacts (`actor_fwd`, `maddpg_train`, `ppo_fwd`,
//!   `ppo_train`) → [`drl`] over the flat-parameter MLP machinery in
//!   [`mlp`] (ported from `python/compile/drl.py`).
//!
//! All kernels are row-parallel over the crate's `ThreadPool` with
//! bit-identical results for every worker count, and are pinned to
//! the Python oracles by `tests/kernel_parity.rs` (committed golden
//! vectors, `1e-4` absolute tolerance).  [`Store`] synthesizes an
//! in-memory artifact set (manifest + weights + datasets) so the
//! whole serving/training stack runs without the Python toolchain.

pub mod kernels;
pub mod mlp;

mod drl;
mod store;

pub use store::{Store, BATCH, C_PAD, HIDDEN, M_AGENTS, N_MAX};

use anyhow::{bail, ensure, Context};

use super::backend::Backend;
use super::manifest::ExeSpec;
use crate::tensor::Matrix;

/// Pure-Rust [`Backend`] over the thread pool.
pub struct NativeBackend {
    workers: usize,
}

impl NativeBackend {
    pub fn new(workers: usize) -> Self {
        NativeBackend { workers: workers.max(1) }
    }

    /// Size the worker count from the host (capped at 8 — the row
    /// blocks here saturate memory bandwidth well before that).
    pub fn auto() -> Self {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8);
        NativeBackend::new(workers)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports_dynamic_batch(&self) -> bool {
        true
    }

    fn execute(&self, name: &str, _spec: &ExeSpec, inputs: &[&Matrix]) -> crate::Result<Vec<Matrix>> {
        let w = self.workers;
        match name {
            "actor_fwd" => drl::actor_fwd(inputs, w),
            "maddpg_train" => drl::maddpg_train(inputs, w),
            "ppo_fwd" => drl::ppo_fwd(inputs, w),
            "ppo_train" => drl::ppo_train(inputs, w),
            _ => {
                let model = name.split('_').next().unwrap_or(name);
                gnn_forward(model, inputs, w)
                    .with_context(|| format!("native backend: artifact {name:?}"))
            }
        }
    }
}

/// Dispatch a GNN forward by model family.  Input order matches the
/// manifest: graph inputs first (`model.py MODEL_INPUTS`), then the
/// parameter tensors in `param_specs` order.
fn gnn_forward(model: &str, inputs: &[&Matrix], w: usize) -> crate::Result<Vec<Matrix>> {
    let need = |n: usize| -> crate::Result<()> {
        ensure!(inputs.len() == n, "expects {n} inputs, got {}", inputs.len());
        Ok(())
    };
    let out = match model {
        "gcn" => {
            need(6)?;
            // x, a_norm, w0, b0, w1, b1
            kernels::gcn_forward(
                inputs[0], inputs[1], inputs[2], inputs[3], inputs[4], inputs[5], w,
            )
        }
        "sgc" => {
            need(4)?;
            // x, a_norm, w, b
            kernels::sgc_forward(inputs[0], inputs[1], inputs[2], inputs[3], w)
        }
        "sage" => {
            need(9)?;
            // x, adj, inv_deg, ws0, wn0, b0, ws1, wn1, b1
            kernels::sage_forward(
                inputs[0], inputs[1], inputs[2], inputs[3], inputs[4], inputs[5], inputs[6],
                inputs[7], inputs[8], w,
            )
        }
        "gat" => {
            need(10)?;
            // x, adj, w0, al0, ar0, b0, w1, al1, ar1, b1
            kernels::gat_forward(
                inputs[0], inputs[1], inputs[2], inputs[3], inputs[4], inputs[5], inputs[6],
                inputs[7], inputs[8], inputs[9], w,
            )
        }
        other => bail!("no native kernel for model family {other:?}"),
    };
    Ok(vec![out])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_artifact_errors_cleanly() {
        let b = NativeBackend::new(2);
        let spec = ExeSpec::default();
        let err = b.execute("bogus_model", &spec, &[]).unwrap_err();
        assert!(format!("{err:#}").contains("bogus"), "{err:#}");
    }

    #[test]
    fn gnn_dispatch_checks_input_count() {
        let b = NativeBackend::new(1);
        let spec = ExeSpec::default();
        let x = Matrix::zeros(4, 4);
        assert!(b.execute("gcn_cora", &spec, &[&x]).is_err());
    }
}
