//! Pure-Rust GNN forward kernels, ported from the NumPy oracles in
//! `python/compile/kernels/ref.py`.
//!
//! Every kernel here is a line-for-line port of the corresponding
//! `ref.py` function (which is itself the oracle for the Pallas
//! kernels in `python/compile/kernels/`): `matmul_bias_act`,
//! `mean_agg`, `attn_scores`, `masked_softmax`, and the four model
//! forwards composed from them exactly as `python/compile/model.py`
//! composes theirs.  `tests/kernel_parity.rs` pins each one to golden
//! vectors generated from `ref.py` within `1e-4` absolute tolerance.
//!
//! Parallelism: all O(n²·d) products are row-parallel over
//! [`ThreadPool::map_scoped_mut`] — each output row is owned by one
//! worker and accumulated in a fixed order, so results are
//! **bit-identical for every worker count** (also pinned by
//! `tests/kernel_parity.rs`).  Aggregations over the padded adjacency
//! go through [`Csr`] SpMM so cost scales with edges, not `n_max²`.

use crate::tensor::{Csr, Matrix};
use crate::util::threadpool::ThreadPool;

/// LeakyReLU negative slope used by GAT attention (`ref.py NEG_SLOPE`).
pub const NEG_SLOPE: f32 = 0.2;

/// Element-wise activation applied by [`matmul_bias_act`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
    Sigmoid,
}

impl Act {
    #[inline]
    pub fn apply(self, v: f32) -> f32 {
        match self {
            Act::None => v,
            Act::Relu => v.max(0.0),
            Act::Sigmoid => 1.0 / (1.0 + (-v).exp()),
        }
    }
}

/// Dense matmul `a @ b`, row-parallel over `workers` threads.
///
/// Matches [`Matrix::matmul`] bit-for-bit (same k-order accumulation,
/// same skip of zero entries in `a`) — the parallel split is by
/// output row, which each worker owns exclusively.
///
/// ```
/// use graphedge::runtime::native::kernels::matmul;
/// use graphedge::tensor::Matrix;
/// let a = Matrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
/// let b = Matrix::from_rows(vec![vec![1.0, 1.0], vec![1.0, 1.0]]);
/// assert_eq!(matmul(&a, &b, 2).data, vec![3.0, 3.0, 7.0, 7.0]);
/// ```
pub fn matmul(a: &Matrix, b: &Matrix, workers: usize) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let mut out = Matrix::zeros(a.rows, b.cols);
    if a.rows == 0 || b.cols == 0 {
        return out;
    }
    let cols = b.cols;
    let mut rows: Vec<&mut [f32]> = out.data.chunks_mut(cols).collect();
    ThreadPool::map_scoped_mut(&mut rows, workers.max(1), |i, out_row| {
        accumulate_row(a.row(i), b, out_row);
    });
    out
}

/// `act(a @ b + bias)` fused in one pass (`ref.py matmul_bias_act`).
///
/// `bias` is a `[1, b.cols]` row broadcast over every output row;
/// pass `None` to skip it.
///
/// ```
/// use graphedge::runtime::native::kernels::{matmul_bias_act, Act};
/// use graphedge::tensor::Matrix;
/// let a = Matrix::from_rows(vec![vec![1.0, 0.0]]);
/// let b = Matrix::from_rows(vec![vec![1.0, -1.0], vec![0.0, 0.0]]);
/// let bias = Matrix::from_rows(vec![vec![0.0, -1.0]]);
/// let y = matmul_bias_act(&a, &b, Some(&bias), Act::Relu, 1);
/// assert_eq!(y.data, vec![1.0, 0.0]); // relu(1) = 1, relu(-2) = 0
/// ```
pub fn matmul_bias_act(
    a: &Matrix,
    b: &Matrix,
    bias: Option<&Matrix>,
    act: Act,
    workers: usize,
) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    if let Some(bias) = bias {
        assert_eq!(bias.cols, b.cols, "bias width mismatch");
    }
    let mut out = Matrix::zeros(a.rows, b.cols);
    if a.rows == 0 || b.cols == 0 {
        return out;
    }
    let cols = b.cols;
    let mut rows: Vec<&mut [f32]> = out.data.chunks_mut(cols).collect();
    ThreadPool::map_scoped_mut(&mut rows, workers.max(1), |i, out_row| {
        accumulate_row(a.row(i), b, out_row);
        if let Some(bias) = bias {
            for (o, &bv) in out_row.iter_mut().zip(bias.row(0)) {
                *o += bv;
            }
        }
        if act != Act::None {
            for o in out_row.iter_mut() {
                *o = act.apply(*o);
            }
        }
    });
    out
}

/// One dense output row `out += a_row @ b`, k-order, skipping zeros
/// in `a_row` exactly like [`Matrix::matmul`].
#[inline]
fn accumulate_row(a_row: &[f32], b: &Matrix, out_row: &mut [f32]) {
    for (k, &av) in a_row.iter().enumerate() {
        if av == 0.0 {
            continue;
        }
        for (o, &bv) in out_row.iter_mut().zip(b.row(k)) {
            *o += av * bv;
        }
    }
}

/// Mean neighbourhood aggregation (`ref.py mean_agg`):
/// `(adj @ x) * inv_deg`, with `inv_deg` a `[n, 1]` column broadcast
/// over the features.  Padding rows (degree 0) carry `inv_deg = 0`
/// and stay all-zero.
///
/// ```
/// use graphedge::runtime::native::kernels::mean_agg;
/// use graphedge::tensor::{Csr, Matrix};
/// let adj = Csr::from_dense(&Matrix::from_rows(vec![
///     vec![0.0, 1.0],
///     vec![0.0, 0.0], // isolated: inv_deg 0
/// ]));
/// let x = Matrix::from_rows(vec![vec![5.0], vec![3.0]]);
/// let inv_deg = Matrix::from_rows(vec![vec![1.0], vec![0.0]]);
/// assert_eq!(mean_agg(&adj, &x, &inv_deg, 1).data, vec![3.0, 0.0]);
/// ```
pub fn mean_agg(adj: &Csr, x: &Matrix, inv_deg: &Matrix, workers: usize) -> Matrix {
    assert_eq!(adj.rows, inv_deg.rows, "inv_deg length mismatch");
    let mut out = adj.spmm(x, workers);
    for (r, row) in out.data.chunks_mut(out.cols.max(1)).enumerate() {
        let s = inv_deg.at(r, 0);
        if s != 1.0 {
            for v in row.iter_mut() {
                *v *= s;
            }
        }
    }
    out
}

/// GAT attention logits (`ref.py attn_scores`): `leaky_relu(sl + srᵀ)`
/// where `sl`/`sr` are the per-vertex source/target scores `[n, 1]`.
pub fn attn_scores(sl: &Matrix, sr: &Matrix, workers: usize) -> Matrix {
    assert_eq!(sl.rows, sr.rows, "score length mismatch");
    let n = sl.rows;
    let mut out = Matrix::zeros(n, n);
    if n == 0 {
        return out;
    }
    let mut rows: Vec<&mut [f32]> = out.data.chunks_mut(n).collect();
    ThreadPool::map_scoped_mut(&mut rows, workers.max(1), |i, out_row| {
        let l = sl.at(i, 0);
        for (j, o) in out_row.iter_mut().enumerate() {
            let e = l + sr.at(j, 0);
            *o = if e >= 0.0 { e } else { NEG_SLOPE * e };
        }
    });
    out
}

/// Adjacency-masked row softmax (`ref.py masked_softmax`): non-edges
/// are filled with `-1e30` before the row-max subtraction, zeroed
/// after the exp, and the denominator gets `+1e-9` so an all-padding
/// row comes out all-zero instead of NaN.
///
/// ```
/// use graphedge::runtime::native::kernels::masked_softmax;
/// use graphedge::tensor::Matrix;
/// let scores = Matrix::from_rows(vec![vec![1.0, 1.0, 9.0]]);
/// let adj = Matrix::from_rows(vec![vec![1.0, 1.0, 0.0]]);
/// let att = masked_softmax(&scores, &adj, 1);
/// assert!((att.at(0, 0) - 0.5).abs() < 1e-6); // masked 9.0 ignored
/// assert_eq!(att.at(0, 2), 0.0);
/// ```
pub fn masked_softmax(scores: &Matrix, adj: &Matrix, workers: usize) -> Matrix {
    assert_eq!(scores.rows, adj.rows, "mask shape mismatch");
    assert_eq!(scores.cols, adj.cols, "mask shape mismatch");
    let mut out = scores.clone();
    if out.rows == 0 || out.cols == 0 {
        return out;
    }
    let cols = out.cols;
    let mut rows: Vec<&mut [f32]> = out.data.chunks_mut(cols).collect();
    ThreadPool::map_scoped_mut(&mut rows, workers.max(1), |i, row| {
        let mask = adj.row(i);
        let mut max = f32::NEG_INFINITY;
        for (v, &m) in row.iter_mut().zip(mask) {
            if m <= 0.0 {
                *v = -1e30;
            }
            if *v > max {
                max = *v;
            }
        }
        let mut denom = 1e-9f32;
        for (v, &m) in row.iter_mut().zip(mask) {
            *v = if m > 0.0 { (*v - max).exp() } else { 0.0 };
            denom += *v;
        }
        for v in row.iter_mut() {
            *v /= denom;
        }
    });
    out
}

/// Two-layer GCN forward (`model.py gcn_forward`):
/// `a_norm @ relu(a_norm @ (x @ w0) + b0) @ w1 + b1` with the relu
/// applied after the first propagation.
pub fn gcn_forward(
    x: &Matrix,
    a_norm: &Matrix,
    w0: &Matrix,
    b0: &Matrix,
    w1: &Matrix,
    b1: &Matrix,
    workers: usize,
) -> Matrix {
    let a = Csr::from_dense(a_norm);
    let h = matmul(x, w0, workers);
    let h = bias_act_inplace(a.spmm(&h, workers), b0, Act::Relu);
    let h = matmul(&h, w1, workers);
    bias_act_inplace(a.spmm(&h, workers), b1, Act::None)
}

/// Simplified GCN forward (`model.py sgc_forward`):
/// `(a_norm @ (a_norm @ x)) @ w + b` — two propagations, one linear
/// readout, no hidden nonlinearity.
pub fn sgc_forward(x: &Matrix, a_norm: &Matrix, w: &Matrix, b: &Matrix, workers: usize) -> Matrix {
    let a = Csr::from_dense(a_norm);
    let p = a.spmm(&a.spmm(x, workers), workers);
    matmul_bias_act(&p, w, Some(b), Act::None, workers)
}

/// Two-layer GraphSAGE forward (`model.py sage_forward`): each layer
/// computes `x @ ws + mean_agg(adj, x, inv_deg) @ wn + b`, relu on
/// layer 0 only.
#[allow(clippy::too_many_arguments)]
pub fn sage_forward(
    x: &Matrix,
    adj: &Matrix,
    inv_deg: &Matrix,
    ws0: &Matrix,
    wn0: &Matrix,
    b0: &Matrix,
    ws1: &Matrix,
    wn1: &Matrix,
    b1: &Matrix,
    workers: usize,
) -> Matrix {
    let a = Csr::from_dense(adj);
    let h = sage_layer(x, &a, inv_deg, ws0, wn0, b0, Act::Relu, workers);
    sage_layer(&h, &a, inv_deg, ws1, wn1, b1, Act::None, workers)
}

#[allow(clippy::too_many_arguments)]
fn sage_layer(
    x: &Matrix,
    adj: &Csr,
    inv_deg: &Matrix,
    ws: &Matrix,
    wn: &Matrix,
    b: &Matrix,
    act: Act,
    workers: usize,
) -> Matrix {
    let neigh = mean_agg(adj, x, inv_deg, workers);
    let mut own = matmul(x, ws, workers);
    let agg = matmul(&neigh, wn, workers);
    for (o, &v) in own.data.iter_mut().zip(&agg.data) {
        *o += v;
    }
    bias_act_inplace(own, b, act)
}

/// Two-layer GAT forward (`model.py gat_forward`): per layer
/// `h = x @ w`, attention logits from `h @ al` / `h @ ar`, masked
/// softmax over the adjacency, then `att @ h + b`; relu on layer 0.
#[allow(clippy::too_many_arguments)]
pub fn gat_forward(
    x: &Matrix,
    adj: &Matrix,
    w0: &Matrix,
    al0: &Matrix,
    ar0: &Matrix,
    b0: &Matrix,
    w1: &Matrix,
    al1: &Matrix,
    ar1: &Matrix,
    b1: &Matrix,
    workers: usize,
) -> Matrix {
    let h = gat_layer(x, adj, w0, al0, ar0, b0, Act::Relu, workers);
    gat_layer(&h, adj, w1, al1, ar1, b1, Act::None, workers)
}

#[allow(clippy::too_many_arguments)]
fn gat_layer(
    x: &Matrix,
    adj: &Matrix,
    w: &Matrix,
    al: &Matrix,
    ar: &Matrix,
    b: &Matrix,
    act: Act,
    workers: usize,
) -> Matrix {
    let h = matmul(x, w, workers);
    let sl = matmul(&h, al, workers);
    let sr = matmul(&h, ar, workers);
    let att = masked_softmax(&attn_scores(&sl, &sr, workers), adj, workers);
    matmul_bias_act(&att, &h, Some(b), act, workers)
}

/// `act(m + bias)` in place, bias broadcast row-wise.
fn bias_act_inplace(mut m: Matrix, bias: &Matrix, act: Act) -> Matrix {
    assert_eq!(bias.cols, m.cols, "bias width mismatch");
    for row in m.data.chunks_mut(m.cols.max(1)) {
        for (o, &bv) in row.iter_mut().zip(bias.row(0)) {
            *o = act.apply(*o + bv);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randm(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = crate::util::rng::Rng::seed_from(seed);
        let mut m = Matrix::zeros(rows, cols);
        for v in &mut m.data {
            *v = rng.range_f64(-1.0, 1.0) as f32;
        }
        m
    }

    #[test]
    fn parallel_matmul_is_bit_identical_to_sequential_oracle() {
        let a = randm(17, 11, 1);
        let b = randm(11, 9, 2);
        let want = a.matmul(&b);
        for workers in [1usize, 2, 3, 8] {
            assert_eq!(matmul(&a, &b, workers), want, "workers = {workers}");
        }
    }

    #[test]
    fn bias_and_act_apply_after_product() {
        let a = randm(5, 4, 3);
        let b = randm(4, 6, 4);
        let bias = randm(1, 6, 5);
        let y = matmul_bias_act(&a, &b, Some(&bias), Act::Relu, 2);
        let p = a.matmul(&b);
        for r in 0..5 {
            for c in 0..6 {
                let want = (p.at(r, c) + bias.at(0, c)).max(0.0);
                assert_eq!(y.at(r, c), want);
            }
        }
    }

    #[test]
    fn masked_softmax_rows_sum_to_one_or_zero() {
        let scores = randm(8, 8, 6);
        let mut adj = Matrix::zeros(8, 8);
        let mut rng = crate::util::rng::Rng::seed_from(9);
        for v in &mut adj.data {
            *v = if rng.chance(0.4) { 1.0 } else { 0.0 };
        }
        // Make one row all-padding.
        for c in 0..8 {
            adj.set(3, c, 0.0);
        }
        let att = masked_softmax(&scores, &adj, 2);
        for r in 0..8 {
            let s: f32 = att.row(r).iter().sum();
            let deg: f32 = adj.row(r).iter().sum();
            if deg == 0.0 {
                assert_eq!(s, 0.0, "padding row {r} must be zero");
            } else {
                assert!((s - 1.0).abs() < 1e-5, "row {r} sums to {s}");
            }
        }
    }

    #[test]
    fn sigmoid_matches_closed_form() {
        assert!((Act::Sigmoid.apply(0.0) - 0.5).abs() < 1e-7);
        assert!((Act::Sigmoid.apply(2.0) - 0.880_797).abs() < 1e-5);
    }
}
