//! Streaming statistics and histogram helpers for metrics + benches.

use crate::util::version::{Memoized, Version};

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a stored sample (benches are small enough).
///
/// Percentile queries sort lazily into a [`Memoized`] view keyed on a
/// push-bumped [`Version`], so report loops calling
/// `median`/`percentile` per metric pay one sort per batch instead of
/// one clone-and-sort per call (which was quadratic-ish across the
/// bench report loop).  The memo cell's interior mutability keeps the
/// query API `&self` for every existing caller; `Sample` stays `Send`,
/// which is all the metrics registry's `Mutex` needs.
#[derive(Clone, Debug, Default)]
pub struct Sample {
    xs: Vec<f64>,
    /// Bumped on every `push`; the key for the sorted view below.
    edits: Version,
    /// Lazily built sorted copy of `xs`, current iff built at `edits`.
    sorted: Memoized<Vec<f64>>,
}

impl Sample {
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.edits.bump();
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let s = self.sorted.get_or_rebuild(&[self.edits], || {
            let mut s = self.xs.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s
        });
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            let w = rank - lo as f64;
            s[lo] * (1.0 - w) + s[hi] * w
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Fixed-bucket histogram (log-ish layout is the caller's concern).
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        assert!(hi > lo && n > 0);
        Histogram { lo, hi, buckets: vec![0; n], underflow: 0, overflow: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.buckets.len();
            let w = (self.hi - self.lo) / n as f64;
            let i = ((x - self.lo) / w) as usize;
            self.buckets[i.min(n - 1)] += 1;
        }
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

/// Degree-distribution helper used by the Fig. 5 bench: counts of each
/// integer value.
pub fn int_distribution(values: impl IntoIterator<Item = usize>) -> Vec<(usize, usize)> {
    let mut counts: std::collections::BTreeMap<usize, usize> = Default::default();
    for v in values {
        *counts.entry(v).or_default() += 1;
    }
    counts.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // Sample variance of this classic dataset is 32/7.
        assert!((w.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = Sample::default();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 4.0);
        assert!((s.median() - 2.5).abs() < 1e-12);
        assert!((s.percentile(25.0) - 1.75).abs() < 1e-12);
    }

    /// The pre-cache implementation: clone + sort on every call.
    fn naive_percentile(xs: &[f64], p: f64) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let (lo, hi) = (rank.floor() as usize, rank.ceil() as usize);
        if lo == hi {
            s[lo]
        } else {
            let w = rank - lo as f64;
            s[lo] * (1.0 - w) + s[hi] * w
        }
    }

    #[test]
    fn cached_percentiles_match_fresh_sorts_across_pushes() {
        // Interleave pushes and queries so the sorted cache is built,
        // reused, and invalidated repeatedly; every answer must equal
        // the old clone-and-sort implementation exactly.
        let mut s = Sample::default();
        let mut reference: Vec<f64> = Vec::new();
        let mut rng = crate::util::rng::Rng::seed_from(5);
        for _ in 0..5 {
            for _ in 0..50 {
                let x = rng.f64();
                s.push(x);
                reference.push(x);
            }
            for p in [0.0, 12.5, 25.0, 50.0, 90.0, 99.0, 100.0] {
                assert_eq!(s.percentile(p), naive_percentile(&reference, p));
                assert_eq!(s.percentile(p), s.percentile(p)); // cached re-read
            }
            assert_eq!(s.median(), naive_percentile(&reference, 50.0));
        }
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(11.0);
        assert_eq!(h.buckets(), &[1u64; 10][..]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn int_distribution_counts() {
        let d = int_distribution([1, 1, 2, 5, 5, 5]);
        assert_eq!(d, vec![(1, 2), (2, 1), (5, 3)]);
    }
}
