//! Minimal env-filtered logging backend for the `log` facade.
//!
//! `GRAPHEDGE_LOG=debug` (or error/warn/info/trace) selects the level;
//! default is `info`.  Output goes to stderr with elapsed-time stamps.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use log::{Level, LevelFilter, Log, Metadata, Record};
use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct Logger {
    level: Level,
}

impl Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.elapsed().as_secs_f64();
        eprintln!(
            "[{t:9.3}s {:<5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger once; subsequent calls are no-ops.
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("GRAPHEDGE_LOG")
        .unwrap_or_default()
        .to_lowercase()
        .as_str()
    {
        "trace" => Level::Trace,
        "debug" => Level::Debug,
        "warn" => Level::Warn,
        "error" => Level::Error,
        _ => Level::Info,
    };
    let _ = log::set_boxed_logger(Box::new(Logger { level }));
    log::set_max_level(match level {
        Level::Trace => LevelFilter::Trace,
        Level::Debug => LevelFilter::Debug,
        Level::Info => LevelFilter::Info,
        Level::Warn => LevelFilter::Warn,
        Level::Error => LevelFilter::Error,
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
