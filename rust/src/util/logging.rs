//! Minimal env-filtered logging backend for the `log` facade.
//!
//! `GRAPHEDGE_LOG` selects the level: one of `off`, `error`, `warn`,
//! `info` (the default), `debug`, `trace`.  An unrecognized value gets
//! a one-time stderr warning naming the bad value and the accepted set
//! — it does *not* silently become `info`-with-no-explanation.  Output
//! goes to stderr with elapsed-time stamps.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use log::{Level, LevelFilter, Log, Metadata, Record};
use once_cell::sync::Lazy;

// lint:allow(wall-clock) — log lines are stamped with elapsed wall
// time for humans; nothing algorithmic reads this clock.
static START: Lazy<Instant> = Lazy::new(Instant::now);
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct Logger {
    filter: LevelFilter,
}

impl Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.filter
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.elapsed().as_secs_f64();
        eprintln!(
            "[{t:9.3}s {:<5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Parse a `GRAPHEDGE_LOG` value.  `Ok` for the accepted set
/// (case-insensitive; empty = default `info`), `Err` echoes the bad
/// value back for the warning.
fn parse_level(raw: &str) -> Result<LevelFilter, String> {
    match raw.to_lowercase().as_str() {
        "" => Ok(LevelFilter::Info),
        "off" | "none" => Ok(LevelFilter::Off),
        "error" => Ok(LevelFilter::Error),
        "warn" => Ok(LevelFilter::Warn),
        "info" => Ok(LevelFilter::Info),
        "debug" => Ok(LevelFilter::Debug),
        "trace" => Ok(LevelFilter::Trace),
        other => Err(other.to_string()),
    }
}

/// Install the logger once; subsequent calls are no-ops.
pub fn init() {
    // ordering: SeqCst — one-time install flag on a cold path; the
    // single total order makes "exactly one caller proceeds" obvious,
    // and the `log` facade does its own synchronization internally.
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let raw = std::env::var("GRAPHEDGE_LOG").unwrap_or_default();
    let filter = match parse_level(&raw) {
        Ok(f) => f,
        Err(bad) => {
            eprintln!(
                "warning: unrecognized GRAPHEDGE_LOG={bad:?}; accepted values are \
                 off, error, warn, info, debug, trace — falling back to info"
            );
            LevelFilter::Info
        }
    };
    let _ = log::set_boxed_logger(Box::new(Logger { filter }));
    log::set_max_level(filter);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }

    #[test]
    fn parse_level_accepts_the_documented_set() {
        assert_eq!(parse_level(""), Ok(LevelFilter::Info));
        assert_eq!(parse_level("off"), Ok(LevelFilter::Off));
        assert_eq!(parse_level("OFF"), Ok(LevelFilter::Off));
        assert_eq!(parse_level("Error"), Ok(LevelFilter::Error));
        assert_eq!(parse_level("warn"), Ok(LevelFilter::Warn));
        assert_eq!(parse_level("info"), Ok(LevelFilter::Info));
        assert_eq!(parse_level("debug"), Ok(LevelFilter::Debug));
        assert_eq!(parse_level("TRACE"), Ok(LevelFilter::Trace));
    }

    #[test]
    fn parse_level_rejects_garbage_with_the_offending_value() {
        assert_eq!(parse_level("verbose"), Err("verbose".to_string()));
        assert_eq!(parse_level("2"), Err("2".to_string()));
    }

    #[test]
    fn levels_filter_as_expected() {
        let quiet = Logger { filter: LevelFilter::Off };
        let m = Metadata::builder().level(Level::Error).build();
        assert!(!quiet.enabled(&m));
        let warn = Logger { filter: LevelFilter::Warn };
        assert!(warn.enabled(&Metadata::builder().level(Level::Warn).build()));
        assert!(!warn.enabled(&Metadata::builder().level(Level::Info).build()));
    }
}
