//! Structured tracing: scoped spans + a per-thread flight recorder,
//! drained to JSONL or consumed programmatically.
//!
//! The metrics registry ([`super::metrics`]) answers *how much / how
//! fast on aggregate*; this module answers *what happened, in what
//! order, nested inside what*.  The serving loop uses it to record the
//! per-batch lifecycle (`router.enqueue` → `router.batch_close` →
//! `serve.infer` → `serve.batch_complete`), the incremental
//! partitioner records repair-vs-full-recut spans and drift events,
//! and the trainers emit one `train.episode` event per finished
//! episode.
//!
//! # Model
//!
//! * A **span** ([`span`] / [`span_with`]) is a scoped guard: it
//!   captures its start time on creation and records one
//!   [`TraceEvent`] (with duration) when dropped.  Spans nest: each
//!   thread keeps a stack of open span ids, and a new span's `parent`
//!   is whatever span is open on that thread at creation time.  Guards
//!   are `!Send`, so the stack discipline cannot be broken by moving a
//!   guard across threads.
//! * An **instant** ([`instant`]) is a point event with no duration;
//!   its `parent` is the innermost open span of the emitting thread.
//! * Events carry up to [`MAX_FIELDS`] numeric fields (static-str key,
//!   `f64` value) — no per-event allocation.
//!
//! # Flight recorder
//!
//! Events land in a **per-thread ring buffer** (capacity
//! `GRAPHEDGE_TRACE_BUF`, default 65536 events); when a buffer fills,
//! the oldest events are overwritten and counted in [`dropped`].  A
//! thread that exits migrates its remaining events into a shared
//! bounded *retired* ring so short-lived pool/scoped threads are not
//! lost.  [`snapshot`] merges every buffer into one ts-ordered event
//! list without clearing; [`drain`] clears as it collects.
//!
//! # Overhead contract
//!
//! Tracing is **off by default**: [`span`]/[`instant`] check one
//! relaxed atomic and return inert guards, so instrumented hot paths
//! pay ~1 ns when disabled.  When enabled, recording one event takes
//! one uncontended per-thread mutex lock and a ring push — no
//! allocation (names and field keys are `&'static str`, fields are an
//! inline array).  Aggregate statistics on hot paths should still use
//! [`super::metrics`] handles; spans are for *phase*-grained work
//! (batches, repairs, episodes), not per-vertex loops.
//!
//! # Knobs: env vars vs CLI flags
//!
//! * `GRAPHEDGE_TRACE=<path>` (env) — enable tracing at process start
//!   ([`init_from_env`]) and write the full JSONL to `<path>` on exit
//!   ([`flush_env_trace`]); works for every subcommand, example and
//!   bench.
//! * `graphedge serve --trace <path>` / `graphedge train --telemetry
//!   <path>` (CLI) — per-run capture scoped to that command.
//! * `GRAPHEDGE_TRACE_BUF=<events>` (env) — per-thread ring capacity.
//!
//! # Naming conventions
//!
//! Event names are `<subsystem>.<what>` in snake_case: `serve.step`,
//! `serve.churn`, `serve.batch`, `serve.infer`, `router.enqueue`,
//! `router.batch_close`, `partition.repair`, `partition.full_recut`,
//! `partition.drift`, `vec_env.step`, `vec_env.slot_step`,
//! `train.episode`, `runtime.exec`.  Field keys are snake_case;
//! enumerated fields (e.g. `router.batch_close`'s `reason`) document
//! their code → meaning map where they are emitted.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::io::Write as _;
use std::marker::PhantomData;
use std::path::{Path, PathBuf};
// Const-initialized statics need const constructors, which loom's
// atomics do not have — the flag/id statics therefore stay on std
// atomics even under `--cfg loom` (the loom models never touch them).
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

// The ring mutexes go through the shim so the retired-ring handoff can
// be model-checked under loom (see `loom_tests` at the bottom).
use super::sync::Mutex;

/// Maximum numeric fields per event (inline, no allocation).
pub const MAX_FIELDS: usize = 8;

/// Span (has a duration) or instant (point event, `dur_us == 0`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    Span,
    Instant,
}

impl EventKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::Span => "span",
            EventKind::Instant => "instant",
        }
    }
}

/// Inline key→value payload of an event.
#[derive(Clone, Copy, Debug)]
pub struct Fields {
    keys: [&'static str; MAX_FIELDS],
    vals: [f64; MAX_FIELDS],
    len: u8,
}

impl Default for Fields {
    fn default() -> Self {
        Fields { keys: [""; MAX_FIELDS], vals: [0.0; MAX_FIELDS], len: 0 }
    }
}

impl Fields {
    pub fn from_slice(kv: &[(&'static str, f64)]) -> Self {
        let mut f = Fields::default();
        for &(k, v) in kv {
            f.push(k, v);
        }
        f
    }

    /// Append a field; silently ignored past [`MAX_FIELDS`] (events are
    /// diagnostics — overflowing must never panic a pipeline).
    pub fn push(&mut self, key: &'static str, val: f64) {
        let i = self.len as usize;
        if i < MAX_FIELDS {
            self.keys[i] = key;
            self.vals[i] = val;
            self.len += 1;
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&'static str, f64)> + '_ {
        (0..self.len as usize).map(|i| (self.keys[i], self.vals[i]))
    }

    pub fn get(&self, key: &str) -> Option<f64> {
        self.iter().find(|&(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// One recorded event (span close or instant).
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub name: &'static str,
    pub kind: EventKind,
    /// Microseconds since the tracer epoch (span *start* for spans).
    pub ts_us: u64,
    /// Span duration in microseconds; 0 for instants.
    pub dur_us: u64,
    /// Span id (unique per process run); 0 for instants.
    pub span: u64,
    /// Enclosing span id at creation time; 0 = root.
    pub parent: u64,
    /// Recorder thread slot (registration order, not OS tid).
    pub thread: u32,
    pub fields: Fields,
}

struct Ring {
    events: VecDeque<TraceEvent>,
    cap: usize,
    dropped: u64,
}

impl Ring {
    fn new(cap: usize) -> Self {
        Ring { events: VecDeque::with_capacity(cap.min(1024)), cap, dropped: 0 }
    }

    fn push(&mut self, e: TraceEvent) {
        if self.events.len() >= self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(e);
    }
}

struct ThreadBuf {
    thread: u32,
    ring: Mutex<Ring>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_SPAN: AtomicU64 = AtomicU64::new(1);
static NEXT_THREAD: AtomicU32 = AtomicU32::new(0);
static EPOCH: Lazy<Instant> = Lazy::new(Instant::now);
static REGISTRY: Lazy<Mutex<Vec<std::sync::Arc<ThreadBuf>>>> =
    Lazy::new(|| Mutex::new(Vec::new()));
/// Events of exited threads (bounded; see module docs).
static RETIRED: Lazy<Mutex<Ring>> = Lazy::new(|| Mutex::new(Ring::new(4 * ring_cap())));
/// `GRAPHEDGE_TRACE` output path, when set ([`init_from_env`]).
static ENV_PATH: Lazy<Mutex<Option<PathBuf>>> = Lazy::new(|| Mutex::new(None));

fn ring_cap() -> usize {
    static CAP: Lazy<usize> = Lazy::new(|| {
        std::env::var("GRAPHEDGE_TRACE_BUF")
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|&c: &usize| c > 0)
            .unwrap_or(65536)
    });
    *CAP
}

struct Tls {
    buf: std::sync::Arc<ThreadBuf>,
    stack: Vec<u64>,
}

/// Move every buffered event — and the overwrite count — of `ring`
/// into `retired`.  `ring` is left empty with `dropped == 0`, so a
/// concurrent [`dropped`] sum cannot double-count the handoff.
///
/// Lock order: ring, then retired.  The only other multi-lock path is
/// `collect`/`dropped` (registry → one ring at a time → retired after
/// every ring lock is released), so the inverse pairing never occurs.
fn migrate_into_retired(ring: &Mutex<Ring>, retired: &Mutex<Ring>) {
    let mut ring = ring.lock().unwrap();
    let mut retired = retired.lock().unwrap();
    retired.dropped += ring.dropped;
    ring.dropped = 0;
    for e in ring.events.drain(..) {
        retired.push(e);
    }
}

impl Drop for Tls {
    fn drop(&mut self) {
        // Migrate this thread's events into the retired ring and
        // unregister the buffer, so short-lived scoped/pool threads
        // neither lose their events nor leak registry entries.  The
        // ring+retired locks are released before taking the registry
        // lock: `collect` acquires registry → ring, so holding either
        // of the first two while waiting on the registry could form a
        // three-thread cycle.
        migrate_into_retired(&self.buf.ring, &RETIRED);
        let mut reg = REGISTRY.lock().unwrap();
        reg.retain(|b| b.thread != self.buf.thread);
    }
}

thread_local! {
    // lint:allow(memo) — lazy per-thread buffer registration, not a
    // cache of derived state; the slot fills once and is never stale.
    static TLS: RefCell<Option<Tls>> = const { RefCell::new(None) };
}

fn with_tls<R>(f: impl FnOnce(&mut Tls) -> R) -> R {
    TLS.with(|cell| {
        let mut slot = cell.borrow_mut();
        let tls = slot.get_or_insert_with(|| {
            let buf = std::sync::Arc::new(ThreadBuf {
                // ordering: Relaxed — slot ids only need uniqueness
                // (fetch_add is atomic at any ordering); readers learn
                // of the new buffer via the REGISTRY lock below.
                thread: NEXT_THREAD.fetch_add(1, Ordering::Relaxed),
                ring: Mutex::new(Ring::new(ring_cap())),
            });
            REGISTRY.lock().unwrap().push(buf.clone());
            Tls { buf, stack: Vec::new() }
        });
        f(tls)
    })
}

/// Is tracing currently recording?
#[inline]
pub fn enabled() -> bool {
    // ordering: Relaxed — a lone flag with no associated payload; a
    // hot path observing a stale value records (or skips) one event,
    // which the overhead contract explicitly permits.
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on/off (buffers are kept either way).
pub fn set_enabled(on: bool) {
    if on {
        Lazy::force(&EPOCH); // pin the epoch before the first event
    }
    // ordering: Relaxed — pairs with the Relaxed load in `enabled`;
    // the EPOCH pin above is published by `Lazy`'s own internal
    // synchronization, not by this store.
    ENABLED.store(on, Ordering::Relaxed);
}

/// Microseconds since the tracer epoch.
pub fn now_us() -> u64 {
    EPOCH.elapsed().as_micros() as u64
}

/// Scoped span guard: records one [`EventKind::Span`] event on drop.
///
/// `!Send` by construction — a guard must be dropped on the thread
/// that opened it, which is what keeps the per-thread parent stack
/// consistent.
pub struct Span {
    name: &'static str,
    id: u64,
    parent: u64,
    ts_us: u64,
    start: Instant,
    fields: Fields,
    armed: bool,
    _not_send: PhantomData<*const ()>,
}

impl Span {
    /// Attach a numeric field (no-op on a disabled span).
    pub fn field(&mut self, key: &'static str, val: f64) {
        if self.armed {
            self.fields.push(key, val);
        }
    }

    /// The span id events of children will carry as `parent` (0 when
    /// tracing was disabled at creation).
    pub fn id(&self) -> u64 {
        if self.armed {
            self.id
        } else {
            0
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let dur_us = self.start.elapsed().as_micros() as u64;
        let event = TraceEvent {
            name: self.name,
            kind: EventKind::Span,
            ts_us: self.ts_us,
            dur_us,
            span: self.id,
            parent: self.parent,
            thread: 0, // patched below
            fields: self.fields,
        };
        with_tls(|tls| {
            // Pop this span (and, defensively, anything opened after
            // it that leaked without dropping in LIFO order).
            while let Some(top) = tls.stack.pop() {
                if top == self.id {
                    break;
                }
            }
            let mut e = event;
            e.thread = tls.buf.thread;
            tls.buf.ring.lock().unwrap().push(e);
        });
    }
}

/// Open a span; it records itself when the guard drops.
pub fn span(name: &'static str) -> Span {
    span_with(name, &[])
}

/// Open a span with initial fields.
pub fn span_with(name: &'static str, fields: &[(&'static str, f64)]) -> Span {
    if !enabled() {
        return Span {
            name,
            id: 0,
            parent: 0,
            ts_us: 0,
            start: Instant::now(),
            fields: Fields::default(),
            armed: false,
            _not_send: PhantomData,
        };
    }
    // ordering: Relaxed — span ids only need to be unique; parent
    // links are established through the per-thread stack, never by
    // comparing ids across threads.
    let id = NEXT_SPAN.fetch_add(1, Ordering::Relaxed);
    let parent = with_tls(|tls| {
        let parent = tls.stack.last().copied().unwrap_or(0);
        tls.stack.push(id);
        parent
    });
    Span {
        name,
        id,
        parent,
        ts_us: now_us(),
        start: Instant::now(),
        fields: Fields::from_slice(fields),
        armed: true,
        _not_send: PhantomData,
    }
}

/// Record a point event under the innermost open span of this thread.
pub fn instant(name: &'static str, fields: &[(&'static str, f64)]) {
    if !enabled() {
        return;
    }
    let ts_us = now_us();
    with_tls(|tls| {
        let event = TraceEvent {
            name,
            kind: EventKind::Instant,
            ts_us,
            dur_us: 0,
            span: 0,
            parent: tls.stack.last().copied().unwrap_or(0),
            thread: tls.buf.thread,
            fields: Fields::from_slice(fields),
        };
        tls.buf.ring.lock().unwrap().push(event);
    });
}

fn collect(clear: bool) -> Vec<TraceEvent> {
    let mut out = Vec::new();
    {
        let reg = REGISTRY.lock().unwrap();
        for buf in reg.iter() {
            let mut ring = buf.ring.lock().unwrap();
            out.extend(ring.events.iter().copied());
            if clear {
                ring.events.clear();
            }
        }
    }
    {
        let mut retired = RETIRED.lock().unwrap();
        out.extend(retired.events.iter().copied());
        if clear {
            retired.events.clear();
        }
    }
    // One global timeline: ts order, span id as the tie-break so a
    // parent (opened first, lower id) sorts before its children.
    out.sort_by_key(|e| (e.ts_us, e.span));
    out
}

/// Merge every thread's buffer into one ts-ordered list (no clearing).
pub fn snapshot() -> Vec<TraceEvent> {
    collect(false)
}

/// Like [`snapshot`], but clears the buffers as it collects.
pub fn drain() -> Vec<TraceEvent> {
    collect(true)
}

/// Drop every buffered event (does not change the enabled flag).
pub fn clear() {
    let _ = drain();
}

/// Events lost to ring overwrites since process start.
pub fn dropped() -> u64 {
    let reg = REGISTRY.lock().unwrap();
    let live: u64 = reg.iter().map(|b| b.ring.lock().unwrap().dropped).sum();
    live + RETIRED.lock().unwrap().dropped
}

fn write_json_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        // `{}` on f64 is the shortest round-trip form — valid JSON.
        out.push_str(&format!("{v}"));
    } else {
        out.push_str("null");
    }
}

/// One event as a single JSONL line (no trailing newline).
pub fn event_to_json(e: &TraceEvent) -> String {
    let mut s = String::with_capacity(128);
    s.push_str(&format!(
        "{{\"ts_us\":{},\"dur_us\":{},\"kind\":\"{}\",\"name\":\"{}\",\"span\":{},\
         \"parent\":{},\"thread\":{},\"fields\":{{",
        e.ts_us,
        e.dur_us,
        e.kind.as_str(),
        e.name,
        e.span,
        e.parent,
        e.thread
    ));
    for (i, (k, v)) in e.fields.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("\"{k}\":"));
        write_json_f64(&mut s, v);
    }
    s.push_str("}}");
    s
}

/// Write events as JSONL (one event object per line).
pub fn write_jsonl(path: &Path, events: &[TraceEvent]) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for e in events {
        writeln!(f, "{}", event_to_json(e))?;
    }
    f.flush()
}

/// Process-start hook: `GRAPHEDGE_TRACE=<path>` enables recording and
/// remembers the path for [`flush_env_trace`].  Idempotent.
pub fn init_from_env() {
    if let Ok(path) = std::env::var("GRAPHEDGE_TRACE") {
        if !path.is_empty() {
            *ENV_PATH.lock().unwrap() = Some(PathBuf::from(path));
            set_enabled(true);
        }
    }
}

/// Drain and write to the `GRAPHEDGE_TRACE` path, if one was set.
/// Returns the path written, or `None` when the env var is unset.
pub fn flush_env_trace() -> Option<std::io::Result<PathBuf>> {
    let path = ENV_PATH.lock().unwrap().clone()?;
    let events = drain();
    Some(write_jsonl(&path, &events).map(|()| path))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Global-state tests must not interleave.  Explicitly `std`: the
    /// shim's loom double has no `const` constructor, and this static
    /// is test plumbing, not a model subject.
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn guard() -> std::sync::MutexGuard<'static, ()> {
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_tracing_records_nothing() {
        let _g = guard();
        set_enabled(false);
        clear();
        {
            let mut s = span("t.disabled");
            s.field("x", 1.0);
            instant("t.disabled_instant", &[("y", 2.0)]);
        }
        assert!(snapshot().iter().all(|e| !e.name.starts_with("t.disabled")));
    }

    #[test]
    fn spans_nest_and_instants_attach() {
        let _g = guard();
        set_enabled(true);
        clear();
        let outer_id;
        {
            let outer = span("t.outer");
            outer_id = outer.id();
            {
                let mut inner = span_with("t.inner", &[("k", 3.0)]);
                inner.field("k2", 4.0);
                instant("t.mark", &[("v", 5.0)]);
            }
        }
        set_enabled(false);
        let events = drain();
        let outer = events.iter().find(|e| e.name == "t.outer").unwrap();
        let inner = events.iter().find(|e| e.name == "t.inner").unwrap();
        let mark = events.iter().find(|e| e.name == "t.mark").unwrap();
        assert_eq!(outer.span, outer_id);
        assert_eq!(outer.parent, 0);
        assert_eq!(inner.parent, outer.span);
        assert_eq!(mark.parent, inner.span);
        assert_eq!(mark.kind, EventKind::Instant);
        assert_eq!(inner.fields.get("k"), Some(3.0));
        assert_eq!(inner.fields.get("k2"), Some(4.0));
        assert!(outer.ts_us <= inner.ts_us);
        assert!(outer.dur_us >= inner.dur_us);
    }

    #[test]
    fn scoped_threads_retire_into_the_shared_ring() {
        let _g = guard();
        set_enabled(true);
        clear();
        std::thread::scope(|s| {
            for i in 0..3 {
                s.spawn(move || {
                    let mut sp = span("t.worker");
                    sp.field("i", i as f64);
                });
            }
        });
        set_enabled(false);
        let events = drain();
        let workers: Vec<_> = events.iter().filter(|e| e.name == "t.worker").collect();
        assert_eq!(workers.len(), 3, "exited threads must not lose events");
        // Three distinct recorder threads.
        let mut threads: Vec<u32> = workers.iter().map(|e| e.thread).collect();
        threads.sort_unstable();
        threads.dedup();
        assert_eq!(threads.len(), 3);
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let _g = guard();
        let mut ring = Ring::new(4);
        for i in 0..10u64 {
            ring.push(TraceEvent {
                name: "t.r",
                kind: EventKind::Instant,
                ts_us: i,
                dur_us: 0,
                span: 0,
                parent: 0,
                thread: 0,
                fields: Fields::default(),
            });
        }
        assert_eq!(ring.events.len(), 4);
        assert_eq!(ring.dropped, 6);
        assert_eq!(ring.events.front().unwrap().ts_us, 6);
    }

    #[test]
    fn jsonl_lines_are_valid_json() {
        let _g = guard();
        let e = TraceEvent {
            name: "t.json",
            kind: EventKind::Span,
            ts_us: 12,
            dur_us: 34,
            span: 7,
            parent: 2,
            thread: 1,
            fields: Fields::from_slice(&[("a", 1.5), ("b", f64::NAN)]),
        };
        let line = event_to_json(&e);
        let v = crate::util::json::Value::parse(&line).expect("valid JSON");
        assert_eq!(v.path(&["name"]).unwrap().as_str(), Some("t.json"));
        assert_eq!(v.path(&["ts_us"]).unwrap().as_usize(), Some(12));
        assert_eq!(v.path(&["fields", "a"]).unwrap().as_f64(), Some(1.5));
        // Non-finite values serialize as null, keeping the line valid.
        assert!(matches!(
            v.path(&["fields", "b"]),
            Some(crate::util::json::Value::Null)
        ));
    }

    #[test]
    fn write_jsonl_roundtrips_through_a_file() {
        let _g = guard();
        set_enabled(true);
        clear();
        {
            let _s = span_with("t.file", &[("n", 9.0)]);
        }
        set_enabled(false);
        let events: Vec<TraceEvent> =
            drain().into_iter().filter(|e| e.name == "t.file").collect();
        assert_eq!(events.len(), 1);
        let dir = std::env::temp_dir().join(format!("ge_trace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        write_jsonl(&path, &events).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 1);
        assert!(text.contains("\"name\":\"t.file\""));
        std::fs::remove_dir_all(&dir).ok();
    }
}

// Loom models for the retirement handoff (`migrate_into_retired`): the
// subjects are locally constructed rings, never the process statics —
// loom primitives cannot live in consts and must be created inside
// `loom::model`.  Run with:
//   RUSTFLAGS="--cfg loom" cargo test --release --lib loom_
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;
    use crate::util::sync::Arc;

    fn ev(ts_us: u64) -> TraceEvent {
        TraceEvent {
            name: "loom.ev",
            kind: EventKind::Instant,
            ts_us,
            dur_us: 0,
            span: 0,
            parent: 0,
            thread: 0,
            fields: Fields::default(),
        }
    }

    /// An exiting thread handing its ring off while another thread is
    /// still pushing must conserve every event: after a final sweep,
    /// each push is in the retired ring exactly once.
    #[test]
    fn loom_ring_handoff_conserves_events() {
        loom::model(|| {
            let ring = Arc::new(Mutex::new(Ring::new(2)));
            let retired = Arc::new(Mutex::new(Ring::new(16)));
            let (r2, ret2) = (ring.clone(), retired.clone());
            let t = loom::thread::spawn(move || {
                r2.lock().unwrap().push(ev(1));
                migrate_into_retired(&r2, &ret2);
            });
            ring.lock().unwrap().push(ev(2));
            t.join().unwrap();
            migrate_into_retired(&ring, &retired);
            let retired = retired.lock().unwrap();
            assert_eq!(retired.dropped, 0);
            assert_eq!(retired.events.len(), 2, "handoff lost an event");
        });
    }

    /// `collect`-style draining racing the retirement handoff must see
    /// the surviving event exactly once, and the overflow count must
    /// transfer without being lost or double-counted.
    #[test]
    fn loom_ring_handoff_races_drain_without_loss() {
        loom::model(|| {
            let ring = Arc::new(Mutex::new(Ring::new(1)));
            let retired = Arc::new(Mutex::new(Ring::new(16)));
            // Overflow the 1-slot ring: one event survives, one is
            // counted in `dropped`.
            ring.lock().unwrap().push(ev(1));
            ring.lock().unwrap().push(ev(2));
            let (r2, ret2) = (ring.clone(), retired.clone());
            let t = loom::thread::spawn(move || migrate_into_retired(&r2, &ret2));
            // Drain in `collect(clear)` lock order: the ring first,
            // then retired only after the ring lock is released.
            let mut got = {
                let mut ring = ring.lock().unwrap();
                ring.events.drain(..).collect::<Vec<_>>()
            };
            got.extend(retired.lock().unwrap().events.drain(..));
            t.join().unwrap();
            let ring = ring.lock().unwrap();
            let retired = retired.lock().unwrap();
            let seen = got.len() + ring.events.len() + retired.events.len();
            assert_eq!(seen, 1, "surviving event must be seen exactly once");
            assert_eq!(ring.dropped + retired.dropped, 1, "overflow count lost or doubled");
        });
    }
}
