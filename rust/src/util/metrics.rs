//! Process-wide metrics registry: handle-based counters, gauges and
//! log-linear histograms, plus the legacy string-keyed API, rendered
//! as a plain-text report (`graphedge serve` prints it on shutdown;
//! examples print it after each run).
//!
//! # Two APIs, one registry
//!
//! * **Handles** ([`Counter`], [`Gauge`], [`Histogram`]) are interned
//!   once via [`Metrics::counter_handle`] /
//!   [`Metrics::gauge_handle`] / [`Metrics::histogram_handle`]
//!   (typically into a `Lazy` static) and record via atomics: **no
//!   lock, no string hashing, no allocation per event**.  Every hot
//!   path — per-request latency in the serve loop, per-execution
//!   runtime timers — must use handles.
//! * **String-keyed calls** ([`Metrics::inc`], [`Metrics::observe`],
//!   …) take the registry mutex and intern the name per call.  They
//!   are fine for cold paths (startup, once-per-run accounting) and
//!   keep every pre-existing call site working.
//!
//! String-keyed `observe` timers still accumulate exact [`Sample`]s —
//! appropriate for small bench populations.  Histogram handles are
//! the bounded-memory replacement for high-volume series.
//!
//! # Log-linear histograms
//!
//! [`Histogram`] covers `[2^-20, 2^10)` seconds (≈1 µs … ≈17 min)
//! with [`SUB`] linear sub-buckets per power of two: 240 fixed
//! buckets, ≤ 12.5 % relative error per bucket, O(1) memory no matter
//! how many events are recorded.  Values outside the range land in
//! under/overflow counters (so `count` stays exact).  Snapshots are
//! plain `u64` vectors and [`HistogramSnapshot::merge`] is exact
//! bucket-wise addition, which makes per-thread histograms mergeable
//! and percentile queries (`p50/p99/p999`) deterministic.
//!
//! # Naming conventions
//!
//! Metric names are `<subsystem>.<metric>` in snake_case
//! (`serve.requests`, `partition.cut_edges`, `runtime.exec.<model>`);
//! durations are recorded in **seconds**.
//!
//! See [`super::trace`] for the event-level (span) counterpart and
//! for which knobs are environment variables vs. CLI flags.

use std::collections::BTreeMap;
use std::sync::atomic::AtomicI64;
use std::sync::Mutex;
use std::time::Instant;

use once_cell::sync::Lazy;

use super::stats::Sample;
use super::sync::{Arc, AtomicU64, Ordering};

/// Global registry (examples and the launcher share one process).
pub static GLOBAL: Lazy<Metrics> = Lazy::new(Metrics::new);

/// Smallest representable histogram exponent: buckets start at
/// `2^MIN_EXP` seconds (≈ 0.95 µs).
pub const MIN_EXP: i32 = -20;
/// One past the largest bucketed exponent: values ≥ `2^MAX_EXP`
/// seconds (1024 s) count as overflow.
pub const MAX_EXP: i32 = 10;
/// Linear sub-buckets per power of two (relative width ≤ 1/SUB).
pub const SUB: usize = 8;
/// Total fixed bucket count of a [`Histogram`].
pub const HIST_BUCKETS: usize = ((MAX_EXP - MIN_EXP) as usize) * SUB;

/// Lower edge of bucket 0 (`2^MIN_EXP`).
pub fn hist_min() -> f64 {
    (MIN_EXP as f64).exp2()
}

/// Upper edge of the last bucket (`2^MAX_EXP`); also the overflow
/// representative value.
pub fn hist_max() -> f64 {
    (MAX_EXP as f64).exp2()
}

/// Bucket index for a value, or `None` when it belongs to the
/// under/overflow counters (non-finite, negative, or out of range).
///
/// Pure bit manipulation — the exponent comes straight from the f64
/// representation and the sub-bucket from the top [`SUB`]-log2
/// mantissa bits, so boundary values `2^e * (1 + k/SUB)` classify
/// exactly into bucket `(e - MIN_EXP) * SUB + k`.
pub fn bucket_index(v: f64) -> Option<usize> {
    if !v.is_finite() || v < hist_min() || v >= hist_max() {
        return None;
    }
    let bits = v.to_bits();
    let e = ((bits >> 52) & 0x7ff) as i32 - 1023;
    let sub = ((bits >> 49) & 0x7) as usize;
    Some(((e - MIN_EXP) as usize) * SUB + sub)
}

/// `[lo, hi)` value range of bucket `i` (panics if `i` is out of
/// range).
pub fn bucket_bounds(i: usize) -> (f64, f64) {
    assert!(i < HIST_BUCKETS, "bucket {i} out of range");
    let base = ((MIN_EXP + (i / SUB) as i32) as f64).exp2();
    let k = (i % SUB) as f64;
    let w = SUB as f64;
    (base * (1.0 + k / w), base * (1.0 + (k + 1.0) / w))
}

/// Monotonic event counter handle (clone-to-share, atomic adds).
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

// Manual impl: loom's `Arc`/atomics (used under `--cfg loom`, see
// [`super::sync`]) do not implement `Default`.
impl Default for Counter {
    fn default() -> Self {
        Counter(Arc::new(AtomicU64::new(0)))
    }
}

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        // ordering: Relaxed — a count is an independent event tally; an
        // increment publishes no other memory, so no release is needed.
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        // ordering: Relaxed — monitoring read; staleness is acceptable
        // and no memory is acquired through the value.
        self.0.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        // ordering: Relaxed — registry-wide zeroing is best-effort and
        // racing increments may land on either side of it by design.
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Last-value gauge handle (clone-to-share, atomic store).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicI64>);

// Manual impl: loom's `Arc` (under `--cfg loom`) has no `Default`.
impl Default for Gauge {
    fn default() -> Self {
        Gauge(Arc::new(AtomicI64::new(0)))
    }
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&self, v: i64) {
        // ordering: Relaxed — last-writer-wins sample; readers only need
        // *some* recent value, not an ordering with other state.
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        // ordering: Relaxed — the RMW keeps concurrent deltas exact; no
        // cross-variable ordering is promised.
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        // ordering: Relaxed — monitoring read; staleness is acceptable.
        self.0.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        // ordering: Relaxed — best-effort zeroing, same as Counter.
        self.0.store(0, Ordering::Relaxed);
    }
}

struct HistCore {
    buckets: Vec<AtomicU64>,
    underflow: AtomicU64,
    overflow: AtomicU64,
    /// Running sum of observed values, stored as f64 bits and updated
    /// with a CAS loop so `observe` never locks.
    sum_bits: AtomicU64,
}

/// Fixed-bucket log-linear duration histogram handle.
///
/// [`Histogram::observe`] is one relaxed `fetch_add` plus one CAS-add
/// — no lock, no allocation — and is safe to hammer from every worker
/// thread through clones of the same handle.
#[derive(Clone)]
pub struct Histogram {
    core: Arc<HistCore>,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            core: Arc::new(HistCore {
                buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
                underflow: AtomicU64::new(0),
                overflow: AtomicU64::new(0),
                sum_bits: AtomicU64::new(0.0f64.to_bits()),
            }),
        }
    }

    /// Record one value (seconds).  Lock- and allocation-free.
    pub fn observe(&self, v: f64) {
        // ordering: Relaxed — each bucket is an independent tally (the
        // RMW itself guarantees no lost increment); snapshot() makes no
        // cross-bucket consistency promise, so no release/acquire pair
        // is needed anywhere in this histogram.
        match bucket_index(v) {
            Some(i) => {
                self.core.buckets[i].fetch_add(1, Ordering::Relaxed);
            }
            None if v.is_finite() && v >= hist_max() => {
                self.core.overflow.fetch_add(1, Ordering::Relaxed);
            }
            None => {
                self.core.underflow.fetch_add(1, Ordering::Relaxed);
            }
        }
        let add = if v.is_finite() { v } else { 0.0 };
        // ordering: Relaxed load + Relaxed CAS — only sum_bits itself
        // must be lost-update-free (the CAS retry loop provides that);
        // the sum orders nothing else.  A stale first load merely costs
        // one extra CAS round.
        let mut cur = self.core.sum_bits.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + add).to_bits();
            match self.core.sum_bits.compare_exchange_weak(
                cur,
                new,
                // ordering: Relaxed/Relaxed — see the loop header note.
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Time a closure into this histogram.  The observation is made by
    /// a drop guard, so it is recorded even when `f` panics.
    pub fn time<R>(&self, f: impl FnOnce() -> R) -> R {
        let _guard = HistTimer { h: self, t0: Instant::now() };
        f()
    }

    /// Consistent point-in-time copy of the counts.
    pub fn snapshot(&self) -> HistogramSnapshot {
        // ordering: Relaxed — reads race in-flight observes by design;
        // an event straddling the snapshot lands wholly in this one or
        // wholly in the next (each count is a single RMW).
        HistogramSnapshot {
            buckets: self
                .core
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            underflow: self.core.underflow.load(Ordering::Relaxed),
            overflow: self.core.overflow.load(Ordering::Relaxed),
            sum: f64::from_bits(self.core.sum_bits.load(Ordering::Relaxed)),
        }
    }

    pub fn count(&self) -> u64 {
        self.snapshot().count()
    }

    pub fn mean(&self) -> f64 {
        self.snapshot().mean()
    }

    /// Percentile query (`p` in `[0, 100]`); see
    /// [`HistogramSnapshot::percentile`].
    pub fn percentile(&self, p: f64) -> f64 {
        self.snapshot().percentile(p)
    }

    fn reset(&self) {
        // ordering: Relaxed — best-effort zeroing; concurrent observes
        // may straddle the reset, same contract as Counter::reset.
        for b in &self.core.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.core.underflow.store(0, Ordering::Relaxed);
        self.core.overflow.store(0, Ordering::Relaxed);
        self.core.sum_bits.store(0.0f64.to_bits(), Ordering::Relaxed);
    }
}

struct HistTimer<'a> {
    h: &'a Histogram,
    t0: Instant,
}

impl Drop for HistTimer<'_> {
    fn drop(&mut self) {
        self.h.observe(self.t0.elapsed().as_secs_f64());
    }
}

/// Plain-data copy of a [`Histogram`]'s counts: mergeable across
/// threads/processes and queryable without touching the live atomics.
#[derive(Clone, Debug, Default)]
pub struct HistogramSnapshot {
    pub buckets: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
    pub sum: f64,
}

impl HistogramSnapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum / n as f64
        }
    }

    /// Exact bucket-wise addition: merging per-thread snapshots yields
    /// the same counts as a single shared histogram would have.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        if self.buckets.is_empty() {
            self.buckets = vec![0; other.buckets.len()];
        }
        assert_eq!(
            self.buckets.len(),
            other.buckets.len(),
            "merging snapshots with different bucket layouts"
        );
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.overflow += other.overflow;
        self.sum += other.sum;
    }

    /// Percentile query, `p` in `[0, 100]`.  Walks underflow (reported
    /// as 0.0) → buckets (reported as the bucket midpoint, ≤ 12.5 %
    /// relative error) → overflow (reported as the range maximum).
    pub fn percentile(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = (((p / 100.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = self.underflow;
        if rank <= seen {
            return 0.0;
        }
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if rank <= seen {
                let (lo, hi) = bucket_bounds(i);
                return 0.5 * (lo + hi);
            }
        }
        hist_max()
    }
}

#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, Counter>>,
    gauges: Mutex<BTreeMap<String, Gauge>>,
    timers: Mutex<BTreeMap<String, Sample>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    // -- handle interning (call once, store in a Lazy/static/field) --------

    /// Intern (or fetch) the named counter and return a recording
    /// handle.  The handle stays valid across [`Metrics::reset`].
    pub fn counter_handle(&self, name: &str) -> Counter {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Intern (or fetch) the named gauge handle.
    pub fn gauge_handle(&self, name: &str) -> Gauge {
        let mut m = self.gauges.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Intern (or fetch) the named histogram handle.
    pub fn histogram_handle(&self, name: &str) -> Histogram {
        let mut m = self.histograms.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Snapshot of the named histogram, if it exists.
    pub fn histogram_stats(&self, name: &str) -> Option<HistogramSnapshot> {
        let m = self.histograms.lock().unwrap();
        m.get(name).map(|h| h.snapshot())
    }

    // -- string-keyed compatibility API (cold paths) -----------------------

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, n: u64) {
        self.counter_handle(name).add(n);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.get())
            .unwrap_or(0)
    }

    pub fn set_gauge(&self, name: &str, v: i64) {
        self.gauge_handle(name).set(v);
    }

    pub fn gauge(&self, name: &str) -> i64 {
        self.gauges
            .lock()
            .unwrap()
            .get(name)
            .map(|g| g.get())
            .unwrap_or(0)
    }

    /// Record a duration sample in seconds (exact [`Sample`] storage —
    /// unbounded, for low-volume series; use a histogram handle on hot
    /// paths).
    pub fn observe(&self, name: &str, seconds: f64) {
        let mut m = self.timers.lock().unwrap();
        m.entry(name.to_string()).or_default().push(seconds);
    }

    /// Time a closure into the named sample.  The observation is made
    /// by a drop guard, so a panicking closure (tolerated by
    /// [`super::threadpool::ThreadPool`]'s catch_unwind) still records
    /// its elapsed time instead of silently vanishing from the timer.
    pub fn time<R>(&self, name: &str, f: impl FnOnce() -> R) -> R {
        let _guard = TimeGuard { m: self, name, t0: Instant::now() };
        f()
    }

    pub fn timer_stats(&self, name: &str) -> Option<(usize, f64, f64, f64)> {
        let m = self.timers.lock().unwrap();
        let s = m.get(name)?;
        Some((s.len(), s.mean(), s.percentile(50.0), s.percentile(99.0)))
    }

    /// Human-readable dump of everything recorded.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let counters = self.counters.lock().unwrap();
        if !counters.is_empty() {
            out.push_str("counters:\n");
            for (k, v) in counters.iter() {
                out.push_str(&format!("  {k:<40} {}\n", v.get()));
            }
        }
        let gauges = self.gauges.lock().unwrap();
        if !gauges.is_empty() {
            out.push_str("gauges:\n");
            for (k, v) in gauges.iter() {
                out.push_str(&format!("  {k:<40} {}\n", v.get()));
            }
        }
        let timers = self.timers.lock().unwrap();
        if !timers.is_empty() {
            out.push_str("timers (n / mean / p50 / p99, seconds):\n");
            for (k, s) in timers.iter() {
                out.push_str(&format!(
                    "  {k:<40} {} / {:.6} / {:.6} / {:.6}\n",
                    s.len(),
                    s.mean(),
                    s.percentile(50.0),
                    s.percentile(99.0)
                ));
            }
        }
        let histograms = self.histograms.lock().unwrap();
        if !histograms.is_empty() {
            out.push_str("histograms (n / mean / p50 / p99 / p999, seconds):\n");
            for (k, h) in histograms.iter() {
                let s = h.snapshot();
                out.push_str(&format!(
                    "  {k:<40} {} / {:.6} / {:.6} / {:.6} / {:.6}\n",
                    s.count(),
                    s.mean(),
                    s.percentile(50.0),
                    s.percentile(99.0),
                    s.percentile(99.9)
                ));
            }
        }
        out
    }

    /// Zero every value.  Counters, gauges and histograms are zeroed
    /// in place (not removed), so handles interned before the reset
    /// keep recording into the registry afterwards.
    pub fn reset(&self) {
        for c in self.counters.lock().unwrap().values() {
            c.reset();
        }
        for g in self.gauges.lock().unwrap().values() {
            g.reset();
        }
        self.timers.lock().unwrap().clear();
        for h in self.histograms.lock().unwrap().values() {
            h.reset();
        }
    }
}

struct TimeGuard<'a> {
    m: &'a Metrics,
    name: &'a str,
    t0: Instant,
}

impl Drop for TimeGuard<'_> {
    fn drop(&mut self) {
        self.m.observe(self.name, self.t0.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let m = Metrics::new();
        m.inc("requests");
        m.add("requests", 4);
        m.set_gauge("queue_depth", 7);
        assert_eq!(m.counter("requests"), 5);
        assert_eq!(m.gauge("queue_depth"), 7);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timers_record() {
        let m = Metrics::new();
        m.observe("op", 0.5);
        m.observe("op", 1.5);
        let (n, mean, p50, _) = m.timer_stats("op").unwrap();
        assert_eq!(n, 2);
        assert!((mean - 1.0).abs() < 1e-12);
        assert!((p50 - 1.0).abs() < 1e-12);
        let r = m.time("op2", || 42);
        assert_eq!(r, 42);
        assert!(m.timer_stats("op2").is_some());
    }

    #[test]
    fn report_contains_names() {
        let m = Metrics::new();
        m.inc("a.b");
        m.observe("lat", 0.1);
        m.histogram_handle("hist.lat").observe(0.01);
        let rep = m.report();
        assert!(rep.contains("a.b"));
        assert!(rep.contains("lat"));
        assert!(rep.contains("hist.lat"));
    }

    #[test]
    fn handles_share_state_with_the_string_api() {
        let m = Metrics::new();
        let c = m.counter_handle("h.req");
        c.inc();
        c.add(2);
        m.inc("h.req");
        assert_eq!(m.counter("h.req"), 4);
        assert_eq!(m.counter_handle("h.req").get(), 4);

        let g = m.gauge_handle("h.depth");
        g.set(-3);
        assert_eq!(m.gauge("h.depth"), -3);
        g.add(5);
        assert_eq!(m.gauge("h.depth"), 2);
    }

    #[test]
    fn reset_keeps_handles_alive() {
        let m = Metrics::new();
        let c = m.counter_handle("r.c");
        let h = m.histogram_handle("r.h");
        c.add(9);
        h.observe(0.5);
        m.reset();
        assert_eq!(m.counter("r.c"), 0);
        assert_eq!(m.histogram_stats("r.h").unwrap().count(), 0);
        // Handles interned before the reset still feed the registry.
        c.inc();
        h.observe(0.25);
        assert_eq!(m.counter("r.c"), 1);
        assert_eq!(m.histogram_stats("r.h").unwrap().count(), 1);
    }

    #[test]
    fn time_records_even_when_the_closure_panics() {
        let m = Metrics::new();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            m.time("panicky", || panic!("job poisoned"))
        }));
        assert!(r.is_err());
        let (n, ..) = m.timer_stats("panicky").expect("observation recorded");
        assert_eq!(n, 1);
    }

    #[test]
    fn histogram_time_records_even_when_the_closure_panics() {
        let h = Histogram::new();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            h.time(|| panic!("job poisoned"))
        }));
        assert!(r.is_err());
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn bucket_index_classifies_boundaries_exactly() {
        // 2^e * (1 + k/SUB) is the lower edge of bucket (e-MIN)*SUB+k.
        for e in MIN_EXP..MAX_EXP {
            for k in 0..SUB {
                let v = (e as f64).exp2() * (1.0 + k as f64 / SUB as f64);
                let want = ((e - MIN_EXP) as usize) * SUB + k;
                assert_eq!(bucket_index(v), Some(want), "v={v}");
                let (lo, hi) = bucket_bounds(want);
                assert!(lo <= v && v < hi);
            }
        }
        assert_eq!(bucket_index(0.0), None);
        assert_eq!(bucket_index(-1.0), None);
        assert_eq!(bucket_index(hist_max()), None);
        assert_eq!(bucket_index(f64::NAN), None);
        assert_eq!(bucket_index(f64::INFINITY), None);
        assert_eq!(bucket_index(hist_min()), Some(0));
    }

    #[test]
    fn histogram_counts_and_percentiles() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.observe(0.001); // ~1 ms
        }
        for _ in 0..10 {
            h.observe(1.0); // 1 s
        }
        h.observe(1e-9); // underflow
        h.observe(5000.0); // overflow
        let s = h.snapshot();
        assert_eq!(s.count(), 102);
        assert_eq!(s.underflow, 1);
        assert_eq!(s.overflow, 1);
        let p50 = s.percentile(50.0);
        assert!((0.0009..0.0012).contains(&p50), "p50={p50}");
        let p99 = s.percentile(99.0);
        assert!((0.9..1.2).contains(&p99), "p99={p99}");
        assert_eq!(s.percentile(100.0), hist_max()); // overflow sample
        let mean = s.mean();
        assert!(mean > 0.0 && mean < 60.0, "mean={mean}");
    }

    #[test]
    fn snapshot_merge_is_exact() {
        let a = Histogram::new();
        let b = Histogram::new();
        let whole = Histogram::new();
        let mut rng = crate::util::rng::Rng::seed_from(11);
        for i in 0..500 {
            let v = 1e-6 * 10f64.powf(rng.f64() * 8.0); // 1 µs .. 100 s
            if i % 2 == 0 {
                a.observe(v);
            } else {
                b.observe(v);
            }
            whole.observe(v);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        let reference = whole.snapshot();
        assert_eq!(merged.buckets, reference.buckets);
        assert_eq!(merged.underflow, reference.underflow);
        assert_eq!(merged.overflow, reference.overflow);
        assert!((merged.sum - reference.sum).abs() < 1e-9 * reference.sum.abs());
        for p in [50.0, 90.0, 99.0, 99.9] {
            assert_eq!(merged.percentile(p), reference.percentile(p));
        }
    }
}

// Model-checked interleavings of the lock-free histogram.  Compiled
// and run only via the loom harness (see ANALYSIS.md):
//   RUSTFLAGS="--cfg loom" cargo test --release --lib loom_
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;

    #[test]
    fn loom_histogram_concurrent_observes_lose_nothing() {
        loom::model(|| {
            let h = Histogram::new();
            let h2 = h.clone();
            let t = loom::thread::spawn(move || {
                h2.observe(0.001);
            });
            h.observe(1.0);
            t.join().unwrap();
            // Across every interleaving: both the bucket RMWs and the
            // sum CAS loop must be lost-update-free.
            let s = h.snapshot();
            assert_eq!(s.count(), 2);
            assert!((s.sum - 1.001).abs() < 1e-12, "lost sum update: {}", s.sum);
        });
    }

    #[test]
    fn loom_histogram_snapshot_races_observe_safely() {
        loom::model(|| {
            let h = Histogram::new();
            let h2 = h.clone();
            let t = loom::thread::spawn(move || {
                h2.observe(0.5);
            });
            // A snapshot taken mid-observe sees the event either not at
            // all or exactly once — never torn across buckets.
            let mid = h.snapshot();
            assert!(mid.count() <= 1);
            t.join().unwrap();
            let done = h.snapshot();
            assert_eq!(done.count(), 1);
            assert!((done.sum - 0.5).abs() < 1e-12);
            // Merge of the post-join snapshot into an empty one is
            // exact (plain data, but keeps the model honest end to end).
            let mut merged = HistogramSnapshot::default();
            merged.merge(&done);
            assert_eq!(merged.count(), 1);
            assert_eq!(merged.percentile(50.0), done.percentile(50.0));
        });
    }
}
