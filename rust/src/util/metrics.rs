//! Process-wide metrics registry: named counters, gauges and latency
//! samples, rendered as a plain-text report (`graphedge serve` prints
//! it on shutdown; examples print it after each run).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use once_cell::sync::Lazy;

use super::stats::Sample;

/// Global registry (examples and the launcher share one process).
pub static GLOBAL: Lazy<Metrics> = Lazy::new(Metrics::new);

#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    gauges: Mutex<BTreeMap<String, AtomicI64>>,
    timers: Mutex<BTreeMap<String, Sample>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, n: u64) {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(n, Ordering::Relaxed);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    pub fn set_gauge(&self, name: &str, v: i64) {
        let mut m = self.gauges.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| AtomicI64::new(0))
            .store(v, Ordering::Relaxed);
    }

    pub fn gauge(&self, name: &str) -> i64 {
        self.gauges
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Record a duration sample in seconds.
    pub fn observe(&self, name: &str, seconds: f64) {
        let mut m = self.timers.lock().unwrap();
        m.entry(name.to_string()).or_default().push(seconds);
    }

    /// Time a closure into the named sample.
    pub fn time<R>(&self, name: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.observe(name, t0.elapsed().as_secs_f64());
        r
    }

    pub fn timer_stats(&self, name: &str) -> Option<(usize, f64, f64, f64)> {
        let m = self.timers.lock().unwrap();
        let s = m.get(name)?;
        Some((s.len(), s.mean(), s.percentile(50.0), s.percentile(99.0)))
    }

    /// Human-readable dump of everything recorded.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let counters = self.counters.lock().unwrap();
        if !counters.is_empty() {
            out.push_str("counters:\n");
            for (k, v) in counters.iter() {
                out.push_str(&format!("  {k:<40} {}\n", v.load(Ordering::Relaxed)));
            }
        }
        let gauges = self.gauges.lock().unwrap();
        if !gauges.is_empty() {
            out.push_str("gauges:\n");
            for (k, v) in gauges.iter() {
                out.push_str(&format!("  {k:<40} {}\n", v.load(Ordering::Relaxed)));
            }
        }
        let timers = self.timers.lock().unwrap();
        if !timers.is_empty() {
            out.push_str("timers (n / mean / p50 / p99, seconds):\n");
            for (k, s) in timers.iter() {
                out.push_str(&format!(
                    "  {k:<40} {} / {:.6} / {:.6} / {:.6}\n",
                    s.len(),
                    s.mean(),
                    s.percentile(50.0),
                    s.percentile(99.0)
                ));
            }
        }
        out
    }

    pub fn reset(&self) {
        self.counters.lock().unwrap().clear();
        self.gauges.lock().unwrap().clear();
        self.timers.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let m = Metrics::new();
        m.inc("requests");
        m.add("requests", 4);
        m.set_gauge("queue_depth", 7);
        assert_eq!(m.counter("requests"), 5);
        assert_eq!(m.gauge("queue_depth"), 7);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timers_record() {
        let m = Metrics::new();
        m.observe("op", 0.5);
        m.observe("op", 1.5);
        let (n, mean, p50, _) = m.timer_stats("op").unwrap();
        assert_eq!(n, 2);
        assert!((mean - 1.0).abs() < 1e-12);
        assert!((p50 - 1.0).abs() < 1e-12);
        let r = m.time("op2", || 42);
        assert_eq!(r, 42);
        assert!(m.timer_stats("op2").is_some());
    }

    #[test]
    fn report_contains_names() {
        let m = Metrics::new();
        m.inc("a.b");
        m.observe("lat", 0.1);
        let rep = m.report();
        assert!(rep.contains("a.b"));
        assert!(rep.contains("lat"));
    }
}
