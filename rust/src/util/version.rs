//! Versioned memoization: one substrate for every "repair instead of
//! recompute" cache in the crate.
//!
//! GraphEdge's whole premise is incremental per-time-step work, and
//! before this module three subsystems hand-rolled the same staleness
//! pattern independently (`ObsState`'s static templates, the
//! incremental partitioner's "which graph state did I repair to"
//! bookkeeping, `Router`'s cached batch deadlines) while `CostModel`
//! simply recomputed its rate tables on every call.  The shared idiom
//! is tiny: producers own monotonically increasing [`Version`]
//! counters, consumers hold [`Memoized`] cells stamped with the version
//! vector their value was derived from, and a read either returns the
//! cached value (stamps match) or rebuilds and re-stamps.
//!
//! # Who bumps what
//!
//! | version    | producer                            | bumped when |
//! |------------|-------------------------------------|-------------|
//! | `topology` | `graph::dynamic::DynamicGraph`      | any edge / user-set / position mutation (every `GraphDelta` source, recorded or not) |
//! | `layout`   | `drl::env::Env::install_partition`  | a new partition (full recut or incremental repair) is adopted |
//! | `params`   | `drl::env::Env::assemble`           | pinned once per `SystemParams`/`EdgeNetwork` setup; never re-bumped today, so consumers survive a future "hot-reload params" path unchanged |
//!
//! # Invalidation rules
//!
//! A [`Memoized`] value is current iff the version vector it was built
//! under is *equal* to the producer versions observed at read time —
//! not merely `<=`: equality keeps the contract symmetric if a
//! producer is ever rebuilt/replaced wholesale.  Consumers therefore
//! never need explicit invalidation hooks wired through choke points;
//! they read through [`Memoized::get_or_rebuild`] with the current
//! producer stamps and rebuilding happens lazily on first stale read.
//! The derived-data consumers in this crate key as follows:
//!
//! * `ObsState` static templates — (topology, layout, params);
//! * `Env` rate tables for `CostModel` — (topology, params): uplink
//!   rates depend on user positions (topology), compute rates only on
//!   the drawn network (params);
//! * incremental repair — records the topology version it repaired the
//!   layout to (`IncrementalPartitioner::repaired_to`), so "is this
//!   layout current?" is one integer compare instead of a cut audit;
//! * `Router` — stamps its deadline windows with the params version and
//!   flushes them if the stamp ever disagrees (`revalidate`).
//!
//! # Ordering contract for `SharedVersion`
//!
//! [`SharedVersion`] is the cross-thread variant.  Its `bump` is a
//! release increment and `load` is an acquire read: a reader that
//! observes version `v` also observes every write the producer made
//! before bumping to `v`.  That is the entire contract — readers must
//! *not* assume two loads are ordered with anything else, and the
//! counter value itself is the only synchronized datum.  Plain
//! [`Version`] is `Copy` and single-threaded; it is what the `Env`
//! pipeline uses (one mutator at a time), while `SharedVersion` exists
//! for pipelined serving stages that publish layout progress across
//! threads.

use std::cell::{Cell, Ref, RefCell};

use crate::util::sync::{AtomicU64, Ordering};

/// A monotonically increasing change stamp (cheap `Copy` newtype).
///
/// Producers own one per invalidation domain and call [`bump`] on
/// every mutation; consumers compare stamps for equality.  The counter
/// is 64-bit: at one bump per nanosecond it takes ~584 years to wrap,
/// so overflow is a non-concern (and `bump` would panic in debug
/// builds long before silently wrapping in release).
///
/// [`bump`]: Version::bump
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Version(u64);

impl Version {
    /// The pre-first-mutation stamp.
    pub const ZERO: Version = Version(0);

    /// The raw counter value (gauges, lag arithmetic, debugging).
    pub fn value(self) -> u64 {
        self.0
    }

    /// Advance to the next version and return the new stamp.
    ///
    /// `Version` is `Copy`, so call this on the *owning* field — a
    /// bump through a copy advances only the copy.
    pub fn bump(&mut self) -> Version {
        self.0 += 1;
        *self
    }

    /// How far `self` trails `newer` (0 when current or ahead).
    pub fn lag(self, newer: Version) -> u64 {
        newer.0.saturating_sub(self.0)
    }
}

/// Atomic [`Version`] counter for cross-thread producers/readers.
///
/// See the module docs for the release/acquire contract.  Not `Copy`
/// (it is the shared counter itself, not a stamp); `load` returns a
/// plain `Version` stamp that can be stored in version vectors.
#[derive(Debug, Default)]
pub struct SharedVersion(AtomicU64);

impl SharedVersion {
    pub fn new() -> Self {
        SharedVersion(AtomicU64::new(0))
    }

    /// The current stamp.
    pub fn load(&self) -> Version {
        // ordering: Acquire — pairs with the Release bump so a reader
        // that observes version v also observes the producer writes
        // that preceded the bump to v.
        Version(self.0.load(Ordering::Acquire))
    }

    /// Advance the counter and return the *new* stamp.
    pub fn bump(&self) -> Version {
        // ordering: AcqRel — the increment publishes (Release) the
        // producer's preceding writes to any Acquire load, and the
        // Acquire half keeps chained bump-then-read sequences on the
        // bumping thread from floating above earlier bumps.
        Version(self.0.fetch_add(1, Ordering::AcqRel) + 1)
    }
}

impl Clone for SharedVersion {
    /// Cloning snapshots the current count into an independent counter
    /// (used when a version-carrying owner like `Env` is replicated
    /// into `VecEnv` slots — each slot then versions independently).
    fn clone(&self) -> Self {
        SharedVersion(AtomicU64::new(self.load().value()))
    }
}

/// A lazily (re)built value stamped with the version vector it was
/// derived from.
///
/// `get_or_rebuild(&self, versions, rebuild)` returns the cached value
/// when `versions` equals the stored stamp vector and otherwise runs
/// `rebuild` and re-stamps — so the *consumer* decides which producer
/// versions its derived data depends on, and no producer needs to know
/// who caches what.  Interior mutability (`RefCell`) keeps the read
/// API `&self` for query-shaped callers (`Sample::percentile`,
/// `Env::state`); the cell is `Send` (not `Sync`) exactly like
/// the `RefCell` caches it replaces.
///
/// The hit/miss counters exist for the memoization bench and the
/// equivalence property tests ("a second read at the same versions
/// must not rebuild"); they are plain `Cell`s, not metrics handles, so
/// a `Memoized` in a hot struct costs nothing when nobody reads them.
#[derive(Debug, Default)]
pub struct Memoized<T> {
    entry: RefCell<Option<MemoEntry<T>>>,
    reads: Cell<u64>,
    rebuilds: Cell<u64>,
}

#[derive(Debug)]
struct MemoEntry<T> {
    versions: Vec<Version>,
    value: T,
}

impl<T> Memoized<T> {
    pub fn new() -> Self {
        Memoized { entry: RefCell::new(None), reads: Cell::new(0), rebuilds: Cell::new(0) }
    }

    /// Return the cached value if it was built at exactly `versions`,
    /// rebuilding (and re-stamping) it via `rebuild` otherwise.
    ///
    /// The borrow of the returned [`Ref`] must end before the next
    /// `get_or_rebuild`/`invalidate` on the same cell (standard
    /// `RefCell` discipline); `rebuild` runs with no outstanding
    /// borrow, so it may freely read other fields of the owner.
    pub fn get_or_rebuild(
        &self,
        versions: &[Version],
        rebuild: impl FnOnce() -> T,
    ) -> Ref<'_, T> {
        self.reads.set(self.reads.get() + 1);
        let stale = {
            let entry = self.entry.borrow();
            match entry.as_ref() {
                Some(e) => e.versions != versions,
                None => true,
            }
        };
        if stale {
            self.rebuilds.set(self.rebuilds.get() + 1);
            let value = rebuild();
            *self.entry.borrow_mut() =
                Some(MemoEntry { versions: versions.to_vec(), value });
        }
        Ref::map(self.entry.borrow(), |e| {
            // The slot was just filled above when empty; `unwrap` here
            // can only fire on a re-entrant invalidate inside `Ref`'s
            // lifetime, which the borrow discipline already forbids.
            &e.as_ref().unwrap().value
        })
    }

    /// Is the cached value current for `versions`?
    pub fn is_current(&self, versions: &[Version]) -> bool {
        self.entry
            .borrow()
            .as_ref()
            .is_some_and(|e| e.versions == versions)
    }

    /// Drop the cached value; the next read rebuilds unconditionally.
    pub fn invalidate(&self) {
        *self.entry.borrow_mut() = None;
    }

    /// Total `get_or_rebuild` calls.
    pub fn reads(&self) -> u64 {
        self.reads.get()
    }

    /// How many of those reads had to rebuild (misses).
    pub fn rebuilds(&self) -> u64 {
        self.rebuilds.get()
    }
}

impl<T: Clone> Clone for Memoized<T> {
    fn clone(&self) -> Self {
        Memoized {
            entry: RefCell::new(self.entry.borrow().as_ref().map(|e| MemoEntry {
                versions: e.versions.clone(),
                value: e.value.clone(),
            })),
            reads: self.reads.clone(),
            rebuilds: self.rebuilds.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bump_is_strictly_monotonic() {
        let mut v = Version::ZERO;
        let mut prev = v;
        for i in 1..=1000u64 {
            let now = v.bump();
            assert!(now > prev);
            assert_eq!(now.value(), i);
            prev = now;
        }
    }

    #[test]
    fn lag_saturates_at_zero() {
        let mut a = Version::ZERO;
        let b = a.bump();
        assert_eq!(Version::ZERO.lag(b), 1);
        assert_eq!(b.lag(Version::ZERO), 0);
        assert_eq!(b.lag(b), 0);
    }

    #[test]
    fn shared_version_bumps_across_threads() {
        let v = std::sync::Arc::new(SharedVersion::new());
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let v = std::sync::Arc::clone(&v);
                std::thread::spawn(move || {
                    let mut last = Version::ZERO;
                    for _ in 0..250 {
                        let now = v.bump();
                        assert!(now > last, "bumps must be monotone per thread");
                        last = now;
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(v.load().value(), 1000);
    }

    #[test]
    fn memoized_rebuilds_only_on_version_change() {
        let mut topo = Version::ZERO;
        let cell: Memoized<u64> = Memoized::new();
        let built = Cell::new(0u64);
        let read = |stamp: Version| {
            *cell.get_or_rebuild(&[stamp], || {
                built.set(built.get() + 1);
                stamp.value() * 10
            })
        };
        assert_eq!(read(topo), 0);
        assert_eq!(read(topo), 0); // hit: no rebuild
        assert_eq!(built.get(), 1);
        let t1 = topo.bump();
        assert_eq!(read(t1), 10);
        assert_eq!(built.get(), 2);
        assert_eq!(cell.reads(), 3);
        assert_eq!(cell.rebuilds(), 2);
    }

    #[test]
    fn invalidate_forces_rebuild() {
        let cell: Memoized<u32> = Memoized::new();
        let _ = cell.get_or_rebuild(&[Version::ZERO], || 7);
        assert!(cell.is_current(&[Version::ZERO]));
        cell.invalidate();
        assert!(!cell.is_current(&[Version::ZERO]));
        assert_eq!(*cell.get_or_rebuild(&[Version::ZERO], || 9), 9);
        assert_eq!(cell.rebuilds(), 2);
    }

    #[test]
    fn clone_carries_value_and_counters() {
        let cell: Memoized<u32> = Memoized::new();
        let _ = cell.get_or_rebuild(&[Version::ZERO], || 3);
        let copy = cell.clone();
        assert!(copy.is_current(&[Version::ZERO]));
        assert_eq!(copy.rebuilds(), 1);
        // Clones diverge after the copy.
        copy.invalidate();
        assert!(cell.is_current(&[Version::ZERO]));
    }
}
