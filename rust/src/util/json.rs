//! Minimal strict JSON parser (no serde in the offline environment).
//!
//! Parses the artifact `manifest.json` written by `python/compile/aot.py`
//! into a [`Value`] tree with typed accessors.  Supports the full JSON
//! grammar except `\u` surrogate pairs outside the BMP (not emitted by
//! the manifest writer).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

/// Parse error with byte offset context.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {at}: {msg}")]
pub struct ParseError {
    pub at: usize,
    pub msg: String,
}

impl Value {
    pub fn parse(src: &str) -> Result<Value, ParseError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]...` path access.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers → `Vec<usize>` (shape lists in the manifest).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    // -- serialization -----------------------------------------------------

    /// Pretty serializer (2-space indent): the inverse of
    /// [`Value::parse`] up to whitespace and float formatting.  Lets
    /// sibling benches merge their sections into one shared results
    /// file (`BENCH_partition.json`) without clobbering each other.
    /// Non-finite numbers are not representable in JSON and serialize
    /// as `null`.
    pub fn to_json_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        use std::fmt::Write as _;
        fn pad(out: &mut String, d: usize) {
            for _ in 0..d {
                out.push_str("  ");
            }
        }
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Value::Num(n) if !n.is_finite() => out.push_str("null"),
            Value::Num(n) => {
                // Integral values print without a fraction so counters
                // stay readable; f64 `Display` never emits exponent
                // notation, so both arms are valid JSON numbers.
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => escape_json_str(out, s),
            Value::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    pad(out, depth + 1);
                    v.write_pretty(out, depth + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, depth);
                out.push(']');
            }
            Value::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    pad(out, depth + 1);
                    escape_json_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, depth);
                out.push('}');
            }
        }
    }
}

fn escape_json_str(out: &mut String, s: &str) {
    use std::fmt::Write as _;
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => write!(f, "{n}"),
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{k:?}:{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { at: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek().ok_or_else(|| self.err("unexpected EOF"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected {:?}", c as char))),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self
                .b
                .get(self.i)
                .ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self
                        .b
                        .get(self.i)
                        .ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{
          "version": 1,
          "constants": {"n_max": 320, "lr": 3e-4},
          "executables": {
            "gcn_cora": {"path": "models/gcn_cora.hlo.txt",
                          "inputs": [{"name": "x", "shape": [320, 1536]}]}
          },
          "flags": [true, false, null]
        }"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(v.path(&["constants", "n_max"]).unwrap().as_usize(), Some(320));
        assert!((v.path(&["constants", "lr"]).unwrap().as_f64().unwrap()
            - 3e-4).abs() < 1e-12);
        let inputs = v
            .path(&["executables", "gcn_cora", "inputs"])
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(inputs[0].get("name").unwrap().as_str(), Some("x"));
        assert_eq!(
            inputs[0].get("shape").unwrap().as_usize_vec(),
            Some(vec![320, 1536])
        );
    }

    #[test]
    fn parses_strings_with_escapes() {
        let v = Value::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ A"));
    }

    #[test]
    fn parses_numbers() {
        for (s, want) in [("0", 0.0), ("-12.5", -12.5), ("1e3", 1000.0),
                          ("2.5E-2", 0.025)] {
            assert_eq!(Value::parse(s).unwrap().as_f64(), Some(want), "{s}");
        }
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Value::parse("{} x").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("{\"a\" 1}").is_err());
        assert!(Value::parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Value::parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(Value::parse("{}").unwrap(), Value::Obj(BTreeMap::new()));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Value::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn pretty_serializer_roundtrips() {
        let src = r#"{
          "bench": "partition_parallel",
          "note": "line\nbreak \"quoted\" \\ tab\t",
          "n": 2000,
          "rate": 0.125,
          "tiny": 0.0000012,
          "flag": true,
          "none": null,
          "runs": [{"workers": 4, "speedup": 3.5}, {"workers": 8}],
          "empty_arr": [],
          "empty_obj": {},
          "uni": "héllo → 世界"
        }"#;
        let v = Value::parse(src).unwrap();
        let text = v.to_json_pretty();
        assert_eq!(Value::parse(&text).unwrap(), v);
        // Integral floats print as integers, fractions keep the point.
        assert!(text.contains("\"n\": 2000"));
        assert!(text.contains("\"rate\": 0.125"));
    }

    #[test]
    fn pretty_serializer_escapes_control_chars() {
        let v = Value::Str("a\u{1}b".into());
        let text = v.to_json_pretty();
        assert!(text.contains("\\u0001"));
        assert_eq!(Value::parse(text.trim()).unwrap(), v);
    }
}
