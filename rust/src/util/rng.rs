//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Every stochastic component in the system (scenario dynamics, DRL
//! exploration noise, synthetic graph generation, baselines) takes an
//! explicit `&mut Rng` so experiments are reproducible from a single
//! seed recorded in EXPERIMENTS.md.

/// xoshiro256** (Blackman & Vigna) — fast, 256-bit state, passes
/// BigCrush; more than adequate for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give uncorrelated streams.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], spare_normal: None }
    }

    /// Derive an independent child stream (for parallel workers).
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection-free-ish
    /// multiply-shift; bias is negligible for n << 2^64 but we reject
    /// to stay exact.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        // Rejection sampling on the top bits.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range {lo}..{hi}");
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::seed_from(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = Rng::seed_from(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from(9);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_independent() {
        let mut a = Rng::seed_from(1);
        let mut c = a.fork();
        let matches = (0..64).filter(|_| a.next_u64() == c.next_u64()).count();
        assert_eq!(matches, 0);
    }
}
