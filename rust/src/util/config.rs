//! TOML-subset configuration system.
//!
//! Grammar supported (a strict subset of TOML — everything the
//! `configs/*.toml` files use):
//!
//! ```toml
//! # comment
//! top_level = 1
//! [section]
//! int = 3
//! float = 2.5
//! string = "hello"
//! flag = true
//! list = [1, 2, 3]
//! names = ["a", "b"]
//! ```
//!
//! Keys are addressed as `"section.key"` (or bare `"key"` for the
//! top-level table).  Typed getters return defaults so configs may be
//! sparse; `require_*` variants error instead.  CLI `--set sec.key=v`
//! overrides land in the same store (see [`Config::set_override`]).

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// A configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum CfgValue {
    Int(i64),
    Float(f64),
    Str(String),
    Bool(bool),
    List(Vec<CfgValue>),
}

impl fmt::Display for CfgValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CfgValue::Int(v) => write!(f, "{v}"),
            CfgValue::Float(v) => write!(f, "{v}"),
            CfgValue::Str(v) => write!(f, "{v:?}"),
            CfgValue::Bool(v) => write!(f, "{v}"),
            CfgValue::List(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("config line {line}: {msg}")]
    Parse { line: usize, msg: String },
    #[error("missing required config key {0:?}")]
    Missing(String),
    #[error("config key {key:?} has wrong type (expected {expected})")]
    Type { key: String, expected: &'static str },
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

/// Parsed configuration: flat map of `section.key` → value.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, CfgValue>,
}

impl Config {
    pub fn empty() -> Self {
        Self::default()
    }

    pub fn from_file(path: impl AsRef<Path>) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_str(&text)
    }

    #[allow(clippy::should_implement_trait)]
    pub fn from_str(text: &str) -> Result<Self, ConfigError> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').ok_or_else(|| {
                    ConfigError::Parse { line: ln + 1, msg: "unterminated [section]".into() }
                })?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| ConfigError::Parse {
                line: ln + 1,
                msg: format!("expected key = value, got {line:?}"),
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let value = parse_value(v.trim()).map_err(|msg| ConfigError::Parse {
                line: ln + 1,
                msg,
            })?;
            values.insert(key, value);
        }
        Ok(Config { values })
    }

    /// Apply a `sec.key=value` override (from `--set` CLI flags).
    pub fn set_override(&mut self, spec: &str) -> Result<(), ConfigError> {
        let (k, v) = spec.split_once('=').ok_or_else(|| ConfigError::Parse {
            line: 0,
            msg: format!("override must be key=value, got {spec:?}"),
        })?;
        let value = parse_value(v.trim())
            .map_err(|msg| ConfigError::Parse { line: 0, msg })?;
        self.values.insert(k.trim().to_string(), value);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&CfgValue> {
        self.values.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        match self.values.get(key) {
            Some(CfgValue::Float(v)) => *v,
            Some(CfgValue::Int(v)) => *v as f64,
            _ => default,
        }
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        match self.values.get(key) {
            Some(CfgValue::Int(v)) => *v as usize,
            _ => default,
        }
    }

    pub fn i64(&self, key: &str, default: i64) -> i64 {
        match self.values.get(key) {
            Some(CfgValue::Int(v)) => *v,
            _ => default,
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.values.get(key) {
            Some(CfgValue::Bool(v)) => *v,
            _ => default,
        }
    }

    pub fn str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        match self.values.get(key) {
            Some(CfgValue::Str(v)) => v,
            _ => default,
        }
    }

    pub fn require_str(&self, key: &str) -> Result<&str, ConfigError> {
        match self.values.get(key) {
            Some(CfgValue::Str(v)) => Ok(v),
            Some(_) => Err(ConfigError::Type { key: key.into(), expected: "string" }),
            None => Err(ConfigError::Missing(key.into())),
        }
    }

    pub fn require_f64(&self, key: &str) -> Result<f64, ConfigError> {
        match self.values.get(key) {
            Some(CfgValue::Float(v)) => Ok(*v),
            Some(CfgValue::Int(v)) => Ok(*v as f64),
            Some(_) => Err(ConfigError::Type { key: key.into(), expected: "number" }),
            None => Err(ConfigError::Missing(key.into())),
        }
    }

    /// List of f64 (ints coerced).
    pub fn f64_list(&self, key: &str) -> Option<Vec<f64>> {
        match self.values.get(key)? {
            CfgValue::List(xs) => xs
                .iter()
                .map(|x| match x {
                    CfgValue::Float(v) => Some(*v),
                    CfgValue::Int(v) => Some(*v as f64),
                    _ => None,
                })
                .collect(),
            _ => None,
        }
    }

    pub fn str_list(&self, key: &str) -> Option<Vec<String>> {
        match self.values.get(key)? {
            CfgValue::List(xs) => xs
                .iter()
                .map(|x| match x {
                    CfgValue::Str(v) => Some(v.clone()),
                    _ => None,
                })
                .collect(),
            _ => None,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a string literal must not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<CfgValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s == "true" {
        return Ok(CfgValue::Bool(true));
    }
    if s == "false" {
        return Ok(CfgValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string {s:?}"))?;
        return Ok(CfgValue::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated list {s:?}"))?
            .trim();
        if inner.is_empty() {
            return Ok(CfgValue::List(vec![]));
        }
        let items = split_top_level(inner)
            .into_iter()
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(CfgValue::List(items));
    }
    if let Ok(v) = s.parse::<i64>() {
        return Ok(CfgValue::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(CfgValue::Float(v));
    }
    Err(format!("cannot parse value {s:?}"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# GraphEdge test config
seed = 42
[net]
plane_m = 2000.0
servers = 4
noise_dbm = -110  # Table 2
[drl]
lr = 3e-4
explore = 0.1
enabled = true
name = "maddpg"
caps = [1.25, 1.0, 0.75]
tags = ["hi", "lo"]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::from_str(SAMPLE).unwrap();
        assert_eq!(c.usize("seed", 0), 42);
        assert_eq!(c.f64("net.plane_m", 0.0), 2000.0);
        assert_eq!(c.usize("net.servers", 0), 4);
        assert_eq!(c.i64("net.noise_dbm", 0), -110);
        assert!((c.f64("drl.lr", 0.0) - 3e-4).abs() < 1e-12);
        assert!(c.bool("drl.enabled", false));
        assert_eq!(c.str("drl.name", ""), "maddpg");
        assert_eq!(c.f64_list("drl.caps").unwrap(), vec![1.25, 1.0, 0.75]);
        assert_eq!(c.str_list("drl.tags").unwrap(), vec!["hi", "lo"]);
    }

    #[test]
    fn defaults_and_missing() {
        let c = Config::from_str("").unwrap();
        assert_eq!(c.usize("nope", 7), 7);
        assert!(matches!(c.require_str("x"), Err(ConfigError::Missing(_))));
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::from_str(SAMPLE).unwrap();
        c.set_override("net.servers=25").unwrap();
        c.set_override("drl.name=\"ppo\"").unwrap();
        assert_eq!(c.usize("net.servers", 0), 25);
        assert_eq!(c.str("drl.name", ""), "ppo");
    }

    #[test]
    fn comment_inside_string_preserved() {
        let c = Config::from_str("k = \"a # b\"").unwrap();
        assert_eq!(c.str("k", ""), "a # b");
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let err = Config::from_str("a = 1\nbad line\n").unwrap_err();
        match err {
            ConfigError::Parse { line, .. } => assert_eq!(line, 2),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn type_mismatch_reported() {
        let c = Config::from_str("x = 3").unwrap();
        assert!(matches!(
            c.require_str("x"),
            Err(ConfigError::Type { .. })
        ));
    }
}
