//! Concurrency-primitive shim: `std::sync` in normal builds,
//! [`loom`](https://docs.rs/loom)'s model-checked doubles under
//! `--cfg loom`.
//!
//! The lock-free layers ([`super::metrics`], [`super::trace`],
//! [`super::threadpool`]) import their atomics, `Arc` and `Mutex` from
//! here instead of `std::sync`, so the same production code can be
//! compiled into a loom model and have *every* interleaving of its
//! atomic operations explored, not just the ones a stress test happens
//! to hit.  See `ANALYSIS.md` ("loom") for how to run the models; in a
//! normal build this module is a zero-cost re-export of `std`.
//!
//! Two deliberate gaps, both because loom types cannot live in
//! `static`s (their constructors are not `const`):
//!
//! * Const-initialized statics (`trace::ENABLED`, `logging::INSTALLED`,
//!   …) stay on `std::sync::atomic` via explicit paths.  The loom
//!   models construct their subjects locally and never touch process
//!   globals.
//! * Only the types the checked layers actually use are re-exported.
//!   Add to both arms when a new primitive is needed.

#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
#[cfg(not(loom))]
pub use std::sync::{Arc, Mutex};

#[cfg(loom)]
pub use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
#[cfg(loom)]
pub use loom::sync::{Arc, Mutex};

/// Thread helpers with loom doubles (only what the checked code uses).
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::yield_now;

    #[cfg(loom)]
    pub use loom::thread::yield_now;
}
