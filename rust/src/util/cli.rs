//! Declarative command-line parsing for the `graphedge` launcher.
//!
//! A small clap-shaped API (clap is not available offline): an [`App`]
//! owns subcommands, each subcommand declares typed flags, and parsing
//! produces a [`Matches`] with typed getters plus auto-generated
//! `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown flag --{0}")]
    UnknownFlag(String),
    #[error("flag --{0} expects a value")]
    MissingValue(String),
    #[error("unknown subcommand {0:?} (try --help)")]
    UnknownCommand(String),
    #[error("missing required flag --{0}")]
    MissingRequired(String),
    #[error("help requested")]
    HelpRequested,
}

/// Flag arity/type.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arity {
    /// Boolean switch, no value.
    Switch,
    /// Takes one value; may repeat (last one wins except `values()`).
    Value,
}

#[derive(Clone, Debug)]
pub struct Flag {
    pub name: &'static str,
    pub arity: Arity,
    pub default: Option<&'static str>,
    pub required: bool,
    pub help: &'static str,
}

/// One subcommand specification.
#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<Flag>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, flags: Vec::new() }
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, arity: Arity::Switch, default: None, required: false, help });
        self
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag {
            name,
            arity: Arity::Value,
            default: Some(default),
            required: false,
            help,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, arity: Arity::Value, default: None, required: true, help });
        self
    }
}

/// Application: a set of subcommands.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

/// Parse result.
#[derive(Debug)]
pub struct Matches {
    pub command: String,
    values: BTreeMap<String, Vec<String>>,
    switches: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Matches {
    pub fn str(&self, name: &str) -> &str {
        self.values
            .get(name)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
            .unwrap_or("")
    }

    pub fn values(&self, name: &str) -> &[String] {
        self.values.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn usize(&self, name: &str) -> usize {
        self.str(name).parse().unwrap_or_else(|_| {
            panic!("flag --{name} is not a valid integer: {:?}", self.str(name))
        })
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.str(name).parse().unwrap_or_else(|_| {
            panic!("flag --{name} is not a valid number: {:?}", self.str(name))
        })
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }

    /// Resolve a `--workers N` knob: `0` means "size to the machine"
    /// (available parallelism, capped at 16 like
    /// `ThreadPool::default_size`), anything else is taken literally.
    /// Callers treat `1` as the sequential path.
    pub fn workers(&self) -> usize {
        match self.usize("workers") {
            0 => std::thread::available_parallelism()
                .map(|n| n.get().min(16))
                .unwrap_or(4),
            n => n,
        }
    }
}

impl App {
    pub fn help(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        let _ = writeln!(s, "USAGE: {} <command> [flags]\n", self.name);
        let _ = writeln!(s, "COMMANDS:");
        for c in &self.commands {
            let _ = writeln!(s, "  {:<12} {}", c.name, c.about);
        }
        let _ = writeln!(s, "\nRun `{} <command> --help` for command flags.", self.name);
        s
    }

    pub fn command_help(&self, cmd: &Command) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} {} — {}\n", self.name, cmd.name, cmd.about);
        let _ = writeln!(s, "FLAGS:");
        for f in &cmd.flags {
            let meta = match (f.arity, f.default, f.required) {
                (Arity::Switch, _, _) => String::new(),
                (_, Some(d), _) => format!(" <val> (default {d})"),
                (_, None, true) => " <val> (required)".into(),
                (_, None, false) => " <val>".into(),
            };
            let _ = writeln!(s, "  --{:<18} {}{}", format!("{}{}", f.name, meta), f.help, "");
        }
        s
    }

    /// Parse `args` (without argv[0]).
    pub fn parse(&self, args: &[String]) -> Result<Matches, CliError> {
        if args.is_empty()
            || args[0] == "--help"
            || args[0] == "-h"
            || args[0] == "help"
        {
            print!("{}", self.help());
            return Err(CliError::HelpRequested);
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == args[0])
            .ok_or_else(|| CliError::UnknownCommand(args[0].clone()))?;

        let mut values: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut switches: BTreeMap<String, bool> = BTreeMap::new();
        let mut positional = Vec::new();
        for f in &cmd.flags {
            if let Some(d) = f.default {
                values.insert(f.name.to_string(), vec![d.to_string()]);
            }
        }

        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                print!("{}", self.command_help(cmd));
                return Err(CliError::HelpRequested);
            }
            if let Some(name) = a.strip_prefix("--") {
                // --name=value or --name value or switch
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let flag = cmd
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| CliError::UnknownFlag(name.to_string()))?;
                match flag.arity {
                    Arity::Switch => {
                        switches.insert(name.to_string(), true);
                    }
                    Arity::Value => {
                        let v = if let Some(v) = inline {
                            v
                        } else {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(name.to_string()))?
                        };
                        values.entry(name.to_string()).or_default().push(v);
                    }
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }

        for f in &cmd.flags {
            if f.required && !values.contains_key(f.name) {
                return Err(CliError::MissingRequired(f.name.to_string()));
            }
        }
        // For defaulted flags that also got explicit values, drop default.
        for f in &cmd.flags {
            if let Some(v) = values.get_mut(f.name) {
                if v.len() > 1 && f.default.map(|d| d == v[0]).unwrap_or(false) {
                    v.remove(0);
                }
            }
        }

        Ok(Matches { command: cmd.name.to_string(), values, switches, positional })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App {
            name: "graphedge",
            about: "test",
            commands: vec![
                Command::new("serve", "run the coordinator")
                    .opt("config", "configs/table2.toml", "config file")
                    .opt("model", "gcn", "gnn model")
                    .switch("verbose", "log more")
                    .req("dataset", "dataset name"),
                Command::new("info", "dump info"),
            ],
        }
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_defaults() {
        let m = app()
            .parse(&argv(&["serve", "--dataset", "cora", "--verbose"]))
            .unwrap();
        assert_eq!(m.command, "serve");
        assert_eq!(m.str("dataset"), "cora");
        assert_eq!(m.str("config"), "configs/table2.toml");
        assert_eq!(m.str("model"), "gcn");
        assert!(m.switch("verbose"));
    }

    #[test]
    fn inline_equals_syntax() {
        let m = app()
            .parse(&argv(&["serve", "--dataset=pubmed", "--model=gat"]))
            .unwrap();
        assert_eq!(m.str("dataset"), "pubmed");
        assert_eq!(m.str("model"), "gat");
    }

    #[test]
    fn missing_required_rejected() {
        assert!(matches!(
            app().parse(&argv(&["serve"])),
            Err(CliError::MissingRequired(_))
        ));
    }

    #[test]
    fn unknown_flag_and_command_rejected() {
        assert!(matches!(
            app().parse(&argv(&["serve", "--dataset", "x", "--bogus"])),
            Err(CliError::UnknownFlag(_))
        ));
        assert!(matches!(
            app().parse(&argv(&["frobnicate"])),
            Err(CliError::UnknownCommand(_))
        ));
    }

    #[test]
    fn repeated_values_collect() {
        let m = app()
            .parse(&argv(&["serve", "--dataset", "a", "--model", "x",
                           "--model", "y"]))
            .unwrap();
        assert_eq!(m.values("model"), &["x".to_string(), "y".to_string()]);
        assert_eq!(m.str("model"), "y");
    }

    #[test]
    fn workers_knob_resolves_zero_to_machine_size() {
        let app = App {
            name: "graphedge",
            about: "test",
            commands: vec![Command::new("serve", "run")
                .opt("workers", "1", "layout worker threads (0 = auto)")],
        };
        let m = app.parse(&argv(&["serve"])).unwrap();
        assert_eq!(m.workers(), 1);
        let m = app.parse(&argv(&["serve", "--workers", "6"])).unwrap();
        assert_eq!(m.workers(), 6);
        let m = app.parse(&argv(&["serve", "--workers", "0"])).unwrap();
        let auto = m.workers();
        assert!((1..=16).contains(&auto), "auto workers out of range: {auto}");
    }

    #[test]
    fn positionals_collected() {
        let m = app().parse(&argv(&["serve", "--dataset", "a", "pos1"]))
            .unwrap();
        assert_eq!(m.positional, vec!["pos1".to_string()]);
    }
}
