//! Miniature property-testing harness (the `proptest` crate is not in
//! the offline vendor set).
//!
//! Usage inside `#[cfg(test)]` modules:
//!
//! ```ignore
//! check(200, |rng| gen_graph(rng), |g| prop_partition_covers(g));
//! ```
//!
//! On failure the harness re-runs a bisection-style shrink when the
//! generator supports it via [`Shrink`], and always reports the seed of
//! the failing case so it can be replayed deterministically.

use super::rng::Rng;

/// Types that know how to propose smaller versions of themselves.
pub trait Shrink: Sized {
    /// Candidate smaller values, most aggressive first.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[1..].to_vec());
        out.push(self[..self.len() - 1].to_vec());
        out
    }
}

/// Run `cases` random property checks.  Panics (with the seed and a
/// shrunk witness when available) on the first failure.
pub fn check<T, G, P>(cases: usize, mut generate: G, mut property: P)
where
    T: std::fmt::Debug + Shrink + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let base_seed = match std::env::var("GRAPHEDGE_PROPTEST_SEED") {
        Ok(s) => s.parse().expect("GRAPHEDGE_PROPTEST_SEED must be u64"),
        Err(_) => 0x5EED_u64,
    };
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::seed_from(seed);
        let input = generate(&mut rng);
        if property(&input) {
            continue;
        }
        // Shrink: greedily take any smaller failing candidate.
        let mut witness = input.clone();
        let mut progress = true;
        let mut rounds = 0;
        while progress && rounds < 64 {
            progress = false;
            rounds += 1;
            for cand in witness.shrink() {
                if !property(&cand) {
                    witness = cand;
                    progress = true;
                    break;
                }
            }
        }
        panic!(
            "property failed (case {case}, seed {seed}; replay with \
             GRAPHEDGE_PROPTEST_SEED={seed}).\nshrunk witness: {witness:#?}"
        );
    }
}

/// Convenience: property over plain seeds, no shrinking.
pub fn check_seeds<P: FnMut(&mut Rng) -> bool>(cases: usize, mut property: P) {
    for case in 0..cases {
        let seed = 0xFACE_u64.wrapping_add(case as u64);
        let mut rng = Rng::seed_from(seed);
        assert!(
            property(&mut rng),
            "seeded property failed at case {case} (seed {seed})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone, Debug)]
    struct SmallVec(Vec<usize>);

    impl Shrink for SmallVec {
        fn shrink(&self) -> Vec<Self> {
            self.0.shrink().into_iter().map(SmallVec).collect()
        }
    }

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            50,
            |rng| rng.below(100),
            |_| {
                count += 1;
                true
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(50, |rng| rng.below(100), |&x| x > 1000);
    }

    #[test]
    fn shrinking_reduces_vectors() {
        // Property "no vector contains 7" fails; the shrunk witness
        // should be much smaller than the original failing input.
        let result = std::panic::catch_unwind(|| {
            check(
                100,
                |rng| {
                    SmallVec((0..rng.range(5, 50)).map(|_| rng.below(10)).collect())
                },
                |v| !v.0.contains(&7),
            );
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("expected failure"),
        };
        assert!(msg.contains("shrunk witness"));
    }

    #[test]
    fn check_seeds_deterministic() {
        let mut seen = Vec::new();
        check_seeds(3, |rng| {
            seen.push(rng.next_u64());
            true
        });
        let mut seen2 = Vec::new();
        check_seeds(3, |rng| {
            seen2.push(rng.next_u64());
            true
        });
        assert_eq!(seen, seen2);
    }
}
