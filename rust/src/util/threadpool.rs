//! Fixed-size worker pool (tokio is unavailable offline; the serving
//! loop, the sharded partitioner and the benches need bounded
//! parallelism, not an async runtime).
//!
//! Work items are `FnOnce() + Send` closures submitted with
//! [`ThreadPool::execute`]; [`ThreadPool::map_scoped`] offers a
//! rayon-like scoped API through which borrowed data can be processed
//! in parallel chunks.
//!
//! Jobs are panic-isolated: a panicking job is caught on the worker,
//! counted in [`ThreadPool::panicked`], and — critically — still
//! decrements the in-flight counter (via a drop guard, so the
//! decrement survives the unwind).  Without that guard a single
//! panicking job would leave [`ThreadPool::wait_idle`] spinning
//! forever and silently kill the worker thread.

use std::panic::AssertUnwindSafe;
use std::sync::mpsc;
// Pool scaffolding (channel, receiver lock, join handles) stays on
// `std`: loom has no mpsc or scoped threads, and the models never
// construct a full pool.
use std::sync::{Arc, Mutex};
use std::thread;

// The in-flight / panic accounting goes through the shim so the
// drop-guard protocol can be model-checked under loom (`loom_tests`).
use super::sync::{AtomicUsize, Ordering};

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Decrements the in-flight job counter when dropped, so the count
/// stays exact even when the job unwinds: `wait_idle` must never hang
/// on a panicking job.
struct InFlightGuard<'a>(&'a AtomicUsize);

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        // ordering: SeqCst — completes the execute/finish/wait trio in
        // one total order: the decrement sits after the job's effects,
        // so `wait_idle` reading 0 implies every job ran to completion
        // (or unwound).  One RMW per job, not per item — not hot.
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Run one job under the pool's panic protocol: the in-flight
/// decrement rides a drop guard so it survives an unwind, and a
/// panicking job bumps `panicked` instead of killing the worker.
/// Factored out of the worker loop so the loom models drive the exact
/// production code path.
fn run_job(job: impl FnOnce(), queued: &AtomicUsize, panicked: &AtomicUsize) {
    let _in_flight = InFlightGuard(queued);
    // Catch the unwind so the worker thread survives a poisoned job
    // instead of silently shrinking the pool.
    if std::panic::catch_unwind(AssertUnwindSafe(job)).is_err() {
        // ordering: SeqCst — panic counting is cold (once per failed
        // job); keeping it in the same total order as the in-flight
        // counter means `panicked()` read after `wait_idle` is exact.
        panicked.fetch_add(1, Ordering::SeqCst);
    }
}

/// A fixed pool of worker threads.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
    panicked: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "thread pool needs at least one worker");
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let panicked = Arc::new(AtomicUsize::new(0));
        let handles = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                let panicked = Arc::clone(&panicked);
                thread::Builder::new()
                    .name(format!("graphedge-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => run_job(job, &queued, &panicked),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, handles, queued, panicked }
    }

    /// Pool sized to the machine (cores, capped at 16).
    pub fn default_size() -> Self {
        let n = thread::available_parallelism()
            .map(|n| n.get().min(16))
            .unwrap_or(4);
        Self::new(n)
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Number of submitted jobs that panicked (caught on the worker;
    /// the pool keeps running and `wait_idle` still returns).
    pub fn panicked(&self) -> usize {
        // ordering: SeqCst — same total order as the worker's
        // increment, so a read after `wait_idle` sees every panic.
        self.panicked.load(Ordering::SeqCst)
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        // ordering: SeqCst — the increment must precede the channel
        // send in the global order, so the count can never dip to 0
        // while a submitted job is still in flight (`wait_idle` would
        // return early).  One RMW per job submission — not hot.
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.tx.send(Msg::Run(Box::new(job))).expect("pool alive");
    }

    /// Busy-wait (with yields) until all submitted jobs have finished.
    pub fn wait_idle(&self) {
        // ordering: SeqCst — pairs with execute's increment and the
        // guard's decrement; reading 0 here implies the effects of
        // every submitted job are visible to this thread.
        while self.queued.load(Ordering::SeqCst) != 0 {
            super::sync::thread::yield_now();
        }
    }

    /// Run `f(index, &mut item)` on every item of `items` in parallel,
    /// collecting results in input order.  The mutable counterpart of
    /// [`ThreadPool::map_scoped`]: items are split into `workers`
    /// contiguous chunks, each owned by one scoped thread, so every
    /// item is visited exactly once with exclusive access.  For a
    /// deterministic `f` the result is therefore *independent of the
    /// worker count* — the invariant the vectorized environment
    /// ([`crate::drl::vec_env`]) leans on.
    pub fn map_scoped_mut<T, R, F>(items: &mut [T], workers: usize, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        assert!(workers >= 1);
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        if workers == 1 || n == 1 {
            // Sequential fast path: no thread spawn per call.
            return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let chunk = n.div_ceil(workers.min(n));
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        thread::scope(|s| {
            let f = &f;
            let mut rest_items = &mut items[..];
            let mut rest_out = &mut out[..];
            let mut base = 0usize;
            while !rest_items.is_empty() {
                let take = chunk.min(rest_items.len());
                let (chunk_items, tail_items) = rest_items.split_at_mut(take);
                let (chunk_out, tail_out) = rest_out.split_at_mut(take);
                rest_items = tail_items;
                rest_out = tail_out;
                let start = base;
                base += take;
                s.spawn(move || {
                    for (j, (item, slot)) in
                        chunk_items.iter_mut().zip(chunk_out.iter_mut()).enumerate()
                    {
                        *slot = Some(f(start + j, item));
                    }
                });
            }
        });
        out.into_iter().map(|r| r.expect("worker filled slot")).collect()
    }

    /// Run `f` on every item of `items` in parallel, collecting results
    /// in input order.  Uses scoped threads so borrows are fine.
    pub fn map_scoped<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Sync,
    {
        assert!(workers >= 1);
        let next = AtomicUsize::new(0);
        let results: Vec<Mutex<Option<R>>> = items.iter().map(|_| Mutex::new(None)).collect();
        thread::scope(|s| {
            for _ in 0..workers.min(items.len().max(1)) {
                s.spawn(|| loop {
                    // ordering: SeqCst — only uniqueness of the claimed
                    // index matters (any ordering gives that); results
                    // are published through the per-slot mutexes, and
                    // one RMW per item is noise next to `f`.
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= items.len() {
                        break;
                    }
                    let r = f(&items[i]);
                    *results[i].lock().unwrap() = Some(r);
                });
            }
        });
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("worker filled slot"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_scoped_preserves_order() {
        let items: Vec<usize> = (0..57).collect();
        let out = ThreadPool::map_scoped(&items, 8, |&x| x * x);
        assert_eq!(out, items.iter().map(|&x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_scoped_single_worker() {
        let items = vec![1, 2, 3];
        assert_eq!(ThreadPool::map_scoped(&items, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn map_scoped_mut_mutates_every_item_in_order() {
        let mut items: Vec<usize> = (0..57).collect();
        let out = ThreadPool::map_scoped_mut(&mut items, 8, |i, x| {
            *x += 100;
            (i, *x)
        });
        assert_eq!(items, (100..157).collect::<Vec<_>>());
        assert_eq!(out, (0..57).map(|i| (i, i + 100)).collect::<Vec<_>>());
    }

    #[test]
    fn map_scoped_mut_result_is_worker_count_invariant() {
        let reference: Vec<usize> = (0..23).map(|i| i * 3 + 1).collect();
        for workers in [1usize, 2, 3, 8, 32] {
            let mut items: Vec<usize> = (0..23).collect();
            let out = ThreadPool::map_scoped_mut(&mut items, workers, |_, x| {
                *x = *x * 3 + 1;
                *x
            });
            assert_eq!(out, reference, "diverged at {workers} workers");
            assert_eq!(items, reference);
        }
    }

    #[test]
    fn map_scoped_mut_handles_empty_and_single() {
        let mut empty: Vec<usize> = Vec::new();
        let out = ThreadPool::map_scoped_mut(&mut empty, 4, |_, x| *x);
        assert!(out.is_empty());
        let mut one = vec![7usize];
        let out = ThreadPool::map_scoped_mut(&mut one, 4, |i, x| {
            *x += i + 1;
            *x
        });
        assert_eq!(out, vec![8]);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn panicking_job_neither_hangs_wait_idle_nor_kills_the_pool() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("poisoned job"));
        // Regression: the panic used to skip the queued decrement, so
        // this call spun forever (and the worker thread died).
        pool.wait_idle();
        assert_eq!(pool.panicked(), 1);

        // The pool must still execute new work on every worker.
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..32 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 32);
        assert_eq!(pool.panicked(), 1);
    }

    #[test]
    fn panics_are_counted_per_job() {
        let pool = ThreadPool::new(4);
        for _ in 0..5 {
            pool.execute(|| panic!("again"));
        }
        pool.wait_idle();
        assert_eq!(pool.panicked(), 5);
    }
}

// Loom models for the job accounting protocol (`run_job` + the drop
// guard).  The subjects are bare counters driven through the exact
// production `run_job` — loom has no mpsc/scoped threads, so the
// channel plumbing itself stays covered by the stress tests above.
// Run with:
//   RUSTFLAGS="--cfg loom" cargo test --release --lib loom_
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;
    use crate::util::sync::Arc;

    // Unwind without invoking the panic hook: keeps thousands of loom
    // iterations from spamming backtraces for an *expected* panic.
    fn quiet_panic() {
        std::panic::resume_unwind(Box::new("expected test panic"));
    }

    /// The in-flight decrement must survive a job that unwinds —
    /// otherwise `wait_idle` spins forever after one poisoned job.
    #[test]
    fn loom_inflight_guard_survives_panic() {
        loom::model(|| {
            let queued = Arc::new(AtomicUsize::new(1));
            let panicked = Arc::new(AtomicUsize::new(0));
            let (q, p) = (queued.clone(), panicked.clone());
            let t = loom::thread::spawn(move || run_job(quiet_panic, &q, &p));
            t.join().unwrap();
            assert_eq!(queued.load(Ordering::SeqCst), 0, "decrement lost in unwind");
            assert_eq!(panicked.load(Ordering::SeqCst), 1);
        });
    }

    /// Two racing jobs — one clean, one panicking — must leave the
    /// counters exact in every interleaving.
    #[test]
    fn loom_queued_counter_exact_across_racing_jobs() {
        loom::model(|| {
            let queued = Arc::new(AtomicUsize::new(2));
            let panicked = Arc::new(AtomicUsize::new(0));
            let (q1, p1) = (queued.clone(), panicked.clone());
            let t1 = loom::thread::spawn(move || run_job(|| {}, &q1, &p1));
            let (q2, p2) = (queued.clone(), panicked.clone());
            let t2 = loom::thread::spawn(move || run_job(quiet_panic, &q2, &p2));
            t1.join().unwrap();
            t2.join().unwrap();
            assert_eq!(queued.load(Ordering::SeqCst), 0);
            assert_eq!(panicked.load(Ordering::SeqCst), 1);
        });
    }
}
