//! Zero-dependency substrates.
//!
//! The offline build environment ships only a handful of vendored
//! crates (`xla`, `anyhow`, `thiserror`, `log`, `once_cell`), so the
//! utilities a production service would normally pull from the
//! ecosystem are implemented here as first-class modules:
//!
//! * [`rng`] — xoshiro256** PRNG with the distribution helpers the
//!   simulators need (uniform, normal, choice, shuffle).
//! * [`json`] — a strict, minimal JSON parser for `manifest.json`.
//! * [`config`] — a TOML-subset configuration system (`configs/*.toml`).
//! * [`cli`] — declarative command-line parsing for the launcher.
//! * [`threadpool`] — a fixed-size worker pool for parallel benches.
//! * [`stats`] — streaming means/percentiles for metrics + benches.
//! * [`metrics`] — a process-wide metrics registry with handle-based
//!   counters/gauges/histograms for lock-free hot-path recording.
//! * [`sync`] — `std::sync` re-exports that swap to loom's
//!   model-checked doubles under `--cfg loom`.
//! * [`trace`] — scoped spans + a per-thread flight recorder drained
//!   to JSONL (`GRAPHEDGE_TRACE`, `graphedge serve --trace`).
//! * [`version`] — monotonic version counters + [`version::Memoized`]
//!   cells: the shared staleness substrate for every derived-data
//!   cache (obs templates, cost tables, router deadlines).
//! * [`logging`] — an env-filtered `log::Log` backend.
//! * [`proptest`] — a miniature property-testing harness used by the
//!   `#[cfg(test)]` suites across the crate.

pub mod cli;
pub mod config;
pub mod json;
pub mod logging;
pub mod metrics;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod threadpool;
pub mod trace;
pub mod version;
