//! `graphedge` — the GraphEdge launcher.
//!
//! Subcommands:
//!   info       manifest + config dump (Table 2 parameters)
//!   partition  HiCut vs max-flow min-cut on synthetic graphs (Fig. 6 style)
//!   train      train DRLGO / PTOM, checkpoint, print the reward curve
//!   simulate   evaluate offloading methods on dataset scenarios
//!   serve      online serving loop: router + batcher + fleet inference

use graphedge::bench::{fmt_secs, Table};
use graphedge::coordinator::Controller;
use graphedge::drl::{Method, MaddpgConfig, PpoConfig};
use graphedge::graph::generate::{random_weights, uniform_random};
use graphedge::net::SystemParams;
use graphedge::partition::{hicut, mincut_partition, parallel_hicut_pool};
use graphedge::util::cli::{App, CliError, Command};
use graphedge::util::threadpool::ThreadPool;
use graphedge::util::config::Config;
use graphedge::util::metrics::GLOBAL as METRICS;
use graphedge::util::rng::Rng;

fn app() -> App {
    App {
        name: "graphedge",
        about: "dynamic graph partition and task scheduling for GNN edge computing",
        commands: vec![
            Command::new("info", "dump manifest, datasets and Table 2 parameters")
                .opt("config", "configs/table2.toml", "config file"),
            Command::new("partition", "compare HiCut vs min-cut on a random graph")
                .opt("vertices", "2000", "vertex count")
                .opt("edges", "20000", "edge count")
                .opt("servers", "25", "server count for min-cut iterations")
                .opt("workers", "1", "shard HiCut across N pool workers (0 = auto)")
                .opt("seed", "7", "rng seed"),
            Command::new("train", "train an offloading policy")
                .opt("method", "drlgo", "drlgo | ptom | drl-only")
                .opt("dataset", "pubmed", "training dataset")
                .opt("episodes", "100", "training episodes")
                .opt("users", "300", "users per scenario")
                .opt("assocs", "4800", "associations per scenario")
                .opt("envs", "1", "parallel episode slots per vector step (vectorized rollout)")
                .opt(
                    "scenarios",
                    "replicate",
                    "per-slot scenarios: replicate | mixed | list of \
                     uniform|pa[:deg]|clustered[:k]|hotspot[:k], each with optional @NxE size",
                )
                .opt("out", "checkpoints", "checkpoint directory")
                .opt("telemetry", "", "write per-episode training telemetry JSONL here")
                .opt("config", "configs/table2.toml", "config file")
                .opt("seed", "3401", "rng seed"),
            Command::new("simulate", "evaluate offloading methods on one scenario")
                .opt("dataset", "cora", "dataset")
                .opt("model", "gcn", "gnn model")
                .opt("users", "150", "users")
                .opt("assocs", "900", "associations")
                .opt("episodes", "40", "training episodes for the DRL methods")
                .opt("envs", "1", "parallel episode slots for DRL training")
                .opt("scenarios", "replicate", "per-slot training scenarios (see train --help)")
                .opt("config", "configs/table2.toml", "config file")
                .opt("seed", "11", "rng seed")
                .switch("no-inference", "skip fleet GNN inference"),
            Command::new("serve", "online serving: router + dynamic batching + fleet")
                .opt("dataset", "cora", "dataset")
                .opt("model", "gcn", "gnn model")
                .opt("users", "200", "users")
                .opt("assocs", "1200", "associations")
                .opt("requests", "600", "request count")
                .opt("policy", "", "DRLGO checkpoint (.gta); empty = greedy placement")
                .opt("steps", "0", "churn steps (0 = static scenario)")
                .opt("per-step", "40", "requests per churn step (dynamic mode)")
                .opt(
                    "scenario",
                    "",
                    "generated scenario spec (synthetic mode, no artifacts needed; \
                     e.g. uniform@120x360)",
                )
                .opt("trace", "", "write span/event JSONL to this path")
                .opt("config", "configs/table2.toml", "config file")
                .opt("seed", "5", "rng seed")
                .opt("workers", "1", "layout worker threads, dynamic mode (0 = auto)")
                .switch("incremental", "delta-driven partition repair (dynamic mode)"),
        ],
    }
}

fn main() {
    graphedge::util::logging::init();
    // GRAPHEDGE_TRACE=<path> enables tracing process-wide; the buffer
    // is written on exit (the serve --trace flag overrides this).
    graphedge::util::trace::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let matches = match app().parse(&args) {
        Ok(m) => m,
        Err(CliError::HelpRequested) => return,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match matches.command.as_str() {
        "info" => cmd_info(&matches),
        "partition" => cmd_partition(&matches),
        "train" => cmd_train(&matches),
        "simulate" => cmd_simulate(&matches),
        "serve" => cmd_serve(&matches),
        other => {
            eprintln!("unknown command {other}");
            std::process::exit(2);
        }
    };
    match graphedge::util::trace::flush_env_trace() {
        Some(Ok(path)) => eprintln!("trace: wrote {}", path.display()),
        Some(Err(e)) => eprintln!("warning: failed to write GRAPHEDGE_TRACE file: {e}"),
        None => {}
    }
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_params(matches: &graphedge::util::cli::Matches) -> SystemParams {
    let path = matches.str("config");
    match Config::from_file(path) {
        Ok(cfg) => SystemParams::from_config(&cfg),
        Err(_) => {
            log::warn!("config {path} not found; using Table 2 defaults");
            SystemParams::default()
        }
    }
}

fn cmd_info(matches: &graphedge::util::cli::Matches) -> graphedge::Result<()> {
    let params = load_params(matches);
    let ctrl = Controller::new(params.clone())?;
    println!("GraphEdge — manifest + parameters\n");
    println!("backend: {}\n", ctrl.rt.backend_name());
    println!("datasets:");
    for (name, ds) in &ctrl.rt.manifest.datasets {
        println!(
            "  {name:<10} |V|={:<6} |E|={:<6} F={:<5} classes={}",
            ds.n, ds.e, ds.feat, ds.classes
        );
    }
    println!("\nexecutables ({}):", ctrl.rt.manifest.executables.len());
    for (name, e) in &ctrl.rt.manifest.executables {
        println!("  {name:<16} {} inputs  {}", e.inputs.len(), e.path);
    }
    println!("\npre-trained accuracy (paper band 0.60–0.80):");
    for (k, v) in &ctrl.rt.manifest.accuracy {
        println!("  {k:<16} {v:.3}");
    }
    println!("\nTable 2 parameters (SI units):");
    println!(
        "  servers={}  plane={}m  noise={:.1e}W",
        params.servers, params.plane_m, params.noise_w
    );
    println!("  P_user={:?}W  P_server={:?}W", params.p_user_w, params.p_server_w);
    println!("  B_user={:?}Hz  B_server={:.1e}Hz", params.bw_user_hz, params.bw_server_hz);
    println!(
        "  f_k={:?}Hz  μ={:.1e}  ϑ={:.1e}  φ={:.1e}",
        params.f_hz, params.mu_j_bit, params.theta_j, params.phi_j
    );
    Ok(())
}

fn cmd_partition(matches: &graphedge::util::cli::Matches) -> graphedge::Result<()> {
    let (v, e) = (matches.usize("vertices"), matches.usize("edges"));
    let servers = matches.usize("servers");
    let workers = matches.workers();
    let mut rng = Rng::seed_from(matches.usize("seed") as u64);
    println!("generating random graph |V|={v} |E|={e} ...");
    let g = uniform_random(v, e, &mut rng);
    let w = random_weights(&g, 1, 100, &mut rng);

    // lint:allow(wall-clock) — the partition demo prints method wall
    // times side by side; the layouts do not depend on the clock.
    let t0 = std::time::Instant::now();
    let hp = hicut(&g, &|_| true);
    let t_hicut = t0.elapsed().as_secs_f64();
    // lint:allow(wall-clock) — same comparison table as above.
    let t0 = std::time::Instant::now();
    let mp = mincut_partition(&g, &w, servers, &mut rng);
    let t_mincut = t0.elapsed().as_secs_f64();

    let mut t = Table::new(
        "HiCut vs max-flow min-cut",
        &["method", "time", "subgraphs", "cut edges", "cut weight", "locality"],
    );
    t.row(vec![
        "HiCut".into(),
        fmt_secs(t_hicut),
        hp.len().to_string(),
        hp.cut_edges(&g).to_string(),
        hp.cut_weight(&g, &w).to_string(),
        format!("{:.3}", hp.locality(&g)),
    ]);
    if workers > 1 {
        let pool = ThreadPool::new(workers);
        // lint:allow(wall-clock) — sharded-HiCut wall time for the
        // same printed comparison; the layout is asserted identical to
        // the sequential one right below.
        let t0 = std::time::Instant::now();
        let pp = parallel_hicut_pool(&g, |_| true, &pool);
        let t_par = t0.elapsed().as_secs_f64();
        assert_eq!(
            pp.subgraphs, hp.subgraphs,
            "sharded HiCut must match the sequential layout"
        );
        t.row(vec![
            format!("HiCut x{workers}"),
            fmt_secs(t_par),
            pp.len().to_string(),
            pp.cut_edges(&g).to_string(),
            pp.cut_weight(&g, &w).to_string(),
            format!("{:.3}", pp.locality(&g)),
        ]);
    }
    t.row(vec![
        "min-cut [36]".into(),
        fmt_secs(t_mincut),
        mp.len().to_string(),
        mp.cut_edges(&g).to_string(),
        mp.cut_weight(&g, &w).to_string(),
        format!("{:.3}", mp.locality(&g)),
    ]);
    print!("{}", t.render());
    Ok(())
}

fn cmd_train(matches: &graphedge::util::cli::Matches) -> graphedge::Result<()> {
    let params = load_params(matches);
    let ctrl = Controller::new(params)?;
    let dataset = matches.str("dataset").to_string();
    let episodes = matches.usize("episodes");
    let users = matches.usize("users");
    let assocs = matches.usize("assocs");
    let seed = matches.usize("seed") as u64;
    let envs = matches.usize("envs").max(1);
    let scenarios = scenarios_flag(matches);
    let outdir = std::path::PathBuf::from(matches.str("out"));
    std::fs::create_dir_all(&outdir)?;
    let method = matches.str("method").to_string();
    let curve = match method.as_str() {
        "drlgo" | "drl-only" => {
            let cfg = MaddpgConfig { episodes, seed, envs, scenarios, ..MaddpgConfig::default() };
            let ablation = method == "drl-only";
            let (trainer, _env, curve) = ctrl.train_drlgo(&dataset, ablation, users, assocs, &cfg)?;
            let ckpt = outdir.join(format!("{method}_{dataset}.gta"));
            trainer.save(&ckpt)?;
            println!("saved checkpoint {}", ckpt.display());
            curve
        }
        "ptom" => {
            let cfg = PpoConfig { episodes, seed, envs, scenarios, ..PpoConfig::default() };
            let (_trainer, _env, curve) = ctrl.train_ptom(&dataset, users, assocs, &cfg)?;
            curve
        }
        other => anyhow::bail!("unknown method {other}"),
    };
    print_curve(&curve);
    let telemetry = matches.str("telemetry").to_string();
    if !telemetry.is_empty() {
        let path = std::path::Path::new(&telemetry);
        graphedge::drl::telemetry::write_episode_jsonl(path, &curve)?;
        println!("telemetry: {} episodes -> {telemetry}", curve.len());
    }
    Ok(())
}

/// The `--scenarios` flag, normalized: `replicate` (the default) and
/// the empty string mean single-scenario mode (`None`).
fn scenarios_flag(matches: &graphedge::util::cli::Matches) -> Option<String> {
    match matches.str("scenarios").trim() {
        "" | "replicate" => None,
        spec => Some(spec.to_string()),
    }
}

fn print_curve(curve: &[graphedge::drl::maddpg::EpisodeStats]) {
    let mut t = Table::new("training curve", &["episode", "reward", "system cost"]);
    let stride = (curve.len() / 20).max(1);
    for s in curve.iter().step_by(stride) {
        t.row(vec![
            s.episode.to_string(),
            format!("{:.3}", s.reward),
            format!("{:.3}", s.system_cost),
        ]);
    }
    print!("{}", t.render());
}

/// CLI boundary check for `--model`: a typo should fail loudly here,
/// not fall back to gcn deep inside the cost model
/// ([`graphedge::net::GnnProfile::from_name`] stays lenient for
/// library callers; the CLI is strict).
fn validate_model(model: &str) -> graphedge::Result<()> {
    use graphedge::net::GnnProfile;
    if GnnProfile::try_from_name(model).is_none() {
        anyhow::bail!(
            "unknown GNN model {model:?}; known models: {}",
            GnnProfile::KNOWN_NAMES.join(", ")
        );
    }
    Ok(())
}

fn cmd_simulate(matches: &graphedge::util::cli::Matches) -> graphedge::Result<()> {
    let params = load_params(matches);
    let ctrl = Controller::new(params)?;
    let dataset = matches.str("dataset").to_string();
    let model = matches.str("model").to_string();
    validate_model(&model)?;
    let users = matches.usize("users");
    let assocs = matches.usize("assocs");
    let episodes = matches.usize("episodes");
    let envs = matches.usize("envs").max(1);
    let seed = matches.usize("seed") as u64;
    let inference = !matches.switch("no-inference");
    let scenarios = scenarios_flag(matches);

    let mcfg = MaddpgConfig {
        episodes,
        seed,
        envs,
        scenarios: scenarios.clone(),
        ..MaddpgConfig::default()
    };
    let (mut drlgo, _, _) = ctrl.train_drlgo(&dataset, false, users, assocs, &mcfg)?;
    let pcfg = PpoConfig { episodes, seed, envs, scenarios, ..PpoConfig::default() };
    let (mut ptom, _, _) = ctrl.train_ptom(&dataset, users, assocs, &pcfg)?;

    let mut table = Table::new(
        &format!("scenario {dataset}/{model} N={users} E={assocs}"),
        &["method", "T_all (s)", "I_all (J)", "C", "cross-Mb", "accuracy", "decision"],
    );
    for method in [Method::Drlgo, Method::Ptom, Method::Greedy, Method::Random] {
        let mut rng = Rng::seed_from(seed + 100);
        let mut env = ctrl.make_env(method, &dataset, users, assocs, &mut rng)?;
        let report = ctrl.run_scenario(
            method,
            &mut env,
            &dataset,
            &model,
            Some(&mut drlgo),
            Some(&mut ptom),
            inference,
            &mut rng,
        )?;
        table.row(vec![
            report.method.into(),
            format!("{:.4}", report.cost.t_all()),
            format!("{:.4}", report.cost.i_all()),
            format!("{:.4}", report.cost.total()),
            format!("{:.2}", report.cost.cross_mb),
            format!("{:.3}", report.accuracy),
            fmt_secs(report.decision_s),
        ]);
    }
    print!("{}", table.render());
    print!("{}", METRICS.report());
    Ok(())
}

fn cmd_serve(matches: &graphedge::util::cli::Matches) -> graphedge::Result<()> {
    use graphedge::util::trace;
    let trace_path = matches.str("trace").to_string();
    if !trace_path.is_empty() {
        trace::set_enabled(true);
    }
    let result = cmd_serve_inner(matches);
    if !trace_path.is_empty() {
        let events = trace::drain();
        trace::write_jsonl(std::path::Path::new(&trace_path), &events)?;
        println!("trace           {} events -> {trace_path}", events.len());
    }
    result
}

fn cmd_serve_inner(matches: &graphedge::util::cli::Matches) -> graphedge::Result<()> {
    let params = load_params(matches);
    let users = matches.usize("users");
    let assocs = matches.usize("assocs");
    let seed = matches.usize("seed") as u64;
    let steps = matches.usize("steps");
    let scenario = matches.str("scenario").to_string();
    if !scenario.is_empty() {
        // Synthetic mode: generated scenario, no-op model stage — runs
        // without runtime artifacts (this is the CI trace-smoke path).
        return graphedge::serving::serve_synthetic(
            &params,
            &scenario,
            users,
            assocs,
            steps.max(1),
            matches.usize("per-step"),
            seed,
            matches.switch("incremental"),
            matches.workers(),
        );
    }
    let ctrl = Controller::new(params)?;
    let dataset = matches.str("dataset").to_string();
    let model = matches.str("model").to_string();
    validate_model(&model)?;
    let requests = matches.usize("requests");
    if steps > 0 {
        // Dynamic mode: §3.2 churn every step; the layout is repaired
        // from GraphDeltas (--incremental) or recut in full.
        return graphedge::serving::serve_dynamic(
            &ctrl,
            &dataset,
            &model,
            users,
            assocs,
            steps,
            matches.usize("per-step"),
            seed,
            matches.switch("incremental"),
            matches.workers(),
        );
    }
    let policy = matches.str("policy").to_string();
    let placement = if policy.is_empty() {
        graphedge::serving::Placement::Greedy
    } else {
        graphedge::serving::Placement::DrlgoCheckpoint(std::path::Path::new(
            Box::leak(policy.clone().into_boxed_str()),
        ))
    };
    graphedge::serving::serve_loop(
        &ctrl, &dataset, &model, users, assocs, requests, seed, placement,
    )
}
