//! Bench harness + the shared paper-figure experiment drivers.
//!
//! Original header: (criterion is unavailable offline): timing loops,
//! table rendering and CSV emission for the paper-figure benches in
//! `rust/benches/`.

pub mod figs;

use std::fmt::Write as _;
use std::time::Instant;

use crate::util::stats::Sample;

/// Time one closure over `reps` repetitions (after `warmup` runs);
/// returns per-rep seconds.
pub fn time_reps<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut s = Sample::default();
    for _ in 0..reps {
        // lint:allow(wall-clock) — timing closures is the bench
        // harness's entire purpose; the measurement is reported, never
        // fed back into an algorithm.
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    s
}

/// A simple aligned text table, printed to stdout and collected as CSV.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "ragged table row");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Print to stdout and also write `bench_results/<name>.csv`.
    pub fn emit(&self, name: &str) {
        print!("{}", self.render());
        let _ = std::fs::create_dir_all("bench_results");
        let mut csv = String::new();
        let _ = writeln!(csv, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(csv, "{}", row.join(","));
        }
        let path = format!("bench_results/{name}.csv");
        if std::fs::write(&path, csv).is_ok() {
            println!("[wrote {path}]");
        }
    }
}

/// Merge `section` under `key` into a shared bench-results JSON file.
///
/// Looks for `../<file>` first (the repo root when a bench runs from
/// the crate directory), then `<file>` in the current directory.  Any
/// *other* top-level sections a sibling bench has written are
/// preserved as long as the existing file parses as a JSON object;
/// a missing or unparseable file starts fresh.  Returns the path
/// written.
pub fn write_bench_section(
    file: &str,
    key: &str,
    section: crate::util::json::Value,
) -> std::io::Result<String> {
    use crate::util::json::Value;
    let parent = format!("../{file}");
    let path = if std::path::Path::new(&parent).exists() {
        parent
    } else {
        file.to_string()
    };
    let mut root = std::fs::read_to_string(&path)
        .ok()
        .and_then(|s| Value::parse(&s).ok())
        .and_then(|v| match v {
            Value::Obj(m) => Some(m),
            _ => None,
        })
        .unwrap_or_default();
    root.insert(key.to_string(), section);
    std::fs::write(&path, Value::Obj(root).to_json_pretty())?;
    Ok(path)
}

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_and_emits() {
        let mut t = Table::new("test", &["a", "b"]);
        t.row(vec!["1".into(), "hello".into()]);
        let r = t.render();
        assert!(r.contains("test") && r.contains("hello"));
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_rejected() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn time_reps_counts() {
        let s = time_reps(1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn bench_sections_merge_without_clobbering() {
        use crate::util::json::Value;
        use std::collections::BTreeMap;
        let path = std::env::temp_dir()
            .join(format!("graphedge_bench_merge_{}.json", std::process::id()));
        let path_s = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);

        let mut a = BTreeMap::new();
        a.insert("x".to_string(), Value::Num(1.0));
        write_bench_section(&path_s, "alpha", Value::Obj(a)).unwrap();
        let mut b = BTreeMap::new();
        b.insert("y".to_string(), Value::Num(2.0));
        write_bench_section(&path_s, "beta", Value::Obj(b)).unwrap();

        let v = Value::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v.path(&["alpha", "x"]).and_then(|x| x.as_f64()), Some(1.0));
        assert_eq!(v.path(&["beta", "y"]).and_then(|x| x.as_f64()), Some(2.0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-7).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }
}
