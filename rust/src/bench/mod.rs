//! Bench harness + the shared paper-figure experiment drivers.
//!
//! Original header: (criterion is unavailable offline): timing loops,
//! table rendering and CSV emission for the paper-figure benches in
//! `rust/benches/`.

pub mod figs;

use std::fmt::Write as _;
use std::time::Instant;

use crate::util::stats::Sample;

/// Time one closure over `reps` repetitions (after `warmup` runs);
/// returns per-rep seconds.
pub fn time_reps<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut s = Sample::default();
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    s
}

/// A simple aligned text table, printed to stdout and collected as CSV.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "ragged table row");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Print to stdout and also write `bench_results/<name>.csv`.
    pub fn emit(&self, name: &str) {
        print!("{}", self.render());
        let _ = std::fs::create_dir_all("bench_results");
        let mut csv = String::new();
        let _ = writeln!(csv, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(csv, "{}", row.join(","));
        }
        let path = format!("bench_results/{name}.csv");
        if std::fs::write(&path, csv).is_ok() {
            println!("[wrote {path}]");
        }
    }
}

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_and_emits() {
        let mut t = Table::new("test", &["a", "b"]);
        t.row(vec!["1".into(), "hello".into()]);
        let r = t.render();
        assert!(r.contains("test") && r.contains("hello"));
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_rejected() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn time_reps_counts() {
        let s = time_reps(1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-7).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }
}
