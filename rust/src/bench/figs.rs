//! Shared experiment drivers behind the paper-figure benches
//! (`rust/benches/fig*.rs`) — each bench binary is a thin wrapper so
//! the logic is testable and reusable from the CLI/examples.
//!
//! Scale note: episode/repetition counts default to paper-faithful
//! values scaled down to CI-friendly sizes and can be raised via
//! `GRAPHEDGE_BENCH_EPISODES` / `GRAPHEDGE_BENCH_REPS` (the paper
//! averages 10 evaluations per point; default here is 3).

use crate::coordinator::Controller;
use crate::drl::{MaddpgConfig, MaddpgTrainer, Method, PpoConfig, PpoTrainer};
use crate::net::SystemParams;
use crate::util::rng::Rng;

use super::Table;

pub fn bench_episodes() -> usize {
    std::env::var("GRAPHEDGE_BENCH_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60)
}

pub fn bench_reps() -> usize {
    std::env::var("GRAPHEDGE_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3)
}

/// Policies trained once per bench run (the paper trains on a PubMed
/// sample and evaluates across datasets, §6.4).
pub struct TrainedPolicies<'c> {
    pub drlgo: MaddpgTrainer<'c>,
    pub ptom: PpoTrainer<'c>,
}

pub fn train_policies<'c>(
    ctrl: &'c Controller,
    train_dataset: &str,
    users: usize,
    assocs: usize,
    episodes: usize,
) -> crate::Result<TrainedPolicies<'c>> {
    eprintln!("[bench] training DRLGO ({episodes} episodes on {train_dataset}) ...");
    let mcfg = MaddpgConfig { episodes, ..MaddpgConfig::default() };
    let (drlgo, _, _) = ctrl.train_drlgo(train_dataset, false, users, assocs, &mcfg)?;
    eprintln!("[bench] training PTOM ({episodes} episodes) ...");
    let pcfg = PpoConfig { episodes, ..PpoConfig::default() };
    let (ptom, _, _) = ctrl.train_ptom(train_dataset, users, assocs, &pcfg)?;
    Ok(TrainedPolicies { drlgo, ptom })
}

pub const METHODS: [Method; 4] = [Method::Drlgo, Method::Ptom, Method::Greedy, Method::Random];

/// Average system cost of `method` over `reps` fresh scenarios.
#[allow(clippy::too_many_arguments)]
pub fn avg_cost(
    ctrl: &Controller,
    pol: &mut TrainedPolicies,
    method: Method,
    dataset: &str,
    users: usize,
    assocs: usize,
    reps: usize,
    seed: u64,
) -> crate::Result<(f64, f64)> {
    let mut total = 0.0;
    let mut cross = 0.0;
    for rep in 0..reps {
        let mut rng = Rng::seed_from(seed + rep as u64 * 7919);
        let mut env = ctrl.make_env(method, dataset, users, assocs, &mut rng)?;
        let report = ctrl.run_scenario(
            method,
            &mut env,
            dataset,
            "gcn",
            Some(&mut pol.drlgo),
            Some(&mut pol.ptom),
            false,
            &mut rng,
        )?;
        total += report.cost.total();
        cross += report.cost.cross_mb;
    }
    Ok((total / reps as f64, cross / reps as f64))
}

/// Figs. 7–9 panels (a)+(b): system cost vs #users and vs #associations.
pub fn dynamic_cost_figure(dataset: &str) -> crate::Result<()> {
    let ctrl = Controller::new(SystemParams::default())?;
    let episodes = bench_episodes();
    let reps = bench_reps();
    let mut pol = train_policies(&ctrl, "pubmed", 300, 4800, episodes)?;

    // Panel (a): users 50..300 with associations scaled 6x (300..1800).
    let mut ta = Table::new(
        &format!("{dataset}: system cost vs users (assoc = 6x users) — Fig panel (a)"),
        &["users", "DRLGO", "PTOM", "GM", "RM"],
    );
    for users in [50, 100, 150, 200, 250, 300] {
        let mut row = vec![users.to_string()];
        for method in METHODS {
            let (c, _) = avg_cost(&ctrl, &mut pol, method, dataset, users, 6 * users, reps, 42)?;
            row.push(format!("{c:.3}"));
        }
        ta.row(row);
    }
    ta.emit(&format!("{dataset}_cost_vs_users"));

    // Panel (b): associations 300..1800 at 300 users.
    let mut tb = Table::new(
        &format!("{dataset}: system cost vs associations (300 users) — Fig panel (b)"),
        &["assocs", "DRLGO", "PTOM", "GM", "RM"],
    );
    for assocs in [300, 600, 900, 1200, 1500, 1800] {
        let mut row = vec![assocs.to_string()];
        for method in METHODS {
            let (c, _) = avg_cost(&ctrl, &mut pol, method, dataset, 300, assocs, reps, 77)?;
            row.push(format!("{c:.3}"));
        }
        tb.row(row);
    }
    tb.emit(&format!("{dataset}_cost_vs_assocs"));

    // Panel (c): mobility — random user positions at each time step.
    let mut tc = Table::new(
        &format!("{dataset}: system cost under mobility — Fig panel (c)"),
        &["step", "DRLGO", "PTOM", "GM", "RM"],
    );
    let mut rng = Rng::seed_from(99);
    let mut envs: Vec<_> = METHODS
        .iter()
        .map(|&m| ctrl.make_env(m, dataset, 150, 900, &mut rng).unwrap())
        .collect();
    for step in 0..8 {
        let mut row = vec![step.to_string()];
        for (i, &method) in METHODS.iter().enumerate() {
            let env = &mut envs[i];
            let plane = env.params.plane_m;
            env.users.scatter_users(plane, &mut rng);
            env.recut();
            // The scatter bumped the graph's topology version; the
            // recut must have caught the layout up before this row is
            // measured, or the figure reports a stale layout's cost.
            assert_eq!(
                env.layout_lag(),
                0,
                "mobility panel would measure a stale layout"
            );
            let report = ctrl.run_scenario(
                method,
                env,
                dataset,
                "gcn",
                Some(&mut pol.drlgo),
                Some(&mut pol.ptom),
                false,
                &mut rng,
            )?;
            row.push(format!("{:.3}", report.cost.total()));
        }
        tc.row(row);
    }
    tc.emit(&format!("{dataset}_cost_mobility"));

    // Panel (d): cross-server communication under random state churn.
    let mut td = Table::new(
        &format!("{dataset}: cross-server communication (Mb) — Fig panel (d)"),
        &["step", "DRLGO", "PTOM", "GM", "RM"],
    );
    for step in 0..6 {
        let mut row = vec![step.to_string()];
        for method in METHODS {
            let (_, cross) = avg_cost(
                &ctrl, &mut pol, method, dataset, 150, 900, reps,
                1000 + step as u64 * 31,
            )?;
            row.push(format!("{cross:.2}"));
        }
        td.row(row);
    }
    td.emit(&format!("{dataset}_cross_comm"));
    Ok(())
}

/// Fig. 10: system cost across GNN models × datasets (N=300, E=4800).
pub fn gnn_models_figure() -> crate::Result<()> {
    let ctrl = Controller::new(SystemParams::default())?;
    let episodes = bench_episodes();
    let mut pol = train_policies(&ctrl, "pubmed", 300, 4800, episodes)?;
    for dataset in ["citeseer", "cora", "pubmed"] {
        let mut t = Table::new(
            &format!("Fig. 10 — {dataset}: cost & accuracy per GNN model (N=300, E=4800)"),
            &["model", "DRLGO", "PTOM", "GM", "RM", "accuracy(DRLGO)", "infer(s)"],
        );
        for model in ["gcn", "gat", "sage", "sgc"] {
            let mut row = vec![model.to_string()];
            let mut acc = 0.0;
            let mut infer = 0.0;
            for method in METHODS {
                // Same seed for every model: rows differ only through
                // the architecture profile (and the measured inference).
                let mut rng = Rng::seed_from(7);
                let mut env = ctrl.make_env(method, dataset, 300, 4800, &mut rng)?;
                let rep = ctrl.run_scenario(
                    method,
                    &mut env,
                    dataset,
                    model,
                    Some(&mut pol.drlgo),
                    Some(&mut pol.ptom),
                    method == Method::Drlgo, // fleet inference once per row
                    &mut rng,
                )?;
                row.push(format!("{:.3}", rep.cost.total()));
                if method == Method::Drlgo {
                    acc = rep.accuracy;
                    infer = rep.inference_s;
                }
            }
            row.push(format!("{acc:.3}"));
            row.push(format!("{infer:.3}"));
            t.row(row);
        }
        t.emit(&format!("fig10_{dataset}"));
    }
    Ok(())
}

/// Fig. 11: reward-convergence curves for DRLGO and PTOM.
pub fn convergence_figure() -> crate::Result<()> {
    let ctrl = Controller::new(SystemParams::default())?;
    let episodes = bench_episodes().max(40);
    eprintln!("[bench] fig11: {episodes} episodes each (20% churn per episode)");
    let mcfg = MaddpgConfig { episodes, ..MaddpgConfig::default() };
    let (_d, _e, dcurve) = ctrl.train_drlgo("pubmed", false, 300, 4800, &mcfg)?;
    let pcfg = PpoConfig { episodes, ..PpoConfig::default() };
    let (_p, _e2, pcurve) = ctrl.train_ptom("pubmed", 300, 4800, &pcfg)?;

    let mut t = Table::new(
        "Fig. 11 — training reward (negative system cost) per episode",
        &["episode", "DRLGO reward", "PTOM reward", "DRLGO cost", "PTOM cost"],
    );
    for i in 0..episodes {
        t.row(vec![
            i.to_string(),
            format!("{:.3}", dcurve[i].reward),
            format!("{:.3}", pcurve[i].reward),
            format!("{:.3}", dcurve[i].system_cost),
            format!("{:.3}", pcurve[i].system_cost),
        ]);
    }
    t.emit("fig11_convergence");

    // Stability summary over the final third (the paper's claim:
    // DRLGO converges better *and more stably* than PTOM).  Raw reward
    // scales differ between the methods (DRLGO's includes the R_sp
    // shaping term), so the comparable series is the evaluated system
    // cost of each episode's final offload.
    let tail = episodes / 3;
    let stats = |c: &[crate::drl::maddpg::EpisodeStats]| {
        let xs: Vec<f64> = c[c.len() - tail..].iter().map(|s| s.system_cost).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        (mean, var.sqrt())
    };
    let (dm, ds) = stats(&dcurve);
    let (pm, ps) = stats(&pcurve);
    let mut s = Table::new(
        "Fig. 11 — converged system cost, final third (lower/steadier = better)",
        &["method", "mean cost C", "std"],
    );
    s.row(vec!["DRLGO".into(), format!("{dm:.3}"), format!("{ds:.3}")]);
    s.row(vec!["PTOM".into(), format!("{pm:.3}"), format!("{ps:.3}")]);
    s.emit("fig11_summary");
    Ok(())
}

/// Fig. 12: DRLGO vs DRL-only (no HiCut, no R_sp) ablation.
pub fn ablation_figure() -> crate::Result<()> {
    let ctrl = Controller::new(SystemParams::default())?;
    let episodes = bench_episodes();
    let reps = bench_reps();
    let mcfg = MaddpgConfig { episodes, ..MaddpgConfig::default() };
    eprintln!("[bench] training DRLGO ...");
    let (mut drlgo, _, _) = ctrl.train_drlgo("pubmed", false, 300, 4800, &mcfg)?;
    eprintln!("[bench] training DRL-only (ablation) ...");
    let (mut drlonly, _, _) = ctrl.train_drlgo("pubmed", true, 300, 4800, &mcfg)?;

    let mut t = Table::new(
        "Fig. 12 — ablation: DRLGO vs DRL-only (N=300, E=4800)",
        &["dataset", "DRLGO cost", "DRL-only cost", "DRLGO cross-Mb", "DRL-only cross-Mb"],
    );
    for dataset in ["citeseer", "cora", "pubmed"] {
        let mut c = [0.0f64; 2];
        let mut x = [0.0f64; 2];
        for rep in 0..reps {
            for (i, (method, tr)) in [
                (Method::Drlgo, &mut drlgo),
                (Method::DrlOnly, &mut drlonly),
            ]
            .into_iter()
            .enumerate()
            {
                let mut rng = Rng::seed_from(500 + rep as u64);
                let mut env = ctrl.make_env(method, dataset, 300, 4800, &mut rng)?;
                if method == Method::DrlOnly {
                    env.cfg.use_hicut = false;
                    env.cfg.use_rsp = false;
                    env.recut();
                    assert_eq!(
                        env.layout_lag(),
                        0,
                        "ablation row would measure a stale layout"
                    );
                }
                let rep = ctrl.run_scenario(
                    method, &mut env, dataset, "gcn", Some(tr), None, false, &mut rng,
                )?;
                c[i] += rep.cost.total() / reps as f64;
                x[i] += rep.cost.cross_mb / reps as f64;
            }
        }
        t.row(vec![
            dataset.into(),
            format!("{:.3}", c[0]),
            format!("{:.3}", c[1]),
            format!("{:.2}", x[0]),
            format!("{:.2}", x[1]),
        ]);
    }
    t.emit("fig12_ablation");
    Ok(())
}
