//! Compressed-sparse-row matrices for the native inference backend.
//!
//! The padded adjacencies the serving layer builds ([`crate::serving`])
//! are `[n_max, n_max]` dense matrices whose occupancy is the subgraph
//! edge set — a few percent.  The native GNN kernels
//! ([`crate::runtime::native::kernels`]) convert them to CSR once per
//! forward and run every aggregation as SpMM over the nonzeros, which
//! is where the paper's SAGE/GAT serving math actually spends its
//! time.
//!
//! Numerics: `spmm` accumulates each output row over the stored
//! nonzeros in column order — exactly the order a dense row-major
//! matmul that skips zero entries visits them — so CSR and dense
//! paths produce bit-identical results on the same input.

use super::Matrix;
use crate::util::threadpool::ThreadPool;

/// A CSR matrix (f32 values, u32 column indices).
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// `row_ptr[r]..row_ptr[r+1]` indexes row r's nonzeros.
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csr {
    /// Build from a dense matrix, keeping only nonzero entries.
    ///
    /// ```
    /// use graphedge::tensor::{Csr, Matrix};
    /// let d = Matrix::from_rows(vec![vec![0.0, 2.0], vec![1.0, 0.0]]);
    /// let s = Csr::from_dense(&d);
    /// assert_eq!(s.nnz(), 2);
    /// assert_eq!(s.row_ptr, vec![0, 1, 2]);
    /// ```
    pub fn from_dense(m: &Matrix) -> Csr {
        let mut row_ptr = Vec::with_capacity(m.rows + 1);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0u32);
        for r in 0..m.rows {
            for (c, &v) in m.row(r).iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(c as u32);
                    vals.push(v);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Csr { rows: m.rows, cols: m.cols, row_ptr, col_idx, vals }
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Sparse × dense product `self @ x`, row-parallel over `workers`
    /// threads.  Each output row is owned by exactly one worker and
    /// accumulated in stored-column order, so the result is identical
    /// for every worker count.
    ///
    /// ```
    /// use graphedge::tensor::{Csr, Matrix};
    /// let adj = Csr::from_dense(&Matrix::from_rows(vec![
    ///     vec![1.0, 1.0],
    ///     vec![0.0, 1.0],
    /// ]));
    /// let x = Matrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
    /// let y = adj.spmm(&x, 2);
    /// assert_eq!(y.data, vec![4.0, 6.0, 3.0, 4.0]);
    /// ```
    pub fn spmm(&self, x: &Matrix, workers: usize) -> Matrix {
        assert_eq!(self.cols, x.rows, "spmm shape mismatch");
        let mut out = Matrix::zeros(self.rows, x.cols);
        if self.rows == 0 || x.cols == 0 {
            return out;
        }
        let cols = x.cols;
        let mut rows: Vec<&mut [f32]> = out.data.chunks_mut(cols).collect();
        ThreadPool::map_scoped_mut(&mut rows, workers.max(1), |r, out_row| {
            let lo = self.row_ptr[r] as usize;
            let hi = self.row_ptr[r + 1] as usize;
            for nz in lo..hi {
                let v = self.vals[nz];
                let xrow = x.row(self.col_idx[nz] as usize);
                for (o, &xv) in out_row.iter_mut().zip(xrow) {
                    *o += v * xv;
                }
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_dense_round_trips_structure() {
        let d = Matrix::from_rows(vec![
            vec![0.0, 1.5, 0.0],
            vec![0.0, 0.0, 0.0],
            vec![2.0, 0.0, 3.0],
        ]);
        let s = Csr::from_dense(&d);
        assert_eq!(s.nnz(), 3);
        assert_eq!(s.row_ptr, vec![0, 1, 1, 3]);
        assert_eq!(s.col_idx, vec![1, 0, 2]);
        assert_eq!(s.vals, vec![1.5, 2.0, 3.0]);
    }

    #[test]
    fn spmm_matches_dense_matmul_bitwise() {
        let mut rng = crate::util::rng::Rng::seed_from(7);
        let mut a = Matrix::zeros(13, 9);
        for v in &mut a.data {
            if rng.chance(0.3) {
                *v = rng.range_f64(-1.0, 1.0) as f32;
            }
        }
        let mut x = Matrix::zeros(9, 5);
        for v in &mut x.data {
            *v = rng.range_f64(-1.0, 1.0) as f32;
        }
        let want = a.matmul(&x);
        for workers in [1usize, 2, 4] {
            let got = Csr::from_dense(&a).spmm(&x, workers);
            assert_eq!(got, want, "workers = {workers}");
        }
    }

    #[test]
    fn empty_rows_stay_zero() {
        let a = Csr::from_dense(&Matrix::zeros(4, 4));
        let x = Matrix::from_rows(vec![vec![1.0]; 4]);
        let y = a.spmm(&x, 2);
        assert!(y.data.iter().all(|&v| v == 0.0));
    }
}
