//! GTA (GraphEdge Tensor Archive) reader/writer.
//!
//! Mirror of `python/compile/gta.py` — see that module for the layout.
//! The writer exists on the Rust side too so DRL training checkpoints
//! can be saved and reloaded without Python.

use std::io::{Read, Write};
use std::path::Path;

#[derive(Debug, thiserror::Error)]
pub enum GtaError {
    #[error("bad GTA magic")]
    BadMagic,
    #[error("unsupported dtype {0}")]
    BadDtype(u8),
    #[error("tensor {0:?} not found in archive")]
    NotFound(String),
    #[error("tensor {name:?} has shape {actual:?}, expected {expected:?}")]
    ShapeMismatch { name: String, actual: Vec<usize>, expected: Vec<usize> },
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

/// One named tensor (f32 or i32; i32 stored as f32-converted on read
/// convenience accessors).
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub f32_data: Vec<f32>,
    pub is_int: bool,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// A loaded archive, order-preserving.
#[derive(Clone, Debug, Default)]
pub struct Archive {
    pub tensors: Vec<Tensor>,
}

impl Archive {
    pub fn load(path: impl AsRef<Path>) -> Result<Self, GtaError> {
        let mut f = std::fs::File::open(path)?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::parse(&buf)
    }

    pub fn parse(buf: &[u8]) -> Result<Self, GtaError> {
        let mut c = Cursor { buf, pos: 0 };
        if c.take(4)? != b"GTA1" {
            return Err(GtaError::BadMagic);
        }
        let count = c.u32()? as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let nlen = c.u16()? as usize;
            let name = String::from_utf8_lossy(c.take(nlen)?).into_owned();
            let dtype = c.u8()?;
            if dtype > 1 {
                return Err(GtaError::BadDtype(dtype));
            }
            let ndim = c.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(c.u32()? as usize);
            }
            let numel = shape.iter().product::<usize>().max(1);
            let raw = c.take(4 * numel)?;
            let f32_data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|b| {
                    let arr = [b[0], b[1], b[2], b[3]];
                    if dtype == 0 {
                        f32::from_le_bytes(arr)
                    } else {
                        i32::from_le_bytes(arr) as f32
                    }
                })
                .collect();
            tensors.push(Tensor { name, shape, f32_data, is_int: dtype == 1 });
        }
        Ok(Archive { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor, GtaError> {
        self.tensors
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| GtaError::NotFound(name.to_string()))
    }

    /// Typed fetch with shape validation.
    pub fn get_shaped(&self, name: &str, shape: &[usize]) -> Result<&Tensor, GtaError> {
        let t = self.get(name)?;
        if t.shape != shape {
            return Err(GtaError::ShapeMismatch {
                name: name.into(),
                actual: t.shape.clone(),
                expected: shape.to_vec(),
            });
        }
        Ok(t)
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.iter().map(|t| t.name.as_str()).collect()
    }

    /// Save (always f32).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), GtaError> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(b"GTA1")?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for t in &self.tensors {
            let nb = t.name.as_bytes();
            f.write_all(&(nb.len() as u16).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&[0u8, t.shape.len() as u8])?;
            for &d in &t.shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            let mut raw = Vec::with_capacity(4 * t.f32_data.len());
            for v in &t.f32_data {
                raw.extend_from_slice(&v.to_le_bytes());
            }
            f.write_all(&raw)?;
        }
        Ok(())
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], GtaError> {
        if self.pos + n > self.buf.len() {
            return Err(GtaError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "truncated archive",
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, GtaError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, GtaError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, GtaError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Archive {
        Archive {
            tensors: vec![
                Tensor {
                    name: "w0".into(),
                    shape: vec![2, 3],
                    f32_data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
                    is_int: false,
                },
                Tensor {
                    name: "step".into(),
                    shape: vec![],
                    f32_data: vec![7.0],
                    is_int: false,
                },
            ],
        }
    }

    #[test]
    fn round_trip() {
        let dir = std::env::temp_dir().join("graphedge_gta_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.gta");
        sample().save(&path).unwrap();
        let back = Archive::load(&path).unwrap();
        assert_eq!(back.names(), vec!["w0", "step"]);
        assert_eq!(back.get("w0").unwrap().shape, vec![2, 3]);
        assert_eq!(back.get("w0").unwrap().f32_data, sample().get("w0").unwrap().f32_data);
        assert_eq!(back.get("step").unwrap().numel(), 1);
    }

    #[test]
    fn shape_validation() {
        let a = sample();
        assert!(a.get_shaped("w0", &[2, 3]).is_ok());
        assert!(matches!(
            a.get_shaped("w0", &[3, 2]),
            Err(GtaError::ShapeMismatch { .. })
        ));
        assert!(matches!(a.get("nope"), Err(GtaError::NotFound(_))));
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(matches!(Archive::parse(b"NOPE"), Err(GtaError::BadMagic)));
    }

    #[test]
    fn rejects_truncated() {
        let mut bytes = b"GTA1".to_vec();
        bytes.extend_from_slice(&5u32.to_le_bytes());
        assert!(Archive::parse(&bytes).is_err());
    }
}
