//! Dense + sparse tensors and the GTA tensor-archive reader.
//!
//! The serving layer builds padded `[N_MAX, N_MAX]` adjacencies and
//! `[N_MAX, F]` feature matrices as [`Matrix`] values, then hands them
//! to a [`crate::runtime::Backend`] as flat `f32` slices.  The native
//! backend sparsifies adjacencies into [`Csr`] for its SpMM
//! aggregation kernels; [`gta`] reads the pre-trained weights / DRL
//! initial state written by `python/compile/gta.py`.

pub mod csr;
pub mod gta;

pub use csr::Csr;
pub use gta::{Archive, Tensor};

/// Row-major dense f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: Vec<Vec<f32>>) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Naive sequential matmul — the single-threaded oracle the
    /// parallel kernels in [`crate::runtime::native`] are checked
    /// against (same k-order accumulation, same zero-skip).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for (j, &b) in orow.iter().enumerate() {
                    out_row[j] += a * b;
                }
            }
        }
        out
    }

    /// argmax per row over the first `limit` columns (class readout).
    pub fn row_argmax(&self, limit: usize) -> Vec<usize> {
        let limit = limit.min(self.cols);
        (0..self.rows)
            .map(|r| {
                let row = &self.row(r)[..limit];
                let mut best = 0;
                for (j, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = j;
                    }
                }
                best
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = Matrix::from_rows(vec![vec![1.0, 0.0], vec![0.0, 1.0]]);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known_product() {
        let a = Matrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(vec![vec![1.0, 1.0], vec![1.0, 1.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn row_argmax_respects_limit() {
        let m = Matrix::from_rows(vec![vec![0.0, 5.0, 99.0], vec![7.0, 1.0, 99.0]]);
        assert_eq!(m.row_argmax(2), vec![1, 0]);
    }

    #[test]
    fn accessors() {
        let mut m = Matrix::zeros(2, 3);
        m.set(1, 2, 8.0);
        assert_eq!(m.at(1, 2), 8.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 8.0]);
    }
}
