//! The system cost model: Eqs. 3–14 of §3.3–§3.5.
//!
//! Interpretation notes (documented in DESIGN.md §Substitutions):
//!
//! * Task sizes X_i are in Mbit (1 kb per feature dimension, capped at
//!   1500 dims, §6.1); converted to bits where rates are in bit/s.
//! * Eq. (12) as printed sums the inter-server transfer term inside the
//!   per-user sum (multiplying it by N); we count each server pair once,
//!   which is the physically meaningful reading.
//! * S_κ in the GNN energy terms is the *feature dimensionality* of
//!   layer κ.  Aggregation (Eq. 10) moves S_{κ-1}·1kb bits per
//!   neighbor (μ is J/bit); the update (Eq. 11) performs
//!   S_{κ-1}·S_κ multiply-accumulates (ϑ is J/MAC) plus S_κ
//!   activations (φ J each) per vertex.  Reading Eq. 11's product as
//!   bits² would put the update term 6 orders of magnitude above every
//!   other cost and erase the offloading signal the paper optimizes.

use crate::graph::dynamic::DynamicGraph;

use super::params::SystemParams;
use super::topology::{EdgeNetwork, UserLinks};

/// Per-architecture GNN compute profile: the paper's Eq. 10/11 terms
/// depend on which GNN runs on the servers (Fig. 10 compares GCN, GAT,
/// GraphSAGE and SGC).  Profiles are expressed against the layer
/// dimensionality list `[S_0, S_1, ..., S_F]`:
///
/// * `update_mult` — weight matrices applied per layer (GraphSAGE-mean
///   has W_self and W_neigh → 2.0; others 1.0).
/// * `edge_score_macs(s)` — extra per-edge multiply-accumulates in the
///   aggregation (GAT's additive attention scores: 2·S per edge).
/// * `fused_update` — SGC collapses all updates into one S_0 × S_F
///   product with no intermediate activations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GnnProfile {
    Gcn,
    Gat,
    Sage,
    Sgc,
}

impl GnnProfile {
    /// The model names `try_from_name` accepts (the `--model` grammar).
    pub const KNOWN_NAMES: [&'static str; 4] = ["gcn", "gat", "sage", "sgc"];

    /// Strict parse: `None` for anything outside [`KNOWN_NAMES`].
    ///
    /// [`KNOWN_NAMES`]: GnnProfile::KNOWN_NAMES
    pub fn try_from_name(name: &str) -> Option<Self> {
        match name {
            "gcn" => Some(GnnProfile::Gcn),
            "gat" => Some(GnnProfile::Gat),
            "sage" => Some(GnnProfile::Sage),
            "sgc" => Some(GnnProfile::Sgc),
            _ => None,
        }
    }

    /// Lenient parse: unknown names fall back to GCN (the paper's
    /// default architecture) — but no longer silently.  The first
    /// unrecognized name per process is reported on stderr; the CLI
    /// boundary rejects unknown `--model` values outright via
    /// [`try_from_name`], so this path only fires for programmatic
    /// callers.
    ///
    /// [`try_from_name`]: GnnProfile::try_from_name
    pub fn from_name(name: &str) -> Self {
        Self::try_from_name(name).unwrap_or_else(|| {
            use std::sync::atomic::{AtomicBool, Ordering};
            static WARNED: AtomicBool = AtomicBool::new(false);
            // ordering: SeqCst — one-time warn flag on a cold error
            // path; strongest ordering keeps it trivially correct.
            if !WARNED.swap(true, Ordering::SeqCst) {
                eprintln!(
                    "warning: unrecognized GNN model {name:?}; known models are \
                     {} — falling back to gcn",
                    GnnProfile::KNOWN_NAMES.join(", ")
                );
            }
            GnnProfile::Gcn
        })
    }

    pub fn update_mult(&self) -> f64 {
        match self {
            GnnProfile::Sage => 2.0,
            _ => 1.0,
        }
    }

    pub fn edge_score_macs(&self, s_cur: f64) -> f64 {
        match self {
            GnnProfile::Gat => 2.0 * s_cur,
            _ => 0.0,
        }
    }

    pub fn fused_update(&self) -> bool {
        matches!(self, GnnProfile::Sgc)
    }
}

/// An offloading decision: `server[i]` = edge-server id of scenario
/// user `i`, or `UNASSIGNED`.
pub const UNASSIGNED: usize = usize::MAX;

#[derive(Clone, Debug)]
pub struct Offload {
    pub server: Vec<usize>,
}

impl Offload {
    pub fn empty(n: usize) -> Self {
        Offload { server: vec![UNASSIGNED; n] }
    }

    pub fn all_assigned(&self, active: &[usize]) -> bool {
        active.iter().all(|&u| self.server[u] != UNASSIGNED)
    }

    /// Per-server load (assigned-task counts).
    pub fn loads(&self, servers: usize) -> Vec<usize> {
        let mut l = vec![0usize; servers];
        for &s in &self.server {
            if s != UNASSIGNED {
                l[s] += 1;
            }
        }
        l
    }
}

/// Cost decomposition of one completed offloading round.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostBreakdown {
    /// Σ upload delay (s).
    pub t_upload_s: f64,
    /// Σ inter-server transfer delay (s).
    pub t_transfer_s: f64,
    /// Σ GNN compute delay (s).
    pub t_compute_s: f64,
    /// Σ upload energy (J).
    pub i_upload_j: f64,
    /// Σ inter-server communication energy (J).
    pub i_transfer_j: f64,
    /// GNN aggregation + update energy over all layers (J).
    pub i_gnn_j: f64,
    /// Cross-server data volume (Mbit) — the Fig. 7d/8d/9d metric.
    pub cross_mb: f64,
    /// Number of associations crossing servers.
    pub cross_edges: usize,
}

impl CostBreakdown {
    /// T_all (Eq. 12), seconds.
    pub fn t_all(&self) -> f64 {
        self.t_upload_s + self.t_transfer_s + self.t_compute_s
    }

    /// I_all (Eq. 13), joules.
    pub fn i_all(&self) -> f64 {
        self.i_upload_j + self.i_transfer_j + self.i_gnn_j
    }

    /// C = T_all + I_all (§3.5; the paper's scalarized objective).
    pub fn total(&self) -> f64 {
        self.t_all() + self.i_all()
    }
}

/// Precomputed Eq. 3 / Eq. 6 rate tables for one (topology, params)
/// state — the memoizable core of [`CostModel`].
///
/// `uplink[user][server]` depends on user *positions* (gain = ϱ₀·d⁻²),
/// so the table is stale after any mobility/churn step; `server[k]`
/// depends only on the drawn network.  Owners (e.g. `drl::env::Env`)
/// keep one inside a `util::version::Memoized` keyed on (topology,
/// params) and rebuild it lazily; a `CostModel` handed a table via
/// [`CostModel::with_tables`] answers its hot `evaluate` /
/// `marginal_cost` rate lookups from the table instead of re-deriving
/// log₂(1 + SNR) per call.  Entries are produced by the exact same
/// arithmetic as the untabled path, so results are bit-identical.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RateTables {
    /// R_{i,m} (Eq. 3), bit/s, indexed `[user][server]`.
    pub uplink: Vec<Vec<f64>>,
    /// R_{k,l} (Eq. 6), bit/s, indexed by source server `k` (the
    /// backhaul is symmetric-bandwidth, so one row suffices).
    pub server: Vec<f64>,
}

impl RateTables {
    /// Tabulate every rate the given model can be asked for.  Uses the
    /// from-scratch formulas regardless of any table `cm` already
    /// carries, so a rebuild never reads its own stale output.
    pub fn build(cm: &CostModel<'_>) -> Self {
        let m = cm.net.len();
        RateTables {
            uplink: (0..cm.links.bw_hz.len())
                .map(|u| (0..m).map(|s| cm.uplink_rate_fresh(u, s)).collect())
                .collect(),
            server: (0..m).map(|k| cm.server_rate_fresh(k)).collect(),
        }
    }
}

/// Cost evaluator bound to one scenario (users + network + links).
pub struct CostModel<'a> {
    pub params: &'a SystemParams,
    pub net: &'a EdgeNetwork,
    pub links: &'a UserLinks,
    pub users: &'a DynamicGraph,
    /// Hidden feature dimensionality per GNN layer (e.g. [F, 64, C]).
    /// Borrowed: constructing a `CostModel` allocates nothing, so hot
    /// paths (the DRL reward in `Env::step`, the observation engine's
    /// table rebuild) can build one per use for free.
    pub layer_dims: &'a [usize],
    /// Which GNN architecture the servers run (Fig. 10).
    pub profile: GnnProfile,
    /// Optional memoized rate tables (see [`RateTables`]).  `None`
    /// falls back to computing every rate from the Eq. 3/6 formulas.
    tables: Option<&'a RateTables>,
}

impl<'a> CostModel<'a> {
    pub fn new(
        params: &'a SystemParams,
        net: &'a EdgeNetwork,
        links: &'a UserLinks,
        users: &'a DynamicGraph,
        layer_dims: &'a [usize],
    ) -> Self {
        assert_eq!(layer_dims.len(), params.gnn_layers + 1, "dims per layer boundary");
        CostModel { params, net, links, users, layer_dims, profile: GnnProfile::Gcn, tables: None }
    }

    /// Builder-style: switch the GNN architecture profile.
    pub fn with_profile(mut self, profile: GnnProfile) -> Self {
        self.profile = profile;
        self
    }

    /// Builder-style: answer rate lookups from precomputed tables.
    /// The caller owns table freshness (see `util::version`); a stale
    /// table silently prices against an old topology.
    pub fn with_tables(mut self, tables: &'a RateTables) -> Self {
        self.tables = Some(tables);
        self
    }

    /// Channel gain h_{i,m}(t) = ϱ₀ · d⁻² (free-space path loss).
    pub fn gain(&self, user: usize, server: usize) -> f64 {
        let d = self.users.pos(user).dist(&self.net.servers[server].pos).max(1.0);
        self.params.rho0 / (d * d)
    }

    /// Uplink rate R_{i,m}(t), bit/s (Eq. 3).
    pub fn uplink_rate(&self, user: usize, server: usize) -> f64 {
        match self.tables {
            Some(t) => t.uplink[user][server],
            None => self.uplink_rate_fresh(user, server),
        }
    }

    fn uplink_rate_fresh(&self, user: usize, server: usize) -> f64 {
        let bw = self.links.bw_hz[user][server];
        let snr = self.links.p_w[user] * self.gain(user, server) / self.params.noise_w;
        bw * (1.0 + snr).log2()
    }

    /// Inter-server rate R_{k,l}, bit/s (Eq. 6).
    pub fn server_rate(&self, k: usize) -> f64 {
        match self.tables {
            Some(t) => t.server[k],
            None => self.server_rate_fresh(k),
        }
    }

    fn server_rate_fresh(&self, k: usize) -> f64 {
        let snr = self.net.servers[k].p_w * self.params.h0 / self.params.noise_w;
        self.net.server_bw_hz * (1.0 + snr).log2()
    }

    /// Upload delay T^{up}_{i,m} (Eq. 4), seconds.
    pub fn upload_time(&self, user: usize, server: usize) -> f64 {
        self.users.task_mb(user) * 1e6 / self.uplink_rate(user, server)
    }

    /// Upload energy I^{up}_{i,m} (Eq. 5), joules.
    pub fn upload_energy(&self, user: usize) -> f64 {
        self.users.task_mb(user) * self.params.zeta_up_j_mb
    }

    /// GNN compute delay T^{com}_{i,f_k} (Eq. 9), seconds.
    pub fn compute_time(&self, user: usize, server: usize) -> f64 {
        self.users.task_mb(user) * 1e6 / self.net.servers[server].f_hz
    }

    /// Full-system cost of a complete offload (Eqs. 12–13).
    pub fn evaluate(&self, offload: &Offload) -> CostBreakdown {
        let mut out = CostBreakdown::default();
        let active = self.users.active_users();

        // Upload + compute, per user (Eqs. 4, 5, 9).
        for &u in &active {
            let s = offload.server[u];
            if s == UNASSIGNED {
                continue;
            }
            out.t_upload_s += self.upload_time(u, s);
            out.i_upload_j += self.upload_energy(u);
            out.t_compute_s += self.compute_time(u, s);
        }

        // Inter-server transfers: for every association whose endpoints
        // live on different servers, both tasks' data crosses (x̃_kl,
        // Eq. 7).  Accumulated per ordered pair once.
        let m = self.net.len();
        let mut pair_mb = vec![0.0f64; m * m];
        for (i, j) in self.users.graph().edge_list() {
            let (i, j) = (i as usize, j as usize);
            if !self.users.is_active(i) || !self.users.is_active(j) {
                continue;
            }
            let (k, l) = (offload.server[i], offload.server[j]);
            if k == UNASSIGNED || l == UNASSIGNED || k == l {
                continue;
            }
            pair_mb[k * m + l] += self.users.task_mb(i);
            pair_mb[l * m + k] += self.users.task_mb(j);
            out.cross_edges += 1;
        }
        for k in 0..m {
            for l in 0..m {
                if k == l {
                    continue;
                }
                let mb = pair_mb[k * m + l];
                if mb == 0.0 {
                    continue;
                }
                out.cross_mb += mb;
                out.t_transfer_s += mb * 1e6 / self.server_rate(k);
                out.i_transfer_j += mb * self.params.zeta_tran_j_mb;
            }
        }

        // GNN energy (Eqs. 10–11) over F layers, shaped by the model
        // profile (Fig. 10 compares architectures on the same scenario).
        let mut agg = 0.0;
        let mut verts = 0.0;
        for &u in &active {
            if offload.server[u] == UNASSIGNED {
                continue;
            }
            agg += self.users.active_degree(u) as f64;
            verts += 1.0;
        }
        out.i_gnn_j += self.gnn_energy_j(agg, verts);
        out
    }

    /// Eqs. 10–11 for `agg` total neighbor aggregations and `verts`
    /// participating vertices, per the architecture profile.
    pub fn gnn_energy_j(&self, agg: f64, verts: f64) -> f64 {
        let p = self.params;
        let mut e = 0.0;
        for kappa in 1..=p.gnn_layers {
            let s_prev = self.layer_dims[kappa - 1] as f64;
            let s_cur = self.layer_dims[kappa] as f64;
            // Eq. 10: μ · |N_i| · S_{κ-1}·1kb bits per neighbor.
            e += p.mu_j_bit * agg * s_prev * 1e3;
            // GAT: attention-score MACs per (directed) edge.
            e += p.theta_j * self.profile.edge_score_macs(s_cur) * agg;
            if !self.profile.fused_update() {
                // Eq. 11: ϑ·S_{κ-1}·S_κ MACs + φ·S_κ activations/vertex.
                e += verts
                    * (p.theta_j * self.profile.update_mult() * s_prev * s_cur
                        + p.phi_j * s_cur);
            }
        }
        if self.profile.fused_update() {
            // SGC: one S_0 × S_F product, activations only at readout.
            let s0 = self.layer_dims[0] as f64;
            let sf = *self.layer_dims.last().unwrap() as f64;
            e += verts * (p.theta_j * s0 * sf + p.phi_j * sf);
        }
        e
    }

    /// Incremental cost of assigning `user` to `server` given the
    /// current partial offload — the per-step DRL reward basis.  The
    /// transfer term charges both directions of every association
    /// between `user` and already-placed neighbors on other servers.
    pub fn marginal_cost(&self, offload: &Offload, user: usize, server: usize) -> f64 {
        let mut c = self.upload_time(user, server)
            + self.upload_energy(user)
            + self.compute_time(user, server);
        for &nb in self.users.graph().neighbors(user) {
            let nb = nb as usize;
            if !self.users.is_active(nb) {
                continue;
            }
            let s2 = offload.server[nb];
            if s2 == UNASSIGNED || s2 == server {
                continue;
            }
            let mb = self.users.task_mb(user) + self.users.task_mb(nb);
            c += self.users.task_mb(user) * 1e6 / self.server_rate(server);
            c += self.users.task_mb(nb) * 1e6 / self.server_rate(s2);
            c += mb * self.params.zeta_tran_j_mb;
        }
        // Per-user share of GNN energy (profile-aware).
        c += self.gnn_energy_j(self.users.active_degree(user) as f64, 1.0);
        c
    }

    /// Constraint checks C1–C6 (Eq. 14a–f) for a complete offload.
    pub fn check_constraints(&self, offload: &Offload) -> Result<(), String> {
        // C1: every active user on exactly one server.
        for &u in &self.users.active_users() {
            if offload.server[u] == UNASSIGNED {
                return Err(format!("C1 violated: user {u} unassigned"));
            }
        }
        // C2: positive CPU rates.
        if self.net.servers.iter().any(|s| s.f_hz <= 0.0) {
            return Err("C2 violated: non-positive f_k".into());
        }
        // C3: Σ B_{i,m} ≤ B_max1 over *used* links.
        let used_bw: f64 = self
            .users
            .active_users()
            .iter()
            .map(|&u| self.links.bw_hz[u][offload.server[u]])
            .sum();
        if used_bw > self.params.bmax_user_hz {
            return Err(format!(
                "C3 violated: user bandwidth {:.1} MHz > cap",
                used_bw / 1e6
            ));
        }
        // C4: Σ B_{k,l} ≤ B_max2 over active server pairs.
        let m = self.net.len();
        let active_pairs = m * (m - 1) / 2;
        let server_bw = active_pairs as f64 * self.net.server_bw_hz;
        if server_bw > self.params.bmax_server_hz * m as f64 {
            return Err("C4 violated: server bandwidth over cap".into());
        }
        // C5/C6: aggregate transmit power caps.
        let p_users: f64 = self
            .users
            .active_users()
            .iter()
            .map(|&u| self.links.p_w[u])
            .sum();
        if p_users > self.params.pmax_user_w {
            return Err(format!("C5 violated: ΣP_i = {p_users:.3} W"));
        }
        let p_servers: f64 = self.net.servers.iter().map(|s| s.p_w).sum();
        if p_servers > self.params.pmax_server_w {
            return Err(format!("C6 violated: ΣP_k = {p_servers:.3} W"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::util::rng::Rng;

    fn scenario(
        n: usize,
        edges: &[(u32, u32)],
        seed: u64,
    ) -> (SystemParams, EdgeNetwork, UserLinks, DynamicGraph) {
        let params = SystemParams::default();
        let mut rng = Rng::seed_from(seed);
        let net = EdgeNetwork::build(&params, n, &mut rng);
        let links = UserLinks::draw(&params, n, net.len(), &mut rng);
        let g = Graph::from_edges(n, edges);
        let users = DynamicGraph::new(g, vec![1.5; n], params.plane_m, &mut rng);
        (params, net, links, users)
    }

    fn dims() -> &'static [usize] {
        &[1500, 64, 8]
    }

    #[test]
    fn rates_positive_and_distance_monotone() {
        let (p, net, links, users) = scenario(10, &[], 1);
        let cm = CostModel::new(&p, &net, &links, &users, dims());
        for u in 0..10 {
            for s in 0..net.len() {
                assert!(cm.uplink_rate(u, s) > 0.0);
            }
        }
        // Same bandwidth/power, farther server → lower rate: force it.
        let near = net.nearest(users.pos(0));
        let far = (0..net.len())
            .max_by(|&a, &b| {
                users.pos(0)
                    .dist(&net.servers[a].pos)
                    .partial_cmp(&users.pos(0).dist(&net.servers[b].pos))
                    .unwrap()
            })
            .unwrap();
        // Rate ratio dominated by gain when bandwidths are similar; we
        // only check the gain ordering which is deterministic.
        assert!(cm.gain(0, near) > cm.gain(0, far));
    }

    #[test]
    fn colocated_offload_has_zero_transfer() {
        let (p, net, links, users) = scenario(6, &[(0, 1), (2, 3), (4, 5)], 2);
        let cm = CostModel::new(&p, &net, &links, &users, dims());
        let off = Offload { server: vec![1; 6] };
        let cost = cm.evaluate(&off);
        assert_eq!(cost.cross_edges, 0);
        assert_eq!(cost.t_transfer_s, 0.0);
        assert_eq!(cost.i_transfer_j, 0.0);
        assert!(cost.t_upload_s > 0.0);
        assert!(cost.i_gnn_j > 0.0);
    }

    #[test]
    fn split_neighbors_pay_transfer() {
        let (p, net, links, users) = scenario(2, &[(0, 1)], 3);
        let cm = CostModel::new(&p, &net, &links, &users, dims());
        let together = cm.evaluate(&Offload { server: vec![0, 0] });
        let split = cm.evaluate(&Offload { server: vec![0, 1] });
        assert_eq!(split.cross_edges, 1);
        assert!((split.cross_mb - 3.0).abs() < 1e-9); // both 1.5 Mb tasks cross
        assert!(split.total() > together.total());
        assert!(split.i_transfer_j > 0.0);
    }

    #[test]
    fn unassigned_users_cost_nothing() {
        let (p, net, links, users) = scenario(4, &[(0, 1)], 4);
        let cm = CostModel::new(&p, &net, &links, &users, dims());
        let mut off = Offload::empty(4);
        off.server[0] = 0;
        let cost = cm.evaluate(&off);
        let full = cm.evaluate(&Offload { server: vec![0; 4] });
        assert!(cost.t_upload_s < full.t_upload_s);
        assert_eq!(cost.cross_edges, 0);
    }

    #[test]
    fn cost_scales_with_users_and_edges() {
        // More users / more associations → higher total cost, the
        // monotonicity behind Figs. 7–9 panels (a) and (b).
        let (p, net, links, users_small) = scenario(10, &[(0, 1)], 5);
        let cm_small = CostModel::new(&p, &net, &links, &users_small, dims());
        let c_small = cm_small.evaluate(&Offload { server: vec![0; 10] });

        let edges: Vec<(u32, u32)> = (0..20u32)
            .flat_map(|i| ((i + 1)..20).map(move |j| (i, j)))
            .take(60)
            .collect();
        let (p2, net2, links2, users_big) = scenario(20, &edges, 5);
        let cm_big = CostModel::new(&p2, &net2, &links2, &users_big, dims());
        // Spread users over servers so transfers exist.
        let assign: Vec<usize> = (0..20).map(|u| u % 4).collect();
        let c_big = cm_big.evaluate(&Offload { server: assign });
        assert!(c_big.total() > c_small.total());
    }

    #[test]
    fn marginal_cost_prefers_neighbor_server() {
        let (p, net, links, users) = scenario(3, &[(0, 1)], 6);
        let cm = CostModel::new(&p, &net, &links, &users, dims());
        let mut off = Offload::empty(3);
        off.server[0] = 2;
        let with_nb = cm.marginal_cost(&off, 1, 2);
        let away = cm.marginal_cost(&off, 1, 3);
        // Joining the neighbor's server avoids the transfer term; the
        // upload/compute deltas are orders of magnitude smaller here.
        assert!(with_nb < away, "{with_nb} vs {away}");
    }

    #[test]
    fn constraints_detect_violations() {
        let (p, net, links, users) = scenario(5, &[], 7);
        let cm = CostModel::new(&p, &net, &links, &users, dims());
        let mut off = Offload::empty(5);
        assert!(cm.check_constraints(&off).unwrap_err().contains("C1"));
        for u in 0..5 {
            off.server[u] = 0;
        }
        assert!(cm.check_constraints(&off).is_ok());
    }

    #[test]
    fn gnn_profiles_order_energy() {
        // Per-vertex update energy: SAGE (2 weight mats) > GAT (extra
        // per-edge attention) > GCN > SGC (single fused product).
        let (p, net, links, users) = scenario(8, &[(0, 1), (1, 2)], 8);
        let e = |prof: GnnProfile| {
            CostModel::new(&p, &net, &links, &users, dims())
                .with_profile(prof)
                .gnn_energy_j(16.0, 8.0)
        };
        let (gcn, gat, sage, sgc) = (
            e(GnnProfile::Gcn),
            e(GnnProfile::Gat),
            e(GnnProfile::Sage),
            e(GnnProfile::Sgc),
        );
        assert!(sage > gat, "sage {sage} gat {gat}");
        assert!(gat > gcn, "gat {gat} gcn {gcn}");
        assert!(gcn > sgc, "gcn {gcn} sgc {sgc}");
    }

    #[test]
    fn profile_from_name() {
        assert_eq!(GnnProfile::from_name("gat"), GnnProfile::Gat);
        assert_eq!(GnnProfile::from_name("sage"), GnnProfile::Sage);
        assert_eq!(GnnProfile::from_name("sgc"), GnnProfile::Sgc);
        assert_eq!(GnnProfile::from_name("gcn"), GnnProfile::Gcn);
        assert_eq!(GnnProfile::from_name("???"), GnnProfile::Gcn);
    }

    #[test]
    fn try_from_name_is_strict() {
        for name in GnnProfile::KNOWN_NAMES {
            assert_eq!(
                GnnProfile::try_from_name(name),
                Some(GnnProfile::from_name(name))
            );
        }
        assert_eq!(GnnProfile::try_from_name("???"), None);
        assert_eq!(GnnProfile::try_from_name("GCN"), None);
        assert_eq!(GnnProfile::try_from_name(""), None);
    }

    #[test]
    fn rate_tables_are_bit_identical_to_fresh_rates() {
        let (p, net, links, users) = scenario(12, &[(0, 1), (2, 3), (5, 9)], 9);
        let cm = CostModel::new(&p, &net, &links, &users, dims());
        let tables = RateTables::build(&cm);
        let tm = CostModel::new(&p, &net, &links, &users, dims()).with_tables(&tables);
        for u in 0..12 {
            for s in 0..net.len() {
                assert_eq!(
                    cm.uplink_rate(u, s).to_bits(),
                    tm.uplink_rate(u, s).to_bits()
                );
            }
        }
        for k in 0..net.len() {
            assert_eq!(cm.server_rate(k).to_bits(), tm.server_rate(k).to_bits());
        }
        // Whole-pipeline identity: evaluate and marginal_cost go
        // through the same rate lookups.
        let off = Offload { server: (0..12).map(|u| u % net.len()).collect() };
        assert_eq!(cm.evaluate(&off), tm.evaluate(&off));
        let mut partial = Offload::empty(12);
        partial.server[0] = 0;
        assert_eq!(
            cm.marginal_cost(&partial, 1, 1).to_bits(),
            tm.marginal_cost(&partial, 1, 1).to_bits()
        );
    }

    #[test]
    fn t_and_i_aggregate() {
        let b = CostBreakdown {
            t_upload_s: 1.0,
            t_transfer_s: 2.0,
            t_compute_s: 3.0,
            i_upload_j: 4.0,
            i_transfer_j: 5.0,
            i_gnn_j: 6.0,
            cross_mb: 0.0,
            cross_edges: 0,
        };
        assert_eq!(b.t_all(), 6.0);
        assert_eq!(b.i_all(), 15.0);
        assert_eq!(b.total(), 21.0);
    }
}
