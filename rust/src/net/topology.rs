//! Physical topology: M edge servers with co-located APs on the plane.
//!
//! §6.1: four servers serve the 2000 m × 2000 m plane; service
//! capacities are drawn from {5/4·Mean, Mean, 3/4·Mean} where
//! Mean = N/M; per-user AP bandwidths are uniform in [20, 50] MHz and
//! CPU rates uniform in [2, 10] GHz — the server heterogeneity DRLGO is
//! supposed to exploit.

use crate::graph::dynamic::Pos;
use crate::util::rng::Rng;

use super::params::SystemParams;

/// One edge server + its AP.
#[derive(Clone, Debug)]
pub struct EdgeServer {
    pub id: usize,
    pub pos: Pos,
    /// CPU cycles per second available to the GNN (f_k).
    pub f_hz: f64,
    /// Transmit power P_k, watts.
    pub p_w: f64,
    /// Maximum number of user tasks this server accepts per round
    /// (the §6.1 service-capacity levels).
    pub capacity: usize,
}

/// The edge network: servers, APs and link bandwidths.
#[derive(Clone, Debug)]
pub struct EdgeNetwork {
    pub servers: Vec<EdgeServer>,
    /// η_{kl}: inter-server links all up (fully connected backhaul).
    pub server_bw_hz: f64,
}

impl EdgeNetwork {
    /// Place M servers on a near-square grid over the plane and draw
    /// heterogeneous capacities/CPU rates.  `n_users` sets Mean = N/M.
    pub fn build(params: &SystemParams, n_users: usize, rng: &mut Rng) -> Self {
        let m = params.servers;
        let cols = (m as f64).sqrt().ceil() as usize;
        let rows = m.div_ceil(cols);
        let mean = (n_users as f64 / m as f64).max(1.0);
        // §6.1 capacity levels.
        let levels = [1.25 * mean, mean, 0.75 * mean];
        let servers = (0..m)
            .map(|id| {
                let (r, c) = (id / cols, id % cols);
                let cell_w = params.plane_m / cols as f64;
                let cell_h = params.plane_m / rows as f64;
                EdgeServer {
                    id,
                    pos: Pos {
                        x: (c as f64 + 0.5) * cell_w,
                        y: (r as f64 + 0.5) * cell_h,
                    },
                    f_hz: rng.range_f64(params.f_hz.0, params.f_hz.1),
                    p_w: rng.range_f64(params.p_server_w.0, params.p_server_w.1),
                    capacity: levels[rng.below(levels.len())].ceil() as usize,
                }
            })
            .collect();
        EdgeNetwork { servers, server_bw_hz: params.bw_server_hz }
    }

    pub fn len(&self) -> usize {
        self.servers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.servers.is_empty()
    }

    /// Nearest server to a position (the GM baseline's criterion).
    pub fn nearest(&self, pos: Pos) -> usize {
        self.servers
            .iter()
            .min_by(|a, b| {
                a.pos.dist(&pos).partial_cmp(&b.pos.dist(&pos)).unwrap()
            })
            .map(|s| s.id)
            .unwrap()
    }

    /// Total service capacity.
    pub fn total_capacity(&self) -> usize {
        self.servers.iter().map(|s| s.capacity).sum()
    }
}

/// Per-scenario user↔AP bandwidth draws (B_{i,m} of Eq. 3).
#[derive(Clone, Debug)]
pub struct UserLinks {
    /// `bw[user][server]` in Hz.
    pub bw_hz: Vec<Vec<f64>>,
    /// User transmit powers P_i, watts.
    pub p_w: Vec<f64>,
}

impl UserLinks {
    pub fn draw(params: &SystemParams, n_users: usize, servers: usize, rng: &mut Rng) -> Self {
        UserLinks {
            bw_hz: (0..n_users)
                .map(|_| {
                    (0..servers)
                        .map(|_| rng.range_f64(params.bw_user_hz.0, params.bw_user_hz.1))
                        .collect()
                })
                .collect(),
            p_w: (0..n_users)
                .map(|_| rng.range_f64(params.p_user_w.0, params.p_user_w.1))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_places_all_servers_on_plane() {
        let p = SystemParams::default();
        let mut rng = Rng::seed_from(1);
        let net = EdgeNetwork::build(&p, 300, &mut rng);
        assert_eq!(net.len(), 4);
        for s in &net.servers {
            assert!((0.0..=2000.0).contains(&s.pos.x));
            assert!((0.0..=2000.0).contains(&s.pos.y));
            assert!((2e9..=10e9).contains(&s.f_hz));
            assert!((10e-3..=15e-3).contains(&s.p_w));
        }
    }

    #[test]
    fn capacities_are_the_three_levels() {
        let p = SystemParams::default();
        let mut rng = Rng::seed_from(2);
        let net = EdgeNetwork::build(&p, 300, &mut rng);
        let mean = 300.0 / 4.0;
        let levels = [
            (1.25f64 * mean).ceil() as usize,
            mean.ceil() as usize,
            (0.75 * mean).ceil() as usize,
        ];
        for s in &net.servers {
            assert!(levels.contains(&s.capacity), "capacity {}", s.capacity);
        }
    }

    #[test]
    fn nearest_picks_closest_quadrant() {
        let p = SystemParams::default();
        let mut rng = Rng::seed_from(3);
        let net = EdgeNetwork::build(&p, 100, &mut rng);
        // Corner (0,0) must map to the server at (500,500) = id 0.
        assert_eq!(net.nearest(Pos { x: 0.0, y: 0.0 }), 0);
        assert_eq!(net.nearest(Pos { x: 1999.0, y: 1999.0 }), 3);
    }

    #[test]
    fn links_within_ranges() {
        let p = SystemParams::default();
        let mut rng = Rng::seed_from(4);
        let links = UserLinks::draw(&p, 50, 4, &mut rng);
        assert_eq!(links.bw_hz.len(), 50);
        for row in &links.bw_hz {
            assert!(row.iter().all(|&b| (20e6..=50e6).contains(&b)));
        }
        assert!(links.p_w.iter().all(|&pw| (2e-3..=5e-3).contains(&pw)));
    }

    #[test]
    fn grid_works_for_25_servers() {
        let mut p = SystemParams::default();
        p.servers = 25;
        let mut rng = Rng::seed_from(5);
        let net = EdgeNetwork::build(&p, 500, &mut rng);
        assert_eq!(net.len(), 25);
        // All distinct positions.
        for i in 0..25 {
            for j in (i + 1)..25 {
                assert!(net.servers[i].pos.dist(&net.servers[j].pos) > 1.0);
            }
        }
    }
}
