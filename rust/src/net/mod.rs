//! Edge-network substrate: radio model, topology and the system cost
//! model of §3.3–§3.5 (Eqs. 3–14).
//!
//! * [`params::SystemParams`] — every Table 2 constant, loadable from
//!   `configs/*.toml`.
//! * [`topology::EdgeNetwork`] — the M edge servers + co-located APs on
//!   the 2000 m × 2000 m plane, heterogeneous service capacities
//!   (5/4·Mean, Mean, 3/4·Mean) and CPU rates.
//! * [`cost::CostModel`] — uplink rates (Eq. 3), upload delay/energy
//!   (Eqs. 4–5), inter-server transfer (Eqs. 6–8), GNN compute time
//!   (Eq. 9) and energy (Eqs. 10–11), aggregated into
//!   `C = T_all + I_all` (Eqs. 12–13) with the C1–C6 constraint checks.

pub mod cost;
pub mod params;
pub mod topology;

pub use cost::{CostBreakdown, CostModel, GnnProfile, Offload, RateTables};
pub use params::SystemParams;
pub use topology::{EdgeNetwork, EdgeServer};
