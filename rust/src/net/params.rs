//! Table 2 system parameters.
//!
//! All values carry SI units internally (watts, hertz, joules, bits,
//! meters, seconds); the dBm/MHz/pJ/mJ numbers of Table 2 are converted
//! at construction.

use crate::util::config::Config;

/// Full parameter set of the EC system (Table 2 defaults).
#[derive(Clone, Debug)]
pub struct SystemParams {
    /// Plane side, meters (2000).
    pub plane_m: f64,
    /// Number of edge servers / APs (M = 4 in the system experiments,
    /// 25 in the Fig. 6 comparison).
    pub servers: usize,
    /// Noise power σ², watts (−110 dBm).
    pub noise_w: f64,
    /// Reference channel gain ϱ₀ at d₀ = 1 m (free-space path loss
    /// h = ϱ₀ d⁻²); −30 dB is the customary reference.
    pub rho0: f64,
    /// Constant inter-server channel gain h₀ (servers are wired-grade;
    /// modeled as the gain at 1 km).
    pub h0: f64,
    /// User transmit power range [2, 5] mW → watts.
    pub p_user_w: (f64, f64),
    /// Server transmit power range [10, 15] mW → watts.
    pub p_server_w: (f64, f64),
    /// User↔AP bandwidth range [20, 50] MHz → Hz.
    pub bw_user_hz: (f64, f64),
    /// Server↔server bandwidth, Hz (100 MHz).
    pub bw_server_hz: f64,
    /// Server CPU rate range [2, 10] GHz (cycles/s; GNN processes one
    /// bit of task data per cycle, Eq. 9).
    pub f_hz: (f64, f64),
    /// Unit aggregation energy μ, J/bit (20 pJ/bit).
    pub mu_j_bit: f64,
    /// Unit update energy ϑ, J per multiply-accumulate (100 pJ).
    pub theta_j: f64,
    /// Unit activation energy φ, J per output element (50 pJ).
    pub phi_j: f64,
    /// Upload energy ς_{i,m}, J/Mbit (3 mJ/Mb).
    pub zeta_up_j_mb: f64,
    /// Inter-server transfer energy ς_{k,l}, J/Mbit (5 mJ/Mb).
    pub zeta_tran_j_mb: f64,
    /// GNN layer count F (2-layer models per §2.2/§6.1).
    pub gnn_layers: usize,
    /// Aggregate bandwidth caps B_max1/B_max2 (5000 / 500 MHz) → Hz.
    pub bmax_user_hz: f64,
    pub bmax_server_hz: f64,
    /// Aggregate power caps P_max1/P_max2 (1.5 W / 60 mW) → watts.
    pub pmax_user_w: f64,
    pub pmax_server_w: f64,
    /// Subgraph-split reward weight ζ (Eq. 25).
    pub zeta_sp: f64,
}

impl Default for SystemParams {
    fn default() -> Self {
        SystemParams {
            plane_m: 2000.0,
            servers: 4,
            noise_w: dbm_to_w(-110.0),
            rho0: 1e-3,
            h0: 1e-3 / (1000.0 * 1000.0),
            p_user_w: (2e-3, 5e-3),
            p_server_w: (10e-3, 15e-3),
            bw_user_hz: (20e6, 50e6),
            bw_server_hz: 100e6,
            f_hz: (2e9, 10e9),
            mu_j_bit: 20e-12,
            theta_j: 100e-12,
            phi_j: 50e-12,
            zeta_up_j_mb: 3e-3,
            zeta_tran_j_mb: 5e-3,
            gnn_layers: 2,
            bmax_user_hz: 5000e6,
            bmax_server_hz: 500e6,
            pmax_user_w: 1.5,
            pmax_server_w: 60e-3,
            zeta_sp: 1.0,
        }
    }
}

impl SystemParams {
    /// Overlay values from a config file section `[net]` / `[cost]`.
    pub fn from_config(cfg: &Config) -> Self {
        let d = SystemParams::default();
        SystemParams {
            plane_m: cfg.f64("net.plane_m", d.plane_m),
            servers: cfg.usize("net.servers", d.servers),
            noise_w: dbm_to_w(cfg.f64("net.noise_dbm", -110.0)),
            rho0: cfg.f64("net.rho0", d.rho0),
            h0: cfg.f64("net.h0", d.h0),
            p_user_w: (
                cfg.f64("net.p_user_mw_lo", 2.0) * 1e-3,
                cfg.f64("net.p_user_mw_hi", 5.0) * 1e-3,
            ),
            p_server_w: (
                cfg.f64("net.p_server_mw_lo", 10.0) * 1e-3,
                cfg.f64("net.p_server_mw_hi", 15.0) * 1e-3,
            ),
            bw_user_hz: (
                cfg.f64("net.bw_user_mhz_lo", 20.0) * 1e6,
                cfg.f64("net.bw_user_mhz_hi", 50.0) * 1e6,
            ),
            bw_server_hz: cfg.f64("net.bw_server_mhz", 100.0) * 1e6,
            f_hz: (
                cfg.f64("net.f_ghz_lo", 2.0) * 1e9,
                cfg.f64("net.f_ghz_hi", 10.0) * 1e9,
            ),
            mu_j_bit: cfg.f64("cost.mu_pj_bit", 20.0) * 1e-12,
            theta_j: cfg.f64("cost.theta_pj", 100.0) * 1e-12,
            phi_j: cfg.f64("cost.phi_pj", 50.0) * 1e-12,
            zeta_up_j_mb: cfg.f64("cost.zeta_up_mj_mb", 3.0) * 1e-3,
            zeta_tran_j_mb: cfg.f64("cost.zeta_tran_mj_mb", 5.0) * 1e-3,
            gnn_layers: cfg.usize("cost.gnn_layers", d.gnn_layers),
            bmax_user_hz: cfg.f64("net.bmax_user_mhz", 5000.0) * 1e6,
            bmax_server_hz: cfg.f64("net.bmax_server_mhz", 500.0) * 1e6,
            pmax_user_w: cfg.f64("net.pmax_user_w", d.pmax_user_w),
            pmax_server_w: cfg.f64("net.pmax_server_mw", 60.0) * 1e-3,
            zeta_sp: cfg.f64("cost.zeta_sp", d.zeta_sp),
        }
    }
}

/// dBm → watts.
pub fn dbm_to_w(dbm: f64) -> f64 {
    10f64.powf(dbm / 10.0) * 1e-3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dbm_conversion() {
        assert!((dbm_to_w(0.0) - 1e-3).abs() < 1e-12);
        assert!((dbm_to_w(30.0) - 1.0).abs() < 1e-9);
        // Table 2 noise: −110 dBm = 1e-14 W.
        assert!((dbm_to_w(-110.0) - 1e-14).abs() < 1e-20);
    }

    #[test]
    fn defaults_match_table2() {
        let p = SystemParams::default();
        assert_eq!(p.servers, 4);
        assert_eq!(p.plane_m, 2000.0);
        assert!((p.noise_w - 1e-14).abs() < 1e-20);
        assert_eq!(p.p_user_w, (2e-3, 5e-3));
        assert_eq!(p.bw_user_hz, (20e6, 50e6));
        assert_eq!(p.bw_server_hz, 100e6);
        assert_eq!(p.f_hz, (2e9, 10e9));
        assert!((p.mu_j_bit - 20e-12).abs() < 1e-24);
        assert!((p.zeta_up_j_mb - 3e-3).abs() < 1e-12);
        assert_eq!(p.gnn_layers, 2);
    }

    #[test]
    fn config_overlay() {
        let cfg = Config::from_str(
            "[net]\nservers = 25\nbw_server_mhz = 200\n[cost]\nmu_pj_bit = 40\n",
        )
        .unwrap();
        let p = SystemParams::from_config(&cfg);
        assert_eq!(p.servers, 25);
        assert_eq!(p.bw_server_hz, 200e6);
        assert!((p.mu_j_bit - 40e-12).abs() < 1e-24);
        // Untouched values keep Table 2 defaults.
        assert_eq!(p.plane_m, 2000.0);
    }
}
