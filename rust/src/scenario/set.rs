//! Scenario *sets*: families of generated scenarios with a train/eval
//! split, plus the spec-string grammar the CLI exposes.
//!
//! # Spec strings (`--scenarios`)
//!
//! A spec string is either the literal `replicate` (single-scenario
//! mode — every vector slot clones the same sampled scenario, exactly
//! the pre-scenario-subsystem behavior), the shorthand `mixed` (one of
//! each generator kind), or a comma-separated list of entries:
//!
//! ```text
//! entry     := kind [":" param] ["@" users "x" assocs]
//! kind      := "uniform" | "pa" | "clustered" | "hotspot"
//! param     := pa mean degree | clustered community count
//!            | hotspot anchor count
//! ```
//!
//! Examples: `mixed`, `uniform,pa:6`, `clustered:5@200x800,hotspot:2`.
//! Entries without an `@` suffix inherit the run's `--users`/`--assocs`
//! values, so slots can hold genuinely different *user counts*, not
//! just different topologies.
//!
//! # Determinism
//!
//! [`ScenarioSet::generate`] derives scenario `i` from the `i`-th
//! [`Rng::fork`] of `Rng::seed_from(seed)` — the same stream rule the
//! vectorized environment uses for churn — so a (spec list, seed) pair
//! pins the whole set bit for bit regardless of worker counts or
//! construction order.

use anyhow::{bail, Context};

use crate::net::params::SystemParams;
use crate::util::rng::Rng;

use super::{Scenario, ScenarioKind, ScenarioSpec};

/// A generated scenario family with train/eval index splits.
#[derive(Clone, Debug)]
pub struct ScenarioSet {
    pub scenarios: Vec<Scenario>,
    /// Indices into `scenarios` used for training slots.
    pub train: Vec<usize>,
    /// Held-out indices for evaluation.
    pub eval: Vec<usize>,
}

impl ScenarioSet {
    /// Generate `train_count + eval_count` scenarios, cycling through
    /// `specs`; the first `train_count` are the train split, the rest
    /// the eval split.  Scenario `i` is generated from the `i`-th fork
    /// of `Rng::seed_from(seed)`.
    pub fn generate(
        specs: &[ScenarioSpec],
        params: &SystemParams,
        train_count: usize,
        eval_count: usize,
        seed: u64,
    ) -> Self {
        assert!(!specs.is_empty(), "scenario set needs at least one spec");
        assert!(train_count >= 1, "scenario set needs at least one train scenario");
        let mut seeder = Rng::seed_from(seed);
        let total = train_count + eval_count;
        let scenarios: Vec<Scenario> = (0..total)
            .map(|i| {
                let mut rng = seeder.fork();
                specs[i % specs.len()].generate(params, &mut rng)
            })
            .collect();
        ScenarioSet {
            scenarios,
            train: (0..train_count).collect(),
            eval: (train_count..total).collect(),
        }
    }

    /// Parse a spec string (see the module docs) and generate a set
    /// sized for `slots` vector slots: `slots` train scenarios plus
    /// `max(1, slots / 4)` held-out eval scenarios.
    pub fn from_spec(
        spec: &str,
        n_users: usize,
        n_assocs: usize,
        params: &SystemParams,
        slots: usize,
        seed: u64,
    ) -> crate::Result<Self> {
        let specs = parse_spec_list(spec, n_users, n_assocs)?;
        let slots = slots.max(1);
        Ok(Self::generate(&specs, params, slots, (slots / 4).max(1), seed))
    }

    pub fn len(&self) -> usize {
        self.scenarios.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scenarios.is_empty()
    }

    /// The train-split scenario backing vector slot `i` (round-robin).
    pub fn train_scenario(&self, i: usize) -> &Scenario {
        &self.scenarios[self.train[i % self.train.len()]]
    }

    /// Eval-split scenarios, in order.
    pub fn eval_scenarios(&self) -> impl Iterator<Item = &Scenario> {
        self.eval.iter().map(|&i| &self.scenarios[i])
    }
}

/// Parse a `--scenarios` entry list into specs (see the module docs
/// for the grammar).  `replicate` (the single-scenario mode) is *not*
/// accepted here — callers dispatch on it before parsing.
pub fn parse_spec_list(
    spec: &str,
    n_users: usize,
    n_assocs: usize,
) -> crate::Result<Vec<ScenarioSpec>> {
    let spec = spec.trim();
    if spec.is_empty() || spec == "replicate" {
        bail!("spec {spec:?} is the single-scenario mode, not a generator list");
    }
    if spec == "mixed" {
        if n_users == 0 {
            bail!("spec \"mixed\" requests zero users (set --users or use @NxE entries)");
        }
        let mean_degree = default_mean_degree(n_users, n_assocs);
        return Ok(vec![
            ScenarioSpec::new(ScenarioKind::UniformRandom, n_users, n_assocs),
            ScenarioSpec::new(
                ScenarioKind::PreferentialAttachment { mean_degree },
                n_users,
                n_assocs,
            ),
            ScenarioSpec::new(
                ScenarioKind::Clustered { communities: 4, p_inter: 0.05 },
                n_users,
                n_assocs,
            ),
            ScenarioSpec::new(ScenarioKind::Hotspot { hotspots: 2 }, n_users, n_assocs),
        ]);
    }
    spec.split(',')
        .map(|entry| parse_entry(entry.trim(), n_users, n_assocs))
        .collect()
}

fn default_mean_degree(n_users: usize, n_assocs: usize) -> usize {
    ((2 * n_assocs) / n_users.max(1)).max(1)
}

fn parse_entry(entry: &str, n_users: usize, n_assocs: usize) -> crate::Result<ScenarioSpec> {
    // kind[:param][@users x assocs]
    let (head, size) = match entry.split_once('@') {
        Some((h, s)) => (h, Some(s)),
        None => (entry, None),
    };
    let (n_users, n_assocs) = match size {
        None => (n_users, n_assocs),
        Some(s) => {
            let (u, a) = s
                .split_once('x')
                .with_context(|| format!("size {s:?} in {entry:?} wants USERSxASSOCS"))?;
            (
                u.trim().parse().with_context(|| format!("bad user count in {entry:?}"))?,
                a.trim().parse().with_context(|| format!("bad assoc count in {entry:?}"))?,
            )
        }
    };
    if n_users == 0 {
        bail!("entry {entry:?} requests zero users");
    }
    let (kind, param) = match head.split_once(':') {
        Some((k, p)) => (k.trim(), Some(p.trim())),
        None => (head.trim(), None),
    };
    let parse_param = |default: usize| -> crate::Result<usize> {
        match param {
            None => Ok(default),
            Some(p) => p.parse().with_context(|| format!("bad parameter in {entry:?}")),
        }
    };
    let kind = match kind {
        "uniform" => {
            if param.is_some() {
                bail!("uniform takes no parameter (got {entry:?})");
            }
            ScenarioKind::UniformRandom
        }
        "pa" => ScenarioKind::PreferentialAttachment {
            mean_degree: parse_param(default_mean_degree(n_users, n_assocs))?.max(1),
        },
        "clustered" => ScenarioKind::Clustered {
            communities: parse_param(4)?.max(1),
            p_inter: 0.05,
        },
        "hotspot" => ScenarioKind::Hotspot { hotspots: parse_param(2)?.max(1) },
        other => bail!("unknown scenario kind {other:?} (want uniform|pa|clustered|hotspot)"),
    };
    Ok(ScenarioSpec::new(kind, n_users, n_assocs))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixed_expands_to_all_four_kinds() {
        let specs = parse_spec_list("mixed", 100, 300).unwrap();
        let names: Vec<&str> = specs.iter().map(|s| s.kind.name()).collect();
        assert_eq!(names, vec!["uniform", "pa", "clustered", "hotspot"]);
        assert!(specs.iter().all(|s| s.n_users == 100 && s.n_assocs == 300));
    }

    #[test]
    fn entries_parse_params_and_sizes() {
        let specs = parse_spec_list("pa:8,clustered:5@60x120,hotspot", 100, 300).unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].kind, ScenarioKind::PreferentialAttachment { mean_degree: 8 });
        assert_eq!(specs[0].n_users, 100);
        assert!(matches!(specs[1].kind, ScenarioKind::Clustered { communities: 5, .. }));
        assert_eq!((specs[1].n_users, specs[1].n_assocs), (60, 120));
        assert_eq!(specs[2].kind, ScenarioKind::Hotspot { hotspots: 2 });
    }

    #[test]
    fn bad_entries_are_rejected() {
        assert!(parse_spec_list("", 10, 20).is_err());
        assert!(parse_spec_list("replicate", 10, 20).is_err());
        assert!(parse_spec_list("mixed", 0, 20).is_err());
        assert!(parse_spec_list("warp-drive", 10, 20).is_err());
        assert!(parse_spec_list("uniform:3", 10, 20).is_err());
        assert!(parse_spec_list("pa:x", 10, 20).is_err());
        assert!(parse_spec_list("pa@0x5", 10, 20).is_err());
        assert!(parse_spec_list("pa@12", 10, 20).is_err());
    }

    #[test]
    fn set_generation_splits_and_cycles() {
        let params = SystemParams::default();
        let specs = parse_spec_list("uniform@40x80,pa:4@30x60", 0, 0).unwrap();
        let set = ScenarioSet::generate(&specs, &params, 5, 2, 99);
        assert_eq!(set.len(), 7);
        assert_eq!(set.train, vec![0, 1, 2, 3, 4]);
        assert_eq!(set.eval, vec![5, 6]);
        // Specs cycle across the whole set: even indices uniform (40
        // users), odd ones PA (30 users).
        for (i, sc) in set.scenarios.iter().enumerate() {
            let want = if i % 2 == 0 { 40 } else { 30 };
            assert_eq!(sc.n_users(), want, "scenario {i}");
        }
        // Round-robin slot assignment wraps.
        assert_eq!(set.train_scenario(5).n_users(), set.train_scenario(0).n_users());
        assert_eq!(set.eval_scenarios().count(), 2);
    }

    #[test]
    fn from_spec_is_deterministic_in_the_seed() {
        let params = SystemParams::default();
        let a = ScenarioSet::from_spec("mixed", 60, 150, &params, 4, 7).unwrap();
        let b = ScenarioSet::from_spec("mixed", 60, 150, &params, 4, 7).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
            assert_eq!(x.fingerprint(), y.fingerprint());
        }
        // Distinct slots hold distinct scenarios (different generators
        // and independent streams).
        assert_ne!(a.scenarios[0].fingerprint(), a.scenarios[1].fingerprint());
    }
}
