//! Scenario generation: the new-workload axis of the north star.
//!
//! The paper trains DRLGO on *one* sampled scenario per run, which
//! leaves the policy blind to topologies it never saw — yet §5's claim
//! of "good effectiveness and dynamic adaptation … even in dynamic
//! scenarios" is exactly a claim about unseen user topologies.  This
//! module turns the scenario into a first-class, generatable object:
//!
//! * [`ScenarioSpec`] describes one edge-computing scenario — user
//!   count, association budget and a [`ScenarioKind`] from the
//!   generator family:
//!   - **uniform** — uniform-random associations, uniform positions
//!     (the Fig. 6 random-graph setting);
//!   - **pa** — preferential attachment (promoted from
//!     [`crate::graph::generate::preferential_attachment`]), the
//!     heavy-tailed citation-shaped topology;
//!   - **clustered** — planted community structure (most associations
//!     intra-community, communities spatially co-located), the regime
//!     HiCut is built to exploit;
//!   - **hotspot** — users concentrated around a few servers with a
//!     skewed weight profile, the load-imbalance regime where capacity
//!     redirects dominate.
//! * [`ScenarioSpec::generate`] materializes a [`Scenario`]: the user
//!   graph, user positions, per-scenario [`EdgeNetwork`] (server CPU
//!   rates and capacities), [`UserLinks`] bandwidth draws and task
//!   sizes.  Generation is **deterministic from a forked RNG stream**:
//!   every internal stage (topology, network, positions, links) draws
//!   from its own [`Rng::fork`] of the caller's stream, so a spec plus
//!   a seed pins the scenario bit for bit — the property
//!   `tests/properties.rs` checks via [`Scenario::fingerprint`].
//! * [`ScenarioSet`] (see [`set`]) samples a family of scenarios from
//!   a spec list with a train/eval split, the unit
//!   [`crate::drl::vec_env::VecEnv::from_scenario_set`] builds
//!   per-slot environments from.
//!
//! # The padding/masking contract
//!
//! Heterogeneous slots do **not** change the training batch shape.
//! The global state is per-*agent*, not per-user (Eq. 19): every slot
//! contributes one `M × OBS` row block to the `E × M × OBS` matrix,
//! and M — the server count — is fixed by [`SystemParams`] across the
//! whole set ([`crate::drl::vec_env::VecEnv`] asserts it).  Per-slot
//! user counts therefore never need padded observation rows; they
//! surface only as
//!
//! * different *episode lengths* (a 100-user slot finishes its
//!   offloading round before a 300-user slot), which the vector's
//!   auto-reset absorbs — a finished slot starts its next episode
//!   while its siblings keep stepping, so no batch row is ever masked
//!   out or stale; and
//! * per-slot normalization: observation features that divide by N
//!   (obs\[4\], obs\[7\], obs\[14\]) use the *slot's own* user count,
//!   so a small scenario's features occupy the same ~\[0, 1\] range as
//!   a large one's.
//!
//! In short: rows are per-server and servers are shared, so the
//! "padding" is the identity and the "mask" is the auto-reset.

pub mod set;

pub use set::{parse_spec_list, ScenarioSet};

use crate::graph::dynamic::Pos;
use crate::graph::generate::{preferential_attachment, uniform_random};
use crate::graph::Graph;
use crate::net::params::SystemParams;
use crate::net::topology::{EdgeNetwork, UserLinks};
use crate::util::rng::Rng;

/// Which generator of the family produces the user topology and the
/// position layout (see the module docs for the regimes).
#[derive(Clone, Debug, PartialEq)]
pub enum ScenarioKind {
    /// Uniform-random associations, uniform positions.
    UniformRandom,
    /// Preferential attachment with this mean degree; heavy-tailed.
    PreferentialAttachment { mean_degree: usize },
    /// Planted communities: `1 - p_inter` of the associations stay
    /// intra-community and communities cluster spatially.
    Clustered { communities: usize, p_inter: f64 },
    /// Users concentrated around `hotspots` servers with Zipf-skewed
    /// weights — the skewed-server-load regime.
    Hotspot { hotspots: usize },
}

impl ScenarioKind {
    /// Short name used in spec strings and bench labels.
    pub fn name(&self) -> &'static str {
        match self {
            ScenarioKind::UniformRandom => "uniform",
            ScenarioKind::PreferentialAttachment { .. } => "pa",
            ScenarioKind::Clustered { .. } => "clustered",
            ScenarioKind::Hotspot { .. } => "hotspot",
        }
    }
}

/// Declarative description of one scenario (what to generate).
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    pub kind: ScenarioKind,
    pub n_users: usize,
    /// Association budget.  The uniform and clustered generators hit
    /// it exactly (capped by the complete graph); the PA-based kinds
    /// (`pa`, `hotspot`) treat it as a target via their mean degree
    /// and typically land slightly under it.
    pub n_assocs: usize,
    /// Feature dimensionality backing task sizes and GNN layer dims
    /// (the paper maps one feature dimension to 1 kb, §6.1).
    pub feat_dim: usize,
    pub classes: usize,
}

impl ScenarioSpec {
    /// Spec with the default GNN shape (500-dim features, 3 classes —
    /// the cost-model defaults used across the test suite).
    pub fn new(kind: ScenarioKind, n_users: usize, n_assocs: usize) -> Self {
        ScenarioSpec { kind, n_users, n_assocs, feat_dim: 500, classes: 3 }
    }

    /// Task data size in Mbit per user (1 kb per feature dimension,
    /// capped at 1500 dims — mirrors [`crate::graph::geb::Dataset`]).
    pub fn task_mbit(&self) -> f64 {
        (self.feat_dim.min(1500) as f64) * 1.0e3 / 1.0e6
    }

    /// GNN layer dimensions for the cost model (Eqs. 10–11).
    pub fn layer_dims(&self) -> Vec<usize> {
        vec![self.feat_dim.min(1500), 64, self.classes]
    }

    /// Materialize the scenario.  Deterministic in (`self`, `params`,
    /// the state of `rng`): each stage draws from its own fork of
    /// `rng`, in a fixed order, so the result is bit-reproducible and
    /// independent of how the caller schedules the work.
    pub fn generate(&self, params: &SystemParams, rng: &mut Rng) -> Scenario {
        assert!(self.n_users >= 1, "a scenario needs at least one user");
        let mut topo_rng = rng.fork();
        let mut net_rng = rng.fork();
        let mut pos_rng = rng.fork();
        let mut link_rng = rng.fork();

        let n = self.n_users;
        let max_edges = if n < 2 { 0 } else { n * (n - 1) / 2 };
        let edges = self.n_assocs.min(max_edges);
        let net = EdgeNetwork::build(params, n, &mut net_rng);
        let (graph, positions) = match &self.kind {
            ScenarioKind::UniformRandom => (
                uniform_random(n, edges, &mut topo_rng),
                uniform_positions(n, params.plane_m, &mut pos_rng),
            ),
            ScenarioKind::PreferentialAttachment { mean_degree } => (
                preferential_attachment(n, *mean_degree, &mut topo_rng),
                uniform_positions(n, params.plane_m, &mut pos_rng),
            ),
            ScenarioKind::Clustered { communities, p_inter } => {
                let k = (*communities).clamp(1, n);
                let graph = clustered_graph(n, edges, k, *p_inter, &mut topo_rng);
                let positions = clustered_positions(n, k, params.plane_m, &mut pos_rng);
                (graph, positions)
            }
            ScenarioKind::Hotspot { hotspots } => {
                let mean_degree = ((2 * edges) / n.max(1)).max(1);
                let graph = preferential_attachment(n, mean_degree, &mut topo_rng);
                let positions =
                    hotspot_positions(n, &net, (*hotspots).max(1), params.plane_m, &mut pos_rng);
                (graph, positions)
            }
        };
        let links = UserLinks::draw(params, n, net.len(), &mut link_rng);
        Scenario {
            spec: self.clone(),
            params: params.clone(),
            graph,
            positions,
            net,
            links,
            task_mb: vec![self.task_mbit(); n],
            layer_dims: self.layer_dims(),
        }
    }
}

/// One materialized EC scenario: everything an environment needs that
/// is *scenario-specific* — graph, positions, per-scenario server
/// draws, link draws, task sizes and GNN shape.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub spec: ScenarioSpec,
    pub params: SystemParams,
    pub graph: Graph,
    pub positions: Vec<Pos>,
    pub net: EdgeNetwork,
    pub links: UserLinks,
    pub task_mb: Vec<f64>,
    pub layer_dims: Vec<usize>,
}

impl Scenario {
    pub fn n_users(&self) -> usize {
        self.graph.len()
    }

    /// FNV-1a digest over every generated field (topology, position
    /// bits, network draws, link draws, task sizes, layer dims).  Two
    /// scenarios with equal fingerprints are bit-identical for every
    /// purpose the environment has — the determinism property in
    /// `tests/properties.rs` is stated through this.
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv::new();
        h.word(self.n_users() as u64);
        for (u, v) in self.graph.edge_list() {
            h.word((u as u64) << 32 | v as u64);
        }
        for p in &self.positions {
            h.word(p.x.to_bits());
            h.word(p.y.to_bits());
        }
        for s in &self.net.servers {
            h.word(s.pos.x.to_bits());
            h.word(s.pos.y.to_bits());
            h.word(s.f_hz.to_bits());
            h.word(s.p_w.to_bits());
            h.word(s.capacity as u64);
        }
        for row in &self.links.bw_hz {
            for bw in row {
                h.word(bw.to_bits());
            }
        }
        for p in &self.links.p_w {
            h.word(p.to_bits());
        }
        for t in &self.task_mb {
            h.word(t.to_bits());
        }
        for &d in &self.layer_dims {
            h.word(d as u64);
        }
        h.finish()
    }
}

/// Minimal FNV-1a accumulator (no hashing crates offline).
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xCBF2_9CE4_8422_2325)
    }

    fn word(&mut self, w: u64) {
        for byte in w.to_le_bytes() {
            self.0 ^= byte as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

fn uniform_positions(n: usize, plane_m: f64, rng: &mut Rng) -> Vec<Pos> {
    (0..n)
        .map(|_| Pos { x: rng.range_f64(0.0, plane_m), y: rng.range_f64(0.0, plane_m) })
        .collect()
}

/// Contiguous community blocks: vertex `v` belongs to the community
/// whose block `[starts[c], starts[c+1])` contains it.
fn community_starts(n: usize, k: usize) -> Vec<usize> {
    (0..=k).map(|c| c * n / k).collect()
}

/// Planted-partition topology: `1 - p_inter` of the `edges` budget
/// drawn inside contiguous community blocks, the rest across blocks,
/// topped up with arbitrary pairs if either pool stalls (tiny or
/// near-complete communities).
fn clustered_graph(n: usize, edges: usize, k: usize, p_inter: f64, rng: &mut Rng) -> Graph {
    let starts = community_starts(n, k);
    let mut g = Graph::new(n);
    let inter_target = ((edges as f64) * p_inter.clamp(0.0, 1.0)).round() as usize;
    let intra_target = edges.saturating_sub(inter_target);
    // Intra-community associations.
    let mut got = 0usize;
    let mut tries = 0usize;
    while got < intra_target && tries < 60 * intra_target.max(1) {
        tries += 1;
        let u = rng.below(n);
        let c = starts.partition_point(|&s| s <= u) - 1;
        let (lo, hi) = (starts[c], starts[c + 1]);
        if hi - lo < 2 {
            continue;
        }
        let v = rng.range(lo, hi);
        if u != v && g.add_edge(u, v) {
            got += 1;
        }
    }
    // Inter-community associations.
    let mut got = 0usize;
    let mut tries = 0usize;
    while got < inter_target && tries < 60 * inter_target.max(1) && k >= 2 {
        tries += 1;
        let u = rng.below(n);
        let v = rng.below(n);
        let cu = starts.partition_point(|&s| s <= u) - 1;
        let cv = starts.partition_point(|&s| s <= v) - 1;
        if cu != cv && g.add_edge(u, v) {
            got += 1;
        }
    }
    // Top up with arbitrary pairs so the edge budget is exact even
    // when a pool saturated (e.g. complete communities).
    let mut tries = 0usize;
    while g.num_edges() < edges && tries < 60 * edges.max(1) {
        tries += 1;
        let u = rng.below(n);
        let v = rng.below(n);
        if u != v {
            g.add_edge(u, v);
        }
    }
    // Deterministic completion: at near-complete densities the
    // rejection top-up is a coupon collector and can exhaust its try
    // budget short of the target — enumerate the remaining non-edges
    // instead of silently under-delivering (the caller capped `edges`
    // at the complete graph, so this always reaches the budget).
    if g.num_edges() < edges {
        'fill: for u in 0..n {
            for v in (u + 1)..n {
                if g.num_edges() >= edges {
                    break 'fill;
                }
                g.add_edge(u, v);
            }
        }
    }
    g
}

/// Communities co-located on the plane: one uniform center per
/// community, members uniform in a square around it (clamped).
fn clustered_positions(n: usize, k: usize, plane_m: f64, rng: &mut Rng) -> Vec<Pos> {
    let starts = community_starts(n, k);
    let spread = plane_m / (k as f64).sqrt().max(1.0) / 2.0;
    let mut pos = vec![Pos { x: 0.0, y: 0.0 }; n];
    for c in 0..k {
        let center = Pos { x: rng.range_f64(0.0, plane_m), y: rng.range_f64(0.0, plane_m) };
        for p in &mut pos[starts[c]..starts[c + 1]] {
            *p = Pos {
                x: (center.x + rng.range_f64(-spread, spread)).clamp(0.0, plane_m),
                y: (center.y + rng.range_f64(-spread, spread)).clamp(0.0, plane_m),
            };
        }
    }
    pos
}

/// Users piled around `hotspots` servers with Zipf-ish weights
/// (hotspot `i` draws ∝ 1/(i+1)), a tight spread around each anchor —
/// the skewed-server-load regime.
fn hotspot_positions(
    n: usize,
    net: &EdgeNetwork,
    hotspots: usize,
    plane_m: f64,
    rng: &mut Rng,
) -> Vec<Pos> {
    let anchors: Vec<Pos> = net
        .servers
        .iter()
        .take(hotspots.min(net.len()).max(1))
        .map(|s| s.pos)
        .collect();
    let weights: Vec<f64> = (0..anchors.len()).map(|i| 1.0 / (i + 1) as f64).collect();
    let total: f64 = weights.iter().sum();
    let spread = plane_m * 0.08;
    (0..n)
        .map(|_| {
            let mut pick = rng.range_f64(0.0, total);
            let mut a = anchors.len() - 1;
            for (i, w) in weights.iter().enumerate() {
                if pick < *w {
                    a = i;
                    break;
                }
                pick -= w;
            }
            Pos {
                x: (anchors[a].x + rng.range_f64(-spread, spread)).clamp(0.0, plane_m),
                y: (anchors[a].y + rng.range_f64(-spread, spread)).clamp(0.0, plane_m),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(kind: ScenarioKind) -> ScenarioSpec {
        ScenarioSpec::new(kind, 120, 400)
    }

    #[test]
    fn every_kind_generates_with_exact_shape() {
        let params = SystemParams::default();
        for kind in [
            ScenarioKind::UniformRandom,
            ScenarioKind::PreferentialAttachment { mean_degree: 6 },
            ScenarioKind::Clustered { communities: 4, p_inter: 0.05 },
            ScenarioKind::Hotspot { hotspots: 2 },
        ] {
            let mut rng = Rng::seed_from(11);
            let sc = spec(kind.clone()).generate(&params, &mut rng);
            assert_eq!(sc.n_users(), 120, "{}", kind.name());
            assert_eq!(sc.positions.len(), 120);
            assert_eq!(sc.task_mb.len(), 120);
            assert_eq!(sc.net.len(), params.servers);
            assert_eq!(sc.links.bw_hz.len(), 120);
            assert!(sc.graph.num_edges() > 0, "{} generated no edges", kind.name());
            for p in &sc.positions {
                assert!((0.0..=params.plane_m).contains(&p.x));
                assert!((0.0..=params.plane_m).contains(&p.y));
            }
        }
    }

    #[test]
    fn uniform_and_clustered_hit_the_assoc_budget_exactly() {
        let params = SystemParams::default();
        for kind in [
            ScenarioKind::UniformRandom,
            ScenarioKind::Clustered { communities: 4, p_inter: 0.05 },
        ] {
            let mut rng = Rng::seed_from(13);
            let sc = spec(kind).generate(&params, &mut rng);
            assert_eq!(sc.graph.num_edges(), 400);
        }
    }

    #[test]
    fn clustered_budget_exact_even_at_complete_density() {
        // The rejection top-up stalls near full density; the
        // deterministic completion must still deliver the exact
        // budget, up to and including the complete graph.
        let params = SystemParams::default();
        let max_edges = 40 * 39 / 2;
        for edges in [max_edges, max_edges - 3] {
            let spec = ScenarioSpec::new(
                ScenarioKind::Clustered { communities: 2, p_inter: 0.05 },
                40,
                edges,
            );
            let sc = spec.generate(&params, &mut Rng::seed_from(51));
            assert_eq!(sc.graph.num_edges(), edges);
        }
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let params = SystemParams::default();
        let s = spec(ScenarioKind::Clustered { communities: 5, p_inter: 0.1 });
        let a = s.generate(&params, &mut Rng::seed_from(77));
        let b = s.generate(&params, &mut Rng::seed_from(77));
        assert_eq!(a.fingerprint(), b.fingerprint());
        let c = s.generate(&params, &mut Rng::seed_from(78));
        assert_ne!(a.fingerprint(), c.fingerprint(), "seeds must diverge");
    }

    #[test]
    fn clustered_associations_are_mostly_intra_community() {
        let params = SystemParams::default();
        let s = spec(ScenarioKind::Clustered { communities: 4, p_inter: 0.05 });
        let mut rng = Rng::seed_from(21);
        let sc = s.generate(&params, &mut rng);
        let starts = community_starts(120, 4);
        let comm = |v: usize| starts.partition_point(|&s| s <= v) - 1;
        let inter = sc
            .graph
            .edge_list()
            .iter()
            .filter(|&&(u, v)| comm(u as usize) != comm(v as usize))
            .count();
        // 5% target with top-up slack: anything under 20% is clearly
        // community structure (uniform would sit near 75%).
        assert!(
            inter * 5 < sc.graph.num_edges(),
            "{inter}/{} inter-community edges",
            sc.graph.num_edges()
        );
    }

    #[test]
    fn hotspot_positions_skew_toward_the_first_server() {
        let params = SystemParams::default();
        let s = spec(ScenarioKind::Hotspot { hotspots: 2 });
        let mut rng = Rng::seed_from(31);
        let sc = s.generate(&params, &mut rng);
        // Every user sits near one of the two anchors, and the first
        // anchor (weight 1) attracts more than the second (weight 1/2).
        let (a0, a1) = (sc.net.servers[0].pos, sc.net.servers[1].pos);
        let spread = params.plane_m * 0.08;
        let near = |p: &Pos, a: Pos| (p.x - a.x).abs() <= spread && (p.y - a.y).abs() <= spread;
        let n0 = sc.positions.iter().filter(|p| near(p, a0)).count();
        let n1 = sc.positions.iter().filter(|p| near(p, a1)).count();
        assert_eq!(n0 + n1, 120, "positions strayed from the hotspots");
        assert!(n0 > n1, "skew inverted: {n0} vs {n1}");
    }

    #[test]
    fn tiny_scenarios_generate_without_panic() {
        let params = SystemParams::default();
        for kind in [
            ScenarioKind::UniformRandom,
            ScenarioKind::PreferentialAttachment { mean_degree: 4 },
            ScenarioKind::Clustered { communities: 8, p_inter: 0.2 },
            ScenarioKind::Hotspot { hotspots: 99 },
        ] {
            for n in [1usize, 2, 3] {
                let mut rng = Rng::seed_from(41);
                let sc = ScenarioSpec::new(kind.clone(), n, 10).generate(&params, &mut rng);
                assert_eq!(sc.n_users(), n);
            }
        }
    }
}
