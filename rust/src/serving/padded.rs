//! Fixed-shape padded subgraphs — the input contract of the AOT GNN
//! executables (see python/compile/model.py for the Python mirror).

use crate::graph::{Dataset, Graph};
use crate::tensor::Matrix;

/// A padded subgraph ready for inference.
#[derive(Clone, Debug)]
pub struct PaddedGraph {
    /// Scenario-user index of each occupied row (len = real size ≤ n_max).
    pub vertices: Vec<usize>,
    /// Dense features [n_max, feat_pad].
    pub x: Matrix,
    /// 0/1 adjacency with self-loops on occupied rows [n_max, n_max].
    pub adj: Matrix,
    /// D^-1/2 (A+I) D^-1/2 [n_max, n_max].
    pub a_norm: Matrix,
    /// 1/deg per row [n_max, 1] (0 on padding).
    pub inv_deg: Matrix,
}

impl PaddedGraph {
    /// Build from the scenario graph restricted to `vertices` (scenario
    /// user ids, at most `n_max`); features come from the dataset
    /// vertices backing each user (`users_backing[i]` = dataset vertex
    /// of scenario user i).  Errs when `vertices` exceeds `n_max` or
    /// names a user outside `users_backing`.
    // analyze:allow(panic) — `deg` is a local Vec of len n_max and every index into it is r/c < k ≤ n_max, checked at entry.
    pub fn build(
        scenario_graph: &Graph,
        users_backing: &[u32],
        dataset: &Dataset,
        vertices: &[usize],
        n_max: usize,
        feat_pad: usize,
    ) -> crate::Result<Self> {
        if vertices.len() > n_max {
            anyhow::bail!("{} vertices > n_max {}", vertices.len(), n_max);
        }
        let k = vertices.len();
        let index: std::collections::HashMap<usize, usize> =
            vertices.iter().enumerate().map(|(i, &v)| (v, i)).collect();

        let mut x = Matrix::zeros(n_max, feat_pad);
        for (row, &v) in vertices.iter().enumerate() {
            let backing = *users_backing.get(v).ok_or_else(|| {
                anyhow::anyhow!("vertex {v} outside users_backing (len {})", users_backing.len())
            })?;
            dataset.write_dense_row(backing as usize, x.row_mut(row));
        }

        let mut adj = Matrix::zeros(n_max, n_max);
        for (row, &v) in vertices.iter().enumerate() {
            adj.set(row, row, 1.0); // self loop
            for &nb in scenario_graph.neighbors(v) {
                if let Some(&col) = index.get(&(nb as usize)) {
                    adj.set(row, col, 1.0);
                    adj.set(col, row, 1.0);
                }
            }
        }

        // Symmetric normalization + inverse degree.
        let mut deg = vec![0.0f32; n_max];
        for r in 0..k {
            deg[r] = adj.row(r).iter().sum();
        }
        let mut a_norm = Matrix::zeros(n_max, n_max);
        for r in 0..k {
            let dr = deg[r];
            if dr <= 0.0 {
                continue;
            }
            for c in 0..k {
                let v = adj.at(r, c);
                if v != 0.0 && deg[c] > 0.0 {
                    a_norm.set(r, c, v / (dr.sqrt() * deg[c].sqrt()));
                }
            }
        }
        let mut inv_deg = Matrix::zeros(n_max, 1);
        for r in 0..k {
            if deg[r] > 0.0 {
                inv_deg.set(r, 0, 1.0 / deg[r]);
            }
        }
        Ok(PaddedGraph { vertices: vertices.to_vec(), x, adj, a_norm, inv_deg })
    }

    pub fn real_size(&self) -> usize {
        self.vertices.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn tiny_dataset() -> Dataset {
        // 4 docs, 8-dim features, doc i has feature {i, i+4}.
        Dataset {
            name: "t".into(),
            n: 4,
            e: 0,
            feat_dim: 8,
            classes: 2,
            labels: vec![0, 1, 0, 1],
            feat_ptr: vec![0, 2, 4, 6, 8],
            feat_idx: vec![0, 4, 1, 5, 2, 6, 3, 7],
            graph: Graph::new(4),
        }
    }

    #[test]
    fn build_padded_shapes_and_padding() {
        let ds = tiny_dataset();
        let g = Graph::from_edges(4, &[(0, 1), (1, 2)]);
        let backing: Vec<u32> = vec![0, 1, 2, 3];
        let p = PaddedGraph::build(&g, &backing, &ds, &[0, 1, 2], 8, 16).expect("build");
        assert_eq!(p.real_size(), 3);
        assert_eq!(p.x.rows, 8);
        assert_eq!(p.x.cols, 16);
        // Padding rows all zero.
        for r in 3..8 {
            assert!(p.x.row(r).iter().all(|&v| v == 0.0));
            assert!(p.adj.row(r).iter().all(|&v| v == 0.0));
            assert_eq!(p.inv_deg.at(r, 0), 0.0);
        }
    }

    #[test]
    fn adjacency_has_self_loops_and_symmetry() {
        let ds = tiny_dataset();
        let g = Graph::from_edges(4, &[(0, 2), (2, 3)]);
        let p = PaddedGraph::build(&g, &[0, 1, 2, 3], &ds, &[0, 2, 3], 8, 16).expect("build");
        // rows: 0->u0, 1->u2, 2->u3
        assert_eq!(p.adj.at(0, 0), 1.0);
        assert_eq!(p.adj.at(0, 1), 1.0); // u0-u2
        assert_eq!(p.adj.at(1, 0), 1.0);
        assert_eq!(p.adj.at(1, 2), 1.0); // u2-u3
        assert_eq!(p.adj.at(0, 2), 0.0); // u0-u3 absent
    }

    #[test]
    fn a_norm_rows_match_manual() {
        let ds = tiny_dataset();
        let g = Graph::from_edges(2, &[(0, 1)]);
        let p = PaddedGraph::build(&g, &[0, 1], &ds, &[0, 1], 4, 16).expect("build");
        // Both vertices: degree 2 (self + edge): a_norm = 1/2 everywhere.
        for r in 0..2 {
            for c in 0..2 {
                assert!((p.a_norm.at(r, c) - 0.5).abs() < 1e-6);
            }
        }
        assert!((p.inv_deg.at(0, 0) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn oversized_or_unbacked_vertex_sets_err() {
        let ds = tiny_dataset();
        let g = Graph::from_edges(4, &[(0, 1)]);
        // More vertices than n_max.
        assert!(PaddedGraph::build(&g, &[0, 1, 2, 3], &ds, &[0, 1, 2], 2, 16).is_err());
        // Vertex id with no backing entry.
        assert!(PaddedGraph::build(&g, &[0, 1], &ds, &[0, 3], 4, 16).is_err());
    }

    #[test]
    fn excluded_neighbors_do_not_appear() {
        let ds = tiny_dataset();
        let g = Graph::from_edges(4, &[(0, 1), (0, 3)]);
        let p = PaddedGraph::build(&g, &[0, 1, 2, 3], &ds, &[0, 1], 4, 16).expect("build");
        // User 3 not in subgraph: its edge to 0 must not appear anywhere.
        assert_eq!(p.adj.row(0).iter().filter(|&&v| v > 0.0).count(), 2);
    }
}
