//! Online request router + dynamic batcher.
//!
//! The offline experiments evaluate whole scenarios at once; the
//! serving example instead emulates the production path: user requests
//! arrive one at a time, the router places each according to the
//! current offloading policy, and per-server batches are dispatched
//! when either `max_batch` tasks are queued or `max_wait` elapses —
//! the standard dynamic-batching loop of GNN serving systems.

use std::time::{Duration, Instant};

use crate::net::cost::{Offload, UNASSIGNED};
use crate::util::trace;
use crate::util::version::Version;

/// `reason` field values of the `router.batch_close` trace event.
pub const CLOSE_FULL: f64 = 0.0;
/// Batch shipped because its `max_wait` window expired.
pub const CLOSE_TIMEOUT: f64 = 1.0;
/// Batch shipped by a force-[`Router::flush`].
pub const CLOSE_FLUSH: f64 = 2.0;

/// One enqueued inference request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    /// Scenario user id.
    pub user: usize,
    pub enqueued: Instant,
}

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(5) }
    }
}

/// Router state: a queue per server, plus the open batch window.
///
/// `deadlines[s]` is when server `s`'s *forming batch* opened: set by
/// the first [`Router::submit`] into a windowless server, re-anchored
/// to the residue's oldest request when [`Router::ready_batches`]
/// drains full batches, and cleared whenever the queue empties.  The
/// timeout test reads this anchor — which makes clearing it on
/// [`Router::flush`] mandatory (see the regression note there).
/// Invariant: `deadlines[s]` is `Some(q[0].enqueued)` exactly while
/// queue `s` is non-empty.
pub struct Router {
    queues: Vec<Vec<Request>>,
    /// Per-server batch deadline anchor: when the oldest queued
    /// request arrived (`None` = empty queue, no window open).
    deadlines: Vec<Option<Instant>>,
    /// Params version the queued placements and deadline anchors were
    /// built under (see [`crate::util::version`]); `None` until the
    /// first [`Router::revalidate`].  Queued requests embed offload
    /// decisions priced by a [`crate::net::cost::CostModel`] — if the
    /// system params they were priced under are superseded, holding
    /// them to their old windows serves stale placements.
    valid_for: Option<Version>,
    policy: BatchPolicy,
    pub dispatched_batches: usize,
    pub dispatched_requests: usize,
}

impl Router {
    pub fn new(servers: usize, policy: BatchPolicy) -> Self {
        // A zero max_batch (e.g. GRAPHEDGE_MAX_BATCH=0) would make the
        // batch-draining loops spin forever (`drain(..0)` removes
        // nothing); clamp to 1.
        let policy = BatchPolicy { max_batch: policy.max_batch.max(1), ..policy };
        Router {
            queues: vec![Vec::new(); servers],
            deadlines: vec![None; servers],
            valid_for: None,
            policy,
            dispatched_batches: 0,
            dispatched_requests: 0,
        }
    }

    /// Validate the cached deadlines (and the queued placements they
    /// anchor) against the serving environment's params version.  A
    /// mismatch force-flushes every queue — the drained batches are
    /// returned so in-flight requests are served (under their old
    /// placements) rather than dropped — and every deadline anchor is
    /// cleared, so post-revalidate submits open fresh `max_wait`
    /// windows.  The first call adopts `params` without flushing;
    /// calling with an unchanged version is a no-op.  The serve loop
    /// invokes this once per tick.
    pub fn revalidate(&mut self, params: Version) -> Vec<(usize, Vec<usize>)> {
        match self.valid_for {
            Some(v) if v == params => Vec::new(),
            Some(_) => {
                self.valid_for = Some(params);
                self.flush()
            }
            None => {
                self.valid_for = Some(params);
                Vec::new()
            }
        }
    }

    /// Route a request according to the offloading decision; returns
    /// the chosen server.  The first request into an empty queue opens
    /// that server's `max_wait` window.  Users the policy does not
    /// cover, or placements onto servers this router was not sized
    /// for (an offload built for a different fleet), are declined
    /// rather than routed.
    pub fn submit(&mut self, user: usize, offload: &Offload, now: Instant) -> Option<usize> {
        let server = match offload.server.get(user) {
            Some(&s) if s != UNASSIGNED => s,
            _ => return None,
        };
        let (Some(queue), Some(deadline)) =
            (self.queues.get_mut(server), self.deadlines.get_mut(server))
        else {
            return None;
        };
        if deadline.is_none() {
            *deadline = Some(now);
        }
        queue.push(Request { user, enqueued: now });
        trace::instant(
            "router.enqueue",
            &[
                ("user", user as f64),
                ("server", server as f64),
                ("depth", queue.len() as f64),
            ],
        );
        Some(server)
    }

    /// Queue depth of `server` (0 for servers this router has no
    /// queue for).
    pub fn queue_len(&self, server: usize) -> usize {
        self.queues.get(server).map_or(0, Vec::len)
    }

    /// Collect every batch that is ready at `now` (full or timed out).
    /// Returns (server, users) pairs, draining those queues.
    ///
    /// *All* full batches are drained, not just the first: a queue
    /// holding ≥ 2·`max_batch` requests (a burst between poll points)
    /// previously shipped one batch and stranded the residue until the
    /// next timeout.  After the full batches, any remainder whose
    /// window opened more than `max_wait` ago ships too; a surviving
    /// residue re-anchors its window to its own oldest request.
    pub fn ready_batches(&mut self, now: Instant) -> Vec<(usize, Vec<usize>)> {
        let mut out = Vec::new();
        let lanes = self.queues.iter_mut().zip(self.deadlines.iter_mut());
        for (server, (q, deadline)) in lanes.enumerate() {
            let mut drained_full = false;
            while q.len() >= self.policy.max_batch {
                let batch: Vec<usize> = q.drain(..self.policy.max_batch).map(|r| r.user).collect();
                self.dispatched_batches += 1;
                self.dispatched_requests += batch.len();
                trace::instant(
                    "router.batch_close",
                    &[
                        ("server", server as f64),
                        ("size", batch.len() as f64),
                        ("reason", CLOSE_FULL),
                    ],
                );
                out.push((server, batch));
                drained_full = true;
            }
            if drained_full {
                // The residue's window starts at its own oldest request.
                *deadline = q.first().map(|r| r.enqueued);
            }
            if let Some(opened) = *deadline {
                if now.duration_since(opened) >= self.policy.max_wait {
                    let batch: Vec<usize> = q.drain(..).map(|r| r.user).collect();
                    self.dispatched_batches += 1;
                    self.dispatched_requests += batch.len();
                    trace::instant(
                        "router.batch_close",
                        &[
                            ("server", server as f64),
                            ("size", batch.len() as f64),
                            ("reason", CLOSE_TIMEOUT),
                        ],
                    );
                    out.push((server, batch));
                    *deadline = None;
                }
            }
        }
        out
    }

    /// Force-flush everything (end of run — or a layout change that
    /// invalidates queued placements).
    ///
    /// Clears every per-server batch deadline along with the queues:
    /// a post-flush `submit` must open a *fresh* `max_wait` window.
    /// (The pre-cache implementation re-derived the window from
    /// `q[0].enqueued` on every poll and so could not hold a stale
    /// anchor; with the cached deadline, every drain path — this one
    /// included — must clear it, or the next batch after a flush ships
    /// on its predecessor's aged clock at the first poll.  The
    /// `flush_clears_batch_deadlines` regression test pins exactly
    /// that contract.)
    pub fn flush(&mut self) -> Vec<(usize, Vec<usize>)> {
        let mut out = Vec::new();
        let lanes = self.queues.iter_mut().zip(self.deadlines.iter_mut());
        for (server, (q, deadline)) in lanes.enumerate() {
            while !q.is_empty() {
                let take = q.len().min(self.policy.max_batch);
                let batch: Vec<usize> = q.drain(..take).map(|r| r.user).collect();
                self.dispatched_batches += 1;
                self.dispatched_requests += batch.len();
                trace::instant(
                    "router.batch_close",
                    &[
                        ("server", server as f64),
                        ("size", batch.len() as f64),
                        ("reason", CLOSE_FLUSH),
                    ],
                );
                out.push((server, batch));
            }
            *deadline = None;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn offload_all_to(server: usize, n: usize) -> Offload {
        Offload { server: vec![server; n] }
    }

    #[test]
    fn submit_declines_out_of_range_placements() {
        let mut r = Router::new(1, BatchPolicy::default());
        let off = Offload { server: vec![0, 5] };
        let now = Instant::now();
        assert_eq!(r.submit(0, &off, now), Some(0));
        // Placement onto a server this router was not sized for.
        assert_eq!(r.submit(1, &off, now), None);
        // User outside the offload policy entirely.
        assert_eq!(r.submit(9, &off, now), None);
        assert_eq!(r.queue_len(0), 1);
        assert_eq!(r.queue_len(5), 0);
    }

    #[test]
    fn batches_dispatch_when_full() {
        let mut r = Router::new(
            2,
            BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(100) },
        );
        let off = offload_all_to(1, 10);
        let t = Instant::now();
        for u in 0..3 {
            assert_eq!(r.submit(u, &off, t), Some(1));
        }
        let batches = r.ready_batches(t);
        assert_eq!(batches, vec![(1, vec![0, 1, 2])]);
        assert_eq!(r.queue_len(1), 0);
        assert_eq!(r.dispatched_batches, 1);
    }

    #[test]
    fn batches_dispatch_on_timeout() {
        let mut r = Router::new(
            1,
            BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(1) },
        );
        let off = offload_all_to(0, 4);
        let t0 = Instant::now();
        r.submit(0, &off, t0);
        r.submit(1, &off, t0);
        assert!(r.ready_batches(t0).is_empty()); // not expired yet
        let later = t0 + Duration::from_millis(5);
        let batches = r.ready_batches(later);
        assert_eq!(batches, vec![(0, vec![0, 1])]);
    }

    #[test]
    fn burst_drains_every_full_batch() {
        // Regression: ≥ 2·max_batch queued requests used to yield one
        // batch per call, stranding the rest until the next timeout.
        let mut r = Router::new(
            2,
            BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(100) },
        );
        let off = offload_all_to(1, 16);
        let t = Instant::now();
        for u in 0..7 {
            r.submit(u, &off, t);
        }
        let batches = r.ready_batches(t);
        assert_eq!(
            batches,
            vec![(1, vec![0, 1, 2]), (1, vec![3, 4, 5])],
            "both full batches must dispatch in one poll"
        );
        // The residue (below max_batch, not timed out) stays queued.
        assert_eq!(r.queue_len(1), 1);
        assert_eq!(r.dispatched_batches, 2);
        assert_eq!(r.dispatched_requests, 6);
        // Once the residue's oldest request expires it ships too.
        let later = t + Duration::from_secs(200);
        assert_eq!(r.ready_batches(later), vec![(1, vec![6])]);
        assert_eq!(r.queue_len(1), 0);
    }

    #[test]
    fn burst_drains_full_batches_per_server() {
        let mut r = Router::new(
            2,
            BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(100) },
        );
        let mut off = Offload::empty(8);
        for u in 0..8 {
            off.server[u] = u % 2;
        }
        let t = Instant::now();
        for u in 0..8 {
            r.submit(u, &off, t);
        }
        let batches = r.ready_batches(t);
        assert_eq!(batches.len(), 4);
        assert!(batches.iter().all(|(_, b)| b.len() == 2));
        assert_eq!(r.queue_len(0), 0);
        assert_eq!(r.queue_len(1), 0);
        assert_eq!(r.dispatched_requests, 8);
    }

    #[test]
    fn unassigned_users_rejected() {
        let mut r = Router::new(1, BatchPolicy::default());
        let off = Offload::empty(3);
        assert_eq!(r.submit(0, &off, Instant::now()), None);
        assert_eq!(r.queue_len(0), 0);
    }

    #[test]
    fn zero_max_batch_is_clamped_not_an_infinite_loop() {
        // Regression: max_batch = 0 made `while q.len() >= max_batch`
        // spin forever on drain(..0).
        let mut r = Router::new(
            1,
            BatchPolicy { max_batch: 0, max_wait: Duration::from_secs(100) },
        );
        let off = offload_all_to(0, 3);
        let t = Instant::now();
        for u in 0..3 {
            r.submit(u, &off, t);
        }
        let batches = r.ready_batches(t);
        assert_eq!(batches, vec![(0, vec![0]), (0, vec![1]), (0, vec![2])]);
        assert!(r.flush().is_empty());
    }

    #[test]
    fn flush_clears_batch_deadlines() {
        // Pins the cached-deadline contract: if a force-flush left the
        // batch-window anchor behind, the first request of the *next*
        // batch would inherit a deadline already in the past and ship
        // alone on the next poll instead of waiting out a fresh
        // max_wait window.
        let max_wait = Duration::from_millis(50);
        let mut r = Router::new(1, BatchPolicy { max_batch: 100, max_wait });
        let off = offload_all_to(0, 8);
        let t0 = Instant::now();
        r.submit(0, &off, t0);
        r.submit(1, &off, t0);
        // Age the queue well past its window, then force-flush it.
        let aged = t0 + Duration::from_secs(30);
        let flushed = r.flush();
        assert_eq!(flushed, vec![(0, vec![0, 1])]);
        assert_eq!(r.queue_len(0), 0);

        // Refill after the flush: the new batch's window opens at its
        // own first request, not at the flushed batch's.
        let t1 = aged + Duration::from_secs(5);
        r.submit(2, &off, t1);
        r.submit(3, &off, t1 + Duration::from_millis(1));
        assert!(
            r.ready_batches(t1 + max_wait / 2).is_empty(),
            "post-flush batch dispatched on a stale deadline"
        );
        let batches = r.ready_batches(t1 + max_wait);
        assert_eq!(batches, vec![(0, vec![2, 3])]);
    }

    #[test]
    fn residue_window_restarts_at_its_own_oldest_request() {
        // The full-batch drain re-anchors the survivor's window: the
        // residue ships max_wait after *its* arrival, not the burst's.
        let max_wait = Duration::from_millis(50);
        let mut r = Router::new(1, BatchPolicy { max_batch: 3, max_wait });
        let off = offload_all_to(0, 8);
        let t0 = Instant::now();
        for u in 0..3 {
            r.submit(u, &off, t0);
        }
        let t1 = t0 + Duration::from_millis(40);
        r.submit(3, &off, t1);
        // Poll right after the late arrival: the full batch ships, the
        // residue's clock starts at t1.
        let batches = r.ready_batches(t1);
        assert_eq!(batches, vec![(0, vec![0, 1, 2])]);
        assert!(r.ready_batches(t1 + max_wait / 2).is_empty());
        assert_eq!(r.ready_batches(t1 + max_wait), vec![(0, vec![3])]);
    }

    #[test]
    fn revalidate_flushes_only_on_params_version_change() {
        let max_wait = Duration::from_millis(50);
        let mut r = Router::new(1, BatchPolicy { max_batch: 100, max_wait });
        let mut params = Version::ZERO;
        params.bump();
        assert!(r.revalidate(params).is_empty(), "first call only adopts");

        let off = offload_all_to(0, 8);
        let t0 = Instant::now();
        r.submit(0, &off, t0);
        r.submit(1, &off, t0);
        // Same version: nothing flushes, the open window survives.
        assert!(r.revalidate(params).is_empty());
        assert_eq!(r.queue_len(0), 2);
        assert_eq!(r.ready_batches(t0 + max_wait), vec![(0, vec![0, 1])]);

        // Bumped version: queued placements drain immediately and the
        // next batch opens a fresh window.
        r.submit(2, &off, t0);
        params.bump();
        assert_eq!(r.revalidate(params), vec![(0, vec![2])]);
        assert_eq!(r.queue_len(0), 0);
        let t1 = t0 + Duration::from_secs(10);
        r.submit(3, &off, t1);
        assert!(
            r.ready_batches(t1 + max_wait / 2).is_empty(),
            "post-revalidate batch must wait out its own window"
        );
        assert_eq!(r.ready_batches(t1 + max_wait), vec![(0, vec![3])]);
    }

    #[test]
    fn flush_drains_everything() {
        let mut r = Router::new(
            2,
            BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(100) },
        );
        let mut off = Offload::empty(5);
        for u in 0..5 {
            off.server[u] = u % 2;
        }
        let t = Instant::now();
        for u in 0..5 {
            r.submit(u, &off, t);
        }
        let batches = r.flush();
        let total: usize = batches.iter().map(|(_, b)| b.len()).sum();
        assert_eq!(total, 5);
        assert_eq!(r.dispatched_requests, 5);
        assert!(batches.iter().all(|(_, b)| b.len() <= 2));
    }
}
