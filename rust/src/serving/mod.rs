//! Serving layer: the edge-server fleet that actually executes GNN
//! inference on offloaded graph tasks.
//!
//! * [`padded`] — fixed-shape (N_MAX-padded) subgraph construction:
//!   dense features, adjacency with self-loops, symmetric
//!   normalization, inverse degrees — the four graph inputs every AOT
//!   executable binds.
//! * [`gnn`] — [`gnn::GnnService`]: one (model, dataset) executable +
//!   its pre-trained weights; classifies a padded subgraph.
//! * [`fleet`] — [`fleet::Fleet`]: per-server task queues, halo
//!   construction (2-hop neighborhoods with cross-server fetch
//!   accounting) and batched inference execution.
//! * [`router`] — request router + dynamic batcher for the online
//!   serving example: requests accumulate per server until a batch
//!   window closes, then dispatch as one padded-graph inference.

pub mod fleet;
pub mod gnn;
pub mod serve_loop;
pub mod padded;
pub mod router;

pub use fleet::{Fleet, InferenceReport};
pub use serve_loop::{
    serve_dynamic, serve_dynamic_run, serve_loop, serve_run, serve_run_with,
    serve_synthetic, serve_synthetic_run, DynamicServeStats, Placement, ServeStats,
};
pub use gnn::GnnService;
pub use padded::PaddedGraph;
