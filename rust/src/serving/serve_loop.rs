//! Online serving loop: the production-shaped path used by
//! `graphedge serve` and the e2e example.
//!
//! Requests (user task arrivals) stream in; the router places each on
//! its offloaded server, the dynamic batcher closes batches by size or
//! timeout, and every batch becomes one padded-subgraph GNN inference
//! on the fleet.  Reports per-request latency percentiles and
//! throughput.
//!
//! Both paths are traced (see [`crate::util::trace`]): each dynamic
//! step records a `serve.step` span with `serve.churn` /
//! `serve.route` children, every dispatched batch a `serve.batch`
//! span wrapping a `serve.infer` child plus a `serve.batch_complete`
//! instant, and the router contributes `router.enqueue` /
//! `router.batch_close` lifecycle events.  Latency and batch-size
//! series go through bounded [`Histogram`]s, so arbitrarily long runs
//! track percentiles in O(1) memory.
//!
//! [`serve_synthetic_run`] drives the same dynamic pipeline over a
//! *generated* scenario with a no-op model stage — no runtime
//! artifacts needed — which is what the CI trace-smoke gate runs.

use std::time::Instant;

use once_cell::sync::Lazy;

use crate::coordinator::Controller;
use crate::drl::{baselines, Env, EnvConfig, Method};
use crate::net::params::SystemParams;
use crate::serving::router::{BatchPolicy, Router};
use crate::serving::{GnnService, PaddedGraph};
use crate::util::metrics::{Counter, Gauge, Histogram, GLOBAL as METRICS};
use crate::util::rng::Rng;
use crate::util::stats::Sample;
use crate::util::trace;

static SERVE_REQUESTS: Lazy<Counter> =
    Lazy::new(|| METRICS.counter_handle("serve.requests"));
static SERVE_DYN_BATCHES: Lazy<Counter> =
    Lazy::new(|| METRICS.counter_handle("serve.dynamic.batches"));
static SERVE_LATENCY: Lazy<Histogram> =
    Lazy::new(|| METRICS.histogram_handle("serve.latency_s"));
/// Mutations the installed layout trails the live graph by, sampled
/// at two points of each dynamic step (see [`crate::util::version`]):
/// pre-maintenance (after churn, before the layout catches up — the
/// step's repair debt) and post-maintenance (0 unless maintenance
/// was skipped, i.e. the gauge going non-zero flags a stale layout
/// serving traffic).
static VERSION_LAG_PRE: Lazy<Gauge> =
    Lazy::new(|| METRICS.gauge_handle("version.lag.layout_pre_repair"));
static VERSION_LAG_POST: Lazy<Gauge> =
    Lazy::new(|| METRICS.gauge_handle("version.lag.layout"));

/// Summary of one serving run.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub requests: usize,
    pub batches: usize,
    pub total_s: f64,
    pub latency_p50_s: f64,
    pub latency_p99_s: f64,
    pub latency_p999_s: f64,
    pub mean_batch: f64,
    pub accuracy: f64,
}

/// Summary of a dynamic (churning) serving run.
#[derive(Clone, Debug)]
pub struct DynamicServeStats {
    pub steps: usize,
    pub requests: usize,
    /// Mean wall-clock of one churn + layout-maintenance step.
    pub repair_s_mean: f64,
    pub layout_steps_per_s: f64,
    /// Full HiCut runs (drift fallbacks + the initial reference when
    /// incremental; one per step otherwise).
    pub full_recuts: usize,
    pub local_recuts: usize,
    pub cut_edges_final: usize,
    pub drift_final: f64,
    pub accuracy: f64,
    pub latency_p50_s: f64,
    pub latency_p99_s: f64,
    pub latency_p999_s: f64,
}

/// Run each `(server, batch)` of one burst through `process`, charging
/// every request in a batch that batch's *own* wall-clock.  Each batch
/// is wrapped in a `serve.batch` span.
///
/// Regression note: the previous scheme timestamped the whole burst
/// once (`burst_start.elapsed()` after each batch), so batch k was
/// charged the processing time of batches 1..k too — with ≥ 2 servers
/// in a step, every batch after the first inherited its predecessors'
/// latency and the p50/p99 numbers drifted upward with server count.
/// Batches of one burst model independent per-server dispatches, not a
/// serial pipeline; each is timed individually.
fn time_batches<F>(
    batches: Vec<(usize, Vec<usize>)>,
    latency: &Histogram,
    mut process: F,
) -> crate::Result<()>
where
    F: FnMut(usize, &[usize]) -> crate::Result<()>,
{
    for (server, batch) in batches.into_iter().filter(|(_, b)| !b.is_empty()) {
        let _batch_span = trace::span_with(
            "serve.batch",
            &[("server", server as f64), ("size", batch.len() as f64)],
        );
        let t0 = Instant::now();
        process(server, &batch)?;
        let batch_s = t0.elapsed().as_secs_f64();
        for _ in &batch {
            latency.observe(batch_s);
            SERVE_LATENCY.observe(batch_s);
        }
    }
    Ok(())
}

/// Placement policy for the serving run.
pub enum Placement<'a> {
    /// Greedy nearest-eligible-server placement (no training needed).
    Greedy,
    /// A trained DRLGO checkpoint (`graphedge train --method drlgo`).
    DrlgoCheckpoint(&'a std::path::Path),
}

/// Run the online loop; prints and returns the stats.
pub fn serve_loop(
    ctrl: &Controller,
    dataset: &str,
    model: &str,
    n_users: usize,
    n_assocs: usize,
    n_requests: usize,
    seed: u64,
    placement: Placement<'_>,
) -> crate::Result<()> {
    let stats = serve_run_with(
        ctrl, dataset, model, n_users, n_assocs, n_requests, seed, placement,
    )?;
    println!("\n== online serving ({dataset}/{model}) ==");
    println!("requests        {}", stats.requests);
    println!("batches         {} (mean size {:.1})", stats.batches, stats.mean_batch);
    println!("throughput      {:.1} req/s", stats.requests as f64 / stats.total_s);
    println!("latency p50     {:.3} ms", stats.latency_p50_s * 1e3);
    println!("latency p99     {:.3} ms", stats.latency_p99_s * 1e3);
    println!("latency p999    {:.3} ms", stats.latency_p999_s * 1e3);
    println!("accuracy        {:.3}", stats.accuracy);
    print!("{}", METRICS.report());
    Ok(())
}

fn print_dynamic(header: &str, stats: &DynamicServeStats) {
    println!("\n== {header} ==");
    println!("steps            {}", stats.steps);
    println!("requests         {}", stats.requests);
    println!("repair mean      {:.3} ms", stats.repair_s_mean * 1e3);
    println!("layout steps/s   {:.1}", stats.layout_steps_per_s);
    println!(
        "full recuts      {}   local recuts {}",
        stats.full_recuts, stats.local_recuts
    );
    println!(
        "cut edges        {} (drift {:+.1}%)",
        stats.cut_edges_final,
        100.0 * stats.drift_final
    );
    println!(
        "latency p50/p99/p999  {:.3} / {:.3} / {:.3} ms",
        stats.latency_p50_s * 1e3,
        stats.latency_p99_s * 1e3,
        stats.latency_p999_s * 1e3
    );
    println!("accuracy         {:.3}", stats.accuracy);
    print!("{}", METRICS.report());
}

/// Print wrapper for [`serve_dynamic_run`] (the `graphedge serve
/// --steps N [--incremental]` path).
#[allow(clippy::too_many_arguments)]
pub fn serve_dynamic(
    ctrl: &Controller,
    dataset: &str,
    model: &str,
    n_users: usize,
    n_assocs: usize,
    steps: usize,
    requests_per_step: usize,
    seed: u64,
    incremental: bool,
    workers: usize,
) -> crate::Result<()> {
    let stats = serve_dynamic_run(
        ctrl, dataset, model, n_users, n_assocs, steps, requests_per_step, seed,
        incremental, workers,
    )?;
    let mode = if incremental {
        "incremental repair"
    } else {
        "full recut"
    };
    print_dynamic(
        &format!("dynamic serving ({dataset}/{model}, {mode}, {workers} worker(s))"),
        &stats,
    );
    Ok(())
}

/// Print wrapper for [`serve_synthetic_run`] (the `graphedge serve
/// --scenario <spec>` path).
#[allow(clippy::too_many_arguments)]
pub fn serve_synthetic(
    params: &SystemParams,
    spec: &str,
    n_users: usize,
    n_assocs: usize,
    steps: usize,
    requests_per_step: usize,
    seed: u64,
    incremental: bool,
    workers: usize,
) -> crate::Result<()> {
    let stats = serve_synthetic_run(
        params, spec, n_users, n_assocs, steps, requests_per_step, seed,
        incremental, workers,
    )?;
    let mode = if incremental {
        "incremental repair"
    } else {
        "full recut"
    };
    print_dynamic(
        &format!("synthetic serving ({spec}, {mode}, {workers} worker(s))"),
        &stats,
    );
    Ok(())
}

/// Model-stage context of the Controller-backed dynamic path.
struct InferCtx<'a> {
    svc: &'a GnnService,
    ds: &'a crate::graph::Dataset,
}

/// The dynamic serving pipeline over an already-built environment:
/// per step, churn + layout maintenance, greedy re-offload, a routed
/// request burst, and one batched model pass per closed batch.  With
/// `infer = None` the model stage is a no-op (synthetic mode: every
/// request still flows enqueue → close → batch → complete, but
/// nothing is classified against a dataset, so accuracy reads 0).
// analyze:allow(panic) — indexes are `rng.below(len)` draws into non-empty `active` and rows of `padded.vertices`, whose backing arrays are sized by the same environment; all in-bounds by construction.
fn serve_dynamic_core(
    env: &mut Env,
    rng: &mut Rng,
    steps: usize,
    requests_per_step: usize,
    infer: Option<&InferCtx<'_>>,
) -> crate::Result<DynamicServeStats> {
    let mut policy = BatchPolicy::default();
    if let Ok(v) = std::env::var("GRAPHEDGE_MAX_BATCH") {
        if let Ok(b) = v.parse() {
            policy.max_batch = b;
        }
    }
    let mut router = Router::new(env.net.len(), policy);
    let latency = Histogram::new();
    let mut repair = Sample::default();
    let mut correct = 0usize;
    let mut classified = 0usize;
    let mut total_requests = 0usize;

    for step in 0..steps {
        let _step_span = trace::span_with("serve.step", &[("step", step as f64)]);
        {
            let _churn_span = trace::span("serve.churn");
            let topo_before = env.topology_version();
            let debt_before = env.layout_lag();
            let t0 = Instant::now();
            env.mutate(rng); // churn + delta-driven repair / full recut
            repair.push(t0.elapsed().as_secs_f64());
            // Version telemetry: how many mutations this step's layout
            // maintenance had to absorb, and whether it caught up.
            let churned = topo_before.lag(env.topology_version());
            VERSION_LAG_PRE.set((debt_before + churned) as i64);
            VERSION_LAG_POST.set(env.layout_lag() as i64);
        }
        env.reset();
        baselines::run_greedy(env);

        // A burst of requests routed onto the repaired layout.
        let active = env.users.active_users();
        if active.is_empty() {
            continue;
        }
        // Queued placements (none, in this loop's flush-per-step
        // discipline) only survive under the params version they were
        // priced with; anything drained by a version change is served
        // with this step's burst rather than dropped.
        let stale = router.revalidate(env.params_version());
        {
            let mut route_span = trace::span("serve.route");
            let now = Instant::now();
            let mut routed = 0usize;
            for _ in 0..requests_per_step {
                let user = active[rng.below(active.len())];
                if router.submit(user, &env.offload, now).is_some() {
                    routed += 1;
                }
                SERVE_REQUESTS.inc();
            }
            total_requests += routed;
            route_span.field("requests", routed as f64);
        }
        // Close out the step: full batches first, then a force-flush —
        // the next churn step invalidates queued placements.
        let mut batches = stale;
        batches.extend(router.ready_batches(Instant::now()));
        batches.extend(router.flush());
        let env_ref = &*env;
        time_batches(batches, &latency, |server, batch| {
            let served;
            {
                let _infer_span = trace::span("serve.infer");
                match infer {
                    Some(ctx) => {
                        // Batch + 2-hop halo, padded (same shape as
                        // the static loop).
                        let mut verts = env_ref.users.graph().k_hop(batch, 2);
                        verts.retain(|&v| env_ref.users.is_active(v));
                        if verts.len() > ctx.svc.n_max {
                            verts.truncate(ctx.svc.n_max);
                        }
                        let padded = PaddedGraph::build(
                            env_ref.users.graph(),
                            &env_ref.scenario.users,
                            ctx.ds,
                            &verts,
                            ctx.svc.n_max,
                            ctx.svc.feat_pad,
                        )?;
                        let classes = ctx.svc.classify(&padded)?;
                        let in_batch: std::collections::HashSet<usize> =
                            batch.iter().copied().collect();
                        let mut batch_classified = 0usize;
                        for (row, &v) in padded.vertices.iter().enumerate() {
                            if in_batch.contains(&v) {
                                batch_classified += 1;
                                let label = ctx.ds.labels
                                    [env_ref.scenario.users[v] as usize]
                                    as usize;
                                if classes[row] == label {
                                    correct += 1;
                                }
                            }
                        }
                        classified += batch_classified;
                        served = batch_classified;
                    }
                    None => {
                        served = batch.len();
                    }
                }
            }
            SERVE_DYN_BATCHES.inc();
            trace::instant(
                "serve.batch_complete",
                &[
                    ("server", server as f64),
                    ("size", batch.len() as f64),
                    ("classified", served as f64),
                ],
            );
            Ok(())
        })?;
    }

    let (full_recuts, local_recuts, drift_final, cut_edges_final) =
        env.layout_maintenance_stats(steps);
    Ok(DynamicServeStats {
        steps,
        requests: total_requests,
        repair_s_mean: repair.mean(),
        layout_steps_per_s: 1.0 / repair.mean().max(1e-12),
        full_recuts,
        local_recuts,
        cut_edges_final,
        drift_final,
        accuracy: if classified == 0 {
            0.0
        } else {
            correct as f64 / classified as f64
        },
        latency_p50_s: latency.percentile(50.0),
        latency_p99_s: latency.percentile(99.0),
        latency_p999_s: latency.percentile(99.9),
    })
}

/// Online serving over a *churning* scenario: each step applies §3.2
/// dynamics, repairs the layout from the recorded `GraphDelta` batch
/// (incremental) or recuts in full, re-offloads greedily, then serves
/// a burst of requests against the repaired layout.  `workers > 1`
/// shards full recuts and independent dirty-region repairs across that
/// many threads (same layout for any value).
#[allow(clippy::too_many_arguments)]
pub fn serve_dynamic_run(
    ctrl: &Controller,
    dataset: &str,
    model: &str,
    n_users: usize,
    n_assocs: usize,
    steps: usize,
    requests_per_step: usize,
    seed: u64,
    incremental: bool,
    workers: usize,
) -> crate::Result<DynamicServeStats> {
    let mut rng = Rng::seed_from(seed);
    let mut env = ctrl.make_env(Method::Greedy, dataset, n_users, n_assocs, &mut rng)?;
    env.set_workers(workers);
    if incremental {
        env.enable_incremental(Default::default());
    }
    let svc = GnnService::load(&ctrl.rt, model, dataset)?;
    let ds = ctrl.dataset(dataset)?;
    let ctx = InferCtx { svc: &svc, ds };
    serve_dynamic_core(&mut env, &mut rng, steps, requests_per_step, Some(&ctx))
}

/// Dynamic serving over a *generated* scenario with a no-op model
/// stage: the whole churn → repair → route → batch-close pipeline
/// runs for real — with full tracing — but no runtime artifacts are
/// required.  `spec` uses the `--scenarios` grammar (e.g.
/// `uniform@120x360`); the first entry of a list is used.  This is
/// the CI trace-smoke path.
#[allow(clippy::too_many_arguments)]
pub fn serve_synthetic_run(
    params: &SystemParams,
    spec: &str,
    n_users: usize,
    n_assocs: usize,
    steps: usize,
    requests_per_step: usize,
    seed: u64,
    incremental: bool,
    workers: usize,
) -> crate::Result<DynamicServeStats> {
    anyhow::ensure!(steps >= 1, "synthetic serving needs at least one churn step");
    let specs = crate::scenario::parse_spec_list(spec, n_users, n_assocs)?;
    let mut rng = Rng::seed_from(seed);
    let Some(first) = specs.first() else {
        anyhow::bail!("spec {spec:?} resolved to no scenarios");
    };
    let scenario = first.generate(params, &mut rng);
    let mut env = Env::from_scenario(&scenario, EnvConfig::default());
    env.set_workers(workers.max(1));
    if incremental {
        env.enable_incremental(Default::default());
    }
    serve_dynamic_core(&mut env, &mut rng, steps, requests_per_step, None)
}

/// The loop itself (separated for tests/examples); greedy placement.
pub fn serve_run(
    ctrl: &Controller,
    dataset: &str,
    model: &str,
    n_users: usize,
    n_assocs: usize,
    n_requests: usize,
    seed: u64,
) -> crate::Result<ServeStats> {
    serve_run_with(ctrl, dataset, model, n_users, n_assocs, n_requests, seed,
                   Placement::Greedy)
}

/// The loop with an explicit placement policy.
#[allow(clippy::too_many_arguments)]
// analyze:allow(panic) — `submit_times[req]` is pushed before every pending entry, user draws are `rng.below(len)` on a non-empty slice, and label/class rows come from the same padded batch; all in-bounds by construction.
pub fn serve_run_with(
    ctrl: &Controller,
    dataset: &str,
    model: &str,
    n_users: usize,
    n_assocs: usize,
    n_requests: usize,
    seed: u64,
    placement: Placement<'_>,
) -> crate::Result<ServeStats> {
    let mut rng = Rng::seed_from(seed);
    let method = match placement {
        Placement::Greedy => Method::Greedy,
        Placement::DrlgoCheckpoint(_) => Method::Drlgo,
    };
    let mut env = ctrl.make_env(method, dataset, n_users, n_assocs, &mut rng)?;
    match placement {
        Placement::Greedy => baselines::run_greedy(&mut env),
        Placement::DrlgoCheckpoint(path) => {
            let mut tr = crate::drl::MaddpgTrainer::new(&ctrl.rt, 1024)?;
            tr.restore(path)?;
            tr.policy_offload(&mut env)?;
        }
    }

    let svc = GnnService::load(&ctrl.rt, model, dataset)?;
    let ds = ctrl.dataset(dataset)?;
    let active = env.users.active_users();
    let servers = env.net.len();

    let mut policy = BatchPolicy::default();
    if let Ok(v) = std::env::var("GRAPHEDGE_MAX_BATCH") {
        if let Ok(b) = v.parse() {
            policy.max_batch = b;
        }
    }
    let mut router = Router::new(servers, policy);
    // Pin the router's deadline cache to this env's params version
    // (static topology: the version never moves mid-run).
    let _ = router.revalidate(env.params_version());
    let latency = Histogram::new();
    let batch_sizes = Histogram::new();
    let mut correct = 0usize;
    let mut classified = 0usize;

    let started = Instant::now();
    let mut submit_times: Vec<Instant> = Vec::with_capacity(n_requests);
    let mut pending: Vec<(usize, usize)> = Vec::new(); // (request idx, user)

    struct BatchCtx<'a> {
        env: &'a crate::drl::Env,
        svc: &'a GnnService,
        ds: &'a crate::graph::Dataset,
    }

    #[allow(clippy::too_many_arguments)]
    fn process(
        ctx: &BatchCtx,
        batches: Vec<(usize, Vec<usize>)>,
        submit_times: &[Instant],
        pending: &mut Vec<(usize, usize)>,
        latency: &Histogram,
        batch_sizes: &Histogram,
        correct: &mut usize,
        classified: &mut usize,
    ) -> crate::Result<()> {
        for (server, users) in batches {
            let _batch_span = trace::span_with(
                "serve.batch",
                &[("server", server as f64), ("size", users.len() as f64)],
            );
            batch_sizes.observe(users.len() as f64);
            let classes;
            let padded;
            {
                let _infer_span = trace::span("serve.infer");
                // Batch + 2-hop halo, padded.
                let mut verts = ctx.env.users.graph().k_hop(&users, 2);
                {
                    let env = ctx.env;
                    verts.retain(|&v| env.users.is_active(v));
                }
                if verts.len() > ctx.svc.n_max {
                    verts.truncate(ctx.svc.n_max);
                }
                padded = PaddedGraph::build(
                    ctx.env.users.graph(),
                    &ctx.env.scenario.users,
                    ctx.ds,
                    &verts,
                    ctx.svc.n_max,
                    ctx.svc.feat_pad,
                )?;
                classes = ctx.svc.classify(&padded)?;
            }
            let done = Instant::now();
            let in_batch: std::collections::HashSet<usize> = users.iter().copied().collect();
            // Latency for each fulfilled request.
            pending.retain(|&(req, user)| {
                if in_batch.contains(&user) {
                    let waited = done.duration_since(submit_times[req]).as_secs_f64();
                    latency.observe(waited);
                    SERVE_LATENCY.observe(waited);
                    false
                } else {
                    true
                }
            });
            // Accuracy bookkeeping.
            let mut batch_classified = 0usize;
            for (row, &v) in padded.vertices.iter().enumerate() {
                if in_batch.contains(&v) {
                    batch_classified += 1;
                    let label = ctx.ds.labels[ctx.env.scenario.users[v] as usize] as usize;
                    if classes[row] == label {
                        *correct += 1;
                    }
                }
            }
            *classified += batch_classified;
            trace::instant(
                "serve.batch_complete",
                &[
                    ("server", server as f64),
                    ("size", users.len() as f64),
                    ("classified", batch_classified as f64),
                ],
            );
        }
        Ok(())
    }

    let ctx = BatchCtx { env: &env, svc: &svc, ds };

    for req in 0..n_requests {
        let user = active[rng.below(active.len())];
        let now = Instant::now();
        submit_times.push(now);
        if router.submit(user, &env.offload, now).is_some() {
            pending.push((req, user));
        }
        let ready = router.ready_batches(Instant::now());
        if !ready.is_empty() {
            process(&ctx, ready, &submit_times, &mut pending, &latency,
                    &batch_sizes, &mut correct, &mut classified)?;
        }
        SERVE_REQUESTS.inc();
    }
    let rest = router.flush();
    process(&ctx, rest, &submit_times, &mut pending, &latency,
            &batch_sizes, &mut correct, &mut classified)?;

    let total_s = started.elapsed().as_secs_f64();
    Ok(ServeStats {
        requests: n_requests,
        batches: router.dispatched_batches,
        total_s,
        latency_p50_s: latency.percentile(50.0),
        latency_p99_s: latency.percentile(99.0),
        latency_p999_s: latency.percentile(99.9),
        mean_batch: batch_sizes.mean(),
        accuracy: if classified == 0 {
            0.0
        } else {
            correct as f64 / classified as f64
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn burst_batches_are_timed_individually() {
        // ≥ 2 servers' batches in one burst: under the old cumulative
        // `burst_start.elapsed()` accounting the last batch would be
        // charged ~3× the per-batch time; individually timed, every
        // batch stays well under the burst total.  (Histogram buckets
        // carry ≤ 12.5 % relative error — far below the 2× margin.)
        let sleep = Duration::from_millis(30);
        let batches =
            vec![(0, vec![1, 2]), (1, Vec::new()), (2, vec![3]), (0, vec![4, 5, 6])];
        let latency = Histogram::new();
        let mut processed = 0usize;
        time_batches(batches, &latency, |_server, batch| {
            assert!(!batch.is_empty(), "empty batches must be skipped");
            processed += 1;
            std::thread::sleep(sleep);
            Ok(())
        })
        .unwrap();
        assert_eq!(processed, 3);
        // One latency sample per request of every non-empty batch.
        assert_eq!(latency.count(), 6);
        let per_batch = sleep.as_secs_f64();
        assert!(latency.percentile(0.0) >= per_batch * 0.85);
        // Cumulative accounting would put the last batch at ~3×.
        assert!(
            latency.percentile(100.0) < 2.0 * per_batch,
            "a batch inherited its predecessors' time: max {}s",
            latency.percentile(100.0)
        );
    }

    #[test]
    fn time_batches_propagates_errors() {
        let latency = Histogram::new();
        let out = time_batches(vec![(0, vec![1]), (0, vec![2])], &latency, |_, batch| {
            if batch[0] == 2 {
                anyhow::bail!("boom");
            }
            Ok(())
        });
        assert!(out.is_err());
        // The failing batch records no latency.
        assert_eq!(latency.count(), 1);
    }

    #[test]
    fn synthetic_serving_runs_without_artifacts() {
        let stats = serve_synthetic_run(
            &SystemParams::default(),
            "uniform@60x180",
            60,
            180,
            3,
            20,
            17,
            true,
            1,
        )
        .expect("synthetic serve");
        assert_eq!(stats.steps, 3);
        assert!(stats.requests > 0, "no requests were routed");
        assert!(stats.latency_p50_s >= 0.0);
        // One full HiCut builds the incremental reference.
        assert!(stats.full_recuts >= 1);
    }

    #[test]
    fn synthetic_serving_rejects_zero_steps() {
        let r = serve_synthetic_run(
            &SystemParams::default(),
            "uniform@40x80",
            40,
            80,
            0,
            10,
            1,
            false,
            1,
        );
        assert!(r.is_err());
    }
}
