//! GnnService: one (model, dataset) AOT executable + pre-trained
//! weights, exposing padded-subgraph classification.

use std::sync::Arc;

use anyhow::Context;

use crate::runtime::{mat, Executable, Runtime};
use crate::tensor::Matrix;

use super::padded::PaddedGraph;

/// The four GNN architectures of §6.1.
pub const MODELS: &[&str] = &["gcn", "gat", "sage", "sgc"];
/// The three datasets of §6.1.
pub const DATASETS: &[&str] = &["citeseer", "cora", "pubmed"];

pub struct GnnService {
    pub model: String,
    pub dataset: String,
    pub n_max: usize,
    pub feat_pad: usize,
    pub classes: usize,
    exe: Arc<Executable>,
    /// Parameter matrices in executable order (after the graph inputs).
    weights: Vec<Matrix>,
    graph_inputs: Vec<String>,
}

impl GnnService {
    /// Load `"<model>_<dataset>"` from the runtime, including weights.
    pub fn load(rt: &Runtime, model: &str, dataset: &str) -> crate::Result<Self> {
        let key = format!("{model}_{dataset}");
        let exe = rt.load(&key)?;
        let spec = &exe.spec;
        let wpath = spec
            .weights
            .clone()
            .with_context(|| format!("{key} has no weights in manifest"))?;
        let archive = rt.load_archive(&wpath)?;
        let graph_inputs = spec.graph_inputs.clone();
        let mut weights = Vec::new();
        for ts in spec.inputs.iter().skip(graph_inputs.len()) {
            let t = archive.get_shaped(&ts.name, &ts.shape)?;
            weights.push(mat(&t.shape, t.f32_data.clone())?);
        }
        let n_max = rt.manifest.constant("n_max")?;
        let ds = rt
            .manifest
            .datasets
            .get(dataset)
            .with_context(|| format!("dataset {dataset} missing from manifest"))?;
        Ok(GnnService {
            model: model.to_string(),
            dataset: dataset.to_string(),
            n_max,
            feat_pad: ds.feat_pad,
            classes: ds.classes,
            exe,
            weights,
            graph_inputs,
        })
    }

    /// Run inference; returns logits [n_max, c_pad].
    pub fn infer(&self, p: &PaddedGraph) -> crate::Result<Matrix> {
        let mut all: Vec<&Matrix> = Vec::with_capacity(self.graph_inputs.len() + self.weights.len());
        for gi in &self.graph_inputs {
            all.push(match gi.as_str() {
                "x" => &p.x,
                "a_norm" => &p.a_norm,
                "adj" => &p.adj,
                "inv_deg" => &p.inv_deg,
                other => anyhow::bail!("unknown graph input {other:?}"),
            });
        }
        all.extend(self.weights.iter());
        let mut outs = self.exe.run(&all)?;
        outs.pop().with_context(|| format!("{}_{}: no output", self.model, self.dataset))
    }

    /// Classify the real vertices of a padded graph: class per vertex.
    pub fn classify(&self, p: &PaddedGraph) -> crate::Result<Vec<usize>> {
        let logits = self.infer(p)?;
        let mut classes = logits.row_argmax(self.classes);
        classes.truncate(p.real_size());
        Ok(classes)
    }
}
