//! The simulated edge-server fleet: per-server task queues, halo
//! construction and distributed GNN inference with cross-server
//! message-passing accounting.
//!
//! Given a completed offloading decision, each server owns the tasks
//! assigned to it.  For exact 2-layer GNN inference of its own
//! vertices it also needs their 2-hop neighborhood (the *halo*); every
//! halo vertex owned by another server represents a cross-server fetch
//! (`message passing`, §1), which the fleet counts in bytes and in the
//! cost model's terms.

use once_cell::sync::Lazy;

use crate::graph::sample::Scenario;
use crate::graph::Dataset;
use crate::net::cost::{CostModel, Offload, UNASSIGNED};
use crate::util::metrics::{Counter, Histogram, GLOBAL as METRICS};

use super::gnn::GnnService;
use super::padded::PaddedGraph;

static HALO_FETCHES: Lazy<Counter> =
    Lazy::new(|| METRICS.counter_handle("fleet.halo_fetches"));
static ROUND_EXECUTE_S: Lazy<Histogram> =
    Lazy::new(|| METRICS.histogram_handle("fleet.round_execute_s"));

/// Outcome of one full inference round across the fleet.
#[derive(Clone, Debug, Default)]
pub struct InferenceReport {
    /// Predicted class per scenario user (usize::MAX = not inferred).
    pub predictions: Vec<usize>,
    /// Per-server halo fetches (vertices owned elsewhere).
    pub halo_fetches: usize,
    /// Cross-server data volume implied by halo fetches, Mbit.
    pub halo_mb: f64,
    /// Vertices whose halo was truncated by the N_MAX pad (approximate
    /// aggregation for those; counted, never silent).
    pub truncated: usize,
    /// Wall-clock seconds spent inside PJRT execute calls.
    pub execute_s: f64,
    /// Per-server real subgraph sizes.
    pub batch_sizes: Vec<usize>,
}

/// The fleet binds one GnnService (identical model replicas on every
/// server, as in the paper) to a scenario.
pub struct Fleet<'a> {
    pub svc: &'a GnnService,
    pub scenario: &'a Scenario,
    pub dataset: &'a Dataset,
}

impl<'a> Fleet<'a> {
    pub fn new(svc: &'a GnnService, scenario: &'a Scenario, dataset: &'a Dataset) -> Self {
        Fleet { svc, scenario, dataset }
    }

    /// Run distributed inference for a complete offload decision.
    ///
    /// `alive` filters scenario users (the §3.2 mask); `servers` is the
    /// fleet size.  Uses the exact 2-hop halo for 2-layer GNNs.
    pub fn infer_round(
        &self,
        offload: &Offload,
        alive: &dyn Fn(usize) -> bool,
        servers: usize,
        cost: Option<&CostModel>,
    ) -> crate::Result<InferenceReport> {
        self.infer_round_hops(offload, alive, servers, cost, 2)
    }

    /// As [`Self::infer_round`] with a configurable halo radius
    /// (design-choice ablation: 0 = no halo, 1 = approximate boundary
    /// aggregation, 2 = exact for 2-layer GNNs).
    pub fn infer_round_hops(
        &self,
        offload: &Offload,
        alive: &dyn Fn(usize) -> bool,
        servers: usize,
        cost: Option<&CostModel>,
        hops: usize,
    ) -> crate::Result<InferenceReport> {
        let n = self.scenario.graph.len();
        let mut report = InferenceReport {
            predictions: vec![usize::MAX; n],
            ..Default::default()
        };
        for server in 0..servers {
            let owned: Vec<usize> = (0..n)
                .filter(|&u| alive(u) && offload.server.get(u) == Some(&server))
                .collect();
            if owned.is_empty() {
                report.batch_sizes.push(0);
                continue;
            }
            // 2-hop halo in BFS order; truncate to n_max keeping the
            // owned vertices and nearest halo first.
            let mut verts = self
                .scenario
                .graph
                .k_hop(&owned, hops)
                .into_iter()
                .filter(|&v| alive(v))
                .collect::<Vec<_>>();
            if verts.len() > self.svc.n_max {
                report.truncated += verts.len() - self.svc.n_max;
                verts.truncate(self.svc.n_max);
            }
            // Halo accounting: vertices provided by other servers.
            for &v in &verts {
                let owner = offload.server.get(v).copied().unwrap_or(UNASSIGNED);
                if owner != server && owner != UNASSIGNED {
                    report.halo_fetches += 1;
                    report.halo_mb += cost
                        .map(|c| c.users.task_mb(v))
                        .unwrap_or(self.dataset.task_mbit(0));
                }
            }
            let padded = PaddedGraph::build(
                &self.scenario.graph,
                &self.scenario.users,
                self.dataset,
                &verts,
                self.svc.n_max,
                self.svc.feat_pad,
            )?;
            // lint:allow(wall-clock) — measures real inference latency
            // for the report/metrics; scheduling decisions use the
            // simulated cost model, not this timer.
            let t0 = std::time::Instant::now();
            let classes = self.svc.classify(&padded)?;
            report.execute_s += t0.elapsed().as_secs_f64();
            report.batch_sizes.push(padded.real_size());
            // Keep predictions only for owned vertices (halo rows are
            // another server's responsibility).
            let owned_set: std::collections::HashSet<usize> = owned.iter().copied().collect();
            for (row, &v) in padded.vertices.iter().enumerate() {
                if !owned_set.contains(&v) {
                    continue;
                }
                if let (Some(slot), Some(&class)) =
                    (report.predictions.get_mut(v), classes.get(row))
                {
                    *slot = class;
                }
            }
        }
        HALO_FETCHES.add(report.halo_fetches as u64);
        ROUND_EXECUTE_S.observe(report.execute_s);
        Ok(report)
    }

    /// Classification accuracy of a report against dataset labels.
    pub fn accuracy(&self, report: &InferenceReport, alive: &dyn Fn(usize) -> bool) -> f64 {
        let mut hit = 0usize;
        let mut total = 0usize;
        for (u, &pred) in report.predictions.iter().enumerate() {
            if !alive(u) || pred == usize::MAX {
                continue;
            }
            total += 1;
            let label = self
                .scenario
                .users
                .get(u)
                .and_then(|&backing| self.dataset.labels.get(backing as usize));
            if label.map(|&l| l as usize) == Some(pred) {
                hit += 1;
            }
        }
        if total == 0 {
            0.0
        } else {
            hit as f64 / total as f64
        }
    }
}
