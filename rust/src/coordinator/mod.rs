//! The EC controller (core server) — §3.1's processing flow, end to
//! end:
//!
//! 1. **Perceive** the user topology as a dynamic graph layout (§3.2).
//! 2. **Optimize** the layout with HiCut into weakly-associated
//!    subgraphs (§4).
//! 3. **Decide** a graph offloading with DRLGO or a baseline (§5).
//!    Policy rollouts ride the environment's incremental observation
//!    engine (see [`crate::drl::env`]): per-step states are O(M·OBS)
//!    copies, with the static feature table refreshed only when the
//!    topology changes (`Env::mutate` / `Env::recut`).  Training
//!    rolls out on a [`crate::drl::vec_env::VecEnv`] — E episode
//!    slots of the sampled scenario stepped as a batch, each with its
//!    own churn stream (`--envs E`).
//! 4. **Dispatch** each subgraph's tasks to its edge server and run
//!    distributed GNN inference (serving layer), accounting all costs
//!    (Eqs. 12–13).
//!
//! [`Controller`] owns the inference runtime (native kernels by
//! default, PJRT under `--features xla`) and loaded datasets;
//! [`Controller::run_scenario`] executes one full round and returns a
//! [`ScenarioReport`] — the unit every bench and example builds on.

use std::collections::BTreeMap;

use anyhow::Context;

use crate::drl::{
    baselines, Env, EnvConfig, MaddpgConfig, MaddpgTrainer, Method, PpoConfig, PpoTrainer,
};
use crate::graph::Dataset;
use crate::net::cost::CostBreakdown;
use crate::net::SystemParams;
use crate::partition::incremental::IncrementalConfig;
use crate::runtime::Runtime;
use crate::serving::{Fleet, GnnService};
use crate::util::rng::Rng;

/// Result of one coordinated round.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    pub method: &'static str,
    pub dataset: String,
    pub model: String,
    pub n_users: usize,
    pub n_assocs: usize,
    /// Analytic system cost (Eqs. 12–13).
    pub cost: CostBreakdown,
    /// HiCut layout quality on this scenario.
    pub layout_cut_edges: usize,
    pub subgraphs: usize,
    /// Inference results (when the fleet ran).
    pub accuracy: f64,
    pub halo_fetches: usize,
    pub halo_mb: f64,
    pub inference_s: f64,
    /// Wall-clock of the offloading decision itself.
    pub decision_s: f64,
}

/// Aggregate of a multi-step dynamic run ([`Controller::run_dynamic`]).
#[derive(Clone, Debug)]
pub struct DynamicReport {
    pub steps: usize,
    pub incremental: bool,
    /// Layout-maintenance worker threads used (1 = sequential).
    pub workers: usize,
    /// Wall-clock of churn + layout maintenance across all steps.
    pub layout_s_total: f64,
    pub steps_per_s: f64,
    /// Full HiCut runs (per step when not incremental; drift fallbacks
    /// plus the initial reference cut otherwise).
    pub full_recuts: usize,
    pub local_recuts: usize,
    pub final_cut_edges: usize,
    /// Relative drift above the monitor reference (0 when tracking).
    pub final_drift: f64,
    pub mean_cost: f64,
}

/// The EC controller.
pub struct Controller {
    pub rt: Runtime,
    pub params: SystemParams,
    datasets: BTreeMap<String, Dataset>,
}

impl Controller {
    /// Open artifacts and load every dataset in the manifest.
    pub fn new(params: SystemParams) -> crate::Result<Self> {
        let rt = Runtime::open_default()?;
        let mut datasets = BTreeMap::new();
        for name in rt.manifest.datasets.keys().cloned().collect::<Vec<_>>() {
            let ds = rt.dataset(&name).with_context(|| format!("loading dataset {name}"))?;
            datasets.insert(name, ds);
        }
        Ok(Controller { rt, params, datasets })
    }

    pub fn dataset(&self, name: &str) -> crate::Result<&Dataset> {
        self.datasets
            .get(name)
            .with_context(|| format!("unknown dataset {name:?}"))
    }

    pub fn dataset_names(&self) -> Vec<&str> {
        self.datasets.keys().map(|s| s.as_str()).collect()
    }

    /// Build an environment for `method` on `dataset`.
    pub fn make_env(
        &self,
        method: Method,
        dataset: &str,
        n_users: usize,
        n_assocs: usize,
        rng: &mut Rng,
    ) -> crate::Result<Env> {
        let ds = self.dataset(dataset)?;
        let use_hicut = matches!(method, Method::Drlgo | Method::Greedy | Method::Random);
        let cfg = EnvConfig {
            n_users,
            n_assocs,
            use_hicut,
            use_rsp: matches!(method, Method::Drlgo),
            zeta_sp: self.params.zeta_sp,
            ..EnvConfig::default()
        };
        Ok(Env::new(ds, self.params.clone(), cfg, rng))
    }

    /// Train DRLGO (or the DRL-only ablation) on a dataset sample.
    ///
    /// The sampled scenario seeds `cfg.envs` vectorized episode slots
    /// ([`crate::drl::VecEnv`]) trained with one batched
    /// `select_actions`/`train_step` round per vector step.  With
    /// `cfg.scenarios` unset every slot replicates the sample; with a
    /// spec (`--scenarios mixed`, `clustered:5@200x800,…` — see
    /// [`crate::scenario::set`]) each slot instead owns its own
    /// generated topology, training one policy across diverse
    /// scenarios (the dataset sample then only seeds the prototype's
    /// config and is replaced slot by slot).  The returned [`Env`] is
    /// slot 0's final scenario, ready for
    /// [`Controller::run_scenario`] — except that generated scenarios
    /// have no dataset backing, so `run_inference` must stay off for
    /// them (cost evaluation works either way; the guard in
    /// `run_scenario` rejects the mismatch).
    pub fn train_drlgo(
        &self,
        dataset: &str,
        ablation: bool,
        n_users: usize,
        n_assocs: usize,
        cfg: &MaddpgConfig,
    ) -> crate::Result<(MaddpgTrainer<'_>, Env, Vec<crate::drl::maddpg::EpisodeStats>)> {
        let method = if ablation {
            Method::DrlOnly
        } else {
            Method::Drlgo
        };
        let mut rng = Rng::seed_from(cfg.seed);
        let mut env = self.make_env(method, dataset, n_users, n_assocs, &mut rng)?;
        if ablation {
            env.cfg.use_hicut = false;
            env.cfg.use_rsp = false;
            env.recut();
            env.reset();
        }
        if let Some(spec) = &cfg.scenarios {
            log::info!("DRLGO training on a scenario-diverse vector: {spec}");
        }
        let mut trainer = MaddpgTrainer::new(&self.rt, cfg.replay_cap)?;
        let curve = trainer.train(&mut env, cfg)?;
        Ok((trainer, env, curve))
    }

    /// Train the PTOM baseline (vectorized like
    /// [`Controller::train_drlgo`], over `cfg.envs` episode slots;
    /// `cfg.scenarios` selects scenario-diverse slots the same way).
    pub fn train_ptom(
        &self,
        dataset: &str,
        n_users: usize,
        n_assocs: usize,
        cfg: &PpoConfig,
    ) -> crate::Result<(PpoTrainer<'_>, Env, Vec<crate::drl::maddpg::EpisodeStats>)> {
        let mut rng = Rng::seed_from(cfg.seed);
        let mut env = self.make_env(Method::Ptom, dataset, n_users, n_assocs, &mut rng)?;
        let mut trainer = PpoTrainer::new(&self.rt)?;
        let curve = trainer.train(&mut env, cfg)?;
        Ok((trainer, env, curve))
    }

    /// Drive `env` through `steps` churn steps — §3.2 dynamics, layout
    /// maintenance (delta-driven repair when `incremental`, full HiCut
    /// otherwise), greedy re-offload, cost evaluation — and summarize.
    /// `workers > 1` shards full recuts and independent dirty-region
    /// repairs across that many threads (`--workers`; the layout is
    /// identical for any value).  This is the coordinator's
    /// dynamic-scenario entry point; the serving layer builds on the
    /// same loop in [`crate::serving::serve_dynamic_run`].
    pub fn run_dynamic(
        &self,
        env: &mut Env,
        steps: usize,
        incremental: bool,
        workers: usize,
        rng: &mut Rng,
    ) -> crate::Result<DynamicReport> {
        env.set_workers(workers);
        if incremental && env.incremental.is_none() {
            env.enable_incremental(IncrementalConfig::default());
        } else if !incremental && env.incremental.is_some() {
            // The mode flag wins: a leftover partitioner from an
            // earlier incremental run would silently keep repairing
            // and mislabel the full-recut baseline.
            env.disable_incremental();
        }
        let mut layout_s = 0.0;
        let mut cost_sum = 0.0;
        for _ in 0..steps {
            // lint:allow(wall-clock) — measures repair-vs-recut cost
            // for the comparison table; the layouts themselves are
            // clock-independent.
            let t0 = std::time::Instant::now();
            env.mutate(rng); // churn + repair (or full recut)
            layout_s += t0.elapsed().as_secs_f64();
            env.reset();
            baselines::run_greedy(env);
            cost_sum += env.evaluate().total();
        }
        let (full_recuts, local_recuts, final_drift, final_cut_edges) =
            env.layout_maintenance_stats(steps);
        Ok(DynamicReport {
            steps,
            incremental,
            workers: env.workers,
            layout_s_total: layout_s,
            steps_per_s: steps as f64 / layout_s.max(1e-12),
            full_recuts,
            local_recuts,
            final_cut_edges,
            final_drift,
            mean_cost: cost_sum / steps.max(1) as f64,
        })
    }

    /// Execute one full round: decide an offload with `method` (using
    /// pre-trained policies where given), optionally run distributed
    /// inference, and report every cost.
    #[allow(clippy::too_many_arguments)]
    pub fn run_scenario(
        &self,
        method: Method,
        env: &mut Env,
        dataset: &str,
        model: &str,
        drlgo: Option<&mut MaddpgTrainer>,
        ptom: Option<&mut PpoTrainer>,
        run_inference: bool,
        rng: &mut Rng,
    ) -> crate::Result<ScenarioReport> {
        env.profile = crate::net::GnnProfile::from_name(model);
        // lint:allow(wall-clock) — wall time of the offload method is
        // itself a reported figure; nothing downstream branches on it.
        let t0 = std::time::Instant::now();
        match method {
            Method::Drlgo | Method::DrlOnly => {
                let tr = drlgo.context("DRLGO policy required")?;
                tr.policy_offload(env)?;
            }
            Method::Ptom => {
                let tr = ptom.context("PTOM policy required")?;
                tr.policy_offload(env)?;
            }
            Method::Greedy => baselines::run_greedy(env),
            Method::Random => baselines::run_random(env, rng),
        }
        let decision_s = t0.elapsed().as_secs_f64();
        let cost = env.evaluate();

        let mut report = ScenarioReport {
            method: method.name(),
            dataset: dataset.to_string(),
            model: model.to_string(),
            n_users: env.cfg.n_users,
            n_assocs: env.cfg.n_assocs,
            cost,
            layout_cut_edges: env.layout_cut_edges(),
            subgraphs: env.subgraph_size.len(),
            accuracy: 0.0,
            halo_fetches: 0,
            halo_mb: 0.0,
            inference_s: 0.0,
            decision_s,
        };

        if run_inference {
            let ds = self.dataset(dataset)?;
            // Generated scenarios (`--scenarios`) carry an identity
            // user map with no dataset backing: their "documents"
            // would read unrelated dataset rows — or index out of
            // bounds — so fleet inference is only defined for sampled
            // scenarios.
            anyhow::ensure!(
                env.scenario.users.iter().all(|&u| (u as usize) < ds.n),
                "scenario users out of range for dataset {dataset}: generated \
                 scenarios have no dataset backing — evaluate them without inference"
            );
            let svc = GnnService::load(&self.rt, model, dataset)?;
            // The fleet reads the *current* user graph (post-churn).
            let scenario = crate::graph::sample::Scenario {
                users: env.scenario.users.clone(),
                graph: env.users.graph().clone(),
            };
            let fleet = Fleet::new(&svc, &scenario, ds);
            let users = &env.users;
            let alive = |v: usize| users.is_active(v);
            let servers = env.net.len();
            let rep = fleet.infer_round(&env.offload, &alive, servers, None)?;
            report.accuracy = fleet.accuracy(&rep, &alive);
            report.halo_fetches = rep.halo_fetches;
            report.halo_mb = rep.halo_mb;
            report.inference_s = rep.execute_s;
        }
        Ok(report)
    }
}
