//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! Mirrors exactly the API surface `graphedge` uses — [`PjRtClient`],
//! [`PjRtLoadedExecutable`], [`Literal`], [`HloModuleProto`],
//! [`XlaComputation`] — so the crate compiles and all pure-Rust layers
//! (graph, partition, cost model, DRL environment, benches) run
//! without `libxla_extension`.  Host-side literal plumbing
//! (construction, reshape, readback) is real; anything that would
//! enter PJRT (parsing HLO, compiling, executing) returns [`Error`]
//! with a pointer at the real dependency.
//!
//! To execute the AOT artifacts, swap the `xla` path dependency in
//! `rust/Cargo.toml` for a checkout of
//! `github.com/LaurentMazare/xla-rs` (with `XLA_EXTENSION_DIR` set).

use std::path::Path;

/// Error type matching xla-rs's role in `?` chains (`anyhow`-compatible).
#[derive(Clone, Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn stub(what: &str) -> Self {
        Error {
            msg: format!(
                "xla stub: {what} is unavailable in the vendored no-op build; \
                 point rust/Cargo.toml's `xla` dependency at a real xla-rs \
                 checkout to execute AOT artifacts"
            ),
        }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Dense f32 host literal (the only dtype the artifacts bind).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

/// Element types readable out of a [`Literal`].
pub trait NativeElem: Sized {
    fn from_f32(v: f32) -> Self;
}

impl NativeElem for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

/// Array shape of a literal.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl Literal {
    pub fn scalar(v: f32) -> Literal {
        Literal { data: vec![v], dims: Vec::new() }
    }

    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        if numel as usize != self.data.len() {
            return Err(Error {
                msg: format!(
                    "reshape {:?} -> {dims:?}: element count mismatch",
                    self.dims
                ),
            });
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeElem>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Stub literals are never tuples (tuples only come out of
    /// `execute`, which errors first).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::stub("Literal::to_tuple"))
    }
}

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle returned by `execute`.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client.  Construction succeeds so manifest/dataset-only flows
/// (no executable launches) keep working; `compile` errors.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(Literal::scalar(7.0).to_vec::<f32>().unwrap(), vec![7.0]);
    }

    #[test]
    fn pjrt_entry_points_error_descriptively() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 0);
        let comp = XlaComputation { _private: () };
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("xla stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
