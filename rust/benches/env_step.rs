//! §Perf — the DRLGO observation hot path: the incremental
//! observation engine (`Env::state`, an O(M·OBS) copy off the cached
//! `ObsState`) against the from-scratch rebuild it replaced
//! (`Env::state_recompute`: a fresh cost model, O(N) remaining scan
//! and O(deg) neighborhood scan per agent, every query).
//!
//! Three views:
//!
//! * a single `state()` call mid-episode (the Algorithm 2 inner-loop
//!   unit),
//! * a full offloading episode stepping every user and building one
//!   state per step (what one training episode pays),
//! * one `mutate` — churn + layout maintenance + the engine's static
//!   table rebuild — the amortized refresh cost the engine adds.
//!
//! Cached and recomputed states are asserted **bit-identical** before
//! any timing counts (the `tests/properties.rs` equivalence, re-checked
//! here on the bench scenario).
//!
//! Emits `bench_results/env_step.csv` and merges an `"env"` section
//! into `BENCH_partition.json` (repo root when present), next to the
//! partition benches' sections.

use std::collections::BTreeMap;

use graphedge::bench::{fmt_secs, time_reps, write_bench_section, Table};
use graphedge::drl::env::OBS;
use graphedge::drl::{Env, EnvConfig};
use graphedge::graph::Dataset;
use graphedge::net::SystemParams;
use graphedge::util::json::Value;
use graphedge::util::rng::Rng;

fn assert_bit_identical(env: &Env, at: &str) {
    let (new, old) = (env.state(), env.state_recompute());
    assert_eq!(new.len(), old.len(), "state width diverged {at}");
    for (i, (a, b)) in new.iter().zip(&old).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "cached state[{i}] diverged from recompute {at}: {a} vs {b}"
        );
    }
}

fn main() {
    // GRAPHEDGE_BENCH_SMOKE=1: tiny sizes, one rep — CI executes the
    // bench end to end (including the JSON section write) without
    // paying for meaningful numbers.
    let smoke = std::env::var("GRAPHEDGE_BENCH_SMOKE").is_ok();
    let full_suite = std::env::var("GRAPHEDGE_BENCH_FULL").is_ok();
    let (ds_n, n_users, n_assocs, reps) = if smoke {
        (300, 60, 120, 1)
    } else if full_suite {
        (4000, 600, 7200, 200)
    } else {
        (2000, 300, 4800, 50)
    };

    let mut rng = Rng::seed_from(0x0B5E);
    let ds = Dataset::synthetic(ds_n, &mut rng);
    let cfg = EnvConfig { n_users, n_assocs, ..EnvConfig::default() };
    let mut env = Env::new(&ds, SystemParams::default(), cfg, &mut rng);
    let agents = env.agents();
    println!(
        "observation engine: {n_users} users, {agents} agents, OBS={OBS} \
         (|V|={ds_n}, state = {} floats)",
        agents * OBS
    );

    // Advance to mid-episode so the dynamic features are non-trivial
    // (partial loads, placed neighbors, split subgraphs).
    assert_bit_identical(&env, "at reset");
    let half = env.users.active_count() / 2;
    for i in 0..half {
        env.step(i % agents);
    }
    assert_bit_identical(&env, "mid-episode");

    let mut t = Table::new(
        "cached ObsState vs from-scratch recompute",
        &["op", "cached", "recompute", "speedup"],
    );

    // 1. One state() build, mid-episode.
    let state_new = time_reps(10, reps, || {
        std::hint::black_box(env.state());
    });
    let state_old = time_reps(10, reps, || {
        std::hint::black_box(env.state_recompute());
    });
    let state_speedup = state_old.mean() / state_new.mean().max(1e-12);
    t.row(vec![
        "state() mid-episode".into(),
        fmt_secs(state_new.mean()),
        fmt_secs(state_old.mean()),
        format!("{state_speedup:.1}x"),
    ]);

    // 2. A full episode: reset + one state per step (Algorithm 2's
    // inner while-loop, as a training episode drives it).
    let ep_reps = if smoke { 1 } else { (reps / 5).max(3) };
    let episode_new = time_reps(1, ep_reps, || {
        env.reset();
        let mut i = 0;
        while !env.finished() {
            std::hint::black_box(env.state());
            env.step(i % agents);
            i += 1;
        }
    });
    let episode_old = time_reps(1, ep_reps, || {
        env.reset();
        let mut i = 0;
        while !env.finished() {
            std::hint::black_box(env.state_recompute());
            env.step(i % agents);
            i += 1;
        }
    });
    let episode_speedup = episode_old.mean() / episode_new.mean().max(1e-12);
    t.row(vec![
        "episode (state/step)".into(),
        fmt_secs(episode_new.mean()),
        fmt_secs(episode_old.mean()),
        format!("{episode_speedup:.1}x"),
    ]);

    // 3. The refresh cost the engine amortizes: churn + layout
    // maintenance + static-table rebuild, once per topology change.
    let mut churn_rng = Rng::seed_from(0x0B5F);
    let mutate = time_reps(1, ep_reps, || {
        env.mutate(&mut churn_rng);
        env.reset();
    });
    t.row(vec![
        "mutate+reset (rebuild)".into(),
        fmt_secs(mutate.mean()),
        "-".into(),
        "-".into(),
    ]);
    assert_bit_identical(&env, "after churn");

    t.emit("env_step");

    let obj = |pairs: Vec<(&str, Value)>| {
        Value::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect::<BTreeMap<_, _>>(),
        )
    };
    let section = obj(vec![
        (
            "_note",
            Value::Str(
                "Regenerate with `cargo bench --bench env_step` (the bench \
                 rewrites this section).  Cached and recomputed states are \
                 asserted bit-identical before timing."
                    .into(),
            ),
        ),
        ("n_users", Value::Num(n_users as f64)),
        ("agents", Value::Num(agents as f64)),
        ("obs_dim", Value::Num(OBS as f64)),
        ("reps", Value::Num(reps as f64)),
        ("state_cached_s", Value::Num(state_new.mean())),
        ("state_recompute_s", Value::Num(state_old.mean())),
        ("state_speedup", Value::Num(state_speedup)),
        ("episode_cached_s", Value::Num(episode_new.mean())),
        ("episode_recompute_s", Value::Num(episode_old.mean())),
        ("episode_speedup", Value::Num(episode_speedup)),
        ("mutate_reset_s", Value::Num(mutate.mean())),
    ]);
    match write_bench_section("BENCH_partition.json", "env", section) {
        Ok(path) => println!("[wrote {path}]"),
        Err(e) => eprintln!("could not write BENCH_partition.json: {e}"),
    }
}
