//! Fig. 9 — dynamic performance of DRLGO/PTOM/GM/RM on pubmed:
//! system cost vs users, vs associations, under mobility, and
//! cross-server communication cost.  See bench::figs for the driver.

fn main() -> graphedge::Result<()> {
    graphedge::bench::figs::dynamic_cost_figure("pubmed")
}
