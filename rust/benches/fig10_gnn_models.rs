//! Fig. 10 — system cost of every method across GNN models (GCN, GAT,
//! GraphSAGE, SGC) × datasets, N = 300, E = 4800, with real fleet
//! inference (accuracy + execute time) for the DRLGO rows.

fn main() -> graphedge::Result<()> {
    graphedge::bench::figs::gnn_models_figure()
}
