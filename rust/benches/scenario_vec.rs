//! §Perf — scenario-diversity rollout: generation cost of a mixed
//! [`graphedge::scenario::ScenarioSet`] and the throughput of a
//! heterogeneous-slot [`graphedge::drl::vec_env::VecEnv`] (every slot
//! its own generated topology) across batch widths.
//!
//! Before any timing counts, the heterogeneous vector is asserted
//! deterministic: the same (set, seed, actions) rollout re-run under a
//! different build/step worker count must reproduce every assignment
//! bit for bit — the property `tests/properties.rs` proves across
//! seeds, re-checked here on the bench scenario.
//!
//! Three measurements per E:
//!
//! * **set generation** — materializing E train + holdout scenarios
//!   from the `mixed` spec (topology, positions, server + link draws);
//! * **state assembly** — one `states()` call over the mixed slots;
//! * **rollout throughput** — round-robin vector steps with churn +
//!   auto-reset on, in env steps per second.
//!
//! Emits `bench_results/scenario_vec.csv` and merges a `"scenario"`
//! section into `BENCH_partition.json` (repo root when present), next
//! to the `env`/`incremental`/`parallel`/`vec_env` sections.

use std::collections::BTreeMap;

use graphedge::bench::{fmt_secs, time_reps, write_bench_section, Table};
use graphedge::drl::env::OBS;
use graphedge::drl::vec_env::VecEnv;
use graphedge::drl::{baselines, EnvConfig};
use graphedge::net::SystemParams;
use graphedge::scenario::ScenarioSet;
use graphedge::util::json::Value;

fn build_set(params: &SystemParams, n_users: usize, n_assocs: usize, envs: usize) -> ScenarioSet {
    ScenarioSet::from_spec("mixed", n_users, n_assocs, params, envs, 0x5CE0).unwrap()
}

/// Same set + seed + actions, different worker counts: the rollout
/// must be bit-identical (see the module docs).
fn assert_worker_invariant(set: &ScenarioSet, cfg: &EnvConfig, envs: usize) {
    let rollout = |build_workers: usize, step_workers: usize| -> Vec<u64> {
        let mut venv = VecEnv::from_scenario_set(set, cfg, envs, 0xAB, build_workers);
        venv.set_workers(step_workers);
        venv.reset_all();
        let agents = venv.agents();
        let mut trace = Vec::new();
        for step in 0..24usize {
            let servers: Vec<usize> = (0..envs).map(|i| (step + i) % agents).collect();
            for res in venv.step_servers(&servers) {
                trace.push(res.outcome.assigned as u64);
                trace.push(res.reset as u64);
            }
        }
        trace
    };
    assert_eq!(
        rollout(1, 1),
        rollout(envs.max(2), 2),
        "heterogeneous rollout diverged across worker counts"
    );
}

struct Run {
    envs: usize,
    workers: usize,
    gen_s: f64,
    assembly_s: f64,
    steps_per_s: f64,
    episodes: usize,
}

fn main() {
    let smoke = std::env::var("GRAPHEDGE_BENCH_SMOKE").is_ok();
    let full_suite = std::env::var("GRAPHEDGE_BENCH_FULL").is_ok();
    let (n_users, n_assocs, reps) = if smoke {
        (40, 90, 1)
    } else if full_suite {
        (300, 4800, 10)
    } else {
        (150, 1200, 5)
    };

    let params = SystemParams::default();
    let cfg = EnvConfig { n_users, n_assocs, ..EnvConfig::default() };
    println!(
        "scenario vec: mixed spec (uniform/pa/clustered/hotspot), \
         {n_users} users x {n_assocs} assocs per slot, OBS={OBS}"
    );

    {
        let probe = build_set(&params, n_users, n_assocs, 4);
        assert_worker_invariant(&probe, &cfg, 4);
        println!("heterogeneous rollout verified worker-count invariant");
    }

    let mut t = Table::new(
        "scenario-diversity rollout across batch widths",
        &["E", "workers", "set gen", "states() / call", "rollout steps/s", "episodes"],
    );
    let mut runs = Vec::new();
    for envs in [4usize, 8] {
        // 1. Set generation (E train + E/4 holdout scenarios).
        let gen = time_reps(1, reps.max(2), || {
            std::hint::black_box(build_set(&params, n_users, n_assocs, envs));
        });
        let set = build_set(&params, n_users, n_assocs, envs);
        let mut venv = VecEnv::from_scenario_set(&set, &cfg, envs, 0xFACE, envs);
        venv.set_workers(0); // one worker per slot
        let workers = venv.workers();

        // 2. Batch state assembly over heterogeneous slots.
        let assembly = time_reps(3, reps.max(3) * 10, || {
            std::hint::black_box(venv.states());
        });

        // 3. Rollout throughput: round-robin policy, churn + auto-reset
        // on (the training loop's steady state).
        venv.set_churn(true);
        venv.reset_all();
        let agents = venv.agents();
        let vsteps_per_rep = if smoke { 8 } else { 2 * n_users };
        let mut servers = vec![0usize; envs];
        let mut step = 0usize;
        let roll = time_reps(1, reps, || {
            for _ in 0..vsteps_per_rep {
                for (i, s) in servers.iter_mut().enumerate() {
                    *s = (step + i) % agents;
                }
                std::hint::black_box(venv.step_servers(&servers));
                step += 1;
            }
        });
        let steps_per_s = (vsteps_per_rep * envs) as f64 / roll.mean().max(1e-12);

        // 4. Greedy evaluation over the holdout split exercises the
        // same machinery on scenarios training never saw.
        let eval_costs = baselines::run_greedy_eval_set(&set, &cfg, workers);
        assert_eq!(eval_costs.len(), set.eval.len());

        let episodes = venv.episodes_completed();
        t.row(vec![
            envs.to_string(),
            workers.to_string(),
            fmt_secs(gen.mean()),
            fmt_secs(assembly.mean()),
            format!("{steps_per_s:.0}"),
            episodes.to_string(),
        ]);
        runs.push(Run {
            envs,
            workers,
            gen_s: gen.mean(),
            assembly_s: assembly.mean(),
            steps_per_s,
            episodes,
        });
    }
    t.emit("scenario_vec");

    let obj = |pairs: Vec<(&str, Value)>| {
        Value::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect::<BTreeMap<_, _>>(),
        )
    };
    let section = obj(vec![
        (
            "_note",
            Value::Str(
                "Regenerate with `cargo bench --bench scenario_vec` (the bench \
                 rewrites this section).  The heterogeneous rollout is asserted \
                 worker-count invariant before timing."
                    .into(),
            ),
        ),
        ("n_users", Value::Num(n_users as f64)),
        ("n_assocs", Value::Num(n_assocs as f64)),
        ("obs_dim", Value::Num(OBS as f64)),
        ("reps", Value::Num(reps as f64)),
        (
            "runs",
            Value::Arr(
                runs.iter()
                    .map(|r| {
                        obj(vec![
                            ("envs", Value::Num(r.envs as f64)),
                            ("workers", Value::Num(r.workers as f64)),
                            ("set_gen_s", Value::Num(r.gen_s)),
                            ("state_assembly_s", Value::Num(r.assembly_s)),
                            ("rollout_steps_per_s", Value::Num(r.steps_per_s)),
                            ("episodes", Value::Num(r.episodes as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match write_bench_section("BENCH_partition.json", "scenario", section) {
        Ok(path) => println!("[wrote {path}]"),
        Err(e) => eprintln!("could not write BENCH_partition.json: {e}"),
    }
}
