//! Fig. 6 — graph-cut time: HiCut vs iterated max-flow min-cut [36].
//!
//! Sparse and non-sparse random graphs with integer edge weights in
//! [1, 100] and 25 edge servers, per §6.2.  The paper's edge counts
//! are reproduced in *shape* (E ∝ V for sparse, E ∝ 40·V dense-ward
//! for non-sparse, capped by the complete graph; the paper's literal
//! "500 vertices / 500100 edges" non-sparse point exceeds the complete
//! graph and is interpreted as a scaling description).  Expected
//! shape: HiCut wins by ~an order of magnitude on non-sparse graphs,
//! with the gap growing in |E|.

use graphedge::bench::{fmt_secs, Table};
use graphedge::graph::generate::{random_weights, uniform_random};
use graphedge::partition::{hicut, mincut_partition};
use graphedge::util::rng::Rng;

fn run(kind: &str, sizes: &[(usize, usize)], servers: usize) {
    let mut t = Table::new(
        &format!("Fig. 6 — {kind} graphs: cut time (25 servers, weights 1–100)"),
        &["|V|", "|E|", "HiCut", "min-cut [36]", "speedup",
          "HiCut cut-w", "min-cut cut-w"],
    );
    for &(v, e) in sizes {
        let mut rng = Rng::seed_from(0xF16 + v as u64);
        let g = uniform_random(v, e, &mut rng);
        let w = random_weights(&g, 1, 100, &mut rng);

        let t0 = std::time::Instant::now();
        let hp = hicut(&g, &|_| true);
        let t_hi = t0.elapsed().as_secs_f64();

        let t0 = std::time::Instant::now();
        let mp = mincut_partition(&g, &w, servers, &mut rng);
        let t_mc = t0.elapsed().as_secs_f64();

        t.row(vec![
            v.to_string(),
            e.to_string(),
            fmt_secs(t_hi),
            fmt_secs(t_mc),
            format!("{:.1}x", t_mc / t_hi.max(1e-9)),
            hp.cut_weight(&g, &w).to_string(),
            mp.cut_weight(&g, &w).to_string(),
        ]);
        eprintln!("[fig6 {kind}] |V|={v} |E|={e}: hicut {} mincut {}",
                  fmt_secs(t_hi), fmt_secs(t_mc));
    }
    t.emit(&format!("fig6_{kind}"));
}

fn main() {
    let full = std::env::var("GRAPHEDGE_BENCH_FULL").is_ok();
    let sparse: Vec<(usize, usize)> = [500usize, 2000, 5000, 10000, 20000]
        .iter()
        .map(|&v| (v, 10 * v))
        .collect();
    let nonsparse: Vec<(usize, usize)> = [500usize, 2000, 5000, 10000, 20000]
        .iter()
        .map(|&v| (v, (40 * v).min(v * (v - 1) / 4)))
        .collect();
    let (s, n) = if full {
        (sparse.as_slice(), nonsparse.as_slice())
    } else {
        (&sparse[..4], &nonsparse[..4])
    };
    run("sparse", s, 25);
    run("nonsparse", n, 25);
}
