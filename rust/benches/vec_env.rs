//! §Perf — vectorized multi-episode rollout: per-step state assembly
//! and rollout throughput of a [`graphedge::drl::vec_env::VecEnv`]
//! across batch widths E ∈ {1, 4, 16}.
//!
//! Before any timing counts, an E=1 vector (churn off) is asserted
//! trajectory-identical to a plain `Env` driven by the same policy —
//! the correctness contract `tests/properties.rs` proves across seeds,
//! re-checked here on the bench scenario.
//!
//! Two measurements per E:
//!
//! * **state assembly** — one `states()` call, the `E × M × OBS` batch
//!   matrix the training loops feed to `select_actions`;
//! * **rollout throughput** — round-robin vector steps with auto-reset
//!   and churn on, reported as environment steps per second (E env
//!   steps per vector step).
//!
//! Emits `bench_results/vec_env.csv` and merges a `"vec_env"` section
//! into `BENCH_partition.json` (repo root when present), next to the
//! `env`/`incremental`/`parallel` sections.

use std::collections::BTreeMap;

use graphedge::bench::{fmt_secs, time_reps, write_bench_section, Table};
use graphedge::drl::env::OBS;
use graphedge::drl::vec_env::VecEnv;
use graphedge::drl::{baselines, Env, EnvConfig};
use graphedge::graph::Dataset;
use graphedge::net::SystemParams;
use graphedge::util::json::Value;
use graphedge::util::rng::Rng;

/// E=1, churn off: the vector must replay a plain env bit for bit.
fn assert_e1_equivalent(proto: &Env) {
    let mut venv = VecEnv::replicate(proto, 1, 0xE0);
    venv.set_churn(false);
    venv.reset_all();
    let mut env = proto.clone();
    env.reset();
    let agents = env.agents();
    let steps = env.users.active_count().min(64);
    for step in 0..steps {
        let server = step % agents;
        let vres = venv.step_servers(&[server]);
        let out = env.step(server);
        assert_eq!(vres[0].outcome.assigned, out.assigned, "assignment diverged");
        if out.finished {
            env.reset();
        }
        let (a, b) = (venv.states(), env.state());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "state[{i}] diverged at step {step}");
        }
    }
}

struct Run {
    envs: usize,
    workers: usize,
    assembly_s: f64,
    steps_per_s: f64,
    episodes: usize,
}

fn main() {
    let smoke = std::env::var("GRAPHEDGE_BENCH_SMOKE").is_ok();
    let full_suite = std::env::var("GRAPHEDGE_BENCH_FULL").is_ok();
    let (ds_n, n_users, n_assocs, reps) = if smoke {
        (300, 60, 120, 1)
    } else if full_suite {
        (2000, 300, 4800, 20)
    } else {
        (1000, 150, 1200, 8)
    };

    let mut rng = Rng::seed_from(0x0ECE);
    let ds = Dataset::synthetic(ds_n, &mut rng);
    let cfg = EnvConfig { n_users, n_assocs, ..EnvConfig::default() };
    let proto = Env::new(&ds, SystemParams::default(), cfg, &mut rng);
    let agents = proto.agents();
    println!(
        "vec env: {n_users} users, {agents} agents, OBS={OBS} \
         (|V|={ds_n}, state row = {} floats)",
        agents * OBS
    );

    assert_e1_equivalent(&proto);
    println!("E=1 vector verified trajectory-identical to the plain env");

    let mut t = Table::new(
        "vectorized rollout across batch widths",
        &["E", "workers", "states() / call", "rollout steps/s", "episodes"],
    );
    let mut runs = Vec::new();
    for envs in [1usize, 4, 16] {
        let mut venv = VecEnv::replicate(&proto, envs, 0xBEEF + envs as u64);
        venv.set_workers(0); // one worker per slot
        let workers = venv.workers();

        // 1. Batch state assembly.
        let assembly = time_reps(3, reps.max(3) * 10, || {
            std::hint::black_box(venv.states());
        });

        // 2. Rollout throughput: round-robin policy, churn + auto-reset
        // on (the training loop's steady state).
        venv.set_churn(true);
        venv.reset_all();
        let vsteps_per_rep = if smoke { 8 } else { 2 * n_users };
        let mut servers = vec![0usize; envs];
        let mut step = 0usize;
        let roll = time_reps(1, reps, || {
            for _ in 0..vsteps_per_rep {
                for (i, s) in servers.iter_mut().enumerate() {
                    *s = (step + i) % agents;
                }
                std::hint::black_box(venv.step_servers(&servers));
                step += 1;
            }
        });
        let steps_per_s = (vsteps_per_rep * envs) as f64 / roll.mean().max(1e-12);

        // 3. Batched greedy evaluation exercises the same fan-out.
        let costs = baselines::run_greedy_vec(&mut venv);
        assert_eq!(costs.len(), envs);

        let episodes = venv.episodes_completed();
        t.row(vec![
            envs.to_string(),
            workers.to_string(),
            fmt_secs(assembly.mean()),
            format!("{steps_per_s:.0}"),
            episodes.to_string(),
        ]);
        runs.push(Run {
            envs,
            workers,
            assembly_s: assembly.mean(),
            steps_per_s,
            episodes,
        });
    }
    t.emit("vec_env");

    let obj = |pairs: Vec<(&str, Value)>| {
        Value::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect::<BTreeMap<_, _>>(),
        )
    };
    let section = obj(vec![
        (
            "_note",
            Value::Str(
                "Regenerate with `cargo bench --bench vec_env` (the bench \
                 rewrites this section).  An E=1 vector is asserted \
                 trajectory-identical to a plain Env before timing."
                    .into(),
            ),
        ),
        ("n_users", Value::Num(n_users as f64)),
        ("agents", Value::Num(agents as f64)),
        ("obs_dim", Value::Num(OBS as f64)),
        ("reps", Value::Num(reps as f64)),
        (
            "runs",
            Value::Arr(
                runs.iter()
                    .map(|r| {
                        obj(vec![
                            ("envs", Value::Num(r.envs as f64)),
                            ("workers", Value::Num(r.workers as f64)),
                            ("state_assembly_s", Value::Num(r.assembly_s)),
                            ("rollout_steps_per_s", Value::Num(r.steps_per_s)),
                            ("episodes", Value::Num(r.episodes as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match write_bench_section("BENCH_partition.json", "vec_env", section) {
        Ok(path) => println!("[wrote {path}]"),
        Err(e) => eprintln!("could not write BENCH_partition.json: {e}"),
    }
}
