//! §Perf — sequential vs sharded HiCut across worker counts.
//!
//! The workload is a fragmented edge-user topology (independent
//! preferential-attachment communities — geographically separate
//! clusters, the shape component sharding targets; a single giant
//! component falls back to the sequential cut by design).  Every
//! parallel layout is asserted identical to the sequential one before
//! its timing counts — the shard/merge equivalence of
//! `partition::parallel` is a hard invariant here, not a benchmark
//! footnote.
//!
//! Emits `bench_results/partition_parallel.csv` and merges a
//! `"parallel"` section into `BENCH_partition.json` (repo root when
//! present) next to the incremental bench's section.

use std::collections::BTreeMap;

use graphedge::bench::{fmt_secs, time_reps, write_bench_section, Table};
use graphedge::graph::generate::preferential_attachment;
use graphedge::graph::Graph;
use graphedge::partition::{hicut, parallel_hicut_pool};
use graphedge::util::json::Value;
use graphedge::util::rng::Rng;
use graphedge::util::threadpool::ThreadPool;

/// `blocks` disjoint PA communities of `block_n` users each.
fn clustered(blocks: usize, block_n: usize, deg: usize, rng: &mut Rng) -> Graph {
    let mut edges: Vec<(u32, u32)> = Vec::new();
    for b in 0..blocks {
        let off = (b * block_n) as u32;
        let g = preferential_attachment(block_n, deg, rng);
        edges.extend(g.edge_list().into_iter().map(|(u, v)| (u + off, v + off)));
    }
    Graph::from_edges(blocks * block_n, &edges)
}

struct Run {
    workers: usize,
    seq_s: f64,
    par_s: f64,
    speedup: f64,
}

fn main() {
    // GRAPHEDGE_BENCH_SMOKE=1: few tiny communities, one rep — CI
    // executes the bench (layout-equality asserts included) cheaply.
    let smoke = std::env::var("GRAPHEDGE_BENCH_SMOKE").is_ok();
    let full_suite = std::env::var("GRAPHEDGE_BENCH_FULL").is_ok();
    let (blocks, block_n, reps) = if smoke {
        (4, 60, 1)
    } else if full_suite {
        (64, 500, 5)
    } else {
        (32, 150, 3)
    };
    let deg = 6;
    let mut rng = Rng::seed_from(0x5AAD);
    let g = clustered(blocks, block_n, deg, &mut rng);
    let n = g.len();
    println!(
        "sharded HiCut: {blocks} communities x {block_n} users \
         (|V|={n} |E|={})",
        g.num_edges()
    );

    let seq_sample = time_reps(1, reps, || {
        std::hint::black_box(hicut(&g, &|_| true));
    });
    let seq_s = seq_sample.mean();
    let reference = hicut(&g, &|_| true);

    let mut t = Table::new(
        "sequential vs sharded HiCut",
        &["workers", "sequential", "sharded", "speedup", "subgraphs", "cut edges"],
    );
    let mut runs = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(workers);
        let p = parallel_hicut_pool(&g, |_| true, &pool);
        assert_eq!(
            p.subgraphs, reference.subgraphs,
            "sharded layout diverged from sequential at {workers} workers"
        );
        let par_sample = time_reps(1, reps, || {
            std::hint::black_box(parallel_hicut_pool(&g, |_| true, &pool));
        });
        let par_s = par_sample.mean();
        let speedup = seq_s / par_s.max(1e-12);
        t.row(vec![
            workers.to_string(),
            fmt_secs(seq_s),
            fmt_secs(par_s),
            format!("{speedup:.2}x"),
            p.len().to_string(),
            p.cut_edges(&g).to_string(),
        ]);
        runs.push(Run { workers, seq_s, par_s, speedup });
        assert_eq!(pool.panicked(), 0, "shard jobs must not panic");
    }
    t.emit("partition_parallel");

    let obj = |pairs: Vec<(&str, Value)>| {
        Value::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect::<BTreeMap<_, _>>(),
        )
    };
    let section = obj(vec![
        (
            "_note",
            Value::Str(
                "Regenerate with `cargo bench --bench partition_parallel` \
                 (the bench rewrites this section).  Sequential-equivalent \
                 layouts are asserted before timing."
                    .into(),
            ),
        ),
        ("n_users", Value::Num(n as f64)),
        ("communities", Value::Num(blocks as f64)),
        ("mean_degree", Value::Num(deg as f64)),
        ("reps", Value::Num(reps as f64)),
        (
            "runs",
            Value::Arr(
                runs.iter()
                    .map(|r| {
                        obj(vec![
                            ("workers", Value::Num(r.workers as f64)),
                            ("sequential_s", Value::Num(r.seq_s)),
                            ("sharded_s", Value::Num(r.par_s)),
                            ("speedup", Value::Num(r.speedup)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match write_bench_section("BENCH_partition.json", "parallel", section) {
        Ok(path) => println!("[wrote {path}]"),
        Err(e) => eprintln!("could not write BENCH_partition.json: {e}"),
    }
}
