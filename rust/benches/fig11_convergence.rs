//! Fig. 11 — training-reward convergence, DRLGO vs PTOM, with 20%
//! user/association churn per episode (the paper's §6.4 protocol).

fn main() -> graphedge::Result<()> {
    graphedge::bench::figs::convergence_figure()
}
