//! §Perf — serving-path inference latency through the runtime backend:
//! one `GnnService::infer` call (padded subgraph → logits) per model,
//! across real subgraph sizes, on the default backend (the native
//! kernels unless `$GRAPHEDGE_ARTIFACTS` + `--features xla` routes
//! through PJRT).
//!
//! This is the request-path cost the router's deadline accounting has
//! to cover, so the table reports p99 next to the mean.  Merges an
//! `"inference"` section into `BENCH_partition.json` (repo root when
//! present), next to the partition and env benches' sections.

use std::collections::BTreeMap;

use graphedge::bench::{fmt_secs, time_reps, write_bench_section, Table};
use graphedge::runtime::Runtime;
use graphedge::serving::gnn::MODELS;
use graphedge::serving::{GnnService, PaddedGraph};
use graphedge::util::json::Value;
use graphedge::util::rng::Rng;

struct Run {
    model: &'static str,
    real_size: usize,
    infer_s_mean: f64,
    infer_s_p99: f64,
    rows_per_s: f64,
}

fn main() {
    // GRAPHEDGE_BENCH_SMOKE=1: tiny sizes, minimal reps — CI executes
    // the bench (and its JSON section write) without real timing.
    let smoke = std::env::var("GRAPHEDGE_BENCH_SMOKE").is_ok();
    let full_suite = std::env::var("GRAPHEDGE_BENCH_FULL").is_ok();
    let (sizes, warmup, reps): (&[usize], usize, usize) = if smoke {
        (&[24], 1, 3)
    } else if full_suite {
        (&[32, 96, 160], 5, 100)
    } else {
        (&[32, 96, 160], 3, 30)
    };

    let rt = Runtime::open_default().expect("runtime");
    let ds = rt.dataset("pubmed").expect("pubmed dataset");
    let n_max = rt.manifest.constant("n_max").expect("n_max");
    let c_pad = rt.manifest.constant("c_pad").expect("c_pad");
    println!(
        "inference latency: backend={}, pubmed, n_max={n_max}, c_pad={c_pad}, reps={reps}",
        rt.backend_name()
    );

    let mut t = Table::new(
        "GNN inference latency (one padded-subgraph forward)",
        &["model", "real n", "mean", "p99", "rows/s"],
    );
    let mut runs: Vec<Run> = Vec::new();
    for &model in MODELS {
        let svc = GnnService::load(&rt, model, "pubmed")
            .unwrap_or_else(|e| panic!("{model}_pubmed: {e:#}"));
        for &n in sizes {
            let mut rng = Rng::seed_from(0x1F0 + n as u64);
            let scen = graphedge::graph::sample::sample_scenario(&ds, n, 3 * n, &mut rng);
            let verts: Vec<usize> = (0..n).collect();
            let p = PaddedGraph::build(
                &scen.graph,
                &scen.users,
                &ds,
                &verts,
                svc.n_max,
                svc.feat_pad,
            );
            let s = time_reps(warmup, reps, || {
                std::hint::black_box(svc.infer(&p).expect("infer"));
            });
            let mean = s.mean();
            let p99 = s.percentile(99.0);
            // Throughput counts the whole padded matrix — that is what
            // the kernels actually process per request.
            let rows_per_s = svc.n_max as f64 / mean.max(1e-12);
            t.row(vec![
                model.into(),
                format!("{n}"),
                fmt_secs(mean),
                fmt_secs(p99),
                format!("{rows_per_s:.0}"),
            ]);
            runs.push(Run { model, real_size: n, infer_s_mean: mean, infer_s_p99: p99, rows_per_s });
        }
    }
    t.emit("inference");

    let obj = |pairs: Vec<(&str, Value)>| {
        Value::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect::<BTreeMap<_, _>>(),
        )
    };
    let section = obj(vec![
        (
            "_note",
            Value::Str(
                "Regenerate with `cargo bench --bench inference` (the bench \
                 rewrites this section).  Numeric parity of the kernels \
                 behind these timings is pinned by tests/kernel_parity.rs, \
                 not re-proved here."
                    .into(),
            ),
        ),
        ("backend", Value::Str(rt.backend_name().into())),
        ("n_max", Value::Num(n_max as f64)),
        ("c_pad", Value::Num(c_pad as f64)),
        ("reps", Value::Num(reps as f64)),
        (
            "runs",
            Value::Arr(
                runs.iter()
                    .map(|r| {
                        obj(vec![
                            ("model", Value::Str(r.model.into())),
                            ("real_size", Value::Num(r.real_size as f64)),
                            ("infer_s_mean", Value::Num(r.infer_s_mean)),
                            ("infer_s_p99", Value::Num(r.infer_s_p99)),
                            ("rows_per_s", Value::Num(r.rows_per_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match write_bench_section("BENCH_partition.json", "inference", section) {
        Ok(path) => println!("[wrote {path}]"),
        Err(e) => eprintln!("could not write BENCH_partition.json: {e}"),
    }
}
