//! §Perf — the versioned memoization plane (`util::version`): what a
//! version-checked read costs when the cell is current, what a rebuild
//! costs when a producer bumped, and what fraction of reads hit under
//! realistic churn cadences.
//!
//! Three views:
//!
//! * the rate-table cell — a memoized `Env::rate_tables` read (version
//!   compare + borrow) against `RateTables::build` from scratch,
//! * tabled `Env::evaluate` against an untabled fresh `CostModel`
//!   (the end-to-end win the tables buy the reward path),
//! * churn-cadence runs — episodes with a `mutate` every K episodes,
//!   reporting per-cell hit rates from `Env::memo_counters` and the
//!   cold (post-churn, both cells rebuilt) vs warm first-read cost.
//!
//! Emits `bench_results/memo.csv` and merges a `"memo"` section into
//! `BENCH_partition.json` (repo root when present), next to the env
//! and partition benches' sections.

use std::collections::BTreeMap;

use graphedge::bench::{fmt_secs, time_reps, write_bench_section, Table};
use graphedge::drl::env::OBS;
use graphedge::drl::{Env, EnvConfig};
use graphedge::graph::Dataset;
use graphedge::net::cost::{CostModel, RateTables};
use graphedge::net::SystemParams;
use graphedge::util::json::Value;
use graphedge::util::rng::Rng;

/// A fresh, table-free cost model over the env's live state — the
/// recompute the memoized cells replace.
fn fresh_model(env: &Env) -> CostModel<'_> {
    CostModel::new(&env.params, &env.net, &env.links, &env.users, &env.layer_dims)
        .with_profile(env.profile)
}

struct CadenceRun {
    mutate_every: usize,
    episodes: usize,
    obs_hit_rate: f64,
    rates_hit_rate: f64,
    /// First state+evaluate after a churn (both cells stale).
    cold_read_s: f64,
    /// The same pair mid-episode with both cells current.
    warm_read_s: f64,
    rebuild_penalty: f64,
}

fn cadence(env: &mut Env, rng: &mut Rng, mutate_every: usize, episodes: usize) -> CadenceRun {
    let before = env.memo_counters();
    let (mut cold_s, mut colds) = (0.0f64, 0usize);
    let (mut warm_s, mut warms) = (0.0f64, 0usize);
    for ep in 0..episodes {
        let churned = ep % mutate_every == 0;
        if churned {
            env.mutate(rng);
        }
        env.reset();
        let t0 = std::time::Instant::now();
        std::hint::black_box(env.state());
        std::hint::black_box(env.evaluate());
        let dt = t0.elapsed().as_secs_f64();
        if churned {
            cold_s += dt;
            colds += 1;
        }
        let agents = env.agents();
        let mut i = 0;
        while !env.finished() {
            env.step(i % agents);
            i += 1;
        }
        let t0 = std::time::Instant::now();
        std::hint::black_box(env.state());
        std::hint::black_box(env.evaluate());
        warm_s += t0.elapsed().as_secs_f64();
        warms += 1;
    }
    let (obs_r, obs_b, rate_r, rate_b) = env.memo_counters();
    let (obs_r, obs_b) = (obs_r - before.0, obs_b - before.1);
    let (rate_r, rate_b) = (rate_r - before.2, rate_b - before.3);
    let cold = cold_s / colds.max(1) as f64;
    let warm = warm_s / warms.max(1) as f64;
    CadenceRun {
        mutate_every,
        episodes,
        obs_hit_rate: 1.0 - obs_b as f64 / obs_r.max(1) as f64,
        rates_hit_rate: 1.0 - rate_b as f64 / rate_r.max(1) as f64,
        cold_read_s: cold,
        warm_read_s: warm,
        rebuild_penalty: cold / warm.max(1e-12),
    }
}

fn main() {
    // GRAPHEDGE_BENCH_SMOKE=1: tiny sizes, minimal reps — CI executes
    // the bench (and its JSON section write) without real timing.
    let smoke = std::env::var("GRAPHEDGE_BENCH_SMOKE").is_ok();
    let full_suite = std::env::var("GRAPHEDGE_BENCH_FULL").is_ok();
    let (ds_n, n_users, n_assocs, reps, episodes) = if smoke {
        (300, 60, 120, 1, 2)
    } else if full_suite {
        (4000, 600, 7200, 200, 32)
    } else {
        (2000, 300, 4800, 50, 12)
    };

    let mut rng = Rng::seed_from(0x3E30);
    let ds = Dataset::synthetic(ds_n, &mut rng);
    let cfg = EnvConfig { n_users, n_assocs, ..EnvConfig::default() };
    let mut env = Env::new(&ds, SystemParams::default(), cfg, &mut rng);
    let agents = env.agents();
    println!(
        "versioned memo plane: {n_users} users, {agents} agents, OBS={OBS} (|V|={ds_n})"
    );

    let mut t = Table::new(
        "versioned memo cells: hit vs rebuild",
        &["op", "memoized", "fresh", "speedup"],
    );

    // 1. The rate-table cell: a current-version read against a
    // from-scratch table build.
    let _ = env.rate_tables(); // warm the cell
    let hit = time_reps(10, reps, || {
        std::hint::black_box(env.rate_tables().server.len());
    });
    let build = time_reps(2, reps, || {
        std::hint::black_box(RateTables::build(&fresh_model(&env)));
    });
    let rates_speedup = build.mean() / hit.mean().max(1e-12);
    t.row(vec![
        "rate_tables() hit".into(),
        fmt_secs(hit.mean()),
        fmt_secs(build.mean()),
        format!("{rates_speedup:.1}x"),
    ]);

    // 2. End to end: tabled evaluate vs an untabled fresh model.
    let tabled = time_reps(2, reps, || {
        std::hint::black_box(env.evaluate());
    });
    let untabled = time_reps(2, reps, || {
        std::hint::black_box(fresh_model(&env).evaluate(&env.offload));
    });
    let eval_speedup = untabled.mean() / tabled.mean().max(1e-12);
    t.row(vec![
        "evaluate() tabled".into(),
        fmt_secs(tabled.mean()),
        fmt_secs(untabled.mean()),
        format!("{eval_speedup:.1}x"),
    ]);

    // 3. Hit rates and cold/warm read costs across churn cadences.
    let mut runs = Vec::new();
    let mut cadence_rng = Rng::seed_from(0x3E31);
    for mutate_every in [1usize, 4, 16] {
        let r = cadence(&mut env, &mut cadence_rng, mutate_every, episodes);
        t.row(vec![
            format!("churn every {} ep", r.mutate_every),
            format!(
                "hits {:.0}%/{:.0}%",
                r.obs_hit_rate * 100.0,
                r.rates_hit_rate * 100.0
            ),
            fmt_secs(r.cold_read_s),
            format!("cold {:.1}x warm", r.rebuild_penalty),
        ]);
        runs.push(r);
    }
    t.emit("memo");

    let obj = |pairs: Vec<(&str, Value)>| {
        Value::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect::<BTreeMap<_, _>>(),
        )
    };
    let section = obj(vec![
        (
            "_note",
            Value::Str(
                "Regenerate with `cargo bench --bench memo` (the bench \
                 rewrites this section).  Bit-identity of memoized vs fresh \
                 values is pinned by tests/properties.rs, not re-proved here."
                    .into(),
            ),
        ),
        ("n_users", Value::Num(n_users as f64)),
        ("agents", Value::Num(agents as f64)),
        ("obs_dim", Value::Num(OBS as f64)),
        ("reps", Value::Num(reps as f64)),
        ("rates_hit_s", Value::Num(hit.mean())),
        ("rates_build_s", Value::Num(build.mean())),
        ("rates_speedup", Value::Num(rates_speedup)),
        ("evaluate_tabled_s", Value::Num(tabled.mean())),
        ("evaluate_fresh_s", Value::Num(untabled.mean())),
        ("evaluate_speedup", Value::Num(eval_speedup)),
        (
            "runs",
            Value::Arr(
                runs.iter()
                    .map(|r| {
                        obj(vec![
                            ("mutate_every", Value::Num(r.mutate_every as f64)),
                            ("episodes", Value::Num(r.episodes as f64)),
                            ("obs_hit_rate", Value::Num(r.obs_hit_rate)),
                            ("rates_hit_rate", Value::Num(r.rates_hit_rate)),
                            ("cold_read_s", Value::Num(r.cold_read_s)),
                            ("warm_read_s", Value::Num(r.warm_read_s)),
                            ("rebuild_penalty", Value::Num(r.rebuild_penalty)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match write_bench_section("BENCH_partition.json", "memo", section) {
        Ok(path) => println!("[wrote {path}]"),
        Err(e) => eprintln!("could not write BENCH_partition.json: {e}"),
    }
}
