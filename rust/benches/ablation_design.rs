//! Design-choice ablations (DESIGN.md §Key-design-decisions):
//!
//! 1. ζ_sp sweep — how strongly the R_sp subgraph-colocation term
//!    (Eq. 25) should weigh against the marginal cost.
//! 2. Halo hops — 2-hop halos give exact 2-layer GNN inference; 1-hop
//!    trades boundary-accuracy for less cross-server traffic.
//! 3. Router batch size — latency/throughput tradeoff of the dynamic
//!    batcher.

use graphedge::bench::Table;
use graphedge::coordinator::Controller;
use graphedge::drl::{baselines, MaddpgConfig, Method};
use graphedge::net::SystemParams;
use graphedge::serving::{Fleet, GnnService};
use graphedge::util::rng::Rng;

fn zeta_sweep(ctrl: &Controller) -> graphedge::Result<()> {
    let episodes: usize = std::env::var("GRAPHEDGE_BENCH_EPISODES")
        .ok().and_then(|v| v.parse().ok()).unwrap_or(30);
    let mut t = Table::new(
        "ablation: R_sp weight ζ (Eq. 25) — cost & cross-traffic after training",
        &["zeta_sp", "system cost C", "cross-Mb", "cut-size servers/subgraph"],
    );
    for &zeta in &[0.0, 0.1, 0.5, 2.0] {
        let mut params = SystemParams::default();
        params.zeta_sp = zeta;
        let ctrl2 = Controller::new(params)?; // fresh runtime w/ params
        let cfg = MaddpgConfig { episodes, ..MaddpgConfig::default() };
        let (mut tr, _, _) = ctrl2.train_drlgo("cora", false, 150, 900, &cfg)?;
        let mut rng = Rng::seed_from(404);
        let mut env = ctrl2.make_env(Method::Drlgo, "cora", 150, 900, &mut rng)?;
        tr.policy_offload(&mut env)?;
        let c = env.evaluate();
        // Mean number of servers used per (multi-user) subgraph.
        let mut spread = 0.0;
        let mut count = 0.0;
        let subs: std::collections::HashSet<usize> =
            env.subgraph_of.iter().copied().filter(|&s| s != usize::MAX).collect();
        for sg in subs {
            let members: Vec<usize> = (0..env.users.capacity())
                .filter(|&v| env.subgraph_of[v] == sg && env.users.is_active(v))
                .collect();
            if members.len() < 2 {
                continue;
            }
            let servers: std::collections::HashSet<usize> =
                members.iter().map(|&v| env.offload.server[v]).collect();
            spread += servers.len() as f64;
            count += 1.0;
        }
        t.row(vec![
            format!("{zeta}"),
            format!("{:.3}", c.total()),
            format!("{:.1}", c.cross_mb),
            format!("{:.2}", if count > 0.0 { spread / count } else { 0.0 }),
        ]);
        let _ = ctrl;
    }
    t.emit("ablation_zeta");
    Ok(())
}

fn halo_sweep(ctrl: &Controller) -> graphedge::Result<()> {
    let mut t = Table::new(
        "ablation: halo hops — accuracy vs cross-server fetch volume",
        &["hops", "accuracy", "halo fetches", "halo Mb", "exec (s)"],
    );
    let svc = GnnService::load(&ctrl.rt, "gcn", "cora")?;
    let ds = ctrl.dataset("cora")?;
    for hops in [0usize, 1, 2] {
        let mut rng = Rng::seed_from(17);
        let mut env = ctrl.make_env(Method::Greedy, "cora", 150, 600, &mut rng)?;
        baselines::run_greedy(&mut env);
        let scenario = graphedge::graph::sample::Scenario {
            users: env.scenario.users.clone(),
            graph: env.users.graph().clone(),
        };
        let fleet = Fleet::new(&svc, &scenario, ds);
        let users = &env.users;
        let alive = |v: usize| users.is_active(v);
        let rep = fleet.infer_round_hops(&env.offload, &alive, env.net.len(), None, hops)?;
        t.row(vec![
            hops.to_string(),
            format!("{:.3}", fleet.accuracy(&rep, &alive)),
            rep.halo_fetches.to_string(),
            format!("{:.1}", rep.halo_mb),
            format!("{:.3}", rep.execute_s),
        ]);
    }
    t.emit("ablation_halo");
    Ok(())
}

fn batch_sweep(ctrl: &Controller) -> graphedge::Result<()> {
    let mut t = Table::new(
        "ablation: dynamic batcher max_batch — latency vs throughput",
        &["max_batch", "throughput req/s", "p50 ms", "p99 ms", "batches"],
    );
    for max_batch in [8usize, 32, 64, 128] {
        std::env::set_var("GRAPHEDGE_MAX_BATCH", max_batch.to_string());
        let stats = graphedge::serving::serve_run(ctrl, "cora", "gcn", 150, 600, 600, 5)?;
        t.row(vec![
            max_batch.to_string(),
            format!("{:.0}", stats.requests as f64 / stats.total_s),
            format!("{:.3}", stats.latency_p50_s * 1e3),
            format!("{:.3}", stats.latency_p99_s * 1e3),
            stats.batches.to_string(),
        ]);
    }
    std::env::remove_var("GRAPHEDGE_MAX_BATCH");
    t.emit("ablation_batch");
    Ok(())
}

fn main() -> graphedge::Result<()> {
    let ctrl = Controller::new(SystemParams::default())?;
    halo_sweep(&ctrl)?;
    batch_sweep(&ctrl)?;
    zeta_sweep(&ctrl)?;
    Ok(())
}
