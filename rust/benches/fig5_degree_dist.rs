//! Fig. 5 — vertex degree distributions of CiteSeer / Cora / PubMed.
//!
//! Regenerates the three panels as (degree, count) CSV series plus a
//! summary table; the synthetic datasets are matched to the real ones
//! in |V|, |E| and tail shape (see DESIGN.md §Substitutions).

use graphedge::bench::Table;
use graphedge::graph::stats::{degree_distribution, degree_summary, tail_fraction};
use graphedge::runtime::Runtime;

fn main() -> graphedge::Result<()> {
    let rt = Runtime::open_default()?;
    let mut summary = Table::new(
        "Fig. 5 — degree distribution summary",
        &["dataset", "|V|", "|E|", "min", "median", "mean", "max", "P(deg>4·mean)"],
    );
    for name in ["citeseer", "cora", "pubmed"] {
        let ds = rt.dataset(name)?;
        let s = degree_summary(&ds.graph);
        summary.row(vec![
            name.into(),
            ds.n.to_string(),
            ds.graph.num_edges().to_string(),
            s.min.to_string(),
            s.median.to_string(),
            format!("{:.2}", s.mean),
            s.max.to_string(),
            format!("{:.4}", tail_fraction(&ds.graph, 4.0)),
        ]);
        let mut dist = Table::new(
            &format!("Fig. 5 — {name} degree distribution"),
            &["degree", "count"],
        );
        for (d, c) in degree_distribution(&ds.graph) {
            dist.row(vec![d.to_string(), c.to_string()]);
        }
        // CSV only (the full series is long); table print skipped.
        let _ = std::fs::create_dir_all("bench_results");
        let csv: String = std::iter::once("degree,count".to_string())
            .chain(dist.rows.iter().map(|r| r.join(",")))
            .collect::<Vec<_>>()
            .join("\n");
        std::fs::write(format!("bench_results/fig5_{name}.csv"), csv)?;
        println!("[wrote bench_results/fig5_{name}.csv]");
    }
    summary.emit("fig5_summary");
    Ok(())
}
