//! §Perf — incremental partition repair vs full HiCut recut across
//! churn rates (the fig6-style companion for `partition::incremental`).
//!
//! For each churn rate, T steps of §3.2 dynamics run on a 2000-user
//! preferential-attachment scenario; every step is both repaired
//! incrementally and recut from scratch, so the two columns describe
//! the identical graph sequence.  Emits
//! `bench_results/partition_incremental.csv` and records the perf
//! trajectory into `BENCH_partition.json` (repo root when present).
//!
//! The paper-default point (20% user / 20% association churn) carries
//! the ISSUE acceptance gate: repair ≥ 5× faster than a full recut
//! with the mean cut-edge ratio within 1.10 of the fresh full cut.

use std::collections::BTreeMap;

use graphedge::bench::{fmt_secs, write_bench_section, Table};
use graphedge::graph::dynamic::{ChurnConfig, DynamicGraph};
use graphedge::graph::generate::preferential_attachment;
use graphedge::partition::hicut;
use graphedge::partition::incremental::{IncrementalConfig, IncrementalPartitioner};
use graphedge::util::json::Value;
use graphedge::util::rng::Rng;

struct Run {
    churn: f64,
    inc_step_s: f64,
    full_step_s: f64,
    speedup: f64,
    /// Mean of (incremental cut / fresh full-recut cut) per step.
    cut_ratio_mean: f64,
    full_fallbacks: usize,
    local_recuts: usize,
}

fn run(n: usize, mean_deg: usize, churn: f64, steps: usize) -> Run {
    let mut rng = Rng::seed_from(0x1A7 + (churn * 100.0) as u64);
    let g = preferential_attachment(n, mean_deg, &mut rng);
    let mut users = DynamicGraph::new(g, vec![1.0; n], 2000.0, &mut rng);
    users.record_deltas(true);
    let mut inc = IncrementalPartitioner::from_users(&users, IncrementalConfig::default());
    let cfg = ChurnConfig {
        user_change_rate: churn,
        assoc_change_rate: churn,
        ..ChurnConfig::default()
    };
    let (mut inc_s, mut full_s, mut ratio_sum) = (0.0f64, 0.0f64, 0.0f64);
    for _ in 0..steps {
        users.step(&cfg, &mut rng);
        let deltas = users.drain_deltas();

        let t0 = std::time::Instant::now();
        let stats = inc.apply(&users, &deltas);
        inc_s += t0.elapsed().as_secs_f64();

        let t0 = std::time::Instant::now();
        let full = hicut(users.graph(), |v| users.is_active(v));
        full_s += t0.elapsed().as_secs_f64();

        let full_cut = full.cut_edges(users.graph()).max(1);
        ratio_sum += stats.cut_edges as f64 / full_cut as f64;
    }
    Run {
        churn,
        inc_step_s: inc_s / steps as f64,
        full_step_s: full_s / steps as f64,
        speedup: full_s / inc_s.max(1e-12),
        cut_ratio_mean: ratio_sum / steps as f64,
        full_fallbacks: inc.full_recuts.saturating_sub(1),
        local_recuts: inc.local_recuts,
    }
}

fn main() {
    // GRAPHEDGE_BENCH_SMOKE=1: tiny graph, two steps per churn rate —
    // CI executes the bench (and its JSON write) without real timing.
    let smoke = std::env::var("GRAPHEDGE_BENCH_SMOKE").is_ok();
    let full_suite = std::env::var("GRAPHEDGE_BENCH_FULL").is_ok();
    let steps = if smoke {
        2
    } else if full_suite {
        40
    } else {
        20
    };
    let (n, mean_deg) = if smoke { (300, 4) } else { (2000, 6) };

    let mut t = Table::new(
        "incremental repair vs full HiCut recut (2000 users)",
        &["churn", "repair/step", "full/step", "speedup", "cut ratio",
          "fallbacks", "local recuts"],
    );
    let mut runs = Vec::new();
    for churn in [0.05, 0.10, 0.20, 0.40] {
        let r = run(n, mean_deg, churn, steps);
        t.row(vec![
            format!("{:.0}%", churn * 100.0),
            fmt_secs(r.inc_step_s),
            fmt_secs(r.full_step_s),
            format!("{:.1}x", r.speedup),
            format!("{:.3}", r.cut_ratio_mean),
            r.full_fallbacks.to_string(),
            r.local_recuts.to_string(),
        ]);
        runs.push(r);
    }
    t.emit("partition_incremental");

    // Acceptance gate at the paper-default 20% churn point (not
    // meaningful on the smoke-path sizes).
    if !smoke {
        let paper = &runs[2];
        let pass = paper.speedup >= 5.0 && paper.cut_ratio_mean <= 1.10;
        println!(
            "paper-default point (20% churn): speedup {:.1}x (target >=5x), \
             cut ratio {:.3} (target <=1.10) — {}",
            paper.speedup,
            paper.cut_ratio_mean,
            if pass { "PASS" } else { "FAIL" },
        );
    }

    // Perf-trajectory section for future PRs, merged into the shared
    // partition results file (the `partition_parallel` bench owns a
    // sibling section).
    let obj = |pairs: Vec<(&str, Value)>| {
        Value::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect::<BTreeMap<_, _>>(),
        )
    };
    let section = obj(vec![
        (
            "_note",
            Value::Str(
                "Regenerate with `cargo bench --bench partition_incremental` \
                 (the bench rewrites this section)."
                    .into(),
            ),
        ),
        ("n_users", Value::Num(n as f64)),
        ("mean_degree", Value::Num(mean_deg as f64)),
        ("steps", Value::Num(steps as f64)),
        // Keep the acceptance thresholds in the file itself so future
        // PRs can gate against them without digging through bench
        // source.
        (
            "targets",
            obj(vec![
                ("paper_default_churn", Value::Num(0.2)),
                ("min_speedup_vs_full_recut", Value::Num(5.0)),
                ("max_cut_ratio_vs_fresh_full_cut", Value::Num(1.1)),
            ]),
        ),
        (
            "runs",
            Value::Arr(
                runs.iter()
                    .map(|r| {
                        obj(vec![
                            ("churn", Value::Num(r.churn)),
                            ("repair_step_s", Value::Num(r.inc_step_s)),
                            ("full_step_s", Value::Num(r.full_step_s)),
                            ("speedup", Value::Num(r.speedup)),
                            ("cut_ratio_mean", Value::Num(r.cut_ratio_mean)),
                            ("full_fallbacks", Value::Num(r.full_fallbacks as f64)),
                            ("local_recuts", Value::Num(r.local_recuts as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match write_bench_section("BENCH_partition.json", "incremental", section) {
        Ok(path) => println!("[wrote {path}]"),
        Err(e) => eprintln!("could not write BENCH_partition.json: {e}"),
    }
}
