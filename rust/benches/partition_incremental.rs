//! §Perf — incremental partition repair vs full HiCut recut across
//! churn rates (the fig6-style companion for `partition::incremental`).
//!
//! For each churn rate, T steps of §3.2 dynamics run on a 2000-user
//! preferential-attachment scenario; every step is both repaired
//! incrementally and recut from scratch, so the two columns describe
//! the identical graph sequence.  Emits
//! `bench_results/partition_incremental.csv` and records the perf
//! trajectory into `BENCH_partition.json` (repo root when present).
//!
//! The paper-default point (20% user / 20% association churn) carries
//! the ISSUE acceptance gate: repair ≥ 5× faster than a full recut
//! with the mean cut-edge ratio within 1.10 of the fresh full cut.

use std::fmt::Write as _;

use graphedge::bench::{fmt_secs, Table};
use graphedge::graph::dynamic::{ChurnConfig, DynamicGraph};
use graphedge::graph::generate::preferential_attachment;
use graphedge::partition::hicut;
use graphedge::partition::incremental::{IncrementalConfig, IncrementalPartitioner};
use graphedge::util::rng::Rng;

struct Run {
    churn: f64,
    inc_step_s: f64,
    full_step_s: f64,
    speedup: f64,
    /// Mean of (incremental cut / fresh full-recut cut) per step.
    cut_ratio_mean: f64,
    full_fallbacks: usize,
    local_recuts: usize,
}

fn run(n: usize, mean_deg: usize, churn: f64, steps: usize) -> Run {
    let mut rng = Rng::seed_from(0x1A7 + (churn * 100.0) as u64);
    let g = preferential_attachment(n, mean_deg, &mut rng);
    let mut users = DynamicGraph::new(g, vec![1.0; n], 2000.0, &mut rng);
    users.record_deltas(true);
    let mut inc = IncrementalPartitioner::from_users(&users, IncrementalConfig::default());
    let cfg = ChurnConfig {
        user_change_rate: churn,
        assoc_change_rate: churn,
        ..ChurnConfig::default()
    };
    let (mut inc_s, mut full_s, mut ratio_sum) = (0.0f64, 0.0f64, 0.0f64);
    for _ in 0..steps {
        users.step(&cfg, &mut rng);
        let deltas = users.drain_deltas();

        let t0 = std::time::Instant::now();
        let stats = inc.apply(&users, &deltas);
        inc_s += t0.elapsed().as_secs_f64();

        let t0 = std::time::Instant::now();
        let full = hicut(users.graph(), |v| users.is_active(v));
        full_s += t0.elapsed().as_secs_f64();

        let full_cut = full.cut_edges(users.graph()).max(1);
        ratio_sum += stats.cut_edges as f64 / full_cut as f64;
    }
    Run {
        churn,
        inc_step_s: inc_s / steps as f64,
        full_step_s: full_s / steps as f64,
        speedup: full_s / inc_s.max(1e-12),
        cut_ratio_mean: ratio_sum / steps as f64,
        full_fallbacks: inc.full_recuts.saturating_sub(1),
        local_recuts: inc.local_recuts,
    }
}

fn main() {
    let full_suite = std::env::var("GRAPHEDGE_BENCH_FULL").is_ok();
    let steps = if full_suite { 40 } else { 20 };
    let (n, mean_deg) = (2000, 6);

    let mut t = Table::new(
        "incremental repair vs full HiCut recut (2000 users)",
        &["churn", "repair/step", "full/step", "speedup", "cut ratio",
          "fallbacks", "local recuts"],
    );
    let mut runs = Vec::new();
    for churn in [0.05, 0.10, 0.20, 0.40] {
        let r = run(n, mean_deg, churn, steps);
        t.row(vec![
            format!("{:.0}%", churn * 100.0),
            fmt_secs(r.inc_step_s),
            fmt_secs(r.full_step_s),
            format!("{:.1}x", r.speedup),
            format!("{:.3}", r.cut_ratio_mean),
            r.full_fallbacks.to_string(),
            r.local_recuts.to_string(),
        ]);
        runs.push(r);
    }
    t.emit("partition_incremental");

    // Acceptance gate at the paper-default 20% churn point.
    let paper = &runs[2];
    let pass = paper.speedup >= 5.0 && paper.cut_ratio_mean <= 1.10;
    println!(
        "paper-default point (20% churn): speedup {:.1}x (target >=5x), \
         cut ratio {:.3} (target <=1.10) — {}",
        paper.speedup,
        paper.cut_ratio_mean,
        if pass { "PASS" } else { "FAIL" },
    );

    // Perf-trajectory file for future PRs (repo root when running from
    // the crate directory, else the current directory).
    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"partition_incremental\",");
    let _ = writeln!(
        json,
        "  \"_note\": \"Regenerate with `cargo bench --bench \
         partition_incremental` (the bench overwrites this file).\","
    );
    let _ = writeln!(json, "  \"n_users\": {n},");
    let _ = writeln!(json, "  \"mean_degree\": {mean_deg},");
    let _ = writeln!(json, "  \"steps\": {steps},");
    // Keep the acceptance thresholds in the file itself so future PRs
    // can gate against them without digging through bench source.
    let _ = writeln!(json, "  \"targets\": {{");
    let _ = writeln!(json, "    \"paper_default_churn\": 0.2,");
    let _ = writeln!(json, "    \"min_speedup_vs_full_recut\": 5.0,");
    let _ = writeln!(json, "    \"max_cut_ratio_vs_fresh_full_cut\": 1.1");
    let _ = writeln!(json, "  }},");
    let _ = writeln!(json, "  \"runs\": [");
    for (i, r) in runs.iter().enumerate() {
        let comma = if i + 1 < runs.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"churn\": {:.2}, \"repair_step_s\": {:.6e}, \
             \"full_step_s\": {:.6e}, \"speedup\": {:.2}, \
             \"cut_ratio_mean\": {:.4}, \"full_fallbacks\": {}, \
             \"local_recuts\": {}}}{comma}",
            r.churn,
            r.inc_step_s,
            r.full_step_s,
            r.speedup,
            r.cut_ratio_mean,
            r.full_fallbacks,
            r.local_recuts,
        );
    }
    let _ = writeln!(json, "  ]");
    let _ = writeln!(json, "}}");
    let path = if std::path::Path::new("../BENCH_partition.json").exists() {
        "../BENCH_partition.json"
    } else {
        "BENCH_partition.json"
    };
    match std::fs::write(path, json) {
        Ok(()) => println!("[wrote {path}]"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
