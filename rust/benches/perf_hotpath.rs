//! §Perf — hot-path microbenchmarks for the optimization pass:
//! PJRT executes (GNN forward, actor forward, MADDPG train step),
//! HiCut, environment stepping, padded-graph construction.

use graphedge::bench::{fmt_secs, time_reps, Table};
use graphedge::coordinator::Controller;
use graphedge::drl::{MaddpgTrainer, Method};
use graphedge::net::SystemParams;
use graphedge::serving::{GnnService, PaddedGraph};
use graphedge::util::rng::Rng;

fn main() -> graphedge::Result<()> {
    let ctrl = Controller::new(SystemParams::default())?;
    let mut t = Table::new("perf hot paths", &["op", "mean", "p50", "p99", "n"]);
    let mut push = |name: &str, s: graphedge::util::stats::Sample| {
        t.row(vec![
            name.into(),
            fmt_secs(s.mean()),
            fmt_secs(s.percentile(50.0)),
            fmt_secs(s.percentile(99.0)),
            s.len().to_string(),
        ]);
    };

    // Scenario fixtures.
    let mut rng = Rng::seed_from(1);
    let mut env = ctrl.make_env(Method::Greedy, "cora", 300, 1800, &mut rng)?;
    let ds = ctrl.dataset("cora")?;
    let svc = GnnService::load(&ctrl.rt, "gcn", "cora")?;
    let verts: Vec<usize> = (0..300).collect();

    // 1. HiCut on the live scenario graph.
    push("hicut(300u,1800e)", time_reps(3, 30, || {
        let users = &env.users;
        std::hint::black_box(graphedge::partition::hicut(users.graph(), &|v| {
            users.is_active(v)
        }));
    }));

    // 2. Padded-graph construction (320x320 adj + 320x1536 features).
    let padded = PaddedGraph::build(
        env.users.graph(), &env.scenario.users, ds, &verts, svc.n_max, svc.feat_pad,
    );
    push("padded_build", time_reps(2, 20, || {
        std::hint::black_box(PaddedGraph::build(
            env.users.graph(), &env.scenario.users, ds, &verts, svc.n_max,
            svc.feat_pad,
        ));
    }));

    // 3. GNN forward (the serving hot path).
    push("gcn_cora infer", time_reps(3, 20, || {
        std::hint::black_box(svc.infer(&padded).unwrap());
    }));
    for model in ["gat", "sage", "sgc"] {
        let s2 = GnnService::load(&ctrl.rt, model, "cora")?;
        push(&format!("{model}_cora infer"), time_reps(2, 10, || {
            std::hint::black_box(s2.infer(&padded).unwrap());
        }));
    }

    // 4. Environment step + observation build.
    env.reset();
    push("env.obs(all agents)", time_reps(3, 50, || {
        for m in 0..env.agents() {
            std::hint::black_box(env.obs(m));
        }
    }));

    // 5. actor_fwd execute.
    let mut tr = MaddpgTrainer::new(&ctrl.rt, 1024)?;
    let obs = vec![0.1f32; tr.m * graphedge::drl::env::OBS];
    let mut rng2 = Rng::seed_from(2);
    push("actor_fwd exec", time_reps(5, 100, || {
        std::hint::black_box(tr.select_actions(&obs, 0.1, &mut rng2).unwrap());
    }));

    // 6. maddpg_train execute (B=256, all 4 agents).
    {
        let mut env2 = ctrl.make_env(Method::Drlgo, "cora", 64, 200, &mut rng)?;
        // Fill replay.
        let cfg = graphedge::drl::MaddpgConfig {
            episodes: 1, warmup: usize::MAX, ..Default::default()
        };
        let mut r = Rng::seed_from(3);
        tr.run_episode(&mut env2, &cfg, true, &mut r)?;
        while tr.replay_len() < 300 {
            env2.reset();
            tr.run_episode(&mut env2, &cfg, true, &mut r)?;
        }
        push("maddpg_train exec", time_reps(2, 15, || {
            std::hint::black_box(tr.train_step(&mut r).unwrap());
        }));
    }

    t.emit("perf_hotpath");
    Ok(())
}
