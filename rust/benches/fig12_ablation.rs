//! Fig. 12 — ablation: DRLGO vs DRL-only (MADDPG without HiCut and
//! without the R_sp reward constraint), N = 300, E = 4800.

fn main() -> graphedge::Result<()> {
    graphedge::bench::figs::ablation_figure()
}
