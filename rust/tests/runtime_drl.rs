//! Integration: DRL executables (actor_fwd / maddpg_train / ppo_*)
//! through the default runtime backend (native kernels unless a real
//! artifacts tree + `--features xla` routes through PJRT), plus a
//! short end-to-end training smoke.

use graphedge::drl::env::{Env, EnvConfig, OBS};
use graphedge::drl::{MaddpgConfig, MaddpgTrainer, PpoConfig, PpoTrainer};
use graphedge::net::SystemParams;
use graphedge::runtime::Runtime;
use graphedge::util::rng::Rng;

fn runtime() -> Runtime {
    Runtime::open_default().expect("runtime")
}

fn tiny_env(rt: &Runtime, seed: u64) -> Env {
    let ds = rt.dataset("pubmed").unwrap();
    let cfg = EnvConfig { n_users: 32, n_assocs: 64, ..EnvConfig::default() };
    let mut rng = Rng::seed_from(seed);
    Env::new(&ds, SystemParams::default(), cfg, &mut rng)
}

#[test]
fn actor_fwd_outputs_unit_interval_actions() {
    let rt = runtime();
    let mut tr = MaddpgTrainer::new(&rt, 1000).unwrap();
    let mut rng = Rng::seed_from(1);
    let obs = vec![0.3f32; tr.m * OBS];
    let acts = tr.select_actions(&obs, 0.0, &mut rng).unwrap();
    assert_eq!(acts.len(), tr.m);
    for a in &acts {
        assert!(a.iter().all(|&v| (0.0..=1.0).contains(&v)), "{a:?}");
    }
    // Noise stays clipped.
    let noisy = tr.select_actions(&obs, 0.5, &mut rng).unwrap();
    for a in &noisy {
        assert!(a.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}

#[test]
fn maddpg_short_training_runs_and_updates() {
    let rt = runtime();
    let mut env = tiny_env(&rt, 2);
    let mut tr = MaddpgTrainer::new(&rt, 10_000).unwrap();
    let cfg = MaddpgConfig {
        episodes: 3,
        warmup: 32,
        train_every: 8,
        churn: true,
        ..MaddpgConfig::default()
    };
    let curve = tr.train(&mut env, &cfg).unwrap();
    assert_eq!(curve.len(), 3);
    assert!(curve.iter().all(|s| s.reward.is_finite() && s.reward < 0.0));
    assert!(curve.iter().all(|s| s.system_cost > 0.0));
    assert!(tr.replay_len() > 0);
    // Learned policy produces a complete, valid offload.
    tr.policy_offload(&mut env).unwrap();
    assert!(env.offload.all_assigned(&env.users.active_users()));
}

#[test]
fn maddpg_trains_on_a_mixed_scenario_set() {
    // Scenario-diversity end-to-end: every vector slot holds its own
    // generated topology (different graphs and user counts), and
    // train_vec consumes the heterogeneous batch exactly like a
    // replicated one.
    let rt = runtime();
    let mut env = tiny_env(&rt, 7);
    let mut tr = MaddpgTrainer::new(&rt, 10_000).unwrap();
    let cfg = MaddpgConfig {
        episodes: 4,
        warmup: 32,
        train_every: 8,
        envs: 4,
        scenarios: Some("uniform@24x50,clustered:3@36x90".into()),
        ..MaddpgConfig::default()
    };
    let curve = tr.train(&mut env, &cfg).unwrap();
    assert_eq!(curve.len(), 4);
    assert!(curve.iter().all(|s| s.reward.is_finite() && s.system_cost > 0.0));
    // Slot 0's scenario (a generated 24-user uniform graph) is handed
    // back for downstream evaluation.
    assert_eq!(env.users.capacity(), 24);
    tr.policy_offload(&mut env).unwrap();
    assert!(env.offload.all_assigned(&env.users.active_users()));
}

#[test]
fn maddpg_checkpoint_round_trip() {
    let rt = runtime();
    let mut tr = MaddpgTrainer::new(&rt, 1000).unwrap();
    let dir = std::env::temp_dir().join("graphedge_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("maddpg.gta");
    tr.save(&path).unwrap();
    tr.restore(&path).unwrap();
    // Restored params still drive the actor.
    let mut rng = Rng::seed_from(3);
    let obs = vec![0.0f32; tr.m * OBS];
    let acts = tr.select_actions(&obs, 0.0, &mut rng).unwrap();
    assert_eq!(acts.len(), tr.m);
}

#[test]
fn ppo_training_smoke_and_greedy_rollout() {
    let rt = runtime();
    let ds = rt.dataset("pubmed").unwrap();
    let cfg = EnvConfig {
        n_users: 32,
        n_assocs: 64,
        use_hicut: false,
        use_rsp: false,
        ..EnvConfig::default()
    };
    let mut rng = Rng::seed_from(4);
    let mut env = Env::new(&ds, SystemParams::default(), cfg, &mut rng);
    let mut tr = PpoTrainer::new(&rt).unwrap();
    let curve = tr.train(&mut env, &PpoConfig { episodes: 10, ..PpoConfig::default() }).unwrap();
    assert_eq!(curve.len(), 10);
    assert!(curve.iter().all(|s| s.reward.is_finite()));
    tr.policy_offload(&mut env).unwrap();
    assert!(env.offload.all_assigned(&env.users.active_users()));
}

#[test]
fn manifest_dims_match_env() {
    let rt = runtime();
    assert_eq!(rt.manifest.constant("obs_dim").unwrap(), OBS);
    assert_eq!(rt.manifest.constant("m_agents").unwrap(), 4);
    assert_eq!(
        rt.manifest.constant("state_dim").unwrap(),
        4 * OBS,
        "state = concat of agent observations (Eq. 19)"
    );
}
