//! Integration: runtime GNN executables vs a pure-Rust reference.
//!
//! Runs GCN/SGC/SAGE/GAT inference through the default backend (the
//! native kernels, or PJRT over a `make artifacts` tree when
//! `$GRAPHEDGE_ARTIFACTS` points at one under `--features xla`) on a
//! padded subgraph, and checks the logits against a naive
//! Matrix-based reimplementation of the same math — the Rust-side
//! counterpart of the Python kernel-vs-ref tests.  Pretrained-accuracy
//! asserts are gated on the manifest publishing an accuracy entry
//! (the synthesized native store ships random weights and publishes
//! none).

use graphedge::graph::Dataset;
use graphedge::runtime::Runtime;
use graphedge::serving::{GnnService, PaddedGraph};
use graphedge::tensor::{Archive, Matrix};
use graphedge::util::rng::Rng;

fn runtime() -> Runtime {
    Runtime::open_default().expect("runtime")
}

fn load_dataset(rt: &Runtime, name: &str) -> Dataset {
    rt.dataset(name).unwrap()
}

fn sample_padded(
    rt: &Runtime,
    ds: &Dataset,
    svc: &GnnService,
    n: usize,
) -> (graphedge::graph::sample::Scenario, PaddedGraph) {
    let mut rng = Rng::seed_from(42);
    let scen = graphedge::graph::sample::sample_scenario(ds, n, 3 * n, &mut rng);
    let verts: Vec<usize> = (0..n).collect();
    let _ = rt;
    let p = PaddedGraph::build(&scen.graph, &scen.users, ds, &verts, svc.n_max, svc.feat_pad);
    (scen, p)
}

/// Pure-Rust 2-layer GCN over the padded graph.
fn gcn_reference(p: &PaddedGraph, w: &Archive) -> Matrix {
    let get = |name: &str| {
        let t = w.get(name).unwrap();
        Matrix { rows: t.shape[0], cols: t.shape[1], data: t.f32_data.clone() }
    };
    let (w0, b0, w1, b1) = (get("w0"), get("b0"), get("w1"), get("b1"));
    let mut h = p.a_norm.matmul(&p.x.matmul(&w0));
    for r in 0..h.rows {
        for c in 0..h.cols {
            let v = (h.at(r, c) + b0.at(0, c)).max(0.0);
            h.set(r, c, v);
        }
    }
    let mut out = p.a_norm.matmul(&h.matmul(&w1));
    for r in 0..out.rows {
        for c in 0..out.cols {
            out.set(r, c, out.at(r, c) + b1.at(0, c));
        }
    }
    out
}

#[test]
fn gcn_cora_matches_rust_reference() {
    let rt = runtime();
    let ds = load_dataset(&rt, "cora");
    let svc = GnnService::load(&rt, "gcn", "cora").unwrap();
    let (_scen, p) = sample_padded(&rt, &ds, &svc, 120);
    let got = svc.infer(&p).unwrap();
    let weights = rt
        .load_archive(rt.manifest.executables["gcn_cora"].weights.as_ref().unwrap())
        .unwrap();
    let want = gcn_reference(&p, &weights);
    assert_eq!(got.rows, want.rows);
    assert_eq!(got.cols, want.cols);
    let mut max_err = 0f32;
    for (a, b) in got.data.iter().zip(&want.data) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 5e-3, "max |err| = {max_err}");
}

#[test]
fn all_models_all_datasets_run_and_classify() {
    let rt = runtime();
    for dataset in ["citeseer", "cora", "pubmed"] {
        let ds = load_dataset(&rt, dataset);
        for model in ["gcn", "gat", "sage", "sgc"] {
            let svc = GnnService::load(&rt, model, dataset)
                .unwrap_or_else(|e| panic!("{model}_{dataset}: {e:#}"));
            let (scen, p) = sample_padded(&rt, &ds, &svc, 150);
            let classes = svc.classify(&p).unwrap();
            assert_eq!(classes.len(), 150);
            assert!(classes.iter().all(|&c| c < svc.classes));
            // Pre-trained models should beat chance comfortably — but
            // only artifacts that publish an accuracy entry carry
            // pretrained weights (the native store's are random).
            let pretrained = rt
                .manifest
                .accuracy
                .get(&format!("{model}_{dataset}"))
                .copied()
                .unwrap_or(0.0)
                > 0.25;
            if pretrained {
                let hit = classes
                    .iter()
                    .enumerate()
                    .filter(|&(i, &c)| {
                        ds.labels[scen.users[p.vertices[i]] as usize] as usize == c
                    })
                    .count();
                let acc = hit as f64 / 150.0;
                assert!(
                    acc > 1.5 / svc.classes as f64,
                    "{model}_{dataset} accuracy {acc:.3} vs chance {:.3}",
                    1.0 / svc.classes as f64
                );
            }
        }
    }
}

#[test]
fn padding_rows_do_not_affect_real_logits() {
    let rt = runtime();
    let ds = load_dataset(&rt, "pubmed");
    let svc = GnnService::load(&rt, "gcn", "pubmed").unwrap();
    let (_scen, small) = sample_padded(&rt, &ds, &svc, 60);
    let logits = svc.infer(&small).unwrap();
    // Padded rows (>= 60) must be exactly the bias-only output, and
    // finite everywhere.
    assert!(logits.data.iter().all(|v| v.is_finite()));
    for r in 60..svc.n_max {
        // Identical across padded rows.
        assert_eq!(logits.row(r), logits.row(svc.n_max - 1));
    }
}
