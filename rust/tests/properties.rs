//! Cross-module property tests (pure L3, no artifacts needed): the
//! invariants the reproduction's correctness rests on.

use graphedge::graph::dynamic::{ChurnConfig, DynamicGraph};
use graphedge::graph::generate::{preferential_attachment, random_weights, uniform_random};
use graphedge::graph::Graph;
use graphedge::net::cost::{CostModel, Offload};
use graphedge::net::topology::{EdgeNetwork, UserLinks};
use graphedge::net::SystemParams;
use graphedge::partition::incremental::{IncrementalConfig, IncrementalPartitioner};
use graphedge::partition::{hicut, mincut_partition, parallel_hicut, parallel_hicut_pool, Partition};
use graphedge::util::proptest::check_seeds;
use graphedge::util::rng::Rng;
use graphedge::util::threadpool::ThreadPool;

fn scenario(
    n: usize,
    deg: usize,
    rng: &mut Rng,
) -> (SystemParams, EdgeNetwork, UserLinks, DynamicGraph) {
    let params = SystemParams::default();
    let net = EdgeNetwork::build(&params, n, rng);
    let links = UserLinks::draw(&params, n, net.len(), rng);
    let g = preferential_attachment(n, deg, rng);
    let users = DynamicGraph::new(g, vec![1.0; n], params.plane_m, rng);
    (params, net, links, users)
}

#[test]
fn cost_is_nonnegative_and_additive() {
    check_seeds(25, |rng| {
        let n = rng.range(4, 60);
        let (p, net, links, users) = scenario(n, 4, rng);
        let cm = CostModel::new(&p, &net, &links, &users, &[500, 64, 3]);
        let assign: Vec<usize> = (0..n).map(|_| rng.below(net.len())).collect();
        let c = cm.evaluate(&Offload { server: assign });
        c.t_upload_s >= 0.0
            && c.t_transfer_s >= 0.0
            && c.t_compute_s >= 0.0
            && c.i_all() >= 0.0
            && (c.total() - (c.t_all() + c.i_all())).abs() < 1e-9
    });
}

#[test]
fn transfer_cost_monotone_in_split_edges() {
    // Moving one user from its neighbor's server to a different server
    // can only increase the transfer terms.
    check_seeds(25, |rng| {
        let n = rng.range(6, 50);
        let (p, net, links, users) = scenario(n, 6, rng);
        let cm = CostModel::new(&p, &net, &links, &users, &[500, 64, 3]);
        let mut assign: Vec<usize> = vec![0; n];
        // pick a user with a neighbor, co-locate, then split.
        let Some(u) = (0..n).find(|&u| users.graph().degree(u) > 0) else {
            return true;
        };
        let base = cm.evaluate(&Offload { server: assign.clone() });
        assign[u] = 1;
        let split = cm.evaluate(&Offload { server: assign });
        split.i_transfer_j >= base.i_transfer_j
            && split.t_transfer_s >= base.t_transfer_s
            && split.cross_edges >= base.cross_edges
    });
}

#[test]
fn hicut_deterministic() {
    check_seeds(15, |rng| {
        let n = rng.range(4, 80);
        let g = uniform_random(n, rng.below(3 * n), rng);
        let a = hicut(&g, &|_| true);
        let b = hicut(&g, &|_| true);
        a.subgraphs == b.subgraphs
    });
}

#[test]
fn hicut_subgraphs_cover_components() {
    // Every HiCut subgraph must lie within one connected component.
    check_seeds(20, |rng| {
        let n = rng.range(4, 80);
        let g = uniform_random(n, rng.below(2 * n), rng);
        let p = hicut(&g, &|_| true);
        let comps = g.components(|_| true);
        let mut comp_of = vec![usize::MAX; n];
        for (ci, c) in comps.iter().enumerate() {
            for &v in c {
                comp_of[v] = ci;
            }
        }
        p.subgraphs
            .iter()
            .all(|sub| sub.iter().all(|&v| comp_of[v] == comp_of[sub[0]]))
    });
}

#[test]
fn sharded_hicut_is_indistinguishable_from_sequential() {
    // The PR-2 acceptance property: for any graph, alive mask and
    // worker count, the sharded cut covers the identical vertex set
    // and its cut_edges equals the sequential hicut's — here by full
    // structural equality of the partitions.
    check_seeds(40, |rng| {
        let n = rng.range(4, 120);
        let e = rng.below((n * (n - 1) / 2).min(3 * n));
        let g = uniform_random(n, e, rng);
        let dead: std::collections::HashSet<usize> = (0..n).filter(|_| rng.chance(0.3)).collect();
        let alive = |v: usize| !dead.contains(&v);
        let seq = hicut(&g, &alive);
        for workers in [2usize, 5] {
            let par = parallel_hicut(&g, &alive, workers);
            if par.subgraphs != seq.subgraphs
                || par.covered() != seq.covered()
                || par.cut_edges(&g) != seq.cut_edges(&g)
            {
                return false;
            }
        }
        true
    });
    // Same property through a shared worker pool (the serving path).
    let pool = ThreadPool::new(3);
    check_seeds(40, |rng| {
        let n = rng.range(4, 100);
        let g = preferential_attachment(n, 1 + rng.below(4), rng);
        let dead: std::collections::HashSet<usize> = (0..n).filter(|_| rng.chance(0.4)).collect();
        let alive = |v: usize| !dead.contains(&v);
        let seq = hicut(&g, &alive);
        let par = parallel_hicut_pool(&g, &alive, &pool);
        par.subgraphs == seq.subgraphs && par.cut_edges(&g) == seq.cut_edges(&g)
    });
    assert_eq!(pool.panicked(), 0);
}

#[test]
fn mincut_weight_never_exceeds_trivial_cut() {
    // Each split's cut weight is a *minimum* s-t cut, so the total cut
    // weight can't exceed the all-singletons cut (total edge weight).
    check_seeds(15, |rng| {
        let n = rng.range(6, 50);
        let e = rng.range(n, 3 * n);
        let g = uniform_random(n, e.min(n * (n - 1) / 2), rng);
        let w = random_weights(&g, 1, 100, rng);
        let p = mincut_partition(&g, &w, 5, rng);
        let total: u64 = w.values().map(|&x| x as u64).sum();
        p.cut_weight(&g, &w) <= total
    });
}

#[test]
fn partition_locality_plus_cut_conserve_edges() {
    check_seeds(20, |rng| {
        let n = rng.range(4, 60);
        let g = uniform_random(n, rng.below(3 * n), rng);
        let p = hicut(&g, &|_| true);
        let cut = p.cut_edges(&g);
        let loc = p.locality(&g);
        let total = g.num_edges();
        if total == 0 {
            return loc == 1.0;
        }
        ((total - cut) as f64 / total as f64 - loc).abs() < 1e-9
    });
}

#[test]
fn churn_preserves_mask_edge_invariant() {
    // After arbitrary churn sequences, inactive vertices carry no
    // edges and active counts stay within capacity.
    check_seeds(15, |rng| {
        let n = rng.range(10, 80);
        let g = preferential_attachment(n, 4, rng);
        let mut users = DynamicGraph::new(g, vec![1.0; n], 2000.0, rng);
        let cfg = ChurnConfig::default();
        for _ in 0..10 {
            users.step(&cfg, rng);
            for v in 0..n {
                if !users.is_active(v) && users.graph().degree(v) > 0 {
                    return false;
                }
            }
            if users.active_count() > n {
                return false;
            }
        }
        true
    });
}

#[test]
fn hicut_respects_churn_masks() {
    check_seeds(15, |rng| {
        let n = rng.range(10, 60);
        let g = preferential_attachment(n, 4, rng);
        let mut users = DynamicGraph::new(g, vec![1.0; n], 2000.0, rng);
        users.step(&ChurnConfig::default(), rng);
        let p: Partition = hicut(users.graph(), &|v| users.is_active(v));
        let covered: usize = p.subgraphs.iter().map(|s| s.len()).sum();
        covered == users.active_count()
            && p.subgraphs.iter().flatten().all(|&v| users.is_active(v))
    });
}

/// A churning DynamicGraph with delta recording on, plus the
/// incremental partitioner tracking it.
fn churning(n: usize, deg: usize, rng: &mut Rng) -> (DynamicGraph, IncrementalPartitioner) {
    let g = preferential_attachment(n, deg, rng);
    let mut users = DynamicGraph::new(g, vec![1.0; n], 2000.0, rng);
    users.record_deltas(true);
    let inc = IncrementalPartitioner::from_users(&users, IncrementalConfig::default());
    (users, inc)
}

#[test]
fn incremental_repair_keeps_partition_valid_under_any_delta_sequence() {
    // The tentpole invariants: after every delta batch each alive
    // vertex sits in exactly one subgraph, no dead vertex is assigned,
    // the incremental counters equal a from-scratch recount, and the
    // cut never exceeds the drift monitor's limit.
    check_seeds(12, |rng| {
        let n = rng.range(20, 120);
        let (mut users, mut inc) = churning(n, 4, rng);
        let cfg = ChurnConfig::default();
        for _ in 0..8 {
            users.step(&cfg, rng);
            let deltas = users.drain_deltas();
            inc.apply(&users, &deltas);
            if !inc.is_valid_cover(&users) {
                return false;
            }
            if !inc.counters_consistent(users.graph()) {
                return false;
            }
            if inc.cut_edges_now() > inc.monitor().limit() {
                return false;
            }
            // The materialized partition agrees with the counters.
            let p = inc.partition();
            if p.covered() != users.active_count() {
                return false;
            }
            if p.cut_edges(users.graph()) != inc.cut_edges_now() {
                return false;
            }
        }
        true
    });
}

#[test]
fn incremental_full_recut_matches_fresh_hicut() {
    check_seeds(10, |rng| {
        let n = rng.range(20, 100);
        let (mut users, mut inc) = churning(n, 4, rng);
        let cfg = ChurnConfig::default();
        for _ in 0..5 {
            users.step(&cfg, rng);
            let deltas = users.drain_deltas();
            inc.apply(&users, &deltas);
        }
        inc.full_recut(&users);
        let fresh = hicut(users.graph(), |v| users.is_active(v));
        inc.cut_edges_now() == fresh.cut_edges(users.graph())
            && inc.partition().covered() == fresh.covered()
            && inc.monitor().reference() == inc.cut_edges_now()
    });
}

#[test]
fn incremental_cut_stays_within_drift_bound_of_a_full_hicut() {
    // The drift guarantee, stated against full HiCut: the live cut is
    // within (1 + drift_bound) + slack of the monitor's reference —
    // itself a full HiCut of a recent graph version — or of the
    // current graph's fresh cut when that is larger.
    let cfg = IncrementalConfig::default();
    let (bound, slack) = (cfg.drift_bound, cfg.drift_slack);
    check_seeds(8, |rng| {
        let n = rng.range(150, 400);
        let (mut users, mut inc) = churning(n, 6, rng);
        let churn = ChurnConfig::default();
        for _ in 0..5 {
            users.step(&churn, rng);
            let deltas = users.drain_deltas();
            inc.apply(&users, &deltas);
            let fresh = hicut(users.graph(), |v| users.is_active(v))
                .cut_edges(users.graph());
            let anchor = fresh.max(inc.monitor().reference());
            let limit = (anchor as f64 * (1.0 + bound)) as usize + slack;
            if inc.cut_edges_now() > limit {
                return false;
            }
        }
        true
    });
}

#[test]
fn uplink_rate_decreases_with_distance() {
    // Shannon capacity under free-space path loss: farther → lower
    // gain; with bandwidth fixed, rate must fall.
    let mut rng = Rng::seed_from(12);
    let (p, net, mut links, mut users) = scenario(2, 1, &mut rng);
    // Same bandwidth/power for both users; user 0 near server 0, user 1 far.
    links.bw_hz[0][0] = 30e6;
    links.bw_hz[1][0] = 30e6;
    links.p_w[0] = 3e-3;
    links.p_w[1] = 3e-3;
    let s0 = net.servers[0].pos;
    // Position users directly (move_users can't set absolute positions,
    // so rebuild with a custom DynamicGraph).
    let g = Graph::new(2);
    users = DynamicGraph::new(g, vec![1.0; 2], p.plane_m, &mut rng);
    let _ = &users;
    // Access positions via scatter + check monotonicity statistically:
    let cm = CostModel::new(&p, &net, &links, &users, &[500, 64, 3]);
    let d0 = users.pos(0).dist(&s0);
    let d1 = users.pos(1).dist(&s0);
    let (near, far) = if d0 < d1 { (0, 1) } else { (1, 0) };
    assert!(cm.uplink_rate(near, 0) >= cm.uplink_rate(far, 0));
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn vec_env_of_one_is_trajectory_identical_to_a_plain_env() {
    // The VecEnv acceptance property, part 1: a vector of E=1 slots
    // replays exactly the plain-`Env` trajectory — same states (bit
    // for bit), same assignments, rewards and episode boundaries —
    // when the plain env churns through the documented slot stream
    // (the i-th `fork` of `Rng::seed_from(seed)`).
    use graphedge::drl::vec_env::VecEnv;
    use graphedge::drl::{Env, EnvConfig};
    check_seeds(10, |rng| {
        let ds = graphedge::graph::Dataset::synthetic(150, rng);
        let cfg = EnvConfig { n_users: 30, n_assocs: 70, ..EnvConfig::default() };
        let proto = Env::new(&ds, SystemParams::default(), cfg, rng);
        let churn_seed = rng.next_u64();
        let mut venv = VecEnv::replicate(&proto, 1, churn_seed);
        venv.reset_all(); // churn-on-reset is the default
        let mut env = proto.clone();
        let mut churn = Rng::seed_from(churn_seed).fork();
        env.reset();
        let agents = env.agents();
        for step in 0..120usize {
            if !bits_eq(&venv.states(), &env.state()) {
                return false;
            }
            let server = step % agents;
            let vres = venv.step_servers(&[server]);
            let out = env.step(server);
            if vres[0].outcome.assigned != out.assigned
                || vres[0].outcome.finished != out.finished
                || vres[0].outcome.rewards != out.rewards
            {
                return false;
            }
            if out.finished {
                // Episode boundary: the vector reports the terminal
                // cost and auto-resets; mirror it by hand.
                if !vres[0].reset
                    || (vres[0].terminal_cost - env.evaluate().total()).abs() > 1e-9
                {
                    return false;
                }
                env.mutate(&mut churn);
                env.reset();
            } else if !bits_eq(&vres[0].next_state, &env.state()) {
                return false;
            }
        }
        true
    });
}

#[test]
fn vec_env_rollouts_are_deterministic_and_worker_count_invariant() {
    // The VecEnv acceptance property, part 2: an E>1 rollout is a pure
    // function of (prototype, seed, actions) — re-running it under any
    // worker count reproduces every outcome, state and terminal cost
    // bit for bit.
    use graphedge::drl::vec_env::VecEnv;
    use graphedge::drl::{Env, EnvConfig};
    let mut rng = Rng::seed_from(0xC0FE);
    let ds = graphedge::graph::Dataset::synthetic(150, &mut rng);
    let cfg = EnvConfig { n_users: 30, n_assocs: 70, ..EnvConfig::default() };
    let proto = Env::new(&ds, SystemParams::default(), cfg, &mut rng);
    let agents = proto.agents();
    let rollout = |workers: usize| -> Vec<u64> {
        let mut venv = VecEnv::replicate(&proto, 4, 0x99);
        venv.set_workers(workers);
        venv.reset_all();
        let mut trace: Vec<u64> = Vec::new();
        for step in 0..90usize {
            let servers: Vec<usize> = (0..4).map(|i| (step + i) % agents).collect();
            for res in venv.step_servers(&servers) {
                trace.push(res.outcome.assigned as u64);
                trace.push(res.reset as u64);
                trace.push(res.terminal_cost.to_bits());
                trace.extend(res.next_state.iter().map(|v| u64::from(v.to_bits())));
            }
            trace.extend(venv.states().iter().map(|v| u64::from(v.to_bits())));
        }
        trace
    };
    let reference = rollout(1);
    for workers in [2usize, 3, 4, 7] {
        assert_eq!(rollout(workers), reference, "rollout diverged at {workers} workers");
    }
}

#[test]
fn scenario_generation_is_bit_deterministic() {
    // The scenario-subsystem acceptance property, part 1: a
    // (ScenarioSpec, seed) pair pins the generated Scenario bit for
    // bit — same topology, positions, server draws, link draws — no
    // matter how many times or in what context it is generated.
    use graphedge::scenario::{parse_spec_list, ScenarioSet};
    let params = SystemParams::default();
    for spec in ["mixed", "clustered:5@80x300,hotspot:3", "uniform,pa:8@60x100"] {
        let specs = parse_spec_list(spec, 70, 210).unwrap();
        for seed in [1u64, 0xABC, 9999] {
            let a = ScenarioSet::generate(&specs, &params, 6, 2, seed);
            let b = ScenarioSet::generate(&specs, &params, 6, 2, seed);
            let fa: Vec<u64> = a.scenarios.iter().map(|s| s.fingerprint()).collect();
            let fb: Vec<u64> = b.scenarios.iter().map(|s| s.fingerprint()).collect();
            assert_eq!(fa, fb, "spec {spec:?} seed {seed} not deterministic");
            // Distinct slots get distinct forked streams.
            assert_ne!(fa[0], fa[1], "spec {spec:?} seed {seed} collapsed slots");
            let c = ScenarioSet::generate(&specs, &params, 6, 2, seed ^ 0x5A5A);
            assert_ne!(c.scenarios[0].fingerprint(), fa[0], "different seeds must diverge");
        }
    }
}

#[test]
fn scenario_vec_env_rollouts_are_worker_count_invariant() {
    // The scenario-subsystem acceptance property, part 2: a
    // heterogeneous vector (distinct graphs *and* user counts per
    // slot) is a pure function of (set, config, seed, actions) — both
    // the per-slot environment *construction* fan-out and the rollout
    // fan-out reproduce every state and outcome bit for bit under any
    // worker count.
    use graphedge::drl::vec_env::VecEnv;
    use graphedge::drl::EnvConfig;
    use graphedge::scenario::ScenarioSet;
    let params = SystemParams::default();
    let spec = "uniform@40x90,clustered:3@60x150,hotspot@30x60";
    let set = ScenarioSet::from_spec(spec, 0, 0, &params, 3, 0xD1CE).unwrap();
    let cfg = EnvConfig { n_users: 0, n_assocs: 0, ..EnvConfig::default() };
    let rollout = |build_workers: usize, step_workers: usize| -> Vec<u64> {
        let mut venv = VecEnv::from_scenario_set(&set, &cfg, 3, 0x77, build_workers);
        venv.set_workers(step_workers);
        venv.reset_all();
        let agents = venv.agents();
        let mut trace: Vec<u64> = Vec::new();
        for step in 0..70usize {
            let servers: Vec<usize> = (0..3).map(|i| (step + i) % agents).collect();
            for res in venv.step_servers(&servers) {
                trace.push(res.outcome.assigned as u64);
                trace.push(res.reset as u64);
                trace.push(res.terminal_cost.to_bits());
            }
            trace.extend(venv.states().iter().map(|v| u64::from(v.to_bits())));
        }
        trace
    };
    let reference = rollout(1, 1);
    for (bw, sw) in [(2usize, 3usize), (4, 1), (1, 3), (3, 2)] {
        assert_eq!(
            rollout(bw, sw),
            reference,
            "diverged at build_workers={bw} step_workers={sw}"
        );
    }
}

#[test]
fn replicate_mode_unchanged_by_the_scenario_subsystem() {
    // The bugfix guarantee: single-scenario training
    // (`--scenarios replicate`, the default) goes through the same
    // VecEnv::for_training entry point as diverse sets, yet must
    // reproduce VecEnv::replicate — and hence the pre-subsystem
    // trajectories pinned by the E=1 property above — bit for bit.
    use graphedge::drl::vec_env::VecEnv;
    use graphedge::drl::{Env, EnvConfig};
    check_seeds(8, |rng| {
        let ds = graphedge::graph::Dataset::synthetic(140, rng);
        let cfg = EnvConfig { n_users: 25, n_assocs: 60, ..EnvConfig::default() };
        let proto = Env::new(&ds, SystemParams::default(), cfg, rng);
        let seed = rng.next_u64();
        let mut a = VecEnv::for_training(&proto, 3, Some("replicate"), seed).unwrap();
        let mut b = VecEnv::replicate(&proto, 3, seed);
        a.reset_all();
        b.reset_all();
        let agents = proto.agents();
        for step in 0..80usize {
            let servers: Vec<usize> = (0..3).map(|i| (step + i) % agents).collect();
            let ra = a.step_servers(&servers);
            let rb = b.step_servers(&servers);
            for (x, y) in ra.iter().zip(&rb) {
                if x.outcome.assigned != y.outcome.assigned
                    || x.outcome.rewards != y.outcome.rewards
                    || x.reset != y.reset
                    || !bits_eq(&x.next_state, &y.next_state)
                {
                    return false;
                }
            }
            if !bits_eq(&a.states(), &b.states()) {
                return false;
            }
        }
        true
    });
}

#[test]
fn cached_observations_bit_identical_to_recompute_under_churn() {
    // The observation-engine acceptance property: across interleaved
    // `mutate` / `reset` / `step` sequences — in both full-recut and
    // incremental-repair maintenance modes — the cached `obs`/`state`
    // must equal the from-scratch recompute bit for bit.
    use graphedge::drl::{Env, EnvConfig};
    for incremental in [false, true] {
        check_seeds(20, |rng| {
            let ds = graphedge::graph::Dataset::synthetic(160, rng);
            let cfg = EnvConfig { n_users: 40, n_assocs: 90, ..EnvConfig::default() };
            let mut env = Env::new(&ds, SystemParams::default(), cfg, rng);
            if incremental {
                env.enable_incremental(IncrementalConfig::default());
            }
            for _round in 0..4 {
                env.mutate(rng);
                // Pre-reset: the layout install alone must leave the
                // cache coherent with the (stale) episode state.
                if !bits_eq(&env.state(), &env.state_recompute()) {
                    return false;
                }
                env.reset();
                let mut steps = 0usize;
                while !env.finished() && steps < 200 {
                    steps += 1;
                    if !bits_eq(&env.state(), &env.state_recompute()) {
                        return false;
                    }
                    let m = rng.below(env.agents());
                    let (o, r) = (env.obs(m), env.obs_recompute(m));
                    if !bits_eq(&o, &r) {
                        return false;
                    }
                    env.step(rng.below(env.agents()));
                    // Occasional mid-episode reset: the counters must
                    // re-derive, not accumulate.
                    if steps % 17 == 0 {
                        env.reset();
                    }
                }
                if !bits_eq(&env.state(), &env.state_recompute()) {
                    return false;
                }
            }
            true
        });
    }
}

// -- versioned compute plane (util::version) --------------------------------

#[test]
fn versioned_memo_reads_equal_fresh_recompute_under_interleaved_churn() {
    // The versioned-compute-plane acceptance property: across
    // interleaved mutate / recut / reset / step sequences — in both
    // maintenance modes — every Memoized consumer read equals a
    // from-scratch recompute bit for bit: the observation templates
    // (state vs state_recompute), the rate tables behind
    // `Env::evaluate` (vs an untabled CostModel), and the repair
    // layer's repaired-to stamp; version reads stay monotone and the
    // installed layout never trails the live graph.
    use graphedge::drl::{Env, EnvConfig};
    for incremental in [false, true] {
        check_seeds(10, |rng| {
            let ds = graphedge::graph::Dataset::synthetic(160, rng);
            let cfg = EnvConfig { n_users: 40, n_assocs: 90, ..EnvConfig::default() };
            let mut env = Env::new(&ds, SystemParams::default(), cfg, rng);
            if incremental {
                env.enable_incremental(IncrementalConfig::default());
            }
            let evaluate_fresh = |env: &Env| {
                CostModel::new(&env.params, &env.net, &env.links, &env.users, &env.layer_dims)
                    .with_profile(env.profile)
                    .evaluate(&env.offload)
            };
            let mut prev_topo = env.topology_version();
            for round in 0..4 {
                env.mutate(rng);
                let topo = env.topology_version();
                if topo < prev_topo {
                    return false; // producer versions must be monotone
                }
                prev_topo = topo;
                if env.layout_lag() != 0 {
                    return false; // mutate repairs to the live topology
                }
                if let Some(inc) = &env.incremental {
                    if !inc.is_current(&env.users)
                        || inc.repaired_to().lag(env.users.topology_version()) != 0
                    {
                        return false;
                    }
                }
                if round % 2 == 1 {
                    env.recut(); // a redundant recut must stay coherent
                }
                env.reset();
                let mut steps = 0usize;
                while !env.finished() && steps < 120 {
                    steps += 1;
                    if !bits_eq(&env.state(), &env.state_recompute()) {
                        return false;
                    }
                    env.step(rng.below(env.agents()));
                    if steps % 13 == 0 {
                        let (tabled, fresh) = (env.evaluate(), evaluate_fresh(&env));
                        if tabled.total().to_bits() != fresh.total().to_bits()
                            || tabled.t_all().to_bits() != fresh.t_all().to_bits()
                            || tabled.i_all().to_bits() != fresh.i_all().to_bits()
                        {
                            return false;
                        }
                    }
                }
                let (tabled, fresh) = (env.evaluate(), evaluate_fresh(&env));
                if tabled.total().to_bits() != fresh.total().to_bits()
                    || tabled.cross_mb.to_bits() != fresh.cross_mb.to_bits()
                {
                    return false;
                }
            }
            true
        });
    }
}

#[test]
fn memoized_cells_never_rebuild_on_a_version_hit() {
    // Reads against unchanged version keys must serve the cached
    // value: read counters advance, rebuild counters do not — and a
    // mutate staleness is absorbed by exactly one rebuild per cell.
    use graphedge::drl::{Env, EnvConfig};
    check_seeds(10, |rng| {
        let ds = graphedge::graph::Dataset::synthetic(140, rng);
        let cfg = EnvConfig { n_users: 30, n_assocs: 70, ..EnvConfig::default() };
        let mut env = Env::new(&ds, SystemParams::default(), cfg, rng);
        let _ = env.state();
        let _ = env.evaluate();
        let warm = env.memo_counters();
        for _ in 0..5 {
            let _ = env.state();
            let _ = env.evaluate();
        }
        let after = env.memo_counters();
        if after.1 != warm.1 || after.3 != warm.3 {
            return false; // a hit rebuilt
        }
        if after.0 <= warm.0 || after.2 <= warm.2 {
            return false; // reads not counted
        }
        // A churn step can come up empty (no topology bump, so the
        // rate tables — keyed on topology alone — rightly stay put);
        // retry until one lands.
        let topo0 = env.topology_version();
        for _ in 0..16 {
            env.mutate(rng);
            if env.topology_version() > topo0 {
                break;
            }
        }
        if env.topology_version() == topo0 {
            return true; // churn never landed under this seed
        }
        env.reset();
        let _ = env.state();
        let _ = env.evaluate();
        let _ = env.state();
        let rebuilt = env.memo_counters();
        rebuilt.1 == after.1 + 1 && rebuilt.3 == after.3 + 1
    });
}

#[test]
fn repair_stamps_track_topology_versions_exactly() {
    // Producer/consumer version contract at the repair layer: churn
    // bumps the topology version iff it mutated something; the
    // partitioner is stale exactly until `apply` stamps it current.
    check_seeds(12, |rng| {
        let n = rng.range(20, 120);
        let (mut users, mut inc) = churning(n, 4, rng);
        let cfg = ChurnConfig::default();
        for _ in 0..8 {
            let before = users.topology_version();
            users.step(&cfg, rng);
            let deltas = users.drain_deltas();
            if users.topology_version() < before {
                return false;
            }
            if !deltas.is_empty() {
                if users.topology_version() == before {
                    return false; // a recorded mutation must bump
                }
                if inc.is_current(&users) {
                    return false; // stale until repaired
                }
            }
            inc.apply(&users, &deltas);
            if !inc.is_current(&users)
                || inc.repaired_to().lag(users.topology_version()) != 0
            {
                return false;
            }
        }
        true
    });
}

#[test]
fn router_conserves_requests_across_revalidate_interleavings() {
    // Deadline-cache validation never loses or duplicates a request:
    // under arbitrary submit / poll / flush / revalidate interleavings
    // (with the params version bumping mid-stream), every accepted
    // request is dispatched exactly once.
    use graphedge::serving::router::{BatchPolicy, Router};
    use graphedge::util::version::Version;
    use std::time::{Duration, Instant};
    fn count(batches: &[(usize, Vec<usize>)]) -> usize {
        batches.iter().map(|(_, b)| b.len()).sum()
    }
    check_seeds(20, |rng| {
        let servers = 1 + rng.below(4);
        let policy = BatchPolicy {
            max_batch: 1 + rng.below(5),
            max_wait: Duration::from_millis(5),
        };
        let mut r = Router::new(servers, policy);
        let mut params = Version::ZERO;
        params.bump();
        let mut off = Offload::empty(64);
        for u in 0..64 {
            off.server[u] = rng.below(servers);
        }
        let mut now = Instant::now();
        let mut submitted = 0usize;
        let mut dispatched = 0usize;
        for _ in 0..100 {
            match rng.below(5) {
                0 | 1 => {
                    if r.submit(rng.below(64), &off, now).is_some() {
                        submitted += 1;
                    }
                }
                2 => {
                    now += Duration::from_millis(rng.below(10) as u64);
                    dispatched += count(&r.ready_batches(now));
                }
                3 => dispatched += count(&r.flush()),
                _ => {
                    if rng.chance(0.5) {
                        params.bump();
                    }
                    dispatched += count(&r.revalidate(params));
                }
            }
        }
        dispatched += count(&r.flush());
        dispatched == submitted && r.dispatched_requests == submitted
    });
}

// -- metrics histograms -----------------------------------------------------

#[test]
fn histogram_bucket_classification_matches_bounds() {
    // Every finite positive value in range lands in exactly the bucket
    // whose [lo, hi) bounds contain it — including values *on* a
    // boundary, which the bit-arithmetic classifier must put in the
    // bucket that starts there.
    use graphedge::util::metrics::{bucket_bounds, bucket_index, hist_max, hist_min, HIST_BUCKETS};
    for i in 0..HIST_BUCKETS {
        let (lo, hi) = bucket_bounds(i);
        assert!(lo < hi, "bucket {i} is empty: [{lo}, {hi})");
        assert_eq!(bucket_index(lo), Some(i), "lower bound of bucket {i}");
        let mid = lo + (hi - lo) / 2.0;
        assert_eq!(bucket_index(mid), Some(i), "midpoint of bucket {i}");
        if i + 1 < HIST_BUCKETS {
            assert_eq!(bucket_index(hi), Some(i + 1), "upper bound of bucket {i}");
        }
    }
    // Out-of-range and non-finite values never classify.
    assert_eq!(bucket_index(0.0), None);
    assert_eq!(bucket_index(-1.0), None);
    assert_eq!(bucket_index(hist_min() / 2.0), None);
    assert_eq!(bucket_index(hist_max()), None);
    assert_eq!(bucket_index(f64::NAN), None);
    assert_eq!(bucket_index(f64::INFINITY), None);
    // Random in-range values always classify consistently with bounds.
    check_seeds(50, |rng| {
        let v = rng.range_f64(hist_min(), hist_max() * 0.999);
        match bucket_index(v) {
            Some(i) => {
                let (lo, hi) = bucket_bounds(i);
                lo <= v && v < hi
            }
            None => false,
        }
    });
}

#[test]
fn histogram_merge_equals_single_stream() {
    // Splitting an observation stream across K histograms and merging
    // the snapshots is *exactly* the single-histogram result — bucket
    // counts, under/overflow, sum, and therefore every percentile.
    use graphedge::util::metrics::Histogram;
    check_seeds(20, |rng| {
        let whole = Histogram::new();
        let parts: Vec<Histogram> = (0..4).map(|_| Histogram::new()).collect();
        for k in 0..600 {
            // Mix of in-range, underflow and overflow magnitudes.
            let v = match k % 7 {
                0 => rng.range_f64(1e-9, 1e-7),   // underflow
                1 => rng.range_f64(1024.0, 4096.0), // overflow
                _ => rng.range_f64(1e-5, 900.0),
            };
            whole.observe(v);
            parts[k % 4].observe(v);
        }
        let mut merged = parts[0].snapshot();
        for p in &parts[1..] {
            merged.merge(&p.snapshot());
        }
        let lone = whole.snapshot();
        if merged.buckets != lone.buckets
            || merged.underflow != lone.underflow
            || merged.overflow != lone.overflow
            || merged.count() != lone.count()
        {
            return false;
        }
        for p in [0.0, 25.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
            if merged.percentile(p) != lone.percentile(p) {
                return false;
            }
        }
        (merged.sum - lone.sum).abs() < 1e-9 * lone.sum.abs().max(1.0)
    });
}

#[test]
fn histogram_percentiles_track_exact_sample_within_bucket_width() {
    // The log-linear layout guarantees ≤ 1/SUB = 12.5 % relative error
    // per bucket; histogram percentiles must stay within one bucket
    // width of the exact (Sample-based) percentiles.
    use graphedge::util::metrics::Histogram;
    use graphedge::util::stats::Sample;
    check_seeds(10, |rng| {
        let hist = Histogram::new();
        let mut exact = Sample::default();
        for _ in 0..500 {
            // Log-uniform over ~6 decades of latencies.
            let v = 10f64.powf(rng.range_f64(-6.0, 0.5));
            hist.observe(v);
            exact.push(v);
        }
        for p in [10.0, 50.0, 90.0, 99.0] {
            let (h, e) = (hist.percentile(p), exact.percentile(p));
            // One sub-bucket is a factor of (1 + 1/8); the generous
            // margin additionally covers the rank conventions (ceil
            // vs linear interpolation) differing by one observation,
            // which in a sparse log-uniform tail can be a sizable gap.
            if h < e / 1.6 || h > e * 1.6 {
                return false;
            }
        }
        true
    });
}

#[test]
fn histogram_recording_is_exact_under_thread_pool_contention() {
    // The acceptance-criteria hammer: N pool jobs × K observations
    // into *clones of one histogram handle* concurrently.  Atomic
    // bucket counters must lose nothing — the final count, bucket sum
    // and value sum are exact, as if recorded serially.
    use graphedge::util::metrics::Histogram;
    let hist = Histogram::new();
    let pool = ThreadPool::new(8);
    const JOBS: usize = 64;
    const PER_JOB: usize = 2000;
    for j in 0..JOBS {
        let h = hist.clone();
        pool.execute(move || {
            // Deterministic per-job values spread across buckets.
            for k in 0..PER_JOB {
                let v = 1e-4 * ((j * PER_JOB + k) % 1000 + 1) as f64;
                h.observe(v);
            }
        });
    }
    pool.wait_idle();
    assert_eq!(pool.panicked(), 0);
    let snap = hist.snapshot();
    assert_eq!(snap.count(), (JOBS * PER_JOB) as u64);
    assert_eq!(snap.underflow, 0);
    assert_eq!(snap.overflow, 0);
    // The value sum is order-independent up to f64 rounding in the
    // CAS-loop accumulation.
    let expect: f64 = (0..JOBS * PER_JOB)
        .map(|i| 1e-4 * ((i % 1000) + 1) as f64)
        .sum();
    assert!(
        (snap.sum - expect).abs() < 1e-6 * expect,
        "sum drifted: {} vs {expect}",
        snap.sum
    );
    // Percentile of the uniform 0.1ms..100ms sweep: p50 ≈ 50ms.
    let p50 = snap.percentile(50.0);
    assert!((0.035..0.07).contains(&p50), "p50 {p50} out of band");
}
