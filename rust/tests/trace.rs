//! Integration tests for the trace pipeline: span nesting through
//! real call stacks, and end-to-end lifecycle reconstruction of a
//! synthetic serve run from its JSONL export (the same artifact the
//! CI trace-smoke gate validates with `scripts/check_trace_schema.py`).

use std::sync::Mutex;

use graphedge::net::SystemParams;
use graphedge::serving::serve_synthetic_run;
use graphedge::util::json::Value;
use graphedge::util::trace;

/// The recorder is process-global; these tests must not interleave.
static LOCK: Mutex<()> = Mutex::new(());

fn guard() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// A parsed JSONL trace line (the fields the assertions need).
#[derive(Debug)]
struct Line {
    name: String,
    kind: String,
    ts_us: u64,
    span: u64,
    parent: u64,
    server: Option<f64>,
    size: Option<f64>,
}

fn parse_lines(text: &str) -> Vec<Line> {
    text.lines()
        .map(|l| {
            let v = Value::parse(l).expect("every trace line is valid JSON");
            let num = |key: &str| v.path(&[key]).and_then(Value::as_f64).unwrap() as u64;
            Line {
                name: v.path(&["name"]).unwrap().as_str().unwrap().to_string(),
                kind: v.path(&["kind"]).unwrap().as_str().unwrap().to_string(),
                ts_us: num("ts_us"),
                span: num("span"),
                parent: num("parent"),
                server: v.path(&["fields", "server"]).and_then(Value::as_f64),
                size: v.path(&["fields", "size"]).and_then(Value::as_f64),
            }
        })
        .collect()
}

fn helper_with_inner_span() {
    let _inner = trace::span("t.it_inner");
    trace::instant("t.it_mark", &[("v", 1.0)]);
}

#[test]
fn spans_nest_through_real_call_stacks() {
    let _g = guard();
    trace::set_enabled(true);
    trace::clear();
    {
        let _outer = trace::span("t.it_outer");
        helper_with_inner_span();
    }
    trace::set_enabled(false);
    let events = trace::drain();
    let outer = events.iter().find(|e| e.name == "t.it_outer").unwrap();
    let inner = events.iter().find(|e| e.name == "t.it_inner").unwrap();
    let mark = events.iter().find(|e| e.name == "t.it_mark").unwrap();
    assert_eq!(outer.parent, 0, "outer span must be a root");
    assert_eq!(inner.parent, outer.span, "callee span nests under caller");
    assert_eq!(mark.parent, inner.span, "instant attaches to innermost span");
    assert!(outer.ts_us <= inner.ts_us && outer.dur_us >= inner.dur_us);
}

#[test]
fn synthetic_serve_jsonl_reconstructs_the_batch_lifecycle() {
    let _g = guard();
    trace::set_enabled(true);
    trace::clear();
    let stats = serve_synthetic_run(
        &SystemParams::default(),
        "uniform@80x240",
        80,
        240,
        4,
        30,
        9,
        true, // incremental: exercise partition.repair + drift events
        1,
    )
    .expect("synthetic serve");
    trace::set_enabled(false);
    let events = trace::drain();
    assert!(stats.requests > 0, "run routed no requests");

    // Round-trip through the JSONL export — the reconstruction below
    // works from the file format, not the in-memory events.
    let dir = std::env::temp_dir().join(format!("ge_trace_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("serve.jsonl");
    trace::write_jsonl(&path, &events).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    let lines = parse_lines(&text);

    let by_name = |n: &str| lines.iter().filter(move |l| l.name == n);
    let steps: Vec<_> = by_name("serve.step").collect();
    assert_eq!(steps.len(), 4, "one serve.step span per churn step");

    // Nesting: churn and route under a step; repair under churn;
    // drift instants under a repair span.
    let step_ids: Vec<u64> = steps.iter().map(|l| l.span).collect();
    let churns: Vec<_> = by_name("serve.churn").collect();
    assert_eq!(churns.len(), 4);
    for c in &churns {
        assert!(step_ids.contains(&c.parent), "serve.churn outside serve.step");
    }
    let churn_ids: Vec<u64> = churns.iter().map(|l| l.span).collect();
    let repairs: Vec<_> = by_name("partition.repair").collect();
    assert!(!repairs.is_empty(), "incremental run recorded no repair spans");
    for r in &repairs {
        assert!(churn_ids.contains(&r.parent), "repair outside serve.churn");
    }
    let repair_ids: Vec<u64> = repairs.iter().map(|l| l.span).collect();
    let drifts: Vec<_> = by_name("partition.drift").collect();
    assert_eq!(drifts.len(), repairs.len(), "one drift instant per repair");
    for d in &drifts {
        assert_eq!(d.kind, "instant");
        assert!(repair_ids.contains(&d.parent), "drift outside partition.repair");
    }

    // Lifecycle bookkeeping: every routed request is enqueued once and
    // leaves in exactly one closed batch.
    let enqueues: Vec<_> = by_name("router.enqueue").collect();
    assert_eq!(enqueues.len(), stats.requests);
    let closes: Vec<_> = by_name("router.batch_close").collect();
    let closed_total: f64 = closes.iter().map(|l| l.size.unwrap()).sum();
    assert_eq!(closed_total as usize, stats.requests);

    // Every dispatched batch: a serve.batch span wrapping exactly one
    // serve.infer child and one serve.batch_complete instant.
    let batches: Vec<_> = by_name("serve.batch").collect();
    assert_eq!(batches.len(), closes.len());
    let infers: Vec<_> = by_name("serve.infer").collect();
    let completes: Vec<_> = by_name("serve.batch_complete").collect();
    assert_eq!(infers.len(), batches.len());
    assert_eq!(completes.len(), batches.len());
    for b in &batches {
        assert_eq!(
            infers.iter().filter(|i| i.parent == b.span).count(),
            1,
            "each batch span wraps one inference"
        );
        let done: Vec<_> = completes.iter().filter(|c| c.parent == b.span).collect();
        assert_eq!(done.len(), 1, "each batch span ends in one completion");
        assert_eq!(done[0].server, b.server, "completion names the batch's server");
        assert_eq!(done[0].size, b.size);
        // In-order within the batch: close happened before the batch
        // span opened, inference before completion.
        let close_before = closes
            .iter()
            .any(|c| c.server == b.server && c.ts_us <= b.ts_us);
        assert!(close_before, "no batch_close precedes the serve.batch span");
        assert!(done[0].ts_us >= b.ts_us);
    }

    // Enqueue precedes the first close on the global timeline.
    let first_enqueue = enqueues.iter().map(|l| l.ts_us).min().unwrap();
    let first_close = closes.iter().map(|l| l.ts_us).min().unwrap();
    assert!(first_enqueue <= first_close, "a batch closed before any enqueue");
}
