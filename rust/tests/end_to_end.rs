//! Integration: the full coordinator loop through the default runtime
//! backend — every method produces a valid offload, costs behave per
//! the paper's qualitative claims, and the fleet's distributed
//! inference actually executes.  Absolute-accuracy asserts are gated
//! on the manifest publishing an accuracy entry (pretrained weights);
//! the synthesized native store ships random weights and publishes
//! none.

use graphedge::coordinator::Controller;
use graphedge::drl::{MaddpgConfig, Method, PpoConfig};
use graphedge::net::SystemParams;
use graphedge::util::rng::Rng;

fn controller() -> Controller {
    Controller::new(SystemParams::default()).expect("controller")
}

/// Whether `<model>_<dataset>` carries pretrained weights.
fn pretrained(ctrl: &Controller, key: &str) -> bool {
    ctrl.rt.manifest.accuracy.get(key).copied().unwrap_or(0.0) > 0.25
}

#[test]
fn all_methods_produce_valid_offloads_with_inference() {
    let ctrl = controller();
    let users = 48;
    let assocs = 120;
    let mcfg = MaddpgConfig { episodes: 2, warmup: 32, ..MaddpgConfig::default() };
    let (mut drlgo, _, _) = ctrl.train_drlgo("cora", false, users, assocs, &mcfg).unwrap();
    let pcfg = PpoConfig { episodes: 2, ..PpoConfig::default() };
    let (mut ptom, _, _) = ctrl.train_ptom("cora", users, assocs, &pcfg).unwrap();

    for method in [Method::Drlgo, Method::Ptom, Method::Greedy, Method::Random] {
        let mut rng = Rng::seed_from(9);
        let mut env = ctrl.make_env(method, "cora", users, assocs, &mut rng).unwrap();
        let report = ctrl
            .run_scenario(
                method,
                &mut env,
                "cora",
                "gcn",
                Some(&mut drlgo),
                Some(&mut ptom),
                true,
                &mut rng,
            )
            .unwrap();
        assert!(report.cost.total() > 0.0, "{method:?}");
        assert!(report.cost.t_all() > 0.0);
        assert!(report.cost.i_all() > 0.0);
        assert!((0.0..=1.0).contains(&report.accuracy), "{method:?}");
        if pretrained(&ctrl, "gcn_cora") {
            assert!(report.accuracy > 0.3, "{method:?} accuracy {}", report.accuracy);
        }
        // C1 + capacity: all assigned.
        assert!(env.offload.all_assigned(&env.users.active_users()));
        let cm_err = {
            use graphedge::net::cost::CostModel;
            let cm = CostModel::new(
                &env.params,
                &env.net,
                &env.links,
                &env.users,
                &env.layer_dims,
            );
            cm.check_constraints(&env.offload)
        };
        cm_err.unwrap_or_else(|e| panic!("{method:?}: {e}"));
    }
}

#[test]
fn hicut_layout_reduces_cross_server_traffic_for_greedy_colocation() {
    // The qualitative core of the paper: subgraph-aware placement cuts
    // cross-server communication versus placement that ignores layout.
    let ctrl = controller();
    let mut rng = Rng::seed_from(17);
    let mut env = ctrl.make_env(Method::Greedy, "cora", 64, 200, &mut rng).unwrap();

    // Subgraph-colocating placement: each HiCut subgraph goes wholly
    // to one (capacity-checked) server.
    env.reset();
    while let Some(u) = env.current_user() {
        let sg = env.subgraph_of[u];
        let target = sg % env.agents();
        let _ = u;
        env.step(target);
    }
    let coloc = env.evaluate();

    let mut env2 = ctrl.make_env(Method::Greedy, "cora", 64, 200, &mut rng).unwrap();
    env2.reset();
    let mut rr = 0usize;
    while env2.current_user().is_some() {
        env2.step(rr % env2.agents());
        rr += 1;
    }
    let scattered = env2.evaluate();
    assert!(
        coloc.cross_mb <= scattered.cross_mb,
        "colocated {} Mb vs scattered {} Mb",
        coloc.cross_mb,
        scattered.cross_mb
    );
}

#[test]
fn serve_run_reports_latency_and_accuracy() {
    let ctrl = controller();
    let stats = graphedge::serving::serve_run(&ctrl, "pubmed", "sgc", 64, 160, 120, 3).unwrap();
    assert_eq!(stats.requests, 120);
    assert!(stats.batches > 0);
    assert!(stats.latency_p50_s >= 0.0);
    assert!(stats.latency_p99_s >= stats.latency_p50_s);
    assert!((0.0..=1.0).contains(&stats.accuracy));
    if pretrained(&ctrl, "sgc_pubmed") {
        assert!(stats.accuracy > 0.3, "accuracy {}", stats.accuracy);
    }
    assert!(stats.mean_batch >= 1.0);
}
