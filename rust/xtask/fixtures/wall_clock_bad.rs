//! Known-bad fixture: a wall-clock read outside the measurement
//! layers (`util/trace`, `util/metrics`, the serve loop).
pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
