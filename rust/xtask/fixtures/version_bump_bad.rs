//! Analyzed as `graph/dynamic.rs`: a `&mut self` mutator writes a
//! stamped field (`mask`) and never reaches `topology.bump()` — the
//! version pass must fire on `remove_users` and stay quiet on
//! `add_assoc`.

pub struct DynamicGraph {
    graph: Graph,
    mask: Vec<bool>,
    topology: Version,
}

impl DynamicGraph {
    pub fn remove_users(&mut self, users: &[usize]) {
        for &v in users {
            self.mask[v] = false;
        }
    }

    pub fn add_assoc(&mut self, u: usize, v: usize) {
        self.graph.add_edge(u, v);
        self.topology.bump();
    }
}
