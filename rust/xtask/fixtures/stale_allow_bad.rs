//! Analyzed as `util/metrics.rs`: four escape hatches whose rules no
//! longer fire — a lint:allow in an already-exempt file, an orphaned
//! `// ordering:` note, and two dead analyze:allow annotations.

// lint:allow(wall-clock) — this file is on the wall-clock exempt list already.
pub fn snapshot_age_ms() -> u64 {
    7
}

// ordering: Relaxed — there is no atomic operation below anymore.
pub fn hits() -> u64 {
    1
}

// analyze:allow(version) — nothing stamped or memoized here.
pub fn stamp() -> u64 {
    2
}

pub fn first(xs: &[u64]) -> u64 {
    xs.first().copied().unwrap_or(0) // analyze:allow(panic) — no source on this line.
}
