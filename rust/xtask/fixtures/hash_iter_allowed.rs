//! Escape-hatch fixture: the same iteration as `hash_iter_bad.rs`,
//! annotated with a reasoned `lint:allow` — must not fire.
use std::collections::HashMap;

pub fn totals(xs: &[(usize, f64)]) -> f64 {
    let mut acc = HashMap::new();
    for &(k, v) in xs {
        *acc.entry(k).or_insert(0.0) += v;
    }
    let mut sum = 0.0;
    // lint:allow(hash-iter) — floating-point summation over f64 totals
    // is order-sensitive in principle, but this fixture only documents
    // the annotation grammar.
    for (_, v) in &acc {
        sum += v;
    }
    sum
}
