//! Known-bad fixture: a `lint:allow` with no reason.  It must be
//! reported as `allow-syntax` AND fail to suppress the underlying
//! `wall-clock` finding.
pub fn stamp() -> std::time::Instant {
    // lint:allow(wall-clock)
    std::time::Instant::now()
}
