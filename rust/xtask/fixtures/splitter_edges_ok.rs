//! Splitter torture fixture (clean twin): every banned token below
//! sits inside a literal, a comment, or a `#[cfg(test)]` module, and
//! must never reach the code half of the split.

/* Instant::now() in a block comment,
   /* nested: x.unwrap() still inside the comment */
   and still inside the outer comment here */
pub fn opaque_regions() -> &'static str {
    let raw = r##"Instant::now() "# x.unwrap() // not a comment"##;
    let _bracket = '[';
    let _quote = '\'';
    raw
}

pub fn generic<'a>(x: &'a str) -> &'a str {
    // The lifetime ticks above must read as code (not open a char
    // literal that would swallow the rest of the signature).
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwrap_and_clocks_are_fine_in_tests() {
        let v = vec![1usize];
        assert_eq!(v.first().copied().unwrap(), 1);
        let _t = std::time::Instant::now();
        assert_eq!(opaque_regions().len(), 45);
    }
}
