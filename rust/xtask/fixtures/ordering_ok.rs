//! Justified fixture: the same atomic access with an adjacent
//! `// ordering:` note — must not fire.
use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(c: &AtomicU64) -> u64 {
    // ordering: Relaxed — standalone counter; nothing is published
    // through it, the RMW alone guarantees no lost increment.
    c.fetch_add(1, Ordering::Relaxed)
}
