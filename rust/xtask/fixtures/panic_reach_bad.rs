//! Analyzed as `serving/fixture.rs`: a private helper two calls deep
//! asserts and indexes; both must be reported against the pub entry
//! `serve` with the chain `serve -> dispatch -> lookup`.

const TABLE: [usize; 4] = [1, 2, 3, 4];

pub fn serve(reqs: &[usize]) -> usize {
    let mut total = 0;
    for &r in reqs {
        total += dispatch(r);
    }
    total
}

fn dispatch(r: usize) -> usize {
    lookup(r)
}

fn lookup(r: usize) -> usize {
    assert!(r < TABLE.len(), "fixture bound");
    TABLE[r]
}
