//! Escape-hatch fixture: annotated in-loop shim call — must not fire.
pub fn record(xs: &[f64]) {
    for &x in xs {
        // lint:allow(metrics-shim) — fixture: cold loop bounded at a
        // handful of items, registry cost is irrelevant here.
        METRICS.observe("fixture.x", x);
    }
}
