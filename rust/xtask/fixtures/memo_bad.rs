//! Known-bad fixture: hand-rolled memo cells outside
//! `util/version.rs` — an unversioned cache nothing ever proves fresh.
use std::cell::{Cell, RefCell};

pub struct Cache {
    sorted: RefCell<Option<Vec<f64>>>,
    total: Cell<Option<f64>>,
}

#[cfg(test)]
mod tests {
    // Test code is exempt: a scratch cache in a test fixture is fine.
    struct Scratch {
        memo: std::cell::RefCell<Option<u32>>,
    }
}
