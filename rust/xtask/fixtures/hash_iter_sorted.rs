//! Exoneration fixture: hash iteration immediately followed by a
//! sort is order-deterministic — must not fire.
use std::collections::HashMap;

pub fn ordered_keys(m: &HashMap<usize, f64>) -> Vec<usize> {
    let mut keys: Vec<usize> = m.keys().copied().collect();
    keys.sort_unstable();
    keys
}
