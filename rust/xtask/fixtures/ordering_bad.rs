//! Known-bad fixture: an atomic access in a lock-free util file with
//! no memory-order justification comment nearby.
use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(c: &AtomicU64) -> u64 {
    c.fetch_add(1, Ordering::Relaxed)
}
