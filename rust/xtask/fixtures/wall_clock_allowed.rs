//! Escape-hatch fixture: annotated wall-clock read — must not fire.
pub fn stamp() -> std::time::Instant {
    // lint:allow(wall-clock) — fixture: measurement-only timestamp,
    // nothing downstream branches on it.
    std::time::Instant::now()
}
