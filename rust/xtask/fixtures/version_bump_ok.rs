//! Analyzed as `graph/dynamic.rs`: the passing counterpart of
//! `version_bump_bad.rs` — one mutator bumps through a same-file
//! helper (transitive reach), one is reason-annotated.

pub struct DynamicGraph {
    graph: Graph,
    mask: Vec<bool>,
    pos: Vec<Pos>,
    topology: Version,
}

impl DynamicGraph {
    /// The write and the bump live in different fns: the pass must
    /// follow the intra-file call edge.
    pub fn remove_users(&mut self, users: &[usize]) {
        for &v in users {
            self.mask[v] = false;
        }
        self.mark_changed();
    }

    fn mark_changed(&mut self) {
        self.topology.bump();
    }

    // analyze:allow(version) — fixture: shadow buffer, stamped on flush.
    pub fn stage_pos(&mut self, v: usize, p: Pos) {
        self.pos[v] = p;
    }
}
