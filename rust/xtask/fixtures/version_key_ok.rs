//! Analyzed as `drl/env.rs`: sound producers and memo keys — both
//! named producers stamp their versions, both registered rebuild
//! closures carry full keys (one resolved through a multi-line
//! `let key = […]`), and an unregistered scratch cache is
//! reason-annotated.

impl Env {
    fn install_partition(&mut self, partition: &Partition) {
        self.subgraph_of = partition.assignment(self.users.capacity());
        self.layout.bump();
        self.layout_at = self.users.topology_version();
    }

    fn assemble(cfg: EnvConfig, users: DynamicGraph) -> Self {
        let mut env = Env::seed(cfg, users);
        env.params_ver.bump();
        env
    }

    fn obs_templates(&self) -> Row {
        let key = [
            self.users.topology_version(),
            self.layout,
            self.params_ver,
        ];
        self.obs_templates.get_or_rebuild(&key, || self.build_obs_templates())
    }

    fn rate_tables(&self) -> Rates {
        let key = [self.users.topology_version(), self.params_ver];
        self.rates.get_or_rebuild(&key, || RateTables::build(&self.cost_model()))
    }

    // analyze:allow(version) — fixture: scratch cache keyed on an ad-hoc tick.
    fn scratch(&self) -> u64 {
        self.scratch.get_or_rebuild(&[self.tick], || self.compute_scratch())
    }
}
