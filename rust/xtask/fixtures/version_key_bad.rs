//! Analyzed as `drl/env.rs`: `install_partition` forgets
//! `layout.bump()` and the memoized template key omits the layout
//! version its rebuild closure depends on — two version findings.

impl Env {
    fn install_partition(&mut self, partition: &Partition) {
        let n = self.users.capacity();
        self.subgraph_of = partition.assignment(n);
        self.recompute_obs_dynamics();
    }

    fn assemble(cfg: EnvConfig, users: DynamicGraph) -> Self {
        let mut env = Env::seed(cfg, users);
        env.params_ver.bump();
        env
    }

    fn obs_templates(&self) -> Row {
        let key = [self.users.topology_version(), self.params_ver];
        self.obs_templates.get_or_rebuild(&key, || self.build_obs_templates())
    }
}
