//! Escape-hatch fixture: annotated unwrap — must not fire.
pub fn head(xs: &[u32]) -> u32 {
    // lint:allow(panic) — fixture: the caller guarantees non-empty
    // input by construction.
    let first = xs.first().unwrap();
    *first
}
