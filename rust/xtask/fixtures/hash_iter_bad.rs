//! Known-bad fixture: hash-order iteration in a deterministic layer.
//! Not compiled — consumed as text by the linter self-tests.
use std::collections::{HashMap, HashSet};

pub fn totals(xs: &[(usize, f64)]) -> f64 {
    let mut acc = HashMap::new();
    for &(k, v) in xs {
        *acc.entry(k).or_insert(0.0) += v;
    }
    let mut sum = 0.0;
    for (_, v) in &acc {
        sum += v;
    }
    sum
}

pub fn first_key(seen: &mut HashSet<usize>) -> Option<usize> {
    seen.iter().next().copied()
}
