//! Analyzed as `serving/fixture.rs`: the passing counterpart of
//! `panic_reach_bad.rs` — the deep helper is guarded, one fn carries
//! a fn-level allow, and one call edge is explicitly trusted.

const TABLE: [usize; 4] = [1, 2, 3, 4];
const RAW: [usize; 2] = [7, 9];

pub fn serve(reqs: &[usize]) -> usize {
    let mut total = 0;
    for &r in reqs {
        total += dispatch(r);
    }
    total
}

fn dispatch(r: usize) -> usize {
    lookup(r)
}

fn lookup(r: usize) -> usize {
    TABLE.get(r).copied().unwrap_or(0)
}

// analyze:allow(panic) — fixture: bounds pre-validated by the caller.
pub fn checked(xs: &[usize], i: usize) -> usize {
    xs[i]
}

pub fn trusting(r: usize) -> usize {
    risky(r) // analyze:allow(panic: risky) — fixture: r validated upstream.
}

fn risky(r: usize) -> usize {
    RAW[r]
}
