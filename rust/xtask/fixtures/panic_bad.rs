//! Known-bad fixture: unwrap in non-test code of a no-panic layer.
//! The unwrap inside `#[cfg(test)]` must NOT be reported.
pub fn head(xs: &[u32]) -> u32 {
    let first = xs.first().unwrap();
    *first
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(super::head(&[1, 2]), 1);
        assert_eq!("7".parse::<u32>().unwrap(), 7);
    }
}
