//! Analyzed as `util/metrics.rs`: every escape hatch here still
//! suppresses a live finding — the stale pass must stay quiet.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

pub static HITS: AtomicU64 = AtomicU64::new(0);

pub struct Snap {
    // lint:allow(memo) — fixture: deliberate one-slot cache on a cold path.
    cache: RefCell<Option<u64>>,
}

// ordering: Relaxed — monotone counter, no cross-field invariant.
pub fn bump_hits() -> u64 {
    HITS.fetch_add(1, Ordering::Relaxed)
}
