//! Known-bad fixture: a string-keyed metrics-shim call inside a loop
//! body.  The identical call outside the loop must NOT be reported.
pub fn record(xs: &[f64]) {
    for &x in xs {
        METRICS.observe("fixture.x", x);
    }
    METRICS.observe("fixture.done", 1.0);
}
