//! Escape-hatch fixture: annotated memo cell — must not fire.
use std::cell::RefCell;

pub struct Tls {
    // lint:allow(memo) — fixture: thread-local reuse buffer, not a
    // cache of derived state; there is nothing to invalidate.
    slot: RefCell<Option<Vec<u8>>>,
}
