//! Splitter torture fixture (firing twin): the same constructs as the
//! clean twin, but with the banned tokens just *outside* the opaque
//! regions — each must fire exactly once.

/* x.unwrap() safely inside a comment */
pub fn after_comment(v: &[usize]) -> usize {
    *v.first().unwrap()
}

pub fn after_raw_string() -> usize {
    let raw = r#"x.unwrap() not code"#;
    raw.len().checked_add(1).unwrap()
}

pub fn after_lifetime_tick<'a>(xs: &'a [usize]) -> usize {
    *xs.first().unwrap()
}

pub fn before_test_boundary() -> std::time::Instant {
    std::time::Instant::now()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nothing_after_the_boundary_counts() {
        assert_eq!(after_comment(&[7]), 7);
        let _t = std::time::Instant::now();
    }
}
