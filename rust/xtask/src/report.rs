//! Shared finding type and reporting for `lint` and `analyze`.
//!
//! Both subcommands emit the same stable, machine-readable prefix —
//! `file:line:rule: message` — sorted by (file, line, rule), so editor
//! quickfix lists and CI logs link straight to the offending line, and
//! `--format json` produces a diffable artifact for CI upload.

#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    /// 1-based.
    pub line: usize,
    pub msg: String,
}

/// Deterministic report order: (file, line, rule, msg).
pub fn sort_findings(findings: &mut [Finding]) {
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule, a.msg.as_str())
            .cmp(&(b.file.as_str(), b.line, b.rule, b.msg.as_str()))
    });
}

/// One text line per finding: `file:line:rule: message`.
pub fn render_text(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&format!("{}:{}:{}: {}\n", f.file, f.line, f.rule, f.msg));
    }
    out
}

/// The whole report as one JSON object (no dependencies, so the
/// serialization is hand-rolled; strings are escaped per RFC 8259).
pub fn render_json(tool: &str, files: usize, findings: &[Finding]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"tool\": {},\n", json_str(tool)));
    out.push_str(&format!("  \"files\": {files},\n"));
    out.push_str(&format!("  \"finding_count\": {},\n", findings.len()));
    out.push_str("  \"findings\": [");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"file\": {}, \"line\": {}, \"rule\": {}, \"msg\": {}}}",
            json_str(&f.file),
            f.line,
            json_str(f.rule),
            json_str(&f.msg)
        ));
    }
    if !findings.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("]\n}\n");
    out
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Output format for both subcommands.
#[derive(Clone, Copy, PartialEq)]
pub enum Format {
    Text,
    Json,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(rule: &'static str, file: &str, line: usize) -> Finding {
        Finding { rule, file: file.to_string(), line, msg: "m".to_string() }
    }

    #[test]
    fn findings_sort_by_file_then_line_then_rule() {
        let mut v = vec![
            f("panic", "b.rs", 3),
            f("version", "a.rs", 9),
            f("panic", "a.rs", 9),
            f("panic", "a.rs", 2),
        ];
        sort_findings(&mut v);
        let order: Vec<(String, usize, &str)> =
            v.iter().map(|x| (x.file.clone(), x.line, x.rule)).collect();
        assert_eq!(
            order,
            vec![
                ("a.rs".to_string(), 2, "panic"),
                ("a.rs".to_string(), 9, "panic"),
                ("a.rs".to_string(), 9, "version"),
                ("b.rs".to_string(), 3, "panic"),
            ]
        );
    }

    #[test]
    fn text_prefix_is_stable() {
        let out = render_text(&[f("stale-allow", "util/metrics.rs", 7)]);
        assert_eq!(out, "util/metrics.rs:7:stale-allow: m\n");
    }

    #[test]
    fn json_is_well_formed_and_escaped() {
        let finding = Finding {
            rule: "panic",
            file: "serving/a.rs".to_string(),
            line: 4,
            msg: "chain \"x\" → y\tz".to_string(),
        };
        let out = render_json("xtask-analyze", 2, &[finding]);
        assert!(out.contains("\"tool\": \"xtask-analyze\""));
        assert!(out.contains("\"files\": 2"));
        assert!(out.contains("\"finding_count\": 1"));
        assert!(out.contains("\\\"x\\\""));
        assert!(out.contains("\\t"));
        // Exactly balanced braces/brackets (cheap well-formedness probe).
        assert_eq!(out.matches('{').count(), out.matches('}').count());
        assert_eq!(out.matches('[').count(), out.matches(']').count());
    }

    #[test]
    fn empty_report_is_valid_json() {
        let out = render_json("xtask-lint", 0, &[]);
        assert!(out.contains("\"findings\": []"));
    }
}
