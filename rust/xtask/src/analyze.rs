//! `xtask analyze` — semantic passes over the item model and call
//! graph (see `rust/ANALYSIS.md` for the full design):
//!
//! * **version** — version-stamp soundness.  `&mut self` methods on
//!   stamped producers that write stamped state must bump/record the
//!   corresponding `Version` on some path (directly or through a
//!   same-file helper); named producer fns must contain their stamp
//!   markers; every `Memoized::get_or_rebuild` key slice must mention
//!   a version for each registered producer the rebuild closure reads.
//! * **panic** — transitive panic-freedom for `serving/` +
//!   `partition/`.  Direct sources (`panic!`-family macros,
//!   `.unwrap()`, `.expect(`, indexing `[…]`) propagate backward over
//!   the intra-layer call graph; a finding names the call chain from
//!   the nearest pub entry point.
//! * **stale-allow** — a `lint:allow`/`analyze:allow` annotation (or
//!   an `// ordering:` note) whose rule no longer fires on its scope
//!   is itself a finding, so escape hatches cannot rot.
//!
//! Escape hatch grammar: `// analyze:allow(<rule>[: <callee>]) —
//! <reason>` on the offending line, in the contiguous comment block
//! directly above it, or directly above a `fn` header (covering the
//! whole body; `version` also accepts fn-level coverage).  The
//! `: <callee>` form suppresses panic propagation along call edges to
//! `<callee>` on the covered line only.  Stale-allow findings cannot
//! themselves be allowed.

use std::collections::BTreeMap;

use crate::allow::{
    analyze_allowed, analyze_edge_allowed, coverage_of, parse_allow, parse_analyze_allow,
};
use crate::items::{extract_calls, extract_items, CallKind, FnItem};
use crate::lint::{lint_scan, Raw, KNOWN_RULES, ORDERING_FILES, ORDERING_WINDOW};
use crate::report::Finding;
use crate::splitter::{find_word, is_word, leading_ident, Split};

pub const ANALYZE_RULES: [&str; 2] = ["version", "panic"];

// ------------------------------------------------------------------
// Producer/consumer tables.  These encode the version-stamp contract
// of `rust/ARCHITECTURE.md`; growing a new producer or memo consumer
// means extending them (the pass fails closed on unregistered
// `get_or_rebuild` sites, so forgetting is itself a finding).

/// The stamped-field producer: every `&mut self` method of this impl
/// that writes one of the stamped fields must reach the bump marker.
const STAMPED_FILE: &str = "graph/dynamic.rs";
const STAMPED_IMPL: &str = "DynamicGraph";
const STAMPED_FIELDS: [&str; 4] = ["graph", "mask", "pos", "task_mb"];
const STAMPED_BUMP: &str = "topology.bump(";

/// (file, fn name, any-of stamp markers) — producers whose stamp
/// discipline is per-fn rather than per-field.
const NAMED_PRODUCERS: [(&str, &str, &[&str]); 4] = [
    ("drl/env.rs", "install_partition", &["layout.bump("]),
    ("drl/env.rs", "assemble", &["params_ver.bump("]),
    ("partition/incremental/repair.rs", "apply", &["repaired_to =", "note_repaired("]),
    ("partition/incremental/repair.rs", "full_recut", &["repaired_to =", "note_repaired("]),
];

/// (file, closure marker, required version tokens in the key slice):
/// if a `get_or_rebuild` rebuild closure mentions the marker, its key
/// must word-mention every required token.
const MEMO_DEPS: [(&str, &str, &[&str]); 3] = [
    ("drl/env.rs", "cost_model", &["topology_version", "params_ver"]),
    ("drl/env.rs", "build_obs_templates", &["topology_version", "layout", "params_ver"]),
    ("util/stats.rs", "self.xs", &["edits"]),
];

/// Receiver methods that mutate the receiver (write detection for
/// `self.<field>.<method>(…)`).
const MUT_METHODS: [&str; 26] = [
    "push", "pop", "insert", "remove", "clear", "truncate", "extend", "retain", "resize",
    "fill", "swap", "sort", "sort_unstable", "sort_by", "sort_unstable_by", "drain", "take",
    "set", "add_edge", "remove_edge", "isolate", "bump", "get_mut", "iter_mut", "first_mut",
    "last_mut",
];

const COMPOUND_ASSIGN: [&str; 10] =
    ["+=", "-=", "*=", "/=", "%=", "&=", "|=", "^=", "<<=", ">>="];

/// `panic!`-family macro names (word-matched, so `debug_assert*` never
/// matches — debug assertions are compiled out of release serving).
const PANIC_MACROS: [&str; 7] =
    ["panic", "assert", "assert_eq", "assert_ne", "unreachable", "todo", "unimplemented"];

/// Layers under the no-panic contract.
pub fn panic_scope(rel: &str) -> bool {
    rel.starts_with("serving/") || rel.starts_with("partition/")
}

// ------------------------------------------------------------------

struct Ctx {
    rel: String,
    split: Split,
    end: usize,
    items: Vec<FnItem>,
    raw_lint: Vec<Raw>,
}

fn qual(f: &FnItem) -> String {
    match &f.impl_type {
        Some(t) => format!("{t}::{}", f.name),
        None => f.name.clone(),
    }
}

/// Analyze a set of files (rel path with `/` separators, source).
/// Returns *reported* findings (suppressions applied); sort with
/// [`crate::report::sort_findings`] before rendering.
pub fn analyze_tree(files: &[(String, String)]) -> Vec<Finding> {
    let ctxs: Vec<Ctx> = files
        .iter()
        .map(|(rel, src)| {
            let scan = lint_scan(rel, src);
            let items = extract_items(&scan.split, scan.end);
            Ctx { rel: rel.clone(), split: scan.split, end: scan.end, items, raw_lint: scan.raw }
        })
        .collect();

    let raw_version: Vec<Vec<(usize, String)>> = ctxs.iter().map(version_raw).collect();
    let panic = PanicModel::build(&ctxs);

    let mut findings = Vec::new();
    for (ci, ctx) in ctxs.iter().enumerate() {
        for (line, msg) in &raw_version[ci] {
            if !version_suppressed(ctx, *line) {
                findings.push(Finding {
                    rule: "version",
                    file: ctx.rel.clone(),
                    line: line + 1,
                    msg: msg.clone(),
                });
            }
        }
    }
    findings.extend(panic.report(&ctxs));
    findings.extend(stale_pass(&ctxs, &raw_version, &panic));
    findings
}

// ---------------------------------------------------------- version

fn body_range(ctx: &Ctx, f: &FnItem) -> std::ops::RangeInclusive<usize> {
    f.body_start..=f.body_end.min(ctx.split.code.len().saturating_sub(1))
}

fn body_text(ctx: &Ctx, f: &FnItem) -> String {
    ctx.split.code[body_range(ctx, f)].join("\n")
}

/// Same-file call resolution (by impl-qualified name, then unique
/// name).  Used for the marker-reach fixpoint.
fn resolve_in_file(ctx: &Ctx, caller: &FnItem, name: &str, kind: &CallKind) -> Option<usize> {
    let by_name: Vec<usize> =
        ctx.items.iter().enumerate().filter(|(_, f)| f.name == name).map(|(i, _)| i).collect();
    let in_impl = |ty: &Option<String>| -> Vec<usize> {
        ctx.items
            .iter()
            .enumerate()
            .filter(|(_, f)| f.name == name && f.impl_type == *ty)
            .map(|(i, _)| i)
            .collect()
    };
    match kind {
        CallKind::Qualified(q) => {
            let c = in_impl(&Some(q.clone()));
            if c.len() == 1 {
                return Some(c[0]);
            }
            None
        }
        CallKind::Method { on_self: true } => {
            let c = in_impl(&caller.impl_type);
            if c.len() == 1 {
                return Some(c[0]);
            }
            if by_name.len() == 1 {
                return Some(by_name[0]);
            }
            None
        }
        _ => {
            if by_name.len() == 1 {
                return Some(by_name[0]);
            }
            None
        }
    }
}

/// For each fn in the file: does some path through same-file calls
/// reach a body containing one of `markers`?
fn marker_reach(ctx: &Ctx, markers: &[&str]) -> Vec<bool> {
    let n = ctx.items.len();
    let mut reach: Vec<bool> = ctx
        .items
        .iter()
        .map(|f| {
            let body = body_text(ctx, f);
            markers.iter().any(|m| body.contains(m))
        })
        .collect();
    let callees: Vec<Vec<usize>> = ctx
        .items
        .iter()
        .map(|f| {
            extract_calls(&ctx.split, f)
                .iter()
                .filter_map(|c| resolve_in_file(ctx, f, &c.name, &c.kind))
                .collect()
        })
        .collect();
    let mut changed = true;
    while changed {
        changed = false;
        for i in 0..n {
            if !reach[i] && callees[i].iter().any(|&c| reach[c]) {
                reach[i] = true;
                changed = true;
            }
        }
    }
    reach
}

/// Which stamped fields does `f` write?  A write is `self.F… = `
/// (plain or compound assignment, after any `[…]` index groups), a
/// mutating method call `self.F.push(…)`, or a `&mut self.F` borrow.
fn stamped_writes(ctx: &Ctx, f: &FnItem) -> Vec<&'static str> {
    let mut out = Vec::new();
    for field in STAMPED_FIELDS {
        'lines: for i in body_range(ctx, f) {
            let code = &ctx.split.code[i];
            let mut from = 0;
            while let Some(at) = find_word(code, field, from) {
                from = at + field.len();
                let before = &code[..at];
                if !before.ends_with("self.") {
                    continue;
                }
                let pre = before[..before.len() - 5].trim_end();
                let mut_borrow = pre.ends_with("mut")
                    && !pre[..pre.len() - 3].chars().next_back().is_some_and(is_word);
                let mut rest = &code[at + field.len()..];
                // Skip `[…]` index groups (conservatively bail on a
                // group left open by a line break).
                loop {
                    let t = rest.trim_start();
                    let Some(tail) = t.strip_prefix('[') else {
                        rest = t;
                        break;
                    };
                    let mut depth = 1usize;
                    let mut close = None;
                    for (k, c) in tail.char_indices() {
                        match c {
                            '[' => depth += 1,
                            ']' => {
                                depth -= 1;
                                if depth == 0 {
                                    close = Some(k);
                                    break;
                                }
                            }
                            _ => {}
                        }
                    }
                    match close {
                        Some(k) => rest = &tail[k + 1..],
                        None => {
                            rest = "";
                            break;
                        }
                    }
                }
                let written = mut_borrow
                    || (!rest.starts_with("==") && rest.starts_with('='))
                    || COMPOUND_ASSIGN.iter().any(|op| rest.starts_with(op))
                    || rest
                        .strip_prefix('.')
                        .is_some_and(|m| MUT_METHODS.contains(&leading_ident(m.trim_start())));
                if written {
                    out.push(field);
                    break 'lines;
                }
            }
        }
    }
    out
}

/// Raw (pre-suppression) version findings for one file, 0-based lines.
fn version_raw(ctx: &Ctx) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    if ctx.rel == STAMPED_FILE {
        let reach = marker_reach(ctx, &[STAMPED_BUMP]);
        for (fi, f) in ctx.items.iter().enumerate() {
            if f.impl_type.as_deref() != Some(STAMPED_IMPL) || !f.has_mut_self {
                continue;
            }
            let fields = stamped_writes(ctx, f);
            if !fields.is_empty() && !reach[fi] {
                out.push((
                    f.sig_line,
                    format!(
                        "`{}` writes stamped state ({}) with no `{STAMPED_BUMP}…)` on any path",
                        qual(f),
                        fields.join(", ")
                    ),
                ));
            }
        }
    }
    for (file, name, markers) in NAMED_PRODUCERS {
        if ctx.rel != file {
            continue;
        }
        let hits: Vec<usize> = ctx
            .items
            .iter()
            .enumerate()
            .filter(|(_, f)| f.name == name)
            .map(|(i, _)| i)
            .collect();
        if hits.is_empty() {
            out.push((
                0,
                format!(
                    "producer fn `{name}` not found — update NAMED_PRODUCERS in \
                     xtask/src/analyze.rs"
                ),
            ));
            continue;
        }
        let reach = marker_reach(ctx, markers);
        for fi in hits {
            if !reach[fi] {
                out.push((
                    ctx.items[fi].sig_line,
                    format!(
                        "`{}` must record its version (expected one of: {}) on some path",
                        qual(&ctx.items[fi]),
                        markers.join(", ")
                    ),
                ));
            }
        }
    }
    out.extend(memo_sites(ctx));
    out.sort();
    out
}

/// Check every `Memoized::get_or_rebuild` call site in the file
/// against [`MEMO_DEPS`].
fn memo_sites(ctx: &Ctx) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let end = ctx.end.min(ctx.split.code.len());
    let mut offsets = Vec::with_capacity(end);
    let mut text = String::new();
    for i in 0..end {
        offsets.push(text.len());
        text.push_str(&ctx.split.code[i]);
        text.push('\n');
    }
    let deps: Vec<_> = MEMO_DEPS.iter().filter(|(f, _, _)| *f == ctx.rel).collect();
    let mut from = 0;
    while let Some(at) = find_word(&text, "get_or_rebuild", from) {
        from = at + "get_or_rebuild".len();
        let rest = &text[at + "get_or_rebuild".len()..];
        if !rest.starts_with('(') {
            continue;
        }
        // Skip the definition itself (`fn get_or_rebuild(`).
        if text[..at].trim_end().ends_with("fn") {
            continue;
        }
        let line = offsets.partition_point(|&o| o <= at).saturating_sub(1);
        let Some(args) = paren_group(rest) else { continue };
        let (key_expr, closure) = split_first_arg(args);
        let key_text = resolve_key(ctx, line, key_expr.trim());
        let mut matched = false;
        for (_, marker, required) in &deps {
            if !closure.contains(marker) {
                continue;
            }
            matched = true;
            for req in *required {
                if find_word(&key_text, req, 0).is_none() {
                    out.push((
                        line,
                        format!(
                            "memoized key omits `{req}` but the rebuild closure reads \
                             `{marker}`-derived state"
                        ),
                    ));
                }
            }
        }
        if !matched {
            out.push((
                line,
                "get_or_rebuild closure reads no registered producer — extend MEMO_DEPS in \
                 xtask/src/analyze.rs or annotate with `analyze:allow(version)`"
                    .to_string(),
            ));
        }
    }
    out
}

/// The text inside the parenthesis group `s` starts with.
fn paren_group(s: &str) -> Option<&str> {
    let mut depth = 0usize;
    for (k, c) in s.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(&s[1..k]);
                }
            }
            _ => {}
        }
    }
    None
}

/// Split an argument list at its first top-level comma.
fn split_first_arg(args: &str) -> (&str, &str) {
    let mut depth = 0i32;
    for (k, c) in args.char_indices() {
        match c {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => depth -= 1,
            ',' if depth == 0 => return (&args[..k], &args[k + 1..]),
            _ => {}
        }
    }
    (args, "")
}

/// Resolve the key expression of a `get_or_rebuild` site to text we
/// can word-search: inline slices verbatim, `&name` via a backward
/// scan for `let name = … ;`.
fn resolve_key(ctx: &Ctx, site_line: usize, expr: &str) -> String {
    let e = expr.trim_start_matches('&').trim_start();
    if e.starts_with('[') {
        return e.to_string();
    }
    let name = leading_ident(e);
    if name.is_empty() {
        return String::new();
    }
    let lo = site_line.saturating_sub(40);
    for k in (lo..=site_line.min(ctx.split.code.len().saturating_sub(1))).rev() {
        let code = &ctx.split.code[k];
        let Some(lat) = find_word(code, "let", 0) else { continue };
        let Some(nat) = find_word(code, name, lat + 3) else { continue };
        let Some(eq) = code[nat..].find('=') else { continue };
        let mut acc = String::new();
        acc.push_str(&code[nat + eq + 1..]);
        let mut k2 = k + 1;
        while !acc.contains(';') && k2 < ctx.split.code.len() {
            acc.push(' ');
            acc.push_str(&ctx.split.code[k2]);
            k2 += 1;
        }
        return acc;
    }
    String::new()
}

fn version_suppressed(ctx: &Ctx, line: usize) -> bool {
    analyze_allowed("version", line, &ctx.split)
        || ctx.items.iter().any(|f| {
            line >= f.sig_line
                && line <= f.body_end
                && analyze_allowed("version", f.sig_line, &ctx.split)
        })
}

// ------------------------------------------------------------ panic

struct PanicModel {
    /// (ctx index, item index) per global fn id, panic-scope files only.
    fns: Vec<(usize, usize)>,
    /// Direct sources per global fn: (0-based line, description).
    sources: Vec<Vec<(usize, String)>>,
    /// Sources not covered by a line- or fn-level `analyze:allow(panic)`.
    uncovered: Vec<Vec<(usize, String)>>,
    /// Resolved call edges per global fn: (callee id, 0-based line, name).
    edges: Vec<Vec<(usize, usize, String)>>,
    /// Reaches a fn with ≥1 direct source, ignoring every allow
    /// (the stale pass's notion of "this edge allow still matters").
    raw_uncertified: Vec<bool>,
}

impl PanicModel {
    fn build(ctxs: &[Ctx]) -> PanicModel {
        let mut fns = Vec::new();
        for (ci, ctx) in ctxs.iter().enumerate() {
            if !panic_scope(&ctx.rel) {
                continue;
            }
            for ii in 0..ctx.items.len() {
                fns.push((ci, ii));
            }
        }
        let item = |gid: usize| -> &FnItem {
            let (ci, ii) = fns[gid];
            &ctxs[ci].items[ii]
        };
        let mut by_name: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let mut by_qual: BTreeMap<(String, String), Vec<usize>> = BTreeMap::new();
        for gid in 0..fns.len() {
            let f = item(gid);
            by_name.entry(f.name.clone()).or_default().push(gid);
            if let Some(t) = &f.impl_type {
                by_qual.entry((t.clone(), f.name.clone())).or_default().push(gid);
            }
        }
        let unique = |v: Option<&Vec<usize>>| -> Option<usize> {
            match v {
                Some(v) if v.len() == 1 => Some(v[0]),
                _ => None,
            }
        };
        let mut edges = Vec::with_capacity(fns.len());
        let mut sources = Vec::with_capacity(fns.len());
        let mut uncovered = Vec::with_capacity(fns.len());
        for gid in 0..fns.len() {
            let (ci, _) = fns[gid];
            let ctx = &ctxs[ci];
            let f = item(gid);
            let mut es = Vec::new();
            for c in extract_calls(&ctx.split, f) {
                let target = match &c.kind {
                    CallKind::Qualified(q) => {
                        unique(by_qual.get(&(q.clone(), c.name.clone())))
                    }
                    CallKind::Method { on_self: true } => f
                        .impl_type
                        .as_ref()
                        .and_then(|t| unique(by_qual.get(&(t.clone(), c.name.clone()))))
                        .or_else(|| unique(by_name.get(&c.name))),
                    _ => unique(by_name.get(&c.name)),
                };
                if let Some(t) = target {
                    if t != gid {
                        es.push((t, c.line, c.name.clone()));
                    }
                }
            }
            edges.push(es);
            let srcs = direct_sources(ctx, f);
            let fn_allowed = analyze_allowed("panic", f.sig_line, &ctx.split);
            let unc: Vec<(usize, String)> = if fn_allowed {
                Vec::new()
            } else {
                srcs.iter()
                    .filter(|(l, _)| !analyze_allowed("panic", *l, &ctx.split))
                    .cloned()
                    .collect()
            };
            sources.push(srcs);
            uncovered.push(unc);
        }
        // Raw uncertified: reaches any direct source over all edges.
        let mut raw_uncertified: Vec<bool> =
            sources.iter().map(|s| !s.is_empty()).collect();
        let mut changed = true;
        while changed {
            changed = false;
            for gid in 0..fns.len() {
                if !raw_uncertified[gid]
                    && edges[gid].iter().any(|&(t, _, _)| raw_uncertified[t])
                {
                    raw_uncertified[gid] = true;
                    changed = true;
                }
            }
        }
        PanicModel { fns, sources, uncovered, edges, raw_uncertified }
    }

    /// Reported findings: each uncovered source in a fn that is pub or
    /// reachable from a pub entry over unsuppressed edges, with the
    /// offending call chain in the message.
    fn report(&self, ctxs: &[Ctx]) -> Vec<Finding> {
        let n = self.fns.len();
        let active = |gid: usize, edge: &(usize, usize, String)| -> bool {
            let (ci, _) = self.fns[gid];
            !analyze_edge_allowed("panic", &edge.2, edge.1, &ctxs[ci].split)
        };
        // Multi-source BFS from pub fns over unsuppressed edges.
        let mut reached = vec![false; n];
        let mut parent: Vec<Option<usize>> = vec![None; n];
        let mut queue: Vec<usize> = Vec::new();
        for gid in 0..n {
            let (ci, ii) = self.fns[gid];
            if ctxs[ci].items[ii].is_pub {
                reached[gid] = true;
                queue.push(gid);
            }
        }
        let mut head = 0;
        while head < queue.len() {
            let g = queue[head];
            head += 1;
            for e in &self.edges[g] {
                if active(g, e) && !reached[e.0] {
                    reached[e.0] = true;
                    parent[e.0] = Some(g);
                    queue.push(e.0);
                }
            }
        }
        let mut out = Vec::new();
        for gid in 0..n {
            if self.uncovered[gid].is_empty() {
                continue;
            }
            let (ci, ii) = self.fns[gid];
            let f = &ctxs[ci].items[ii];
            if !f.is_pub && !reached[gid] {
                continue;
            }
            let chain = if f.is_pub {
                String::new()
            } else {
                let mut names = vec![qual(f)];
                let mut cur = gid;
                while let Some(p) = parent[cur] {
                    let (pci, pii) = self.fns[p];
                    names.push(qual(&ctxs[pci].items[pii]));
                    cur = p;
                }
                names.reverse();
                format!(" (reached via `{}`)", names.join(" -> "))
            };
            for (line, desc) in &self.uncovered[gid] {
                out.push(Finding {
                    rule: "panic",
                    file: ctxs[ci].rel.clone(),
                    line: line + 1,
                    msg: format!("possible panic: {desc} in `{}`{chain}", qual(f)),
                });
            }
        }
        out
    }

    /// Does any fn in `ci` whose sig sits at `line` have a direct source?
    fn fn_has_source_at(&self, ctxs: &[Ctx], ci: usize, line: usize) -> bool {
        self.fns.iter().enumerate().any(|(gid, &(fci, fii))| {
            fci == ci && ctxs[fci].items[fii].sig_line == line && !self.sources[gid].is_empty()
        })
    }

    /// Is there a direct source on `line` of file `ci`?
    fn line_has_source(&self, ci: usize, line: usize) -> bool {
        self.fns.iter().enumerate().any(|(gid, &(fci, _))| {
            fci == ci && self.sources[gid].iter().any(|(l, _)| *l == line)
        })
    }

    /// Does a call edge from file `ci` at one of `lines` target a
    /// raw-uncertified fn named `callee`?
    fn edge_live(&self, ci: usize, lines: &[usize], callee: &str) -> bool {
        self.fns.iter().enumerate().any(|(gid, &(fci, _))| {
            fci == ci
                && self.edges[gid].iter().any(|(t, l, name)| {
                    lines.contains(l) && name == callee && self.raw_uncertified[*t]
                })
        })
    }
}

/// Direct panic sources in `f`'s body, deduped per (line, kind).
fn direct_sources(ctx: &Ctx, f: &FnItem) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for i in body_range(ctx, f) {
        let code = &ctx.split.code[i];
        let mut descs: Vec<String> = Vec::new();
        for m in PANIC_MACROS {
            let mut from = 0;
            while let Some(at) = find_word(code, m, from) {
                from = at + m.len();
                if code[at + m.len()..].starts_with('!') {
                    descs.push(format!("{m}!"));
                }
            }
        }
        if code.contains(".unwrap()") {
            descs.push(".unwrap()".to_string());
        }
        if code.contains(".expect(") {
            descs.push(".expect(…)".to_string());
        }
        let cv: Vec<char> = code.chars().collect();
        for k in 1..cv.len() {
            if cv[k] == '[' {
                let p = cv[k - 1];
                if is_word(p) || p == ']' || p == ')' {
                    descs.push("indexing `[…]`".to_string());
                    break;
                }
            }
        }
        descs.sort();
        descs.dedup();
        for d in descs {
            out.push((i, d));
        }
    }
    out
}

// ------------------------------------------------------ stale-allow

fn is_ordering_note(comment: &str) -> bool {
    comment.trim_start().trim_start_matches('/').trim_start_matches('!').trim_start()
        .starts_with("ordering:")
}

fn stale_pass(
    ctxs: &[Ctx],
    raw_version: &[Vec<(usize, String)>],
    panic: &PanicModel,
) -> Vec<Finding> {
    let mut out = Vec::new();
    for (ci, ctx) in ctxs.iter().enumerate() {
        let s = &ctx.split;
        let end = ctx.end.min(s.comment.len());
        for j in 0..end {
            let comment = &s.comment[j];
            // Both gates require the opening paren: prose mentions of
            // `lint:allow` / `analyze:allow` in doc comments are not
            // annotations.
            if comment.contains("lint:allow(") {
                if let Some((rule, true)) = parse_allow(comment) {
                    if KNOWN_RULES.contains(&rule.as_str()) {
                        let cov = coverage_of(j, s);
                        let live = ctx
                            .raw_lint
                            .iter()
                            .any(|r| r.rule == rule && cov.contains(&r.line));
                        if !live {
                            out.push(stale(ctx, j, format!(
                                "lint:allow({rule}) no longer suppresses anything here — \
                                 delete it"
                            )));
                        }
                    }
                }
                // Malformed/unknown lint allows are the linter's findings.
            }
            if comment.contains("analyze:allow(") {
                match parse_analyze_allow(comment) {
                    None => out.push(syntax(ctx, j,
                        "malformed allow: need `analyze:allow(<rule>[: <callee>]) — <reason>`"
                            .to_string())),
                    Some((rule, _, _)) if !ANALYZE_RULES.contains(&rule.as_str()) => {
                        out.push(syntax(ctx, j,
                            format!("analyze:allow names unknown rule `{rule}`")));
                    }
                    Some((_, _, false)) => out.push(syntax(ctx, j,
                        "analyze:allow is missing its mandatory `— <reason>`".to_string())),
                    Some((rule, Some(_), true)) if rule == "version" => {
                        out.push(syntax(ctx, j,
                            "analyze:allow(version) takes no `: <callee>`".to_string()));
                    }
                    Some((rule, callee, true)) => {
                        let cov = coverage_of(j, s);
                        let live = match (rule.as_str(), &callee) {
                            ("version", _) => {
                                let rv = &raw_version[ci];
                                rv.iter().any(|(l, _)| cov.contains(l))
                                    || ctx.items.iter().any(|f| {
                                        cov.contains(&f.sig_line)
                                            && rv.iter().any(|(l, _)| {
                                                *l >= f.sig_line && *l <= f.body_end
                                            })
                                    })
                            }
                            ("panic", None) => cov.iter().any(|&k| {
                                panic.fn_has_source_at(ctxs, ci, k)
                                    || panic.line_has_source(ci, k)
                            }),
                            ("panic", Some(c)) => panic.edge_live(ci, &cov, c),
                            _ => unreachable!("rule set checked above"),
                        };
                        if !live {
                            let what = match &callee {
                                Some(c) => format!("analyze:allow({rule}: {c})"),
                                None => format!("analyze:allow({rule})"),
                            };
                            out.push(stale(ctx, j, format!(
                                "{what} no longer suppresses anything here — delete it"
                            )));
                        }
                    }
                }
            }
            if ORDERING_FILES.contains(&ctx.rel.as_str()) && is_ordering_note(comment) {
                let hi = (j + ORDERING_WINDOW + 1).min(end);
                let live = (j..hi).any(|i| s.code[i].contains("Ordering::"));
                if !live {
                    out.push(stale(ctx, j, format!(
                        "`// ordering:` note with no `Ordering::` use within \
                         {ORDERING_WINDOW} lines below — delete or move it"
                    )));
                }
            }
        }
    }
    out
}

fn stale(ctx: &Ctx, line: usize, msg: String) -> Finding {
    Finding { rule: "stale-allow", file: ctx.rel.clone(), line: line + 1, msg }
}

fn syntax(ctx: &Ctx, line: usize, msg: String) -> Finding {
    Finding { rule: "allow-syntax", file: ctx.rel.clone(), line: line + 1, msg }
}

#[cfg(test)]
mod tests {
    use super::*;

    const VERSION_BUMP_BAD: &str = include_str!("../fixtures/version_bump_bad.rs");
    const VERSION_BUMP_OK: &str = include_str!("../fixtures/version_bump_ok.rs");
    const VERSION_KEY_BAD: &str = include_str!("../fixtures/version_key_bad.rs");
    const VERSION_KEY_OK: &str = include_str!("../fixtures/version_key_ok.rs");
    const PANIC_REACH_BAD: &str = include_str!("../fixtures/panic_reach_bad.rs");
    const PANIC_REACH_OK: &str = include_str!("../fixtures/panic_reach_ok.rs");
    const STALE_ALLOW_BAD: &str = include_str!("../fixtures/stale_allow_bad.rs");
    const STALE_ALLOW_OK: &str = include_str!("../fixtures/stale_allow_ok.rs");

    fn run(rel: &str, src: &str) -> Vec<Finding> {
        analyze_tree(&[(rel.to_string(), src.to_string())])
    }

    fn count(findings: &[Finding], rule: &str) -> usize {
        findings.iter().filter(|f| f.rule == rule).count()
    }

    #[test]
    fn missing_bump_on_a_stamped_mutator_fires() {
        let fs = run("graph/dynamic.rs", VERSION_BUMP_BAD);
        assert_eq!(count(&fs, "version"), 1, "{fs:?}");
        assert!(fs.iter().any(|f| f.rule == "version" && f.msg.contains("remove_users")));
    }

    #[test]
    fn transitive_bump_and_version_allow_certify() {
        let fs = run("graph/dynamic.rs", VERSION_BUMP_OK);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn named_producer_and_memo_key_violations_fire() {
        let fs = run("drl/env.rs", VERSION_KEY_BAD);
        assert_eq!(count(&fs, "version"), 2, "{fs:?}");
        assert!(fs.iter().any(|f| f.msg.contains("install_partition")));
        assert!(fs.iter().any(|f| f.msg.contains("omits `layout`")));
    }

    #[test]
    fn sound_producers_and_keys_pass() {
        let fs = run("drl/env.rs", VERSION_KEY_OK);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn panics_propagate_through_helpers_with_a_chain() {
        let fs = run("serving/fixture.rs", PANIC_REACH_BAD);
        assert!(count(&fs, "panic") >= 2, "{fs:?}");
        let chained = fs
            .iter()
            .find(|f| f.rule == "panic" && f.msg.contains("indexing"))
            .expect("indexing finding");
        for name in ["serve", "dispatch", "lookup"] {
            assert!(chained.msg.contains(name), "chain missing {name}: {}", chained.msg);
        }
    }

    #[test]
    fn guards_fn_allows_and_edge_allows_certify() {
        let fs = run("serving/fixture.rs", PANIC_REACH_OK);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn dead_allows_and_notes_are_findings() {
        let fs = run("util/metrics.rs", STALE_ALLOW_BAD);
        assert_eq!(count(&fs, "stale-allow"), 4, "{fs:?}");
    }

    #[test]
    fn live_allows_and_notes_pass() {
        let fs = run("util/metrics.rs", STALE_ALLOW_OK);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn malformed_analyze_allow_is_reported() {
        let src = "// analyze:allow(panic: a b) — bad callee.\npub fn f() {}\n";
        let fs = run("serving/x.rs", src);
        assert_eq!(count(&fs, "allow-syntax"), 1, "{fs:?}");
        let src = "// analyze:allow(version: helper) — version has no edges.\npub fn f() {}\n";
        let fs = run("serving/x.rs", src);
        assert_eq!(count(&fs, "allow-syntax"), 1, "{fs:?}");
    }

    /// The analyzer's reason to exist: the shipped tree must be clean.
    #[test]
    fn the_real_tree_is_analyze_clean() {
        let files = crate::tree_sources();
        let fs = analyze_tree(&files);
        assert!(fs.is_empty(), "analyze findings in rust/src: {fs:#?}");
    }

    /// The acceptance property from the issue: deleting any single
    /// `topology.bump()` from `graph/dynamic.rs` must make the
    /// version-soundness pass fail.
    #[test]
    fn deleting_any_topology_bump_fires() {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../src");
        let src = std::fs::read_to_string(root.join("graph/dynamic.rs"))
            .expect("read graph/dynamic.rs");
        let needle = "self.topology.bump();";
        let count_bumps = src.matches(needle).count();
        assert!(count_bumps >= 5, "expected several bump sites, found {count_bumps}");
        for k in 0..count_bumps {
            let mut pos = 0;
            for _ in 0..k {
                pos = src[pos..].find(needle).unwrap() + pos + needle.len();
            }
            let at = src[pos..].find(needle).unwrap() + pos;
            let mutated = format!("{}{}", &src[..at], &src[at + needle.len()..]);
            let fs = analyze_tree(&[("graph/dynamic.rs".to_string(), mutated)]);
            assert!(
                fs.iter().any(|f| f.rule == "version"),
                "deleting bump #{k} produced no version finding"
            );
        }
    }
}
