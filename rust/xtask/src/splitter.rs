//! Per-line code/comment split of Rust source — the lexical substrate
//! every lint rule and analysis pass stands on.
//!
//! A small state machine tracks string literals, raw strings (with any
//! number of `#` hashes), char literals vs lifetimes, and (nested)
//! block comments, so a banned token inside a string never counts as
//! code and an annotation inside a string never counts as a comment.
//! Literal *contents* are dropped from the code lines (the delimiters
//! stay, so tokens on either side cannot glue together); comment text
//! goes to the comment lines.

/// Per-line split of a source file into code-only and comment-only
/// text.  `code[i]` + `comment[i]` correspond to source line `i`
/// (0-based); string/char contents appear in neither.
pub struct Split {
    pub code: Vec<String>,
    pub comment: Vec<String>,
}

pub fn split_code_comment(src: &str) -> Split {
    enum State {
        Code,
        Str,
        /// Raw string with this many `#` hashes in the delimiter.
        RawStr(usize),
        Char,
        /// Block comment at this nesting depth (block comments nest).
        Block(usize),
    }
    let ch: Vec<char> = src.chars().collect();
    let n = ch.len();
    let mut code = Vec::new();
    let mut comment = Vec::new();
    let mut cl = String::new();
    let mut ml = String::new();
    let mut state = State::Code;
    let mut i = 0;
    while i < n {
        let c = ch[i];
        if c == '\n' {
            code.push(std::mem::take(&mut cl));
            comment.push(std::mem::take(&mut ml));
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == '"' {
                    state = State::Str;
                    cl.push(c);
                } else if c == 'r' && matches!(ch.get(i + 1), Some('"') | Some('#')) {
                    let mut j = i + 1;
                    let mut hashes = 0;
                    while ch.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if ch.get(j) == Some(&'"') {
                        state = State::RawStr(hashes);
                        for &rc in &ch[i..=j] {
                            cl.push(rc);
                        }
                        i = j;
                    } else {
                        cl.push(c);
                    }
                } else if c == '\'' {
                    // char literal ('x', '\n') vs lifetime ('a>)
                    if ch.get(i + 2) == Some(&'\'') || ch.get(i + 1) == Some(&'\\') {
                        state = State::Char;
                    }
                    cl.push(c);
                } else if c == '/' && ch.get(i + 1) == Some(&'/') {
                    while i < n && ch[i] != '\n' {
                        ml.push(ch[i]);
                        i += 1;
                    }
                    continue;
                } else if c == '/' && ch.get(i + 1) == Some(&'*') {
                    state = State::Block(1);
                    i += 2;
                    continue;
                } else {
                    cl.push(c);
                }
            }
            // Literal contents are dropped: only the terminator (and,
            // for escapes, nothing at all) reaches the code line.
            State::Str | State::Char => {
                let terminator = if matches!(state, State::Str) { '"' } else { '\'' };
                if c == '\\' {
                    i += 1;
                } else if c == terminator {
                    cl.push(c);
                    state = State::Code;
                }
            }
            State::RawStr(hashes) => {
                let tail_ok = i + hashes < n && ch[i + 1..=i + hashes].iter().all(|&h| h == '#');
                if c == '"' && tail_ok {
                    cl.push(c);
                    for _ in 0..hashes {
                        cl.push('#');
                    }
                    i += hashes;
                    state = State::Code;
                }
            }
            State::Block(depth) => {
                if c == '*' && ch.get(i + 1) == Some(&'/') {
                    if depth == 1 {
                        state = State::Code;
                    } else {
                        state = State::Block(depth - 1);
                    }
                    i += 1;
                } else if c == '/' && ch.get(i + 1) == Some(&'*') {
                    state = State::Block(depth + 1);
                    ml.push('/');
                    ml.push('*');
                    i += 1;
                } else {
                    ml.push(c);
                }
            }
        }
        i += 1;
    }
    code.push(cl);
    comment.push(ml);
    Split { code, comment }
}

pub fn is_word(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Byte offset of the next whole-word occurrence of (ASCII) `word` at
/// or after byte `from`.
pub fn find_word(s: &str, word: &str, from: usize) -> Option<usize> {
    let mut start = from;
    loop {
        let at = start + s[start..].find(word)?;
        let end = at + word.len();
        if !s[..at].chars().next_back().is_some_and(is_word)
            && !s[end..].chars().next().is_some_and(is_word)
        {
            return Some(at);
        }
        start = end;
    }
}

pub fn leading_ident(s: &str) -> &str {
    let end = s.find(|c: char| !is_word(c)).unwrap_or(s.len());
    &s[..end]
}

pub fn trailing_ident(s: &str) -> &str {
    let start = s
        .char_indices()
        .rev()
        .take_while(|&(_, c)| is_word(c))
        .last()
        .map_or(s.len(), |(i, _)| i);
    &s[start..]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        split_code_comment(src).code
    }

    #[test]
    fn string_contents_never_reach_code_lines() {
        let code = code_of("let s = \"Instant::now() [0] panic!\";\n");
        assert_eq!(code[0], "let s = \"\";");
    }

    #[test]
    fn escaped_quotes_stay_inside_the_literal() {
        let code = code_of("let s = \"a\\\"b\"; x.unwrap();\n");
        assert_eq!(code[0], "let s = \"\"; x.unwrap();");
    }

    #[test]
    fn nested_block_comments_terminate_at_the_matching_close() {
        let src = "a(); /* one /* two */ still comment */ b();\n/* /* x */ */ c();\n";
        let s = split_code_comment(src);
        assert_eq!(s.code[0], "a();  b();");
        assert!(s.comment[0].contains("still comment"));
        assert_eq!(s.code[1], " c();");
    }

    #[test]
    fn raw_strings_with_hashes_are_opaque() {
        // The `"#` inside the r##-string must not close it; contents
        // (including a fake line comment) never reach code or comment.
        let src = "let s = r##\"tail\"# // not a comment\"##; y();\n";
        let s = split_code_comment(src);
        assert_eq!(s.code[0], "let s = r##\"\"##; y();");
        assert!(s.comment[0].is_empty());
    }

    #[test]
    fn multiline_raw_string_swallows_banned_tokens() {
        let src = "let s = r#\"\nInstant::now()\nx[0].unwrap()\n\"#;\n";
        let s = split_code_comment(src);
        assert_eq!(s.code[1], "");
        assert_eq!(s.code[2], "");
        assert_eq!(s.code[3], "\"#;");
    }

    #[test]
    fn lifetimes_are_code_but_char_literals_are_opaque() {
        let code = code_of("fn f<'a>(x: &'a str) -> char { 'a' }\n");
        // The lifetime tick survives (generic syntax stays parseable);
        // the char literal's content is dropped.
        assert_eq!(code[0], "fn f<'a>(x: &'a str) -> char { '' }");
        let code = code_of("let c = '\\n'; let d = '['; idx[c];\n");
        assert_eq!(code[0], "let c = ''; let d = ''; idx[c];");
    }

    #[test]
    fn line_comments_go_to_the_comment_half() {
        let s = split_code_comment("x(); // lint:allow(memo) — reason\n");
        assert_eq!(s.code[0], "x(); ");
        assert!(s.comment[0].contains("lint:allow(memo)"));
    }

    #[test]
    fn annotations_inside_strings_are_not_comments() {
        let s = split_code_comment("let s = \"// lint:allow(panic) — no\";\n");
        assert!(s.comment[0].is_empty());
    }
}
