//! The reasoned escape hatches: `lint:allow(<rule>) — <reason>` (PR 7)
//! and `analyze:allow(<rule>[: <callee>]) — <reason>` (the analysis
//! passes).  A reason is mandatory in both grammars; the accepted dash
//! separators are `—`, `--` and `-`.
//!
//! Coverage is positional and identical for both: an annotation on a
//! code line covers that line; an annotation in a contiguous
//! comment-only block covers the first code line below the block.  The
//! panic pass additionally treats an `analyze:allow(panic)` directly
//! above a `fn` header as covering every panic source in that fn's
//! body, and `analyze:allow(panic: <callee>)` as covering call edges
//! to `<callee>` on the covered line.

use crate::splitter::Split;

/// Parse `lint:allow(<rule>)` out of one comment line.  The `bool` is
/// whether a dash-separated reason follows (`—`, `--` or `-`).
pub fn parse_allow(comment: &str) -> Option<(String, bool)> {
    parse_tagged_allow(comment, "lint:allow(").map(|(rule, _, reason)| (rule, reason))
}

/// Parse `analyze:allow(<rule>[: <callee>])` out of one comment line:
/// `(rule, callee, has_reason)`.
pub fn parse_analyze_allow(comment: &str) -> Option<(String, Option<String>, bool)> {
    parse_tagged_allow(comment, "analyze:allow(")
}

fn parse_tagged_allow(comment: &str, tag: &str) -> Option<(String, Option<String>, bool)> {
    let pos = comment.find(tag)?;
    let rest = &comment[pos + tag.len()..];
    let close = rest.find(')')?;
    let inner = &rest[..close];
    let (rule, callee) = match inner.split_once(':') {
        Some((r, c)) => (r.trim(), Some(c.trim().to_string())),
        None => (inner.trim(), None),
    };
    let rule_ok = !rule.is_empty() && rule.chars().all(|c| c.is_ascii_lowercase() || c == '-');
    let callee_ok = callee.as_deref().is_none_or(|c| {
        !c.is_empty() && c.chars().all(|ch| ch.is_ascii_alphanumeric() || ch == '_')
    });
    if !rule_ok || !callee_ok {
        return None;
    }
    let mut tail = rest[close + 1..].trim_start();
    let mut dashed = false;
    for dash in ["—", "--", "-"] {
        if let Some(t) = tail.strip_prefix(dash) {
            tail = t;
            dashed = true;
            break;
        }
    }
    Some((rule.to_string(), callee, dashed && !tail.trim().is_empty()))
}

/// Is the finding at line `idx` covered by a well-formed
/// `lint:allow(rule)` on the same line or the contiguous comment block
/// directly above?
pub fn allowed(rule: &str, idx: usize, s: &Split) -> bool {
    covered_by(idx, s, |line| {
        parse_allow(line).is_some_and(|(r, reason)| r == rule && reason)
    })
}

/// As [`allowed`], for `analyze:allow(rule)` without a callee.
pub fn analyze_allowed(rule: &str, idx: usize, s: &Split) -> bool {
    covered_by(idx, s, |line| {
        parse_analyze_allow(line)
            .is_some_and(|(r, callee, reason)| r == rule && callee.is_none() && reason)
    })
}

/// Is the call on line `idx` covered by `analyze:allow(rule: callee)`?
pub fn analyze_edge_allowed(rule: &str, callee: &str, idx: usize, s: &Split) -> bool {
    covered_by(idx, s, |line| {
        parse_analyze_allow(line)
            .is_some_and(|(r, c, reason)| r == rule && c.as_deref() == Some(callee) && reason)
    })
}

fn covered_by(idx: usize, s: &Split, hit: impl Fn(&str) -> bool) -> bool {
    if hit(&s.comment[idx]) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let comment_only = s.code[j].trim().is_empty() && !s.comment[j].trim().is_empty();
        if !comment_only {
            return false;
        }
        if hit(&s.comment[j]) {
            return true;
        }
    }
    false
}

/// The set of lines an annotation sitting on line `j` covers: `j`
/// itself when the line carries code (inline annotation), otherwise
/// the first code-bearing line below the comment block.  The stale
/// pass asks the inverse question of [`allowed`] — "which finding
/// would this annotation suppress?" — so the two must stay mirror
/// images.
pub fn coverage_of(j: usize, s: &Split) -> Vec<usize> {
    if !s.code[j].trim().is_empty() {
        return vec![j];
    }
    let mut k = j + 1;
    while k < s.code.len() {
        let comment_only = s.code[k].trim().is_empty() && !s.comment[k].trim().is_empty();
        if !comment_only {
            break;
        }
        k += 1;
    }
    // Skip blank separator-free attachment: `allowed` walks up through
    // comment-only lines exclusively, so a blank line breaks coverage.
    if k < s.code.len() && !s.code[k].trim().is_empty() {
        vec![j, k]
    } else {
        vec![j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::splitter::split_code_comment;

    #[test]
    fn analyze_allow_parses_rule_and_callee() {
        let (r, c, ok) = parse_analyze_allow("// analyze:allow(panic) — indices in range").unwrap();
        assert_eq!((r.as_str(), c, ok), ("panic", None, true));
        let (r, c, ok) =
            parse_analyze_allow("// analyze:allow(panic: helper) -- caller pre-validates").unwrap();
        assert_eq!((r.as_str(), c.as_deref(), ok), ("panic", Some("helper"), true));
    }

    #[test]
    fn analyze_allow_requires_a_reason() {
        let (_, _, ok) = parse_analyze_allow("// analyze:allow(version)").unwrap();
        assert!(!ok);
        let (_, _, ok) = parse_analyze_allow("// analyze:allow(version) — ").unwrap();
        assert!(!ok);
    }

    #[test]
    fn bad_callee_ident_is_malformed() {
        assert!(parse_analyze_allow("// analyze:allow(panic: a b) — x").is_none());
        assert!(parse_analyze_allow("// analyze:allow(panic:) — x").is_none());
    }

    #[test]
    fn coverage_mirrors_allowed() {
        let src = "\
fn f() {
    // analyze:allow(version) — reason one.
    // second comment line.
    mutate();
    other();
}
";
        let s = split_code_comment(src);
        // The block annotation on line 1 covers the attach line 3.
        assert_eq!(coverage_of(1, &s), vec![1, 3]);
        assert!(analyze_allowed("version", 3, &s));
        assert!(!analyze_allowed("version", 4, &s));
        // An inline annotation covers its own line only.
        let src = "x(); // analyze:allow(panic) — inline.\ny();\n";
        let s = split_code_comment(src);
        assert_eq!(coverage_of(0, &s), vec![0]);
        assert!(analyze_allowed("panic", 0, &s));
        assert!(!analyze_allowed("panic", 1, &s));
    }
}
