//! Lightweight item model: fns, impl blocks and methods with
//! brace-matched bodies, extracted from the code half of the
//! [`Split`](crate::splitter::Split) (so braces inside strings, chars
//! and comments are already gone), plus per-fn call extraction for the
//! intra-crate call graph.
//!
//! Deliberate scope limits (documented in `rust/ANALYSIS.md`): fns
//! nested inside other fns, and fns inside inline `mod`/`trait` blocks,
//! are not extracted as items — their bodies are attributed to the
//! enclosing fn (nested fns) or skipped (inline mods, which in this
//! tree are `#[cfg(test)]` modules and excluded anyway).

use crate::splitter::{find_word, is_word, leading_ident, trailing_ident, Split};

#[derive(Debug, Clone)]
pub struct FnItem {
    pub name: String,
    /// `Some(type)` when the fn is a method in `impl Type` /
    /// `impl Trait for Type`.
    pub impl_type: Option<String>,
    pub is_pub: bool,
    pub has_mut_self: bool,
    /// 0-based line of the `fn` keyword.
    pub sig_line: usize,
    /// 0-based line of the body's opening `{`.
    pub body_start: usize,
    /// Char column of that `{` on `body_start` (call extraction starts
    /// after it, so the signature itself never reads as a call).
    pub body_open_col: usize,
    /// 0-based line of the matching `}`.
    pub body_end: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CallKind {
    /// `name(…)`.
    Bare,
    /// `recv.name(…)`; `on_self` when the receiver chain starts at a
    /// bare `self`.
    Method { on_self: bool },
    /// `Qualifier::name(…)` — the qualifier is the last path segment.
    Qualified(String),
}

#[derive(Debug, Clone)]
pub struct Call {
    /// 0-based source line.
    pub line: usize,
    pub name: String,
    pub kind: CallKind,
}

enum Mode {
    Scan,
    /// Accumulating an `impl` header until its opening `{`.
    ImplHeader(String),
    /// Accumulating a fn signature until the body `{` (or a `;` for
    /// body-less declarations).
    FnSig { item: FnItem, paren: i32, bracket: i32, sig: String },
    /// Inside a fn body until brace depth returns to `open_depth`.
    FnBody { item: FnItem, open_depth: usize },
}

/// Extract every top-level fn and impl method from lines `0..end` of
/// the split (callers pass the `#[cfg(test)]` cutoff as `end`).
pub fn extract_items(s: &Split, end: usize) -> Vec<FnItem> {
    let mut items = Vec::new();
    let mut depth: usize = 0;
    // (type name, brace depth of the impl body).
    let mut impls: Vec<(String, usize)> = Vec::new();
    let mut mode = Mode::Scan;

    for i in 0..end.min(s.code.len()) {
        let cv: Vec<char> = s.code[i].chars().collect();
        let mut j = 0;
        while j < cv.len() {
            let c = cv[j];
            match &mut mode {
                Mode::Scan => {
                    if is_word(c) {
                        let k0 = j;
                        while j < cv.len() && is_word(cv[j]) {
                            j += 1;
                        }
                        let word: String = cv[k0..j].iter().collect();
                        if word == "impl" && depth == 0 {
                            mode = Mode::ImplHeader(String::new());
                        } else if word == "fn"
                            && (depth == 0 || impls.last().is_some_and(|f| f.1 == depth))
                        {
                            let prefix: String = cv[..k0].iter().collect();
                            mode = Mode::FnSig {
                                item: FnItem {
                                    name: String::new(),
                                    impl_type: impls.last().map(|f| f.0.clone()),
                                    is_pub: find_word(&prefix, "pub", 0).is_some(),
                                    has_mut_self: false,
                                    sig_line: i,
                                    body_start: i,
                                    body_open_col: 0,
                                    body_end: i,
                                },
                                paren: 0,
                                bracket: 0,
                                sig: String::new(),
                            };
                        }
                        continue;
                    }
                    match c {
                        '{' => depth += 1,
                        '}' => {
                            depth = depth.saturating_sub(1);
                            if impls.last().is_some_and(|f| depth < f.1) {
                                impls.pop();
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
                Mode::ImplHeader(header) => {
                    if c == '{' {
                        let ty = impl_header_type(header);
                        depth += 1;
                        impls.push((ty, depth));
                        mode = Mode::Scan;
                    } else {
                        header.push(c);
                    }
                    j += 1;
                }
                Mode::FnSig { item, paren, bracket, sig } => {
                    match c {
                        '(' => *paren += 1,
                        ')' => *paren -= 1,
                        '[' => *bracket += 1,
                        ']' => *bracket -= 1,
                        '{' if *paren == 0 && *bracket == 0 => {
                            let old = std::mem::replace(&mut mode, Mode::Scan);
                            if let Mode::FnSig { mut item, sig, .. } = old {
                                item.name = leading_ident(sig.trim_start()).to_string();
                                item.has_mut_self = sig_has_mut_self(&sig);
                                item.body_start = i;
                                item.body_open_col = j;
                                mode = Mode::FnBody { item, open_depth: depth };
                            }
                            depth += 1;
                            j += 1;
                            continue;
                        }
                        ';' if *paren == 0 && *bracket == 0 => {
                            // Body-less declaration (trait method, extern).
                            mode = Mode::Scan;
                            j += 1;
                            continue;
                        }
                        _ => {}
                    }
                    sig.push(c);
                    j += 1;
                }
                Mode::FnBody { item, open_depth } => {
                    if c == '{' {
                        depth += 1;
                    } else if c == '}' {
                        depth = depth.saturating_sub(1);
                        if depth == *open_depth {
                            item.body_end = i;
                            let old = std::mem::replace(&mut mode, Mode::Scan);
                            if let Mode::FnBody { item, .. } = old {
                                items.push(item);
                            }
                        }
                    }
                    j += 1;
                }
            }
        }
        // Line break acts as whitespace for multi-line headers/sigs.
        match &mut mode {
            Mode::ImplHeader(h) => h.push(' '),
            Mode::FnSig { sig, .. } => sig.push(' '),
            _ => {}
        }
    }
    // A body still open at the cutoff is kept, truncated — better a
    // conservative partial scan than silently dropping the fn.
    if let Mode::FnBody { mut item, .. } = mode {
        item.body_end = end.min(s.code.len()).saturating_sub(1);
        items.push(item);
    }
    items
}

/// The concrete type an `impl` header names: skip leading generics,
/// prefer the segment after `for` (trait impls), take the final path
/// segment.
fn impl_header_type(header: &str) -> String {
    let h = header.trim();
    let mut rest = h;
    if let Some(stripped) = h.strip_prefix('<') {
        let mut d = 1i32;
        let mut prev = '<';
        let mut cut = stripped.len();
        for (k, c) in stripped.char_indices() {
            match c {
                '<' => d += 1,
                // `->` inside `Fn(..) -> T` bounds is not a close.
                '>' if prev != '-' => {
                    d -= 1;
                    if d == 0 {
                        cut = k + 1;
                        break;
                    }
                }
                _ => {}
            }
            prev = c;
        }
        rest = &stripped[cut.min(stripped.len())..];
    }
    if let Some(fat) = find_word(rest, "for", 0) {
        rest = &rest[fat + 3..];
    }
    let mut t = rest.trim_start().trim_start_matches('&').trim_start();
    loop {
        let id = leading_ident(t);
        if id.is_empty() {
            return String::new();
        }
        match t[id.len()..].strip_prefix("::") {
            Some(next) => t = next,
            None => return id.to_string(),
        }
    }
}

/// Does the signature take `&mut self` (any `mut self` word pair)?
fn sig_has_mut_self(sig: &str) -> bool {
    let mut from = 0;
    while let Some(at) = find_word(sig, "mut", from) {
        from = at + 3;
        let rest = sig[at + 3..].trim_start();
        if rest.starts_with("self") && !rest[4..].starts_with(is_word) {
            return true;
        }
    }
    false
}

/// Words that look like calls but aren't (`match (a, b)` etc.).
const KEYWORDS: [&str; 24] = [
    "if", "else", "match", "while", "for", "loop", "return", "break", "continue", "in", "as",
    "let", "fn", "pub", "impl", "use", "mod", "where", "move", "ref", "mut", "unsafe", "dyn",
    "self",
];

/// Every call site inside `f`'s body.  `ident!(…)` macro invocations
/// are skipped (the `(` is not adjacent to the ident), and so are
/// keywords; enum/tuple-struct constructors survive as bare calls but
/// resolve to nothing downstream.
pub fn extract_calls(s: &Split, f: &FnItem) -> Vec<Call> {
    let mut out = Vec::new();
    let last = f.body_end.min(s.code.len().saturating_sub(1));
    for i in f.body_start..=last {
        let cv: Vec<char> = s.code[i].chars().collect();
        let mut j = if i == f.body_start { (f.body_open_col + 1).min(cv.len()) } else { 0 };
        while j < cv.len() {
            if !is_word(cv[j]) {
                j += 1;
                continue;
            }
            let k0 = j;
            while j < cv.len() && is_word(cv[j]) {
                j += 1;
            }
            if cv.get(j) != Some(&'(') {
                continue;
            }
            let name: String = cv[k0..j].iter().collect();
            if KEYWORDS.contains(&name.as_str()) || name.starts_with(|c: char| c.is_ascii_digit())
            {
                continue;
            }
            let before: String = cv[..k0].iter().collect();
            let kind = if let Some(b) = before.strip_suffix('.') {
                let recv = trailing_ident(b.trim_end());
                CallKind::Method { on_self: recv == "self" && b.trim_end().ends_with("self") }
            } else if let Some(b) = before.strip_suffix("::") {
                CallKind::Qualified(trailing_ident(b.trim_end()).to_string())
            } else {
                CallKind::Bare
            };
            out.push(Call { line: i, name, kind });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::test_cutoff;
    use crate::splitter::split_code_comment;

    const SRC: &str = r#"
pub struct DynamicGraph {
    g: usize,
}

impl DynamicGraph {
    pub fn add_assoc(&mut self, v: usize) {
        self.g += v;
        self.bump_topology();
    }

    fn bump_topology(&self) {
        let _s = "fn fake(){}"; // fn in a string is not an item
    }
}

impl std::fmt::Display for DynamicGraph {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        helper(self.g);
        write!(f, "{}", self.g)
    }
}

impl<T: Fn(usize) -> bool> Wrap<T> {
    fn run(&self) -> bool {
        (self.0)(1)
    }
}

pub fn helper(x: usize) -> usize {
    Other::make(x).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    fn hidden() {}
}
"#;

    fn items() -> Vec<FnItem> {
        let s = split_code_comment(SRC);
        let end = test_cutoff(&s);
        extract_items(&s, end)
    }

    #[test]
    fn fns_and_methods_are_extracted_with_impl_types() {
        let its = items();
        let names: Vec<(&str, Option<&str>)> =
            its.iter().map(|f| (f.name.as_str(), f.impl_type.as_deref())).collect();
        assert_eq!(
            names,
            vec![
                ("add_assoc", Some("DynamicGraph")),
                ("bump_topology", Some("DynamicGraph")),
                ("fmt", Some("DynamicGraph")),
                ("run", Some("Wrap")),
                ("helper", None),
            ]
        );
    }

    #[test]
    fn pub_and_mut_self_flags() {
        let its = items();
        let add = its.iter().find(|f| f.name == "add_assoc").unwrap();
        assert!(add.is_pub && add.has_mut_self);
        let bump = its.iter().find(|f| f.name == "bump_topology").unwrap();
        assert!(!bump.is_pub && !bump.has_mut_self);
        let helper = its.iter().find(|f| f.name == "helper").unwrap();
        assert!(helper.is_pub && !helper.has_mut_self);
    }

    #[test]
    fn bodies_are_brace_matched() {
        let s = split_code_comment(SRC);
        let its = items();
        let add = its.iter().find(|f| f.name == "add_assoc").unwrap();
        let body: String = s.code[add.body_start..=add.body_end].join("\n");
        assert!(body.contains("self.g += v"));
        assert!(!body.contains("bump_topology(&self)"), "body must stop at its own brace");
    }

    #[test]
    fn test_modules_are_cut_off() {
        assert!(items().iter().all(|f| f.name != "hidden"));
    }

    #[test]
    fn calls_are_classified_and_macros_skipped() {
        let s = split_code_comment(SRC);
        let its = items();
        let add = its.iter().find(|f| f.name == "add_assoc").unwrap();
        let calls = extract_calls(&s, add);
        assert_eq!(calls.len(), 1);
        assert_eq!(calls[0].name, "bump_topology");
        assert_eq!(calls[0].kind, CallKind::Method { on_self: true });

        let fmt = its.iter().find(|f| f.name == "fmt").unwrap();
        let calls = extract_calls(&s, fmt);
        // `helper(…)` is a bare call; `write!` is a macro and skipped.
        assert_eq!(calls.len(), 1);
        assert_eq!(calls[0].name, "helper");
        assert_eq!(calls[0].kind, CallKind::Bare);

        let helper = its.iter().find(|f| f.name == "helper").unwrap();
        let calls = extract_calls(&s, helper);
        let kinds: Vec<(&str, &CallKind)> =
            calls.iter().map(|c| (c.name.as_str(), &c.kind)).collect();
        assert_eq!(kinds[0], ("make", &CallKind::Qualified("Other".to_string())));
        assert_eq!(kinds[1], ("unwrap_or", &CallKind::Method { on_self: false }));
    }

    #[test]
    fn chained_receiver_is_not_self() {
        let src = "fn f(&self) {\n    self.queues[s].push(1);\n}\n";
        let s = split_code_comment(src);
        let its = extract_items(&s, s.code.len());
        let calls = extract_calls(&s, &its[0]);
        assert_eq!(calls[0].name, "push");
        assert_eq!(calls[0].kind, CallKind::Method { on_self: false });
    }
}
